"""Reproductions of the paper's figures (one function per figure/table).

Each function returns a dict of results and emits CSV rows via
benchmarks.common.  Numbers to compare against the paper:

* Fig 4: model-vs-execution correlation (paper: R²=0.9412, slope 1.1464).
* Fig 5: e2e-multi vs myopic-multi vs uniform (82–87% / 65–82%).
* Fig 6: multi-phase vs best single-phase (37–64%).
* Fig 7: barrier relaxation, normalized to all-global (biggest win at α=1,
  late boundaries more valuable).
* Fig 8: 1/2/4/8 data centers — optimization wins grow with distribution.
* Fig 9: three applications, optimized plan vs Hadoop-like vs uniform
  (paper: 31–41% over vanilla Hadoop).
* Fig 10/11: dynamic mechanisms atop optimized/baseline plans.
* Fig 12: replication across slow links.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict

import numpy as np

from repro.api import (
    Arrival, GeoJob, GeoPipeline, GeoSchedule, OnlineConfig, split_sources,
)
from repro.core.makespan import BARRIERS_GGL
from repro.core.optimize import (
    optimize_plan,
    optimize_plan_batch,
    replan_batch,
    reset_solver_cache_stats,
    solver_cache_stats,
)
from repro.core.plan import local_push_plan, uniform_plan
from repro.core.platform import (
    CapacityTrace, FailureEvent, Substrate, planetlab_platform,
)
from repro.core.simulate import SimConfig, simulate, simulate_schedule
from repro.mapreduce.apps import (
    generate_documents, generate_logs, inverted_index, sessionization,
    word_count,
)

from .common import emit, timeit

_OPT = dict(n_restarts=16, steps=400)


def fig4_validation() -> Dict:
    """Correlate model-predicted makespan with discrete-event-executed
    makespan across plans × α × barrier configs (paper Fig 4)."""
    preds, meas = [], []
    configs = [("G", "P", "L"), ("P", "P", "L"), ("P", "G", "L"), ("G", "G", "L")]
    for alpha in [0.1, 1.0, 2.0]:
        p = planetlab_platform(8, alpha=alpha, seed=0)
        job = GeoJob(p)
        plans = {
            "uniform": uniform_plan(p),
            "opt": optimize_plan(p, "e2e_multi", **_OPT).plan,
        }
        for barriers, (pname, plan) in itertools.product(configs, plans.items()):
            job.with_plan(plan, barriers)
            preds.append(job.planned.makespan)
            meas.append(job.simulate(chunk_mb=32.0).makespan)
    preds, meas = np.asarray(preds), np.asarray(meas)
    slope, intercept = np.polyfit(preds, meas, 1)
    r2 = float(np.corrcoef(preds, meas)[0, 1] ** 2)
    p = planetlab_platform(8, alpha=1.0, seed=0)
    bench = GeoJob(p).with_plan(uniform_plan(p))
    us, _ = timeit(lambda: bench.simulate(chunk_mb=32.0))
    emit("fig4_validation", us, f"R2={r2:.4f};slope={slope:.3f}")
    return {"r2": r2, "slope": float(slope), "n": len(preds)}


def fig5_e2e_vs_myopic() -> Dict:
    out = {}
    for alpha in [0.1, 1.0, 10.0]:
        p = planetlab_platform(8, alpha=alpha, seed=0)
        us, res = timeit(
            lambda: {m: optimize_plan(p, m, **_OPT) for m in
                     ["uniform", "myopic_multi", "e2e_multi"]},
            repeats=1,
        )
        red_uni = 1 - res["e2e_multi"].makespan / res["uniform"].makespan
        red_myo = 1 - res["e2e_multi"].makespan / res["myopic_multi"].makespan
        emit(f"fig5_alpha{alpha}", us,
             f"vs_uniform={red_uni:.2%};vs_myopic={red_myo:.2%}")
        out[alpha] = {
            m: {"makespan": r.makespan, **r.breakdown} for m, r in res.items()
        }
    return out


def fig6_single_vs_multi() -> Dict:
    out = {}
    for alpha in [0.1, 1.0, 10.0]:
        p = planetlab_platform(8, alpha=alpha, seed=0)
        res = {m: optimize_plan(p, m, **_OPT) for m in
               ["uniform", "e2e_push", "e2e_shuffle", "e2e_multi"]}
        best_single = min(res["e2e_push"].makespan, res["e2e_shuffle"].makespan)
        red = 1 - res["e2e_multi"].makespan / best_single
        emit(f"fig6_alpha{alpha}", 0.0, f"multi_vs_best_single={red:.2%}")
        out[alpha] = {m: r.makespan for m, r in res.items()}
    return out


def fig7_barriers() -> Dict:
    """Relax one global barrier at a time to pipelining (optimized plans),
    normalized to the all-global optimum."""
    out = {}
    combos = {
        "all_global": ("G", "G", "G"),
        "pipe_push_map": ("P", "G", "G"),
        "pipe_map_shuffle": ("G", "P", "G"),
        "pipe_shuffle_reduce": ("G", "G", "P"),
        "all_pipelined": ("P", "P", "P"),
    }
    for alpha in [0.1, 1.0, 10.0]:
        p = planetlab_platform(8, alpha=alpha, seed=0)
        base = optimize_plan(p, "e2e_multi", barriers=("G", "G", "G"), **_OPT)
        row = {}
        for name, b in combos.items():
            r = optimize_plan(p, "e2e_multi", barriers=b, **_OPT)
            row[name] = r.makespan / base.makespan
        out[alpha] = row
        emit(f"fig7_alpha{alpha}", 0.0,
             ";".join(f"{k}={v:.3f}" for k, v in row.items()))
    return out


def fig8_environments() -> Dict:
    out = {}
    for ndc in [1, 2, 4, 8]:
        for alpha in [0.1, 1.0, 10.0]:
            p = planetlab_platform(ndc, alpha=alpha, seed=0)
            res = {m: optimize_plan(p, m, **_OPT).makespan
                   for m in ["uniform", "myopic_multi", "e2e_multi"]}
            out[f"{ndc}dc_alpha{alpha}"] = res
            emit(
                f"fig8_{ndc}dc_alpha{alpha}", 0.0,
                f"myopic_ratio={res['myopic_multi']/res['uniform']:.3f};"
                f"e2e_ratio={res['e2e_multi']/res['uniform']:.3f}",
            )
    return out


def fig9_applications() -> Dict:
    """Three real applications through the :class:`repro.api.GeoJob` facade;
    makespan = actual byte movement priced through the emulated PlanetLab
    platform by the same cost model the planner optimized."""
    out = {}
    apps = {
        "word_count": (word_count(), generate_documents(600, 60, seed=5)),
        "sessionization": (sessionization(gap=1000), generate_logs(40_000, 400, seed=5)),
        "inverted_index": (inverted_index(), generate_documents(600, 60, seed=6)),
    }
    for name, (app, (keys, vals)) in apps.items():
        probe = planetlab_platform(8, alpha=1.0, seed=0)
        srcs = split_sources(keys, vals, probe.nS)
        # probe-measure the app's alpha + input volumes to feed the model
        job = GeoJob(probe, app).calibrate(srcs)
        p = job.platform
        setups = {
            "uniform": lambda: job.with_plan(uniform_plan(p), BARRIERS_GGL),
            "hadoop_local": lambda: job.with_plan(local_push_plan(p), BARRIERS_GGL),
            "optimized": lambda: job.plan("e2e_multi", barriers=BARRIERS_GGL,
                                          **_OPT),
        }
        row, err = {}, {}
        for pname, setup in setups.items():
            setup()
            us, report = timeit(lambda: job.execute(srcs), repeats=1)
            row[pname] = report.measured
            err[pname] = report.model_error()
        out[name] = {"alpha": p.alpha, "model_error": err, **row}
        red = 1 - row["optimized"]["makespan"] / row["hadoop_local"]["makespan"]
        emit(f"fig9_{name}", us,
             f"alpha={p.alpha:.2f};vs_hadoop={red:.2%};"
             f"model_err={err['optimized']:+.1%}")
    return out


def fig10_dynamics() -> Dict:
    """Dynamic mechanisms (speculation / + stealing) atop the optimized and
    the Hadoop-baseline plans, with runtime stragglers the planner cannot
    see."""
    p = planetlab_platform(8, alpha=1.0, seed=0)
    jobs = {
        "optimized": GeoJob(p).plan("e2e_multi", barriers=BARRIERS_GGL, **_OPT),
        "hadoop_baseline": GeoJob(p).with_plan(local_push_plan(p), BARRIERS_GGL),
    }
    strag = {("m", 2): 4.0}
    out = {}
    for pname, job in jobs.items():
        row = {}
        for dyn, cfg in {
            "static": SimConfig(barriers=BARRIERS_GGL, stragglers=strag),
            "spec": SimConfig(barriers=BARRIERS_GGL, stragglers=strag,
                              speculation=True),
            "spec+steal": SimConfig(barriers=BARRIERS_GGL, stragglers=strag,
                                    speculation=True, stealing=True),
        }.items():
            row[dyn] = job.simulate(cfg).makespan
        out[pname] = row
        emit(f"fig10_{pname}", 0.0,
             ";".join(f"{k}={v:.0f}s" for k, v in row.items()))
    return out


def fig12_replication() -> Dict:
    p = planetlab_platform(8, alpha=1.0, seed=0)
    plan = local_push_plan(p)
    out = {}
    for r in [1, 2, 3]:
        res = simulate(
            p, plan,
            SimConfig(barriers=BARRIERS_GGL, replication=r,
                      cross_cluster_replication=r > 1),
        ).as_dict()
        out[r] = res
        emit(f"fig12_replication{r}", 0.0,
             f"makespan={res['makespan']:.0f}s;push={res['push_end']:.0f}s")
    return out


def schedule_contention() -> Dict:
    """Multi-job scheduling on a shared substrate (PR 2): two concurrent
    jobs where per-job-myopic ("independent") planning collides on the
    mapper only one job can actually reach fast, while "sequential" and
    "joint" spread the second job out — the paper's end-to-end-vs-myopic
    gap, across jobs."""
    sub = Substrate(
        B_sm=np.array([[10_000.0, 1.0], [10_000.0, 10_000.0]]),
        B_mr=np.full((2, 2), 10_000.0),
        C_m=np.array([50.0, 50.0]),
        C_r=np.array([10_000.0, 10_000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="contended_pair",
    )
    jobs = [
        GeoJob(sub.view(np.array([40_000.0, 0.0]), 1.0, name="pinned")),
        GeoJob(sub.view(np.array([0.0, 40_000.0]), 1.0, name="flexible")),
    ]
    out = {}
    for policy in ("independent", "sequential", "joint"):
        report = (
            GeoSchedule(jobs)
            .plan(policy=policy, mode="e2e_multi", barriers=BARRIERS_GGL,
                  **_OPT)
            .simulate()
        )
        out[policy] = {
            "modeled": report.makespan_modeled,
            "simulated": report.makespan_sim,
            "contended_resources": len(report.contended()),
            **report.sim.as_dict(),
        }
        emit(f"schedule_{policy}", 0.0,
             f"modeled={report.makespan_modeled:.0f}s;"
             f"sim={report.makespan_sim:.0f}s")
    gap = 1 - out["joint"]["simulated"] / out["independent"]["simulated"]
    emit("schedule_joint_vs_independent", 0.0, f"reduction={gap:.0%}")
    out["joint_vs_independent_reduction"] = gap
    return out


def pipeline_chain_substrate() -> Substrate:
    """The ``pipeline_chain`` fabric: asymmetric *outgoing* access.  Node 0
    hosts the fast reducer (r0: 300 MB/s vs r1: 60 MB/s) but its outgoing
    push links crawl at 4 MB/s; node 1's reducer is slow but its push
    links run at wire speed.  Placing a non-final stage's reduce output on
    r0 is locally optimal and strands the next stage's input behind the
    4 MB/s links — the cross-stage trap stagewise planning walks into."""
    return Substrate(
        B_sm=np.array([[4.0, 4.0], [200.0, 200.0]]),
        B_mr=np.full((2, 2), 200.0),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([300.0, 60.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="pipeline_chain",
    )


def pipeline_chain() -> Dict:
    """Multi-stage pipelines (PR 5): a 3-stage chain where ``end_to_end``
    cross-stage planning beats ``stagewise``.  Stagewise places stage-k
    reducers where stage k finishes fastest (the fast r0), stranding stage
    k+1's 6 GB behind node 0's 4 MB/s outgoing links; end-to-end feels the
    downstream push cost through the inter-stage D coupling and keeps
    non-final reduce output on the well-connected node, conceding reduce
    speed to win the pipeline.  Both modeled (critical-path composition)
    and simulated (real per-source release gating) sides are emitted."""
    sub = pipeline_chain_substrate()

    def stages():
        return [
            GeoJob(sub.view(np.array([0.0, 6000.0]), 1.0, name="ingest")),
            GeoJob(sub.view(np.zeros(2), 1.0, name="transform")),
            GeoJob(sub.view(np.zeros(2), 0.5, name="aggregate")),
        ]

    out = {}
    for mode in ("stagewise", "end_to_end"):
        pipe = GeoPipeline(stages(), name=f"chain_{mode}")
        us, report = timeit(
            lambda: pipe.plan(mode, stage_mode="e2e_multi",
                              barriers=BARRIERS_GGL, **_OPT).simulate(),
            repeats=1,
        )
        out[mode] = {
            "modeled": report.makespan_modeled,
            "simulated": report.makespan_sim,
            "stage_makespans": list(report.result.stage_makespans),
            "stage_finishes": list(report.result.finishes),
        }
        emit(f"pipeline_chain_{mode}", us,
             f"modeled={report.makespan_modeled:.0f}s;"
             f"sim={report.makespan_sim:.0f}s")
    gap = 1 - out["end_to_end"]["simulated"] / out["stagewise"]["simulated"]
    emit("pipeline_chain_e2e_vs_stagewise", 0.0, f"reduction={gap:.0%}")
    out["e2e_vs_stagewise_reduction"] = gap
    return out


def schedule_online() -> Dict:
    """Online control plane (PR 3): re-planning over streaming arrivals and
    drifting capacities.  A steady job's nominal optimum concentrates its
    shuffle on the fast backbone links into reducer r0; both links degrade
    250x at t=105s — mid-shuffle — and a second job arrives at t=50s, mid
    map phase.  The *frozen joint* plan (clairvoyant about the arrival,
    blind to the drift) crawls through the degraded links; ``reactive``
    re-plans each job's residual at the arrival/drift events and swaps the
    not-yet-committed chunks onto the healthy path; ``horizon`` does the
    same on a fixed 40s cadence."""
    sub = Substrate(
        B_sm=np.full((2, 2), 200.0),
        B_mr=np.array([[500.0, 100.0], [500.0, 100.0]]),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([2000.0, 2000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="online_pair",
    ).with_traces({
        "shuffle[m0->r0]": CapacityTrace.step(500.0, 2.0, 105.0),
        "shuffle[m1->r0]": CapacityTrace.step(500.0, 2.0, 105.0),
    })
    steady = GeoJob(sub.view(np.array([8000.0, 8000.0]), 1.0, name="steady"))
    late_view = sub.view(np.array([4000.0, 4000.0]), 1.0, name="late")
    cfg = SimConfig(barriers=BARRIERS_GGL)
    t_arrival = 50.0

    # the frozen baseline: both jobs planned jointly offline, on nominal
    # capacities, with full knowledge of the release times
    frozen = GeoSchedule([steady, GeoJob(late_view)]).plan(
        "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **_OPT
    )
    frozen_sim = simulate_schedule(
        [(steady.platform, frozen.planned.plans[0], cfg),
         (late_view, frozen.planned.plans[1],
          SimConfig(barriers=BARRIERS_GGL, start_time=t_arrival))],
        substrate=sub,
    )
    out = {"frozen_joint": {"simulated": frozen_sim.makespan,
                            **frozen_sim.as_dict()}}
    emit("schedule_online_frozen", 0.0, f"sim={frozen_sim.makespan:.0f}s")

    sched = GeoSchedule([steady]).plan(
        "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **_OPT
    )
    for policy, extra in (("static", {}), ("reactive", {}),
                          ("horizon", {"replan_dt": 40.0})):
        arrival = Arrival(
            GeoJob(late_view).with_plan(frozen.planned.plans[1],
                                        BARRIERS_GGL),
            t_arrival,
        )
        us, report = timeit(
            lambda: sched.run_online(
                policy=policy, arrivals=[arrival], cfg=cfg,
                n_restarts=_OPT["n_restarts"], steps=_OPT["steps"], **extra,
            ),
            repeats=1,
        )
        out[policy] = {
            "simulated": report.makespan_online,
            "static_baseline": report.makespan_static,
            "improvement_vs_static": report.improvement,
            "decisions": len(report.decisions),
            "swaps": len(report.swaps),
            **report.sim.as_dict(),
        }
        emit(f"schedule_online_{policy}", us,
             f"sim={report.makespan_online:.0f}s;"
             f"swaps={len(report.swaps)}")
    gap = 1 - out["reactive"]["simulated"] / out["frozen_joint"]["simulated"]
    emit("schedule_online_reactive_vs_frozen", 0.0, f"reduction={gap:.0%}")
    out["reactive_vs_frozen_joint_reduction"] = gap
    return out


def shared_online_substrate(t_drift: float = 110.0) -> Substrate:
    """The ``schedule_online_shared`` fabric: asymmetric reducer access plus
    a mid-shuffle compute drift.  The steady job's sources (s0/s1) reach
    mappers m0/m1, which see both reducers; the late job's sources (s2/s3)
    reach m2/m3, whose only usable shuffle path is into r1 — the late job
    is *stuck* on r1, a fact only shared-capacity pricing can see.  The
    fast reducer r0 degrades 300→40 MB/s at ``t_drift`` (mid-shuffle of
    the steady job); two later trace steps on dead push links are pure
    nuisance events — nothing real changes, but event-triggered policies
    fire, and hysteresis-free re-planning swaps on the solver's epsilon
    improvements (thrash) while the replan-cost charge rejects them."""
    return Substrate(
        B_sm=np.array([
            [200.0, 200.0, 1.0, 1.0],
            [200.0, 200.0, 1.0, 1.0],
            [1.0, 1.0, 200.0, 200.0],
            [1.0, 1.0, 200.0, 200.0],
        ]),
        B_mr=np.array([
            [200.0, 200.0],
            [200.0, 200.0],
            [1.0, 200.0],
            [1.0, 200.0],
        ]),
        C_m=np.array([100.0, 100.0, 100.0, 100.0]),
        C_r=np.array([300.0, 60.0]),
        cluster_s=np.array([0, 0, 1, 1]),
        cluster_m=np.array([0, 0, 1, 1]),
        cluster_r=np.array([0, 1]),
        name="online_shared",
    ).with_traces({
        "reduce[r0]": CapacityTrace.step(300.0, 40.0, t_drift),
        "push[s0->m2]": CapacityTrace.step(1.0, 0.9, 150.0),
        "push[s1->m2]": CapacityTrace.step(1.0, 0.9, 180.0),
    })


def schedule_online_shared() -> Dict:
    """Shared-capacity residual co-replanning with replan-cost hysteresis
    (PR 4): overlapping jobs + mid-shuffle drift, where solo-residual
    re-planning thrashes and co-replanning wins.

    After the drift, the steady job's solo replan balances its residual
    reduce load against the *raw* capacities (40 vs 60 MB/s) — blind to
    the late job's 12 GB already stuck on r1 — and spills onto the reducer
    the other job cannot leave.  ``reactive_shared`` co-replans both
    residuals through shared pricing, keeps the flexible job on the
    degraded-but-private r0, and its hysteresis rejects the epsilon swaps
    the nuisance drift events bait out of hysteresis-free co-replanning."""
    sub = shared_online_substrate()
    steady = GeoJob(sub.view(np.array([8000.0, 8000.0, 0.0, 0.0]), 1.0,
                             name="steady"))
    late_view = sub.view(np.array([0.0, 0.0, 6000.0, 6000.0]), 1.0,
                         name="late")
    cfg = SimConfig(barriers=BARRIERS_GGL)
    t_arrival = 50.0

    frozen = GeoSchedule([steady, GeoJob(late_view)]).plan(
        "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **_OPT
    )
    frozen_sim = simulate_schedule(
        [(steady.platform, frozen.planned.plans[0], cfg),
         (late_view, frozen.planned.plans[1],
          SimConfig(barriers=BARRIERS_GGL, start_time=t_arrival))],
        substrate=sub,
    )
    out = {"frozen_joint": {"simulated": frozen_sim.makespan,
                            **frozen_sim.as_dict()}}
    emit("schedule_online_shared_frozen", 0.0,
         f"sim={frozen_sim.makespan:.0f}s")

    sched = GeoSchedule([steady]).plan(
        "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **_OPT
    )
    variants = (
        ("reactive_solo", "reactive", None),
        ("reactive_shared", "reactive_shared", None),
        ("shared_no_hysteresis", "reactive_shared",
         OnlineConfig(shared=True, hysteresis=0.0)),
    )
    for name, policy, online in variants:
        arrival = Arrival(
            GeoJob(late_view).with_plan(frozen.planned.plans[1],
                                        BARRIERS_GGL),
            t_arrival,
        )
        us, report = timeit(
            lambda: sched.run_online(
                policy=policy, arrivals=[arrival], cfg=cfg, online=online,
                n_restarts=_OPT["n_restarts"], steps=_OPT["steps"],
            ),
            repeats=1,
        )
        out[name] = {
            "simulated": report.makespan_online,
            "static_baseline": report.makespan_static,
            "improvement_vs_static": report.improvement,
            "decisions": len(report.decisions),
            "swaps": len(report.swaps),
            "rejected": len(report.rejected),
            "charged_s": report.charged_s,
            **report.sim.as_dict(),
        }
        emit(f"schedule_online_shared_{name}", us,
             f"sim={report.makespan_online:.0f}s;"
             f"swaps={len(report.swaps)};rejected={len(report.rejected)}")
    gap_frozen = 1 - (out["reactive_shared"]["simulated"]
                      / out["frozen_joint"]["simulated"])
    gap_solo = 1 - (out["reactive_shared"]["simulated"]
                    / out["reactive_solo"]["simulated"])
    emit("schedule_online_shared_vs_frozen", 0.0,
         f"reduction={gap_frozen:.0%}")
    emit("schedule_online_shared_vs_solo", 0.0, f"reduction={gap_solo:.0%}")
    out["shared_vs_frozen_joint_reduction"] = gap_frozen
    out["shared_vs_solo_reduction"] = gap_solo
    return out


def failover_substrate(failures=()) -> Substrate:
    """The ``schedule_failover`` fabric: two clusters (A: s0/s1, m0/m1,
    r0/r1 — B: s2, m2, r2) with a fast wide-area shuffle path into B's big
    reducer r2 (500 MB/s compute) that the joint plan leans on.  The fault
    sequence kills r1 mid-shuffle and then partitions cluster B with a
    late repair — severing exactly the path the plan concentrated on."""
    sub = Substrate(
        B_sm=np.array([
            [200.0, 200.0, 1.0],
            [200.0, 200.0, 1.0],
            [1.0, 1.0, 200.0],
        ]),
        B_mr=np.array([
            [200.0, 200.0, 150.0],
            [200.0, 200.0, 150.0],
            [2.0, 2.0, 200.0],
        ]),
        C_m=np.array([100.0, 100.0, 100.0]),
        C_r=np.array([100.0, 40.0, 500.0]),
        cluster_s=np.array([0, 0, 1]),
        cluster_m=np.array([0, 0, 1]),
        cluster_r=np.array([0, 0, 1]),
        name="failover",
    )
    return sub.with_failures(list(failures)) if failures else sub


def schedule_failover() -> Dict:
    """Failure injection & recovery (ROADMAP §2): a reducer death
    mid-shuffle plus a cluster partition with a late repair, against a
    frozen clairvoyant joint plan that concentrated shuffle on the paths
    the faults sever.

    The frozen plan parks everything bound for the partitioned cluster
    until repair (t=400s), so its makespan is pinned to the repair time.
    ``reactive_shared`` observes each fault, un-delivers the lost output,
    co-replans the residual around the dead reducer and severed links, and
    pulls the parked queue back onto surviving paths; ``reactive_failover``
    additionally toggles speculative re-execution at each fault decision.
    Both run with ``replication=2`` so lost map output re-executes from
    surviving replicas instead of re-pushing over the WAN."""
    FAILURES = [
        FailureEvent.reducer_kill(1, 115.0),
        FailureEvent.cluster_partition(1, 118.0, 400.0),
    ]
    sub0 = failover_substrate()
    d_steady = np.array([5000.0, 5000.0, 0.0])
    d_late = np.array([3000.0, 3000.0, 0.0])
    steady = GeoJob(sub0.view(d_steady, 1.0, name="steady"))
    late = GeoJob(sub0.view(d_late, 1.0, name="late"))
    frozen = GeoSchedule([steady, late]).plan(
        "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **_OPT
    )
    cfg = SimConfig(barriers=BARRIERS_GGL, replication=2, audit=True)

    subf = failover_substrate(FAILURES)
    sv = subf.view(d_steady, 1.0, name="steady")
    lv = subf.view(d_late, 1.0, name="late")
    frozen_sim = simulate_schedule(
        [(sv, frozen.planned.plans[0], cfg),
         (lv, frozen.planned.plans[1], cfg)],
        substrate=subf,
    )
    out = {"frozen_joint": {"simulated": frozen_sim.makespan,
                            **frozen_sim.as_dict()}}
    emit("schedule_failover_frozen", 0.0, f"sim={frozen_sim.makespan:.0f}s")

    for policy in ("reactive_shared", "reactive_failover"):
        sched = GeoSchedule(
            [GeoJob(sv).with_plan(frozen.planned.plans[0], BARRIERS_GGL),
             GeoJob(lv).with_plan(frozen.planned.plans[1], BARRIERS_GGL)]
        ).with_plans()
        us, report = timeit(
            lambda: sched.run_online(policy=policy, cfg=cfg, **_OPT),
            repeats=1,
        )
        out[policy] = {
            "simulated": report.makespan_online,
            "static_baseline": report.makespan_static,
            "improvement_vs_static": report.improvement,
            "decisions": len(report.decisions),
            "swaps": len(report.swaps),
            "rejected": len(report.rejected),
            "charged_s": report.charged_s,
            **report.sim.as_dict(),
        }
        emit(f"schedule_failover_{policy}", us,
             f"sim={report.makespan_online:.0f}s;"
             f"swaps={len(report.swaps)};rejected={len(report.rejected)}")
    margin = 1 - (out["reactive_shared"]["simulated"]
                  / out["frozen_joint"]["simulated"])
    emit("schedule_failover_margin", 0.0, f"margin={margin:.0%}")
    out["failover_margin"] = margin
    out["failover_margin_speculative"] = 1 - (
        out["reactive_failover"]["simulated"]
        / out["frozen_joint"]["simulated"]
    )
    return out


def bench_planner() -> Dict:
    """Planner-as-a-service throughput (ROADMAP §1): plans/sec for batched
    same-shape solves, p50/p99 single-solve latency cold vs warm, the
    incremental-vs-full replan speedup, and the compile counts behind them
    — all gated by compare.py like any makespan."""
    n_restarts = _OPT["n_restarts"]
    # a step budget no other scenario uses: steps is a static jit arg, so
    # this guarantees the first solve below is a genuinely cold compile
    # even when the full benchmark suite ran first in this process
    steps = _OPT["steps"] + 3
    p = planetlab_platform(8, alpha=1.0, seed=3)
    opts = dict(n_restarts=n_restarts, steps=steps)

    reset_solver_cache_stats()
    t0 = time.perf_counter()
    optimize_plan(p, "e2e_multi", seed=0, **opts)
    cold_s = time.perf_counter() - t0

    warm_lat = []
    for s in range(1, 9):
        t0 = time.perf_counter()
        optimize_plan(p, "e2e_multi", seed=s, **opts)
        warm_lat.append(time.perf_counter() - t0)
    p50_ms = float(np.percentile(warm_lat, 50) * 1e3)
    p99_ms = float(np.percentile(warm_lat, 99) * 1e3)

    # batched throughput: 8 concurrent same-shape requests, one dispatch
    views = [planetlab_platform(8, alpha=1.0, seed=s) for s in range(8)]
    seeds = list(range(10, 18))
    optimize_plan_batch(views, "e2e_multi", seeds=seeds, **opts)  # warm B=8
    t0 = time.perf_counter()
    optimize_plan_batch(views, "e2e_multi", seeds=seeds, **opts)
    batch_s = time.perf_counter() - t0
    plans_per_s = len(views) / batch_s

    # incremental replan vs full anneal, each timed warm through the
    # batched service path run_online actually uses (replan_batch over the
    # 8 views — one dispatch, so Python/dispatch overhead is amortized the
    # way it is in production)
    incumbents = [
        r.plan for r in optimize_plan_batch(views, "e2e_multi",
                                            seeds=seeds, **opts)
    ]
    # the speedup is measured at the PRODUCTION anneal budget (the library
    # default run_online uses), not the quick smoke budget — at tiny step
    # counts the fixed per-request cost (f64 pricing, batch assembly)
    # swamps the anneal and understates what the online loop gains
    ropts = dict(n_restarts=n_restarts, steps=500)
    for incremental in (False, True):
        replan_batch(views, incumbents, seeds=seeds,
                     incremental=incremental, **ropts)

    def best_of(incremental, repeats=3):
        # best-of-N: the min is the least scheduler-noise-polluted sample
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            replan_batch(views, incumbents, seeds=seeds,
                         incremental=incremental, **ropts)
            best = min(best, time.perf_counter() - t0)
        return best

    full_s = best_of(incremental=False)
    inc_s = best_of(incremental=True)

    stats = solver_cache_stats()
    out = {
        "throughput": {
            "plans_per_s": plans_per_s,
            "warm_vs_cold_speedup": cold_s / (p50_ms / 1e3),
            "incremental_speedup": full_s / inc_s,
        },
        "latency": {"cold_s": cold_s, "p50_ms": p50_ms, "p99_ms": p99_ms},
        "cache": {"compiles": stats["compiles"], "hits": stats["hits"],
                  "misses": stats["misses"]},
    }
    emit("bench_planner_throughput", batch_s * 1e6,
         f"plans_per_s={plans_per_s:.1f};"
         f"warm_vs_cold={out['throughput']['warm_vs_cold_speedup']:.0f}x")
    emit("bench_planner_latency", np.mean(warm_lat) * 1e6,
         f"cold={cold_s:.2f}s;p50={p50_ms:.0f}ms;p99={p99_ms:.0f}ms")
    emit("bench_planner_incremental", inc_s * 1e6,
         f"full={full_s*1e3:.0f}ms;incremental={inc_s*1e3:.0f}ms;"
         f"speedup={out['throughput']['incremental_speedup']:.1f}x")
    return out


def bench_scale() -> Dict:
    """Scale-wall benchmark (ROADMAP §3): the three-tier scale scenario.

    Three gated measurements on the edge->region->backbone substrates of
    :mod:`repro.core.topology`:

    * **100-node tier** — the same 100-job mix executed by the scalar and
      the vectorized DES hot path: events/sec for both, the speedup, and
      a makespan cross-check (the two paths are bit-identical; the
      vectorized one is gated at >= 5x by the baseline floor).
    * **rel-error contract** — fluid-mode vs per-chunk DES makespan over
      all 27 barrier triples at fine chunking (``rel_err_pct`` is gated
      one-sided: it may only shrink, with headroom up to the documented
      2% ceiling).
    * **1000-node tier** — ~10^3 nodes x 100 jobs in fluid mode: the
      deterministic makespan is gated; wall-clock is reported (CI
      budget: < 60 s).
    """
    from repro.core.simulate import open_schedule
    from repro.core.topology import scale_job_mix, scale_tier_substrate

    # -- 100-node tier: scalar vs vectorized DES --------------------------
    sub = scale_tier_substrate(seed=0)  # 4x12 edges + 4x8 maps + 2x6 reds
    n_nodes = sub.nS + sub.nM + sub.nR
    entries = scale_job_mix(
        sub, n_jobs=100, seed=3, base_cfg=SimConfig(chunk_mb=16.0)
    )

    def run_des(vectorized: bool):
        jobs = [
            (p, plan, dataclasses.replace(c, vectorized=vectorized))
            for p, plan, c in entries
        ]
        eng = open_schedule(jobs, substrate=sub)  # build excluded: the
        t0 = time.perf_counter()                  # hot path is run()
        res = eng.run()
        wall = time.perf_counter() - t0
        events = sum(r.n_chunks for r in res.resources.values())
        return res, wall, events

    res_s, wall_scalar, events = run_des(vectorized=False)
    res_v, wall_vec, events_v = run_des(vectorized=True)
    speedup = wall_scalar / wall_vec
    ev_per_s_scalar = events / wall_scalar
    ev_per_s_vec = events_v / wall_vec

    # -- fluid-vs-DES rel-error over the 27 barrier triples ---------------
    p = planetlab_platform(4, alpha=1.3, seed=5)
    plan = uniform_plan(p)
    rel_errs = {}
    for trip in itertools.product("GLP", repeat=3):
        b = "".join(trip)
        des = simulate(p, plan, SimConfig(barriers=b, chunk_mb=4.0,
                                          vectorized=True, audit=True))
        fl = simulate(p, plan, SimConfig(barriers=b, mode="fluid",
                                         audit=True))
        rel_errs[b] = abs(fl.makespan - des.makespan) / des.makespan
    rel_err_pct = 100.0 * max(rel_errs.values())

    # -- 1000-node tier: fluid mode ---------------------------------------
    sub1k = scale_tier_substrate(
        n_regions=12, edges_per_region=40, mappers_per_region=28,
        n_backbone=4, reducers_per_backbone=45, seed=1,
    )
    n_nodes_1k = sub1k.nS + sub1k.nM + sub1k.nR
    entries_1k = scale_job_mix(
        sub1k, n_jobs=100, seed=3, arrival_spread_s=600.0,
        base_cfg=SimConfig(mode="fluid"),
    )
    eng = open_schedule(entries_1k, substrate=sub1k)
    t0 = time.perf_counter()
    res_1k = eng.run()
    wall_1k = time.perf_counter() - t0

    out = {
        "des_100": {
            "n_nodes": n_nodes,
            "events": events,
            "events_per_s": ev_per_s_vec,
            "events_per_s_scalar": ev_per_s_scalar,
            "speedup_x": speedup,
            "makespan": res_v.makespan,
            "matches_scalar": bool(
                abs(res_v.makespan - res_s.makespan) < 1e-9
            ),
        },
        "fluid_vs_des": {
            "rel_err_pct": rel_err_pct,
            "worst_triple": max(rel_errs, key=rel_errs.get),
        },
        "fluid_1000": {
            "n_nodes": n_nodes_1k,
            "n_jobs": len(entries_1k),
            "makespan": res_1k.makespan,
            "wall_s": wall_1k,
        },
    }
    emit("scale_tier_des100", wall_vec * 1e6,
         f"events_per_s={ev_per_s_vec:.0f};speedup={speedup:.1f}x;"
         f"match={out['des_100']['matches_scalar']}")
    emit("scale_tier_fluid_relerr", 0.0,
         f"max_rel_err={rel_err_pct:.3f}%;"
         f"worst={out['fluid_vs_des']['worst_triple']}")
    emit("scale_tier_fluid1000", wall_1k * 1e6,
         f"nodes={n_nodes_1k};jobs={len(entries_1k)};"
         f"makespan={res_1k.makespan:.0f}s")
    return out


def bench_scale_online() -> Dict:
    """Online control at the scale tier (ROADMAP §3): steered vectorized
    drains, fluid capacity traces, and a 1000-node online run.

    Three gated measurements:

    * **steered_100** — the 100-node/100-job mix at fine chunking driven
      through mid-run decision points (``run_until`` + ``snapshot`` +
      ``inject`` + ``swap_plan``) on both DES paths.  The steered
      vectorized drain is gated at >= 5x wall-clock over the scalar
      steered path with byte-identical results (full ``as_dict``
      equality, not just makespan).
    * **traced_fluid** — fluid mode vs per-chunk DES on a substrate with
      ``CapacityTrace`` drift on every tier (push/map/shuffle/reduce all
      step mid-run), across a barrier-triple subset: ``rel_err_pct`` is
      gated one-sided under the documented 2% fluid contract.
    * **online_1000** — ~10^3 nodes x 100 jobs in fluid mode with a
      backbone-wide reducer brownout at t=250s: ``reactive_shared``
      incremental co-replanning against the frozen plan.  The run must
      finish under the 60 s CI budget; the online margin and decision
      throughput may only fall so far.
    """
    import json as _json

    from repro.core.simulate import open_schedule
    from repro.core.topology import scale_job_mix, scale_tier_substrate

    # -- steered 100-node tier: scalar vs vectorized drains ----------------
    sub = scale_tier_substrate(seed=0)
    entries = scale_job_mix(
        sub, n_jobs=100, seed=3, base_cfg=SimConfig(chunk_mb=4.0)
    )
    CUTS = (600.0, 1800.0)

    def run_steered(vectorized: bool):
        jobs = [
            (p, plan, dataclasses.replace(c, vectorized=vectorized))
            for p, plan, c in entries
        ]
        eng = open_schedule(jobs, substrate=sub)
        t0 = time.perf_counter()
        for i, cut in enumerate(CUTS):
            eng.run_until(cut)
            eng.snapshot()
            if i == 0:
                # one decision point: admit a streaming arrival and
                # cross-swap two incumbent routings mid-flight
                p0, plan0, c0 = entries[0]
                eng.inject([(p0, plan0, dataclasses.replace(
                    c0, vectorized=vectorized, start_time=cut))])
                eng.swap_plan(0, entries[1][1])
                eng.swap_plan(1, entries[0][1])
        res = eng.run()
        return res, time.perf_counter() - t0

    res_s, wall_scalar = run_steered(vectorized=False)
    res_v, wall_vec = run_steered(vectorized=True)
    speedup = wall_scalar / wall_vec
    identical = (
        _json.dumps(res_s.as_dict(), sort_keys=True)
        == _json.dumps(res_v.as_dict(), sort_keys=True)
    )

    # -- traced fluid vs traced DES ----------------------------------------
    p = planetlab_platform(4, alpha=1.3, seed=5)
    plan = uniform_plan(p)
    tsub = Substrate.of(p).with_traces({
        "push[s0->m1]": CapacityTrace.step(
            float(p.B_sm[0, 1]), float(p.B_sm[0, 1]) * 0.25, 40.0),
        "map[m0]": CapacityTrace.step(
            float(p.C_m[0]), float(p.C_m[0]) * 0.5, 80.0),
        "shuffle[m1->r0]": CapacityTrace.step(
            float(p.B_mr[1, 0]), float(p.B_mr[1, 0]) * 0.3, 150.0),
        "reduce[r2]": CapacityTrace.step(
            float(p.C_r[2]), float(p.C_r[2]) * 0.4, 200.0),
    })
    view = tsub.view(p.D, p.alpha)
    rel_errs = {}
    for b in ("GGL", "GGG", "LLL", "PPP", "LGP"):
        des = simulate_schedule(
            [(view, plan, SimConfig(barriers=b, chunk_mb=4.0,
                                    vectorized=True, audit=True))],
            substrate=tsub)
        fl = simulate_schedule(
            [(view, plan, SimConfig(barriers=b, mode="fluid", audit=True))],
            substrate=tsub)
        rel_errs[b] = abs(fl.makespan - des.makespan) / des.makespan
    rel_err_pct = 100.0 * max(rel_errs.values())

    # -- 1000-node tier: online control under a backbone brownout ----------
    sub1k0 = scale_tier_substrate(
        n_regions=12, edges_per_region=40, mappers_per_region=28,
        n_backbone=4, reducers_per_backbone=45, seed=1,
    )
    cluster_r = np.asarray(sub1k0.cluster_r)
    browned = np.flatnonzero(cluster_r == cluster_r[0])
    C_r = np.asarray(sub1k0.C_r)
    sub1k = sub1k0.with_traces({
        f"reduce[r{k}]": CapacityTrace.step(
            float(C_r[k]), float(C_r[k]) * 0.05, 250.0)
        for k in browned
    })
    n_nodes_1k = sub1k.nS + sub1k.nM + sub1k.nR
    entries_1k = scale_job_mix(
        sub1k, n_jobs=100, seed=3, arrival_spread_s=600.0,
        base_cfg=SimConfig(mode="fluid"),
    )
    # last 10 releases become true streaming arrivals at two instants
    order = np.argsort([c.start_time for _, _, c in entries_1k])
    jobs_1k, cfgs = [], []
    for i in order[:90]:
        pv, pl, c = entries_1k[int(i)]
        jobs_1k.append(GeoJob(pv).with_plan(pl, c.barriers))
        cfgs.append(c)
    arrivals = []
    for n, i in enumerate(order[90:]):
        pv, pl, c = entries_1k[int(i)]
        arrivals.append(Arrival(GeoJob(pv).with_plan(pl, c.barriers),
                                300.0 if n < 5 else 480.0, cfg=c))
    sched = GeoSchedule(jobs_1k).with_plans()
    t0 = time.perf_counter()
    report = sched.run_online(
        policy="reactive_shared", arrivals=arrivals, cfg=cfgs, **_OPT,
        # pinned decision cost: measured-EMA charges would make the
        # swap/keep sequence (and the gated makespan) host-dependent
        online=OnlineConfig(shared=True, hysteresis=1.0, incremental=True,
                            solver_cost_s=5.0),
    )
    wall_1k = time.perf_counter() - t0
    decisions_per_s = len(report.decisions) / wall_1k if wall_1k else 0.0

    out = {
        "steered_100": {
            "n_nodes": sub.nS + sub.nM + sub.nR,
            "n_jobs": len(entries) + 1,
            "speedup_x": speedup,
            "makespan": res_v.makespan,
            "matches_scalar": bool(identical),
            "wall_scalar_s": wall_scalar,
            "wall_vec_s": wall_vec,
        },
        "traced_fluid": {
            "rel_err_pct": rel_err_pct,
            "worst_triple": max(rel_errs, key=rel_errs.get),
            "n_scenarios": len(rel_errs),
        },
        "online_1000": {
            "n_nodes": n_nodes_1k,
            "n_jobs": len(entries_1k),
            "makespan": report.makespan_online,
            "static_makespan": report.makespan_static,
            "online_margin": report.improvement,
            "decisions": len(report.decisions),
            "swaps": len(report.swaps),
            "rejected": len(report.rejected),
            "decisions_per_s": decisions_per_s,
            "wall_s": wall_1k,
        },
    }
    emit("scale_online_steered100", wall_vec * 1e6,
         f"speedup={speedup:.1f}x;identical={identical};"
         f"makespan={res_v.makespan:.0f}s")
    emit("scale_online_traced_fluid", 0.0,
         f"max_rel_err={rel_err_pct:.3f}%;"
         f"worst={out['traced_fluid']['worst_triple']}")
    emit("scale_online_1000", wall_1k * 1e6,
         f"nodes={n_nodes_1k};margin={report.improvement:.0%};"
         f"decisions_per_s={decisions_per_s:.1f};"
         f"swaps={len(report.swaps)}")
    return out
