"""Benchmark driver — one benchmark per paper figure plus the roofline
table, all driven through the :class:`repro.api.GeoJob` facade (plan →
price → execute on one shared cost model).  Emits
``name,us_per_call,derived`` CSV rows (also saved to
``reports/benchmarks.csv``) and a JSON dump of full results.

``--json PATH`` additionally writes a machine-readable timing document —
``{scenario: {wall_s, results}}`` with modeled/simulated makespans where the
scenario produces them — which CI uploads as an artifact to seed the bench
trajectory.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline] [--quick]
                                            [--json PATH]
                                            [--planner-json PATH]

The JSON meta header records jax/numpy/git provenance plus the solver
cache counters (compiles, hits, misses) accumulated over the run.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time

from . import paper_figures as F
from .common import flush_csv


def _json_default(o):
    import numpy as np

    if isinstance(o, (np.floating, np.integer)):
        return float(o)
    return str(o)


def _provenance() -> dict:
    """Library versions + git SHA, so uploaded timing artifacts are
    comparable across CI runs (and a baseline mismatch can be traced to a
    toolchain change rather than a code regression)."""
    import jax
    import numpy as np

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {"jax": jax.__version__, "numpy": np.__version__,
            "git_sha": sha}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the dry-run-report-based roofline table")
    ap.add_argument("--quick", action="store_true",
                    help="small solver budgets (smoke-run the whole suite)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write machine-readable per-scenario timings "
                         "(modeled/simulated makespans + wall seconds)")
    ap.add_argument("--scenario", action="append", default=None,
                    metavar="NAME",
                    help="run only the named scenario (repeatable) — lets "
                         "CI and local dev re-run a single scenario")
    ap.add_argument("--planner-json", default=None, metavar="PATH",
                    help="also write just the bench_planner scenario (plus "
                         "meta) as its own JSON document — the planner-"
                         "throughput artifact CI uploads")
    ap.add_argument("--profile", action="store_true",
                    help="run the scenarios under cProfile and write the "
                         "top-20 cumulative functions next to --json (or "
                         "into --out) — how the executor hot path was "
                         "found")
    ap.add_argument("--out", default="reports")
    args = ap.parse_args()
    if args.quick:
        F._OPT = dict(n_restarts=6, steps=200)
    os.makedirs(args.out, exist_ok=True)

    scenarios = [
        ("fig4", F.fig4_validation),
        ("fig5", F.fig5_e2e_vs_myopic),
        ("fig6", F.fig6_single_vs_multi),
        ("fig7", F.fig7_barriers),
        ("fig8", F.fig8_environments),
        ("fig9", F.fig9_applications),
        ("fig10", F.fig10_dynamics),
        ("fig12", F.fig12_replication),
        ("schedule", F.schedule_contention),
        ("schedule_online", F.schedule_online),
        ("schedule_online_shared", F.schedule_online_shared),
        ("schedule_failover", F.schedule_failover),
        ("pipeline_chain", F.pipeline_chain),
        ("bench_planner", F.bench_planner),
        ("bench_scale", F.bench_scale),
        ("bench_scale_online", F.bench_scale_online),
    ]
    if args.scenario:
        known = {name for name, _ in scenarios}
        unknown = sorted(set(args.scenario) - known)
        if unknown:
            ap.error(f"unknown scenario(s) {unknown} — choose from "
                     f"{sorted(known)}")
        scenarios = [(n, fn) for n, fn in scenarios if n in args.scenario]

    profiler = None
    if args.profile:
        import cProfile

        profiler = cProfile.Profile()

    results, wall = {}, {}
    print("name,us_per_call,derived")
    for name, fn in scenarios:
        t0 = time.perf_counter()
        if profiler is not None:
            results[name] = profiler.runcall(fn)
        else:
            results[name] = fn()
        wall[name] = time.perf_counter() - t0

    if profiler is not None:
        import io
        import pstats

        buf = io.StringIO()
        pstats.Stats(profiler, stream=buf).sort_stats(
            "cumulative").print_stats(20)
        profile_path = (
            os.path.splitext(args.json)[0] + "-profile.txt"
            if args.json else os.path.join(args.out, "profile.txt")
        )
        profile_dir = os.path.dirname(profile_path)
        if profile_dir:
            os.makedirs(profile_dir, exist_ok=True)
        with open(profile_path, "w") as f:
            f.write(buf.getvalue())
        print(f"[profile] top-20 cumulative in {profile_path}")

    if not args.skip_roofline and os.path.isdir(
        os.path.join(args.out, "dryrun")
    ):
        from . import roofline

        t0 = time.perf_counter()
        rows = roofline.run(os.path.join(args.out, "dryrun"),
                            os.path.join(args.out, "roofline.md"))
        results["roofline"] = rows
        wall["roofline"] = time.perf_counter() - t0

    flush_csv(os.path.join(args.out, "benchmarks.csv"))

    with open(os.path.join(args.out, "benchmarks.json"), "w") as f:
        json.dump(results, f, indent=1, default=_json_default)

    if args.json or args.planner_json:
        from repro.core.optimize import solver_cache_stats

        # cumulative solver-cache counters over the whole run: compile-time
        # vs steady-state throughput is visible in the bench trajectory
        meta = {"quick": bool(args.quick),
                "opt": {k: int(v) for k, v in F._OPT.items()},
                "total_wall_s": sum(wall.values()),
                "solver_cache": solver_cache_stats(),
                **_provenance()}

    def _write_json(path, doc):
        json_dir = os.path.dirname(path)
        if json_dir:
            os.makedirs(json_dir, exist_ok=True)
        with open(path, "w") as f:
            json.dump(doc, f, indent=1, default=_json_default)

    if args.json:
        _write_json(args.json, {
            "meta": meta,
            "scenarios": {
                name: {"wall_s": wall[name], "results": results[name]}
                for name in results
            },
        })
        print(f"[json] machine-readable timings in {args.json}")

    if args.planner_json:
        if "bench_planner" not in results:
            ap.error("--planner-json requires the bench_planner scenario "
                     "to run (drop the --scenario filter or include it)")
        _write_json(args.planner_json, {
            "meta": meta,
            "scenarios": {
                "bench_planner": {"wall_s": wall["bench_planner"],
                                  "results": results["bench_planner"]},
            },
        })
        print(f"[json] planner throughput in {args.planner_json}")

    print(f"\n[done] results in {args.out}/benchmarks.{{csv,json}}")


if __name__ == "__main__":
    main()
