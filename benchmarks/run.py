"""Benchmark driver — one benchmark per paper figure plus the roofline
table, all driven through the :class:`repro.api.GeoJob` facade (plan →
price → execute on one shared cost model).  Emits
``name,us_per_call,derived`` CSV rows (also saved to
``reports/benchmarks.csv``) and a JSON dump of full results.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline] [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

from . import paper_figures as F
from .common import flush_csv


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the dry-run-report-based roofline table")
    ap.add_argument("--quick", action="store_true",
                    help="small solver budgets (smoke-run the whole suite)")
    ap.add_argument("--out", default="reports")
    args = ap.parse_args()
    if args.quick:
        F._OPT = dict(n_restarts=6, steps=200)
    os.makedirs(args.out, exist_ok=True)

    results = {}
    print("name,us_per_call,derived")
    results["fig4"] = F.fig4_validation()
    results["fig5"] = F.fig5_e2e_vs_myopic()
    results["fig6"] = F.fig6_single_vs_multi()
    results["fig7"] = F.fig7_barriers()
    results["fig8"] = F.fig8_environments()
    results["fig9"] = F.fig9_applications()
    results["fig10"] = F.fig10_dynamics()
    results["fig12"] = F.fig12_replication()
    results["schedule"] = F.schedule_contention()

    if not args.skip_roofline and os.path.isdir(
        os.path.join(args.out, "dryrun")
    ):
        from . import roofline

        rows = roofline.run(os.path.join(args.out, "dryrun"),
                            os.path.join(args.out, "roofline.md"))
        results["roofline"] = rows

    flush_csv(os.path.join(args.out, "benchmarks.csv"))

    def default(o):
        import numpy as np

        if isinstance(o, (np.floating, np.integer)):
            return float(o)
        return str(o)

    with open(os.path.join(args.out, "benchmarks.json"), "w") as f:
        json.dump(results, f, indent=1, default=default)
    print(f"\n[done] results in {args.out}/benchmarks.{{csv,json}}")


if __name__ == "__main__":
    main()
