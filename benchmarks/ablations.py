"""Solver ablations: what each ingredient of the annealed multi-restart
optimizer buys (restarts, annealing, warm starts), plus sensitivity of the
plan to mis-estimated α — the "what-if" capability the paper highlights.

    PYTHONPATH=src python -m benchmarks.ablations
"""
from __future__ import annotations


from repro.core.makespan import BARRIERS_ALL_GLOBAL, makespan
from repro.core.optimize import optimize_plan
from repro.core.plan import uniform_plan
from repro.core.platform import planetlab_platform

from .common import emit, timeit


def restarts_ablation():
    """Quality vs restart count: hard-max plateaus demand multi-restart."""
    p = planetlab_platform(8, alpha=1.0, seed=0)
    ref = optimize_plan(p, "e2e_multi", n_restarts=32, steps=600).makespan
    out = {}
    for r in [1, 2, 4, 8, 16]:
        us, res = timeit(
            lambda r=r: optimize_plan(p, "e2e_multi", n_restarts=r, steps=400),
            repeats=1,
        )
        out[r] = res.makespan / ref
        emit(f"ablation_restarts{r}", us, f"vs_best={out[r]:.3f}")
    return out


def steps_ablation():
    p = planetlab_platform(8, alpha=1.0, seed=0)
    ref = optimize_plan(p, "e2e_multi", n_restarts=16, steps=800).makespan
    out = {}
    for steps in [50, 100, 200, 400]:
        res = optimize_plan(p, "e2e_multi", n_restarts=16, steps=steps)
        out[steps] = res.makespan / ref
        emit(f"ablation_steps{steps}", 0.0, f"vs_best={out[steps]:.3f}")
    return out


def alpha_misestimation():
    """Plan with a wrong α, evaluate under the true α — how forgiving is
    the optimization to profiling error?  (The paper determines α by
    profiling; this quantifies the stakes.)"""
    out = {}
    for true_alpha in [0.1, 1.0, 10.0]:
        p_true = planetlab_platform(8, alpha=true_alpha, seed=0)
        uni = makespan(p_true, uniform_plan(p_true), BARRIERS_ALL_GLOBAL)
        row = {}
        for assumed in [0.1, 1.0, 10.0]:
            p_assumed = planetlab_platform(8, alpha=assumed, seed=0)
            plan = optimize_plan(p_assumed, "e2e_multi",
                                 n_restarts=12, steps=300).plan
            row[assumed] = makespan(p_true, plan, BARRIERS_ALL_GLOBAL) / uni
        out[true_alpha] = row
        emit(
            f"ablation_alpha_true{true_alpha}", 0.0,
            ";".join(f"assumed{a}={v:.3f}" for a, v in row.items()),
        )
    return out


def main():
    print("name,us_per_call,derived")
    restarts_ablation()
    steps_ablation()
    alpha_misestimation()


if __name__ == "__main__":
    main()
