"""Benchmark infrastructure: timing + CSV row emission.

Every benchmark emits ``name,us_per_call,derived`` rows (derived = the
figure's headline quantity, e.g. a reduction percentage or an R²).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def timeit(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    """us/call of fn() (best of ``repeats``), plus the last result."""
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def flush_csv(path: str):
    with open(path, "w") as f:
        f.write("name,us_per_call,derived\n")
        for n, u, d in ROWS:
            f.write(f"{n},{u:.1f},{d}\n")
