"""Roofline analysis from the dry-run reports.

Three terms per (arch × shape), single-pod mesh (deliverable g):

    compute    = HLO_FLOPs_per_device / peak_FLOPs        [s]
    memory     = HLO_bytes_per_device / HBM_bw            [s]
    collective = collective_bytes_per_device / link_bw    [s]

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
``MODEL_FLOPS`` uses 6·N·D (train) or 2·N_active·D (forward-only), with N
from the *unpadded* config — the MODEL/HLO ratio therefore exposes padding
and remat waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from .common import emit

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s ICI

TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 1 * 128,
    "long_500k": 1 * 1,
}


def model_flops(rep: Dict) -> float:
    n_active = rep["model_active_params"]
    toks = TOKENS[rep["shape"]]
    mult = 6.0 if rep["kind"] == "train" else 2.0
    return mult * n_active * toks


def analytic_bytes_floor(rep: Dict) -> float:
    """Lower bound on per-device HBM traffic: parameter + residual-carry +
    cache + logits I/O, assuming perfect fusion of everything else.

    train: params ×(bf16 fwd read + refwd + bwd read = 6 B) + f32 grad/opt
    (p,m,v read+write = 24 B) + 2 B bf16 recast write ≈ 32 B/param-local;
    stacked carries written+read (2×) in bf16 AND the backend's f32 copy;
    decode: params read once + full KV/state cache read + logits write.
    """
    chips = rep["n_devices"]
    p_local = rep.get("padded_params", rep["model_params"]) / chips
    try:
        from repro.configs import ARCHS

        cfg = ARCHS[rep["arch"]]
        d_model, n_layers = cfg.d_model, cfg.n_layers
    except Exception:  # registry unavailable: params-only floor
        d_model, n_layers = 0, 0
    # model-axis TP shards the hidden dim 16-ways for activations
    toks_local = TOKENS[rep["shape"]] / max(chips / 16, 1)
    if rep["kind"] == "train":
        carry = n_layers * toks_local * d_model * 2.0  # bf16 write
        return p_local * 32.0 + carry * 3.0  # write + fwd/bwd reads
    # inference: bf16 param read + cache/state sweep (the argument bytes
    # are dominated by the cache for decode shapes)
    return p_local * 2.0 + rep.get("argument_size_in_bytes", 0.0)


def analyze(rep: Dict) -> Dict:
    chips = rep["n_devices"]
    # flops: unrolled-analysis HLO count + analytic attention correction
    # (the chunked-attention inner scans stay rolled; see launch/analysis.py)
    flops_pd = (
        rep.get("hlo_flops_per_device",
                rep.get("hlo_flops_per_device_rolled", 0.0))
        + rep.get("attn_flops_total", 0.0) / chips
    )
    compute = flops_pd / PEAK_FLOPS
    # HLO "bytes accessed" counts unfused operand traffic — an UPPER bound
    # on HBM traffic; the analytic parameter/carry/cache floor is the
    # matching LOWER bound (perfect fusion).  Fractions are reported for
    # both ends.
    memory_hi = rep.get(
        "hlo_bytes_per_device", rep.get("hlo_bytes_per_device_rolled", 0.0)
    ) / HBM_BW
    memory_lo = min(analytic_bytes_floor(rep) / HBM_BW, memory_hi)
    memory = memory_hi
    colls = rep.get("collectives_per_device_bytes",
                    rep.get("collectives_per_device_bytes_rolled"))
    coll = colls["total"] / LINK_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    total_hlo_flops = flops_pd * chips
    mf = model_flops(rep)
    ratio = mf / total_hlo_flops if total_hlo_flops else float("nan")
    bound = max(terms.values())
    bound_lo = max(compute, memory_lo, coll)
    # roofline fraction: useful model work per second at the bound, over peak
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else float("nan")
    frac_hi = (
        (mf / chips / PEAK_FLOPS) / bound_lo if bound_lo > 0 else float("nan")
    )
    suggest = {
        "compute": "cut HLO/MODEL FLOP waste: remat recompute, head/expert "
                   "padding, dense-decode attention over the padded cache",
        "memory": "reduce bytes: bf16/int8 KV cache, fused attention "
                  "(Pallas) to avoid logits round-trips, smaller remat set",
        "collective": "reshard to cut all-gathers (fsdp prefetch overlap), "
                      "hierarchical pod-axis reduction, gradient compression",
    }[dominant]
    return {
        "arch": rep["arch"], "shape": rep["shape"], "mesh": rep["mesh"],
        "compute_s": compute, "memory_s": memory, "memory_lo_s": memory_lo,
        "collective_s": coll,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": total_hlo_flops,
        "model_over_hlo": ratio,
        "roofline_fraction": frac,
        "roofline_fraction_hi": frac_hi,
        "per_device_gib": rep.get("per_device_bytes", 0) / 2**30,
        "fix": suggest,
    }


def load_reports(directory: str = "reports/dryrun", mesh: Optional[str] = "16x16"
                 ) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(directory, "*.json"))):
        with open(path) as f:
            rep = json.load(f)
        if mesh is None or rep["mesh"] == mesh:
            out.append(rep)
    return out


def run(directory: str = "reports/dryrun", out_md: str = "reports/roofline.md"):
    rows = [analyze(r) for r in load_reports(directory)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        "| arch | shape | compute s | memory s [lo–hi] | collective s | "
        "dominant | MODEL/HLO | roofline-frac [lo–hi] | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_lo_s']:.4f}–{r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['model_over_hlo']:.2f} | "
            f"{r['roofline_fraction']:.2f}–{r['roofline_fraction_hi']:.2f} | "
            f"{r['per_device_gib']:.1f} |"
        )
        emit(
            f"roofline_{r['arch']}_{r['shape']}", 0.0,
            f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}-"
            f"{r['roofline_fraction_hi']:.3f};"
            f"model/hlo={r['model_over_hlo']:.2f}",
        )
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    return rows
