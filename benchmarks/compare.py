"""Benchmark-regression gate: compare a ``benchmarks.run --json`` document
against the committed baseline and fail on drift.

CI runs the quick benchmark suite, then::

    PYTHONPATH=src python -m benchmarks.compare reports/bench-timings.json

which fails (exit 1) when

* any scenario present in the baseline is missing from the current run, or
* any baseline makespan metric (leaf keys ``makespan`` / ``simulated`` /
  ``modeled`` inside a scenario's results) deviates from the baseline by
  more than ``--tolerance`` (relative, default 0.25), or
* any planner-throughput metric (``plans_per_s``, ``p50_ms``, ``p99_ms``,
  the speedup ratios, ``compiles``) regresses in its *bad* direction past
  its per-metric tolerance — latency/compile counts may only rise so far,
  throughput/speedups may only fall so far; improvement is never a
  failure (see ``METRIC_DIRECTIONS`` / ``METRIC_TOLERANCES``).

Most wall-clock numbers are deliberately *not* gated — they vary with
the host.  The exceptions carry wide one-sided gates: the scale-tier
``wall_s`` budgets (they enforce the < 60 s CI ceilings with 2x
headroom) and throughput/speedup ratios whose acceptance floors are part
of the scale-wall contract.  The makespan metrics are modeled/simulated
seconds produced by the deterministic cost model and discrete-event
executor with fixed seeds, so on a pinned toolchain they reproduce
closely; the planner metrics ARE wall clock, which is why their gates are
wide and one-sided.  The baseline records the jax/numpy versions and git
SHA it was seeded from (see ``benchmarks.run._provenance``) so a
toolchain-driven mismatch is distinguishable from a code regression.

Refreshing after an intentional change::

    PYTHONPATH=src python -m benchmarks.run --quick --skip-roofline \
        --json reports/bench-timings.json
    PYTHONPATH=src python -m benchmarks.compare reports/bench-timings.json \
        --update-baseline

then commit ``benchmarks/baseline.json`` with the change that moved the
numbers.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, Optional

#: leaf keys inside a scenario's results that are gated.  Makespan metrics
#: (seconds) are emitted by the deterministic model/executor, not wall
#: clock; the ``bench_planner`` latency/throughput leaves ARE wall clock,
#: which is why they carry direction-aware per-metric tolerances below.
METRIC_KEYS = frozenset({
    "makespan", "simulated", "modeled",
    "plans_per_s", "p50_ms", "p99_ms",
    "warm_vs_cold_speedup", "incremental_speedup", "compiles",
    "events_per_s", "speedup_x", "rel_err_pct",
    "failover_margin",
    "online_margin", "decisions_per_s", "wall_s",
})

#: per-scenario tolerance overrides (relative; scenarios absent here use
#: ``--tolerance``).  Annealed-solver scenarios whose discrete chunk
#: routing amplifies small plan differences get a wider gate; tighten (or
#: extend via ``--scenario-tolerance NAME=VAL``) as they prove stable.
SCENARIO_TOLERANCES = {
    "pipeline_chain": 0.35,
}

#: which way a metric is allowed to drift freely: ``lower`` metrics only
#: fail when the current value comes in ABOVE baseline (latency, compile
#: counts), ``higher`` metrics only when it comes in BELOW (throughput,
#: speedups).  Metrics absent here are gated both ways (makespans).
METRIC_DIRECTIONS = {
    "p50_ms": "lower",
    "p99_ms": "lower",
    "compiles": "lower",
    "plans_per_s": "higher",
    "warm_vs_cold_speedup": "higher",
    "incremental_speedup": "higher",
    # bench_scale: executor throughput and the vectorized-DES speedup may
    # only fall so far; the fluid-vs-DES rel-error may only grow so far
    "events_per_s": "higher",
    "speedup_x": "higher",
    "rel_err_pct": "lower",
    # schedule_failover: the recovery win over the frozen plan may only
    # shrink so far — the acceptance floor is >= 20% margin
    "failover_margin": "higher",
    # bench_scale_online: the online win over the frozen plan and the
    # decision throughput may only fall so far; wall-clock may only rise
    # so far (the 1000-node run carries a < 60 s CI budget)
    "online_margin": "higher",
    "decisions_per_s": "higher",
    "wall_s": "lower",
}

#: per-metric (leaf key) tolerance overrides — these beat the scenario
#: tolerance.  Wall-clock planner metrics on shared CI runners are far
#: noisier than deterministic makespans, so their gates are wide; the
#: acceptance floors (>=5x warm-vs-cold, >=3x incremental) still bind
#: because the baselines sit well above them.
METRIC_TOLERANCES = {
    "p50_ms": 3.0,
    "p99_ms": 3.0,
    "plans_per_s": 0.75,
    "warm_vs_cold_speedup": 0.6,
    "incremental_speedup": 0.6,
    "compiles": 0.5,
    # wall-clock-derived, so wide — with the baseline at ~13x the 0.6
    # floor still enforces the >= 5x vectorized-DES acceptance criterion
    "events_per_s": 0.75,
    "speedup_x": 0.6,
    # baseline rel-err is ~0.07%; 25x headroom keeps the gate under the
    # documented 2% fluid-mode contract while ignoring float jitter
    "rel_err_pct": 25.0,
    # deterministic simulated margin (~0.5 at baseline): 0.6 headroom
    # floors it at ~0.2 — the >= 20% failover acceptance criterion
    "failover_margin": 0.6,
    # bench_scale_online: the margin is deterministic (pinned
    # solver_cost_s) but the throughput and wall gates are host
    # wall-clock, so they are wide and one-sided
    "online_margin": 0.6,
    "decisions_per_s": 0.75,
    "wall_s": 1.0,
    # scenario-scoped override (``scenario:leaf`` beats the bare leaf):
    # the steered-drain speedup baseline sits just above the >= 5x
    # acceptance floor, so it gets a tight gate — the ratio is
    # host-stable because both sides run on the same machine
    "bench_scale_online:speedup_x": 0.08,
}

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def _walk(node, path, out: Dict[str, float]) -> None:
    if isinstance(node, dict):
        for key, value in node.items():
            if key in METRIC_KEYS and isinstance(value, (int, float)) \
                    and math.isfinite(value):
                out[f"{path}/{key}"] = float(value)
            else:
                _walk(value, f"{path}/{key}", out)
    elif isinstance(node, list):
        for idx, value in enumerate(node):
            _walk(value, f"{path}[{idx}]", out)


def extract_metrics(doc: dict) -> Dict[str, float]:
    """Flatten a ``--json`` timing document (or an already-trimmed
    baseline) to ``{scenario/.../metric: seconds}``."""
    if "metrics" in doc:  # a trimmed baseline written by --update-baseline
        return {k: float(v) for k, v in doc["metrics"].items()}
    metrics: Dict[str, float] = {}
    for name, scenario in doc.get("scenarios", {}).items():
        _walk(scenario.get("results", {}), name, metrics)
    return metrics


def scenario_names(metrics: Dict[str, float]) -> "set[str]":
    return {path.split("/", 1)[0] for path in metrics}


def compare(
    baseline: Dict[str, float],
    current: Dict[str, float],
    tolerance: float,
    scenario_tolerances: "Optional[Dict[str, float]]" = None,
    metric_tolerances: "Optional[Dict[str, float]]" = None,
) -> "list[str]":
    """Return the list of failures (empty = gate passes).

    ``scenario_tolerances`` overrides ``tolerance`` per scenario (the
    metric path's leading component), defaulting to
    :data:`SCENARIO_TOLERANCES`; ``metric_tolerances`` overrides both per
    leaf metric key (defaulting to :data:`METRIC_TOLERANCES`), with a
    ``scenario:leaf`` entry beating a bare ``leaf`` entry.  Deviation
    is direction-aware per :data:`METRIC_DIRECTIONS`: a latency metric
    that got *faster* or a throughput metric that got *faster* never
    fails, however far it moved."""
    overrides = SCENARIO_TOLERANCES if scenario_tolerances is None \
        else scenario_tolerances
    metric_overrides = METRIC_TOLERANCES if metric_tolerances is None \
        else metric_tolerances
    failures = []
    missing_scenarios = scenario_names(baseline) - scenario_names(current)
    for name in sorted(missing_scenarios):
        failures.append(f"scenario disappeared: {name}")
    for path, base in sorted(baseline.items()):
        scenario = path.split("/", 1)[0]
        leaf = path.rsplit("/", 1)[-1]
        if scenario in missing_scenarios:
            continue  # already reported wholesale
        if path not in current:
            failures.append(f"metric disappeared: {path}")
            continue
        cur = current[path]
        tol = metric_overrides.get(
            f"{scenario}:{leaf}",
            metric_overrides.get(leaf, overrides.get(scenario, tolerance)))
        # tiny epsilon floor only (the gated metrics are deterministic
        # model outputs, so sub-second baselines deserve the same relative
        # gate as hundred-second ones)
        denom = max(abs(base), 1e-6)
        direction = METRIC_DIRECTIONS.get(leaf, "both")
        if direction == "lower":      # regression = came in above baseline
            dev, bound = (cur - base) / denom, f">+{tol:.0%}"
        elif direction == "higher":   # regression = came in below baseline
            dev, bound = (base - cur) / denom, f">-{tol:.0%}"
        else:
            dev, bound = abs(cur - base) / denom, f"±{tol:.0%}"
        if dev > tol:
            failures.append(
                f"{path}: {cur:.2f} vs baseline {base:.2f} "
                f"({dev:+.0%} outside {bound})"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fail when benchmark makespans drift from the baseline"
    )
    ap.add_argument("current", help="bench-timings.json from benchmarks.run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="max relative deviation per metric (default 0.25)")
    ap.add_argument("--scenario-tolerance", action="append", default=[],
                    metavar="NAME=VAL",
                    help="per-scenario tolerance override (repeatable), "
                         "e.g. --scenario-tolerance pipeline_chain=0.4; "
                         "adds to the built-in SCENARIO_TOLERANCES")
    ap.add_argument("--metric-tolerance", action="append", default=[],
                    metavar="KEY=VAL",
                    help="per-metric (leaf key) tolerance override "
                         "(repeatable), e.g. --metric-tolerance p99_ms=5.0; "
                         "beats scenario tolerances, adds to the built-in "
                         "METRIC_TOLERANCES")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current run "
                         "instead of comparing")
    args = ap.parse_args()
    def _parse_overrides(items, base, flag):
        out = dict(base)
        for item in items:
            name, _, value = item.partition("=")
            if not name or not value:
                ap.error(f"{flag} expects NAME=VAL, got {item!r}")
            try:
                out[name] = float(value)
            except ValueError:
                ap.error(f"bad tolerance value in {item!r}")
        return out

    scenario_tolerances = _parse_overrides(
        args.scenario_tolerance, SCENARIO_TOLERANCES, "--scenario-tolerance")
    metric_tolerances = _parse_overrides(
        args.metric_tolerance, METRIC_TOLERANCES, "--metric-tolerance")

    with open(args.current) as f:
        doc = json.load(f)
    current = extract_metrics(doc)
    if not current:
        print("[compare] FAIL: no gated metrics in the current run "
              f"({args.current})")
        return 1

    if args.update_baseline:
        trimmed = {"meta": doc.get("meta", {}), "metrics": current}
        with open(args.baseline, "w") as f:
            json.dump(trimmed, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[compare] baseline refreshed: {len(current)} metrics "
              f"-> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"[compare] FAIL: no baseline at {args.baseline} — seed one "
              "with --update-baseline")
        return 1
    with open(args.baseline) as f:
        base_doc = json.load(f)
    baseline = extract_metrics(base_doc)

    failures = compare(baseline, current, args.tolerance,
                       scenario_tolerances, metric_tolerances)
    new = sorted(set(current) - set(baseline))
    if new:
        print(f"[compare] {len(new)} metric(s) not in baseline (not gated; "
              "run --update-baseline to adopt):")
        for path in new[:10]:
            print(f"  + {path} = {current[path]:.2f}s")
    if failures:
        print(f"[compare] FAIL: {len(failures)} regression(s) vs "
              f"{args.baseline} (tolerance ±{args.tolerance:.0%}):")
        for failure in failures:
            print(f"  ! {failure}")
        meta = base_doc.get("meta", {})
        if meta:
            print(f"[compare] baseline provenance: {json.dumps(meta)}")
        return 1
    print(f"[compare] OK: {len(baseline)} metric(s) within "
          f"±{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
