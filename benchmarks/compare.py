"""Benchmark-regression gate: compare a ``benchmarks.run --json`` document
against the committed baseline and fail on drift.

CI runs the quick benchmark suite, then::

    PYTHONPATH=src python -m benchmarks.compare reports/bench-timings.json

which fails (exit 1) when

* any scenario present in the baseline is missing from the current run, or
* any baseline makespan metric (leaf keys ``makespan`` / ``simulated`` /
  ``modeled`` inside a scenario's results) deviates from the baseline by
  more than ``--tolerance`` (relative, default 0.25).

Wall-clock (``wall_s``) and derived ratios are deliberately *not* gated —
they vary with the host.  The gated metrics are modeled/simulated seconds
produced by the deterministic cost model and discrete-event executor with
fixed seeds, so on a pinned toolchain they reproduce closely; the baseline
records the jax/numpy versions and git SHA it was seeded from (see
``benchmarks.run._provenance``) so a toolchain-driven mismatch is
distinguishable from a code regression.

Refreshing after an intentional change::

    PYTHONPATH=src python -m benchmarks.run --quick --skip-roofline \
        --json reports/bench-timings.json
    PYTHONPATH=src python -m benchmarks.compare reports/bench-timings.json \
        --update-baseline

then commit ``benchmarks/baseline.json`` with the change that moved the
numbers.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, Optional

#: leaf keys inside a scenario's results that are gated (seconds; emitted by
#: the deterministic model/executor, not wall clock)
METRIC_KEYS = frozenset({"makespan", "simulated", "modeled"})

#: per-scenario tolerance overrides (relative; scenarios absent here use
#: ``--tolerance``).  Annealed-solver scenarios whose discrete chunk
#: routing amplifies small plan differences get a wider gate; tighten (or
#: extend via ``--scenario-tolerance NAME=VAL``) as they prove stable.
SCENARIO_TOLERANCES = {
    "pipeline_chain": 0.35,
}

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baseline.json")


def _walk(node, path, out: Dict[str, float]) -> None:
    if isinstance(node, dict):
        for key, value in node.items():
            if key in METRIC_KEYS and isinstance(value, (int, float)) \
                    and math.isfinite(value):
                out[f"{path}/{key}"] = float(value)
            else:
                _walk(value, f"{path}/{key}", out)
    elif isinstance(node, list):
        for idx, value in enumerate(node):
            _walk(value, f"{path}[{idx}]", out)


def extract_metrics(doc: dict) -> Dict[str, float]:
    """Flatten a ``--json`` timing document (or an already-trimmed
    baseline) to ``{scenario/.../metric: seconds}``."""
    if "metrics" in doc:  # a trimmed baseline written by --update-baseline
        return {k: float(v) for k, v in doc["metrics"].items()}
    metrics: Dict[str, float] = {}
    for name, scenario in doc.get("scenarios", {}).items():
        _walk(scenario.get("results", {}), name, metrics)
    return metrics


def scenario_names(metrics: Dict[str, float]) -> "set[str]":
    return {path.split("/", 1)[0] for path in metrics}


def compare(
    baseline: Dict[str, float],
    current: Dict[str, float],
    tolerance: float,
    scenario_tolerances: "Optional[Dict[str, float]]" = None,
) -> "list[str]":
    """Return the list of failures (empty = gate passes).

    ``scenario_tolerances`` overrides ``tolerance`` per scenario (the
    metric path's leading component), defaulting to
    :data:`SCENARIO_TOLERANCES`."""
    overrides = SCENARIO_TOLERANCES if scenario_tolerances is None \
        else scenario_tolerances
    failures = []
    missing_scenarios = scenario_names(baseline) - scenario_names(current)
    for name in sorted(missing_scenarios):
        failures.append(f"scenario disappeared: {name}")
    for path, base in sorted(baseline.items()):
        scenario = path.split("/", 1)[0]
        if scenario in missing_scenarios:
            continue  # already reported wholesale
        if path not in current:
            failures.append(f"metric disappeared: {path}")
            continue
        cur = current[path]
        tol = overrides.get(scenario, tolerance)
        # tiny epsilon floor only (the gated metrics are deterministic
        # model outputs, so sub-second baselines deserve the same relative
        # gate as hundred-second ones)
        dev = abs(cur - base) / max(abs(base), 1e-6)
        if dev > tol:
            failures.append(
                f"{path}: {cur:.2f}s vs baseline {base:.2f}s "
                f"({dev:+.0%} > ±{tol:.0%})"
            )
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fail when benchmark makespans drift from the baseline"
    )
    ap.add_argument("current", help="bench-timings.json from benchmarks.run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="max relative deviation per metric (default 0.25)")
    ap.add_argument("--scenario-tolerance", action="append", default=[],
                    metavar="NAME=VAL",
                    help="per-scenario tolerance override (repeatable), "
                         "e.g. --scenario-tolerance pipeline_chain=0.4; "
                         "adds to the built-in SCENARIO_TOLERANCES")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from the current run "
                         "instead of comparing")
    args = ap.parse_args()
    scenario_tolerances = dict(SCENARIO_TOLERANCES)
    for item in args.scenario_tolerance:
        name, _, value = item.partition("=")
        if not name or not value:
            ap.error(f"--scenario-tolerance expects NAME=VAL, got {item!r}")
        try:
            scenario_tolerances[name] = float(value)
        except ValueError:
            ap.error(f"bad tolerance value in {item!r}")

    with open(args.current) as f:
        doc = json.load(f)
    current = extract_metrics(doc)
    if not current:
        print("[compare] FAIL: no gated metrics in the current run "
              f"({args.current})")
        return 1

    if args.update_baseline:
        trimmed = {"meta": doc.get("meta", {}), "metrics": current}
        with open(args.baseline, "w") as f:
            json.dump(trimmed, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"[compare] baseline refreshed: {len(current)} metrics "
              f"-> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"[compare] FAIL: no baseline at {args.baseline} — seed one "
              "with --update-baseline")
        return 1
    with open(args.baseline) as f:
        base_doc = json.load(f)
    baseline = extract_metrics(base_doc)

    failures = compare(baseline, current, args.tolerance,
                       scenario_tolerances)
    new = sorted(set(current) - set(baseline))
    if new:
        print(f"[compare] {len(new)} metric(s) not in baseline (not gated; "
              "run --update-baseline to adopt):")
        for path in new[:10]:
            print(f"  + {path} = {current[path]:.2f}s")
    if failures:
        print(f"[compare] FAIL: {len(failures)} regression(s) vs "
              f"{args.baseline} (tolerance ±{args.tolerance:.0%}):")
        for failure in failures:
            print(f"  ! {failure}")
        meta = base_doc.get("meta", {})
        if meta:
            print(f"[compare] baseline provenance: {json.dumps(meta)}")
        return 1
    print(f"[compare] OK: {len(baseline)} metric(s) within "
          f"±{args.tolerance:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
