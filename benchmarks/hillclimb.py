"""§Perf hillclimb driver: for each selected cell, compile baseline and
candidate variants, record the roofline-relevant deltas.

    PYTHONPATH=src python -m benchmarks.hillclimb --cell nemo_prefill
"""
import argparse
import json
import os

# the dry-run flag must be set before jax init — import dryrun first.
from repro.launch import dryrun as dr  # noqa: E402  (sets XLA_FLAGS)

CELLS = {
    # memory-dominated, paper-representative (MoE): microbatch accumulation
    "llama4_train": [
        ("llama4-scout-17b-a16e", "train_4k", dict(variant="baseline", analysis=False)),
        ("llama4-scout-17b-a16e", "train_4k", dict(variant="baseline_mb8", analysis=False)),
        ("llama4-scout-17b-a16e", "train_4k", dict(variant="baseline_mb16", analysis=False)),
    ],
    # iteration 2+3: expert FSDP (2D expert sharding) × microbatching.
    # NOTE: run after the DEFAULT_RULES expert_in="data" change; the
    # "baseline" files above were captured with model-only expert sharding.
    "llama4_train_opt": [
        ("llama4-scout-17b-a16e", "train_4k", dict(variant="expert_fsdp", analysis=False)),
        ("llama4-scout-17b-a16e", "train_4k", dict(variant="expert_fsdp_mb8", analysis=False)),
        ("llama4-scout-17b-a16e", "train_4k", dict(variant="expert_fsdp_mb16", analysis=False)),
    ],
    # most collective-bound dense cell: pure-TP inference resharding
    "nemo_prefill": [
        ("mistral-nemo-12b", "prefill_32k", dict(variant="baseline")),
        ("mistral-nemo-12b", "prefill_32k", dict(variant="infer_tp")),
    ],
    # worst memory posture: int8 KV cache (+ pure-TP params)
    "musicgen_decode": [
        ("musicgen-large", "decode_32k", dict(variant="baseline")),
        ("musicgen-large", "decode_32k", dict(variant="kv_int8")),
        ("musicgen-large", "decode_32k", dict(variant="infer_tp+kv_int8")),
    ],
    # qwen3 microbatch ladder (methodology cross-check, cheap)
    "qwen3_train_mb": [
        ("qwen3-1.7b", "train_4k", dict(variant="baseline", analysis=False)),
        ("qwen3-1.7b", "train_4k", dict(variant="baseline_mb4", analysis=False)),
        ("qwen3-1.7b", "train_4k", dict(variant="baseline_mb8", analysis=False)),
    ],
}


def run(cell: str, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    for arch, shape, kw in CELLS[cell]:
        variant = kw.pop("variant")
        mb = 1
        if "_mb" in variant:
            mb = int(variant.rsplit("_mb", 1)[1])
        tag = f"{arch}__{shape}__{variant}"
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag}")
            continue
        print(f"[hillclimb] {tag}", flush=True)
        mesh = None
        if "mesh_shape" in kw:
            from repro.launch.mesh import make_mesh

            d, m = kw.pop("mesh_shape")
            mesh = make_mesh((d, m), ("data", "model"))
        rep = dr.run_cell(
            arch, shape, multi_pod=False,
            variant=variant.split("_mb")[0],
            microbatches=mb, mesh=mesh,
            **kw,
        )
        with open(path, "w") as f:
            json.dump(rep, f, indent=1)
        print(
            f"  mem/dev={rep.get('per_device_bytes', -1)/2**30:.2f}GiB "
            f"coll/dev={rep.get('collectives_per_device_bytes', rep.get('collectives_per_device_bytes_rolled'))['total']/2**30:.3f}GiB",
            flush=True,
        )


CELLS["final_iters"] = [
    # nemo prefill: TP16->TP8 mesh reshape (tokens per TP group halve ->
    # per-device AR traffic halves; kv=8 and 32 q-heads divide evenly: no
    # head padding)
    ("mistral-nemo-12b", "prefill_32k",
     dict(variant="infer_tp+last_only+tp8", mesh_shape=(32, 8))),
    # llama4: push microbatching one more step
    ("llama4-scout-17b-a16e", "train_4k",
     dict(variant="expert_fsdp_mb32", analysis=False)),
]

CELLS["nemo_prefill_opt"] = [
    ("mistral-nemo-12b", "prefill_32k", dict(variant="infer_tp+last_only")),
]

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=list(CELLS) + ["all"])
    ap.add_argument("--out", default="reports/hillclimb")
    a = ap.parse_args()
    for c in (CELLS if a.cell == "all" else [a.cell]):
        run(c, a.out)

