#!/usr/bin/env sh
# Tier-1 verification (see ROADMAP.md): the invariant lint pass, then the
# full test suite, fail-fast.  Pass-through args reach pytest, so CI and
# local runs share one entry point:
#   scripts/test.sh -k online       scripts/test.sh tests/test_api.py
cd "$(dirname "$0")/.." || exit 1
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis --lint-only || exit 1
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
exit $?
