#!/usr/bin/env sh
# Tier-1 verification (see ROADMAP.md): the full test suite, fail-fast.
# Usage: scripts/test.sh [extra pytest args]
set -e
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
