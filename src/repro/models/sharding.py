"""Logical-axis sharding (MaxText-style).

Model code annotates arrays with *logical* axis names; a rules table maps
logical names to physical mesh axes.  Outside any mesh context the
annotations are no-ops, so the same model code runs single-device smoke
tests and 512-device dry-runs unchanged.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["axis_rules", "shard", "spec_for", "DEFAULT_RULES", "SP_RULES"]

#: logical-name → physical mesh axis (or tuple of axes, or None).
#: Baseline layout: DP over (pod, data); TP/EP over model; FSDP-style
#: parameter sharding over data.
DEFAULT_RULES: Dict[str, Union[None, str, Tuple[str, ...]]] = {
    # --- activations ---
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ffn": "model",
    "act_vocab": "model",
    "act_exp": "model",
    # --- parameters ---
    "vocab": "model",
    "embed": "data",  # fsdp
    "heads": "model",
    "kv_heads": "model",
    "qkv_fsdp": "data",
    "ffn": "model",
    "ffn_fsdp": "data",
    "experts": "model",
    # experts are 2D-sharded: EP over 'model' AND FSDP over 'data' on the
    # d_model dim — without the data axis, a 16-expert Llama-4-Scout layer
    # leaves ~6.4B params (×18 B/param of f32 master+m+v+grad+bf16 cast)
    # on every device (§Perf hillclimb C).  XLA all-gathers the local
    # expert shard at the shard_map boundary per layer (standard FSDP).
    "expert_in": "data",
    "expert_out": None,
    "ssm_inner": "model",
    "ssm_fsdp": "data",
    "ssm_state": None,
}

#: Sequence-parallel variant: long-prefill shapes shard the sequence
#: dimension over the data axis (batch is then replicated or pod-sharded).
SP_RULES = dict(DEFAULT_RULES, act_seq="data", act_batch="pod")

#: Inference variant (§Perf hillclimb): no optimizer state exists, so
#: FSDP-sharding parameters over 'data' only buys per-layer all-gathers.
#: Replicate params across 'data' (pure TP over 'model') — the per-layer
#: parameter all-gather traffic drops to zero.
INFERENCE_RULES = dict(
    DEFAULT_RULES,
    embed=None, qkv_fsdp=None, ffn_fsdp=None, ssm_fsdp=None,
)

_ctx = threading.local()


def _current() -> Tuple[Optional[Mesh], Dict]:
    return getattr(_ctx, "mesh", None), getattr(_ctx, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    """Activate a mesh + logical-rules table for model code in scope."""
    prev = _current()
    _ctx.mesh = mesh
    _ctx.rules = dict(rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def spec_for(*names: Optional[str]) -> P:
    """PartitionSpec for a sequence of logical axis names (None = replicated)."""
    _, rules = _current()
    axes = []
    used = set()
    for n in names:
        ax = rules.get(n) if n else None
        # an axis may appear at most once in a spec
        if ax is None:
            axes.append(None)
            continue
        flat = (ax,) if isinstance(ax, str) else tuple(ax)
        flat = tuple(a for a in flat if a not in used)
        used.update(flat)
        if not flat:
            axes.append(None)
        elif len(flat) == 1:
            axes.append(flat[0])
        else:
            axes.append(flat)
    return P(*axes)


def shard(x, *names: Optional[str]):
    """Annotate ``x`` with logical axes; no-op outside a mesh context or for
    mesh axes that don't exist on the active mesh."""
    mesh, rules = _current()
    if mesh is None:
        return x
    axes = []
    used = set()
    for n in names:
        ax = rules.get(n) if n else None
        flat = () if ax is None else ((ax,) if isinstance(ax, str) else tuple(ax))
        flat = tuple(a for a in flat if a in mesh.axis_names and a not in used)
        used.update(flat)
        axes.append(None if not flat else (flat[0] if len(flat) == 1 else flat))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*axes))
    )
