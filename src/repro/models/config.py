"""Architecture configuration schema.

Every assigned architecture is an :class:`ArchConfig`; a config fully
determines the model graph (block pattern, mixer kinds, FFN kinds, norms,
positional scheme).  ``reduced()`` derives the small same-family config used
by the CPU smoke tests; the full configs are exercised only through the
dry-run (``ShapeDtypeStruct``, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ArchConfig", "Block"]


@dataclasses.dataclass(frozen=True)
class Block:
    """One block of the repeating layer pattern.

    mixer: 'attn' | 'ssm' | 'rglru'
    ffn:   'dense' | 'moe' | 'none'   ('none': the mixer is the whole block,
           as in Mamba)
    rope:  apply rotary embedding (attn mixers only; False = NoPE)
    window: sliding-attention window (None = full causal)
    """

    mixer: str = "attn"
    ffn: str = "dense"
    rope: bool = True
    window: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    #: repeating block pattern; (n_layers - len(tail)) must divide evenly.
    pattern: Tuple[Block, ...] = (Block(),)
    #: extra blocks after the scanned groups (unrolled) — lets depths that
    #: are not multiples of the pattern stay faithful (RecurrentGemma: 38 =
    #: 12×(rg, rg, attn) + (rg, rg)).
    tail: Tuple[Block, ...] = ()
    head_dim: Optional[int] = None  # default d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0  # per-expert hidden dim (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # --- SSM (mamba-1) ---
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0  # default ceil(d_model/16)
    # --- RG-LRU ---
    rglru_expand: int = 1
    # --- norms / activations / positions ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparam_ln
    qk_norm: bool = False
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- modality frontend stub ---
    #: None: token ids in.  'embed': the frontend is a stub — inputs are
    #: precomputed patch/frame embeddings of size (B, T, d_model).
    frontend: Optional[str] = None
    #: real vocab size when ``vocab`` has been padded for TP divisibility
    #: (padded logit rows are masked to -inf in forward — exact semantics).
    vocab_real: Optional[int] = None
    #: does the paper's technique apply inside the model (MoE dispatch)?
    geo_plannable: bool = False
    #: long_500k support: sub-quadratic sequence mixing available?
    subquadratic: bool = False

    def __post_init__(self):
        assert (self.n_layers - len(self.tail)) % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers minus {len(self.tail)} tail "
            f"not divisible by pattern of {len(self.pattern)}"
        )
        if self.n_heads:
            assert self.n_heads % max(self.n_kv_heads, 1) == 0

    # -- derived quantities -------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_groups(self) -> int:
        return (self.n_layers - len(self.tail)) // len(self.pattern)

    @property
    def expert_d_ff_(self) -> int:
        return self.expert_d_ff or self.d_ff

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_dt_rank_(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def rglru_width(self) -> int:
        return self.rglru_expand * self.d_model

    def n_params(self) -> int:
        """Total parameter count (used for 6·N·D roofline MODEL_FLOPS)."""
        return sum(_param_counts(self).values())

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE counts top_k experts only)."""
        counts = _param_counts(self)
        total = sum(counts.values())
        if self.n_experts:
            moe = counts["moe_experts"]
            total -= moe
            total += moe * self.top_k / self.n_experts
        return int(total)

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        pat = self.pattern
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * len(pat) + len(self.tail),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, 4 * self.n_kv_heads // max(self.n_heads, 1)),
            head_dim=16,
            d_ff=128,
            expert_d_ff=32 if self.n_experts else 0,
            vocab=256,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=8,
            ssm_dt_rank=8,
            pattern=tuple(
                dataclasses.replace(b, window=min(b.window, 32) if b.window else None)
                for b in pat
            ),
            tail=tuple(
                dataclasses.replace(b, window=min(b.window, 32) if b.window else None)
                for b in self.tail
            ),
        )


def _param_counts(cfg: ArchConfig) -> dict:
    """Per-component parameter counts (exact for the graphs built in
    models/model.py, excluding biases/norm scales which are negligible)."""
    d, hd = cfg.d_model, cfg.head_dim_
    counts = {"embed": cfg.vocab * d}
    if not cfg.tie_embeddings:
        counts["unembed"] = cfg.vocab * d
    attn = mamba = rglru = dense_ffn = moe_experts = moe_router = 0
    blocks = [(b, cfg.n_groups) for b in cfg.pattern] + [(b, 1) for b in cfg.tail]
    for blk, reps in blocks:
        if blk.mixer == "attn":
            attn += reps * (
                d * cfg.n_heads * hd  # wq
                + 2 * d * cfg.n_kv_heads * hd  # wk, wv
                + cfg.n_heads * hd * d  # wo
            )
        elif blk.mixer == "ssm":
            di, ds, dtr = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_dt_rank_
            mamba += reps * (
                d * 2 * di  # in_proj (x and gate)
                + di * cfg.ssm_conv  # conv
                + di * (dtr + 2 * ds)  # x_proj
                + dtr * di  # dt_proj
                + di * ds  # A
                + di  # D
                + di * d  # out_proj
            )
        elif blk.mixer == "rglru":
            w = cfg.rglru_width
            rglru += reps * (
                2 * d * w  # in_proj (x and gate branches)
                + w * 4  # conv1d (k=4)
                + 2 * w  # recurrence + input gates (diagonal)
                + w * d  # out_proj
            )
        if blk.ffn == "dense":
            dense_ffn += reps * 3 * d * cfg.d_ff  # gate, up, down
        elif blk.ffn == "moe":
            moe_experts += reps * cfg.n_experts * 3 * d * cfg.expert_d_ff_
            moe_router += reps * d * cfg.n_experts
    counts.update(
        attn=attn, mamba=mamba, rglru=rglru, dense_ffn=dense_ffn,
        moe_experts=moe_experts, moe_router=moe_router,
    )
    return counts
