"""Decoder LM assembled from the layer zoo, with scan-over-layer-groups.

The repeating unit is the config's block *pattern* (e.g. RecurrentGemma's
(rglru, rglru, local-attn), Llama-4's (3×local-RoPE, 1×global-NoPE));
parameters for all ``n_groups`` repetitions are stacked on a leading axis
and the stack is traversed with ``lax.scan`` — compile time and HLO size
are independent of depth, which is what makes the 512-device dry-runs of
48-layer models tractable.

Public entry points (all pure functions):

* ``init(cfg, key, tp)``                          → params
* ``forward(cfg, params, batch, ...)``            → logits, aux
* ``loss_fn(cfg, params, batch, ...)``            → scalar, metrics
* ``init_cache(cfg, params, batch, max_len)``     → cache
* ``prefill(cfg, params, batch, max_len, ...)``   → logits, cache
* ``decode_step(cfg, params, tokens, cache, ...)``→ logits, cache
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import ArchConfig, Block
from .sharding import shard

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(cfg: ArchConfig, blk: Block, key, tp: int) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(cfg, ks[0])}
    if blk.mixer == "attn":
        p["mixer"] = L.init_attention(cfg, ks[1])
    elif blk.mixer == "ssm":
        p["mixer"] = L.init_mamba(cfg, ks[1])
    elif blk.mixer == "rglru":
        p["mixer"] = L.init_rglru(cfg, ks[1])
    else:
        raise ValueError(blk.mixer)
    if blk.ffn != "none":
        p["norm2"] = L.init_norm(cfg, ks[2])
        if blk.ffn == "dense":
            p["ffn"] = L.init_mlp(cfg, ks[3])
        elif blk.ffn == "moe":
            p["ffn"] = L.init_moe(cfg, ks[3], tp=tp)
        else:
            raise ValueError(blk.ffn)
    return p


def init(cfg: ArchConfig, key, tp: int = 1) -> Params:
    """Initialize parameters.  ``tp`` — tensor-parallel degree used for
    expert-count padding (head padding is a config-load concern)."""
    k_embed, k_groups, k_out, k_norm = jax.random.split(key, 4)

    def init_group(gkey):
        bkeys = jax.random.split(gkey, len(cfg.pattern))
        return {
            f"blk{i}": _init_block(cfg, blk, bkeys[i], tp)
            for i, blk in enumerate(cfg.pattern)
        }

    params: Params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02),
        "groups": jax.vmap(init_group)(jax.random.split(k_groups, cfg.n_groups)),
        "final_norm": L.init_norm(cfg, k_norm),
    }
    if cfg.tail:
        tkeys = jax.random.split(jax.random.fold_in(key, 99), len(cfg.tail))
        params["tail"] = {
            f"blk{i}": _init_block(cfg, blk, tkeys[i], tp)
            for i, blk in enumerate(cfg.tail)
        }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(k_out, (cfg.vocab, cfg.d_model))
            / np.sqrt(cfg.d_model)
        )
    return params


#: leaf-name → logical axes for the value *without* the group-stack axis.
#: Group-stacked leaves (everything under ``groups/``) get a leading None.
_PARAM_RULES = {
    "embed": ("vocab", "embed"),
    "unembed": ("vocab", "embed"),
    "wq": ("qkv_fsdp", "heads", None),
    "wk": ("qkv_fsdp", "kv_heads", None),
    "wv": ("qkv_fsdp", "kv_heads", None),
    "wo": ("heads", None, "qkv_fsdp"),
    "router": (None, None),
    "plan_bias": (None,),
    "plan_capacity": ("experts",),
    "in_proj": ("ssm_fsdp", "ssm_inner"),
    "conv": (None, "ssm_inner"),
    "x_proj": ("ssm_inner", None),
    "dt_proj": (None, "ssm_inner"),
    "dt_bias": ("ssm_inner",),
    "A_log": ("ssm_inner", None),
    "D": ("ssm_inner",),
    "out_proj": ("ssm_inner", "ssm_fsdp"),
    "in_x": ("ssm_fsdp", "ssm_inner"),
    "in_gate": ("ssm_fsdp", "ssm_inner"),
    "a_gate_w": ("ssm_inner",),
    "a_gate_b": ("ssm_inner",),
    "x_gate_w": ("ssm_inner",),
}


def param_shardings(cfg: ArchConfig, params_shape: Params):
    """Logical PartitionSpec pytree for the parameter tree (FSDP over
    'data', TP/EP over 'model', experts over 'model')."""
    from .sharding import spec_for

    def path_str(kp):
        return "/".join(
            str(getattr(k, "key", getattr(k, "idx", "?"))) for k in kp
        )

    def spec(kp, leaf):
        path = path_str(kp)
        name = path.split("/")[-1]
        stacked = path.startswith("groups")
        nd = leaf.ndim - (1 if stacked else 0)
        if name in ("w_gate", "w_up", "w_down"):
            if nd == 3:  # MoE experts: (E, d, f)
                names = ("experts", "expert_in", "expert_out")
            elif name == "w_down":
                names = ("ffn", "ffn_fsdp")
            else:
                names = ("ffn_fsdp", "ffn")
        elif name in _PARAM_RULES:
            names = _PARAM_RULES[name]
        else:  # norm scales/biases etc.
            names = (None,) * nd
        if len(names) != nd:  # defensive: replicate anything unexpected
            names = (None,) * nd
        if stacked:
            names = (None,) + tuple(names)
        return spec_for(*names)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _block_fwd(
    cfg, blk: Block, p: Params, x, positions, cache, mode,
    mesh, use_kernels, max_cache_len,
):
    h = L.apply_norm(cfg, p["norm1"], x)
    aux = jnp.float32(0.0)
    if blk.mixer == "attn":
        y, new_cache = L.attention_fwd(
            cfg, blk, p["mixer"], h, positions, cache=cache,
            use_kernel=use_kernels, mode=mode, max_cache_len=max_cache_len,
        )
    elif blk.mixer == "ssm":
        if mode == "prefill" and cache is None:
            B = x.shape[0]
            cache = _ssm_zero_state(cfg, B, x.dtype)
        y, new_cache = L.mamba_fwd(
            cfg, p["mixer"], h, state=cache if mode != "train" else None,
            use_kernel=use_kernels,
        )
    elif blk.mixer == "rglru":
        if mode == "prefill" and cache is None:
            B = x.shape[0]
            cache = _rglru_zero_state(cfg, B, x.dtype)
        y, new_cache = L.rglru_fwd(
            cfg, p["mixer"], h, state=cache if mode != "train" else None,
            use_kernel=use_kernels,
        )
    else:
        raise ValueError(blk.mixer)
    x = x + y
    if blk.ffn != "none":
        h = L.apply_norm(cfg, p["norm2"], x)
        if blk.ffn == "dense":
            y = L.mlp_fwd(cfg, p["ffn"], h)
        else:
            y, aux = L.moe_fwd(cfg, p["ffn"], h, mesh=mesh)
        x = x + y
    return x, new_cache, aux


def _ssm_zero_state(cfg, B, dtype):
    return {
        "h": jnp.zeros((B, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.ssm_d_inner), dtype),
    }


def _rglru_zero_state(cfg, B, dtype):
    return {
        "h": jnp.zeros((B, cfg.rglru_width), jnp.float32),
        "conv": jnp.zeros((B, 3, cfg.rglru_width), dtype),
    }


def _attn_zero_cache(cfg, B, max_len, dtype):
    if dtype == jnp.int8:  # quantized cache (§Perf): int8 values + scales
        return {
            "k": jnp.zeros((B, cfg.n_kv_heads, max_len, cfg.head_dim_), jnp.int8),
            "v": jnp.zeros((B, cfg.n_kv_heads, max_len, cfg.head_dim_), jnp.int8),
            "k_scale": jnp.zeros((B, cfg.n_kv_heads, max_len, 1), jnp.float32),
            "v_scale": jnp.zeros((B, cfg.n_kv_heads, max_len, 1), jnp.float32),
        }
    return {
        "k": jnp.zeros((B, cfg.n_kv_heads, max_len, cfg.head_dim_), dtype),
        "v": jnp.zeros((B, cfg.n_kv_heads, max_len, cfg.head_dim_), dtype),
    }


def init_cache(cfg: ArchConfig, B: int, max_len: int, dtype=jnp.bfloat16,
               kv_int8: bool = False):
    """Zeroed decode cache for the whole stack (stacked over groups).

    Windowed-attention blocks still allocate ``max_len`` (correct, not
    minimal: a ring buffer of ``window`` is the memory-optimal layout and is
    tracked as a §Perf lever)."""
    out = {}
    kv_dtype = jnp.int8 if kv_int8 else dtype
    for i, blk in enumerate(cfg.pattern):
        if blk.mixer == "attn":
            one = _attn_zero_cache(cfg, B, max_len, kv_dtype)
        elif blk.mixer == "ssm":
            one = _ssm_zero_state(cfg, B, dtype)
        else:
            one = _rglru_zero_state(cfg, B, dtype)
        out[f"blk{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_groups,) + x.shape), one
        )
    if cfg.tail:
        tail = {}
        for i, blk in enumerate(cfg.tail):
            if blk.mixer == "attn":
                tail[f"blk{i}"] = _attn_zero_cache(cfg, B, max_len, kv_dtype)
            elif blk.mixer == "ssm":
                tail[f"blk{i}"] = _ssm_zero_state(cfg, B, dtype)
            else:
                tail[f"blk{i}"] = _rglru_zero_state(cfg, B, dtype)
        out["tail"] = tail
    return out


def forward(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jnp.ndarray],
    mode: str = "train",
    cache=None,
    mesh=None,
    use_kernels: bool = False,
    compute_dtype=jnp.float32,
    remat: bool = False,
    max_cache_len: Optional[int] = None,
    logits_dtype=jnp.float32,
    unroll_groups: bool = False,
    last_only: bool = False,
):
    """Run the stack.  ``batch`` carries ``tokens`` (B, T) int32 or — for
    stub-frontend archs — ``embeds`` (B, T, d).  Returns (logits, cache,
    aux_loss).

    ``unroll_groups`` unrolls the layer-group scan — used by the dry-run's
    *analysis build* so ``cost_analysis()``/collective parsing see every
    layer (XLA counts while-loop bodies once; see EXPERIMENTS.md §Dry-run).
    """
    if cfg.frontend == "embed" and "embeds" in batch:
        x = batch["embeds"].astype(compute_dtype)
    else:
        tokens = batch["tokens"]
        x = params["embed"].astype(compute_dtype)[tokens]
    x = shard(x, "act_batch", "act_seq", "act_embed")
    B, T = x.shape[:2]

    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(T)[None, :]
    positions = jnp.broadcast_to(positions, (B, T))

    cast_params = jax.tree.map(lambda a: a.astype(compute_dtype)
                               if a.dtype == jnp.float32 else a, params["groups"])

    def group_fwd(x, gp, gcache):
        new_caches = {}
        aux_total = jnp.float32(0.0)
        for i, blk in enumerate(cfg.pattern):
            c = None if gcache is None else gcache.get(f"blk{i}")
            x, nc, aux = _block_fwd(
                cfg, blk, gp[f"blk{i}"], x, positions, c, mode,
                mesh, use_kernels, max_cache_len,
            )
            if nc is not None:
                new_caches[f"blk{i}"] = nc
            aux_total = aux_total + aux
        return x, new_caches, aux_total

    if remat:
        # nothing_saveable: the scan saves only the per-group carry (the
        # bf16 residual stream); each group's internals — including the
        # O(T·S) attention logits of the chunked double-scan — are
        # recomputed in the backward pass.  (dots_*_saveable policies would
        # stack those logits across scan steps: ~30 GiB/device at 4k×256.)
        group_fwd = jax.checkpoint(
            group_fwd, policy=jax.checkpoint_policies.nothing_saveable
        )

    if mode == "train":
        def body(carry, gp):
            x, aux = carry
            x, _, aux_g = group_fwd(x, gp, None)
            return (x, aux + aux_g), None

        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.float32(0.0)), cast_params, unroll=unroll_groups
        )
        new_cache = None
    elif mode == "prefill":
        def body(carry, gp):
            x, aux = carry
            x, ncache, aux_g = group_fwd(x, gp, None)
            return (x, aux + aux_g), ncache

        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.float32(0.0)), cast_params, unroll=unroll_groups
        )
    else:  # decode
        def body(carry, xs):
            x, aux = carry
            gp, gcache = xs
            x, ncache, aux_g = group_fwd(x, gp, gcache)
            return (x, aux + aux_g), ncache

        scan_cache = {k: v for k, v in cache.items() if k != "tail"}
        (x, aux), new_cache = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (cast_params, scan_cache),
            unroll=unroll_groups,
        )

    if cfg.tail:
        tail_params = jax.tree.map(
            lambda a: a.astype(compute_dtype) if a.dtype == jnp.float32 else a,
            params["tail"],
        )
        tail_new = {}
        for i, blk in enumerate(cfg.tail):
            c = None
            if mode == "decode" and cache is not None:
                c = cache.get("tail", {}).get(f"blk{i}")
            x, nc, aux_t = _block_fwd(
                cfg, blk, tail_params[f"blk{i}"], x, positions, c, mode,
                mesh, use_kernels, max_cache_len,
            )
            if nc is not None:
                tail_new[f"blk{i}"] = nc
            aux = aux + aux_t
        if new_cache is not None and tail_new:
            new_cache = dict(new_cache, tail=tail_new)

    if last_only:
        # serving prefill: only the last position's logits are consumed —
        # skipping the (B, T, V) unembed removes ~2·B·T·d·V FLOPs and the
        # associated cross-shard reduction (§Perf hillclimb B).
        x = x[:, -1:]
    x = L.apply_norm(cfg, params["final_norm"], x)
    w_out = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum(
        "btd,vd->btv", x.astype(compute_dtype), w_out.astype(compute_dtype)
    ).astype(logits_dtype)
    if cfg.vocab_real is not None and cfg.vocab_real < cfg.vocab:
        # TP-padded vocab rows must never win a softmax (exact semantics)
        vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        logits = jnp.where(vpos < cfg.vocab_real, logits, -1e9)
    logits = shard(logits, "act_batch", "act_seq", "act_vocab")
    return logits, new_cache, aux


def loss_fn(
    cfg: ArchConfig,
    params: Params,
    batch: Dict[str, jnp.ndarray],
    mesh=None,
    use_kernels: bool = False,
    compute_dtype=jnp.float32,
    remat: bool = False,
    z_loss: float = 1e-4,
    unroll_groups: bool = False,
):
    """Next-token cross entropy (+ router aux loss + z-loss).  Labels come
    from ``batch['labels']``; positions where ``labels < 0`` are masked."""
    logits, _, aux = forward(
        cfg, params, batch, mode="train", mesh=mesh,
        use_kernels=use_kernels, compute_dtype=compute_dtype, remat=remat,
        unroll_groups=unroll_groups,
    )
    labels = batch["labels"]
    valid = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel label pick: a take_along_axis over the vocab-sharded
    # logits would force an all-gathered (B, T, V) buffer per device; the
    # masked sum partitions as a local reduce + cross-shard add instead.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    ll = jnp.sum(
        jnp.where(vocab_iota == labels_safe[..., None], logits, 0.0), axis=-1
    )
    nll = (logz - ll) * valid
    denom = jnp.maximum(valid.sum(), 1.0)
    ce = nll.sum() / denom
    zl = z_loss * ((logz * valid) ** 2).sum() / denom
    total = ce + zl + cfg.router_aux_weight * aux
    return total, {"ce": ce, "z_loss": zl, "aux": aux, "tokens": denom}


def prefill(
    cfg: ArchConfig, params: Params, batch, max_cache_len: int,
    mesh=None, use_kernels: bool = False, compute_dtype=jnp.float32,
    unroll_groups: bool = False, last_only: bool = False,
):
    return forward(
        cfg, params, batch, mode="prefill", mesh=mesh,
        use_kernels=use_kernels, compute_dtype=compute_dtype,
        max_cache_len=max_cache_len, unroll_groups=unroll_groups,
        last_only=last_only,
    )


def decode_step(
    cfg: ArchConfig, params: Params, batch, cache,
    mesh=None, use_kernels: bool = False, compute_dtype=jnp.float32,
    unroll_groups: bool = False,
):
    """One decode step: batch['tokens'] (B, 1) (or (B, k) for speculative
    chunks), batch['positions'] (B, k) absolute positions."""
    return forward(
        cfg, params, batch, mode="decode", cache=cache, mesh=mesh,
        use_kernels=use_kernels, compute_dtype=compute_dtype,
        unroll_groups=unroll_groups,
    )
