"""Model layers: norms, RoPE, attention (GQA / qk-norm / sliding window /
NoPE), SwiGLU & GeGLU MLPs, expert-parallel MoE, Mamba-1 and RG-LRU blocks.

All layers are pure functions over parameter pytrees.  Distribution is
expressed with the logical-axis annotations from
:mod:`repro.models.sharding`; the MoE FFN additionally uses ``shard_map``
for deterministic expert parallelism (see ``moe_fwd``).

Attention picks one of three evaluation strategies:

* ``ref`` dense einsum — small shapes (smoke tests, decode steps);
* ``chunked`` — pure-jnp online-softmax double-scan over (q, kv) blocks.
  This is the memory-bounded path the 32k-prefill dry-runs lower
  (per-step temporaries are (B, H, bq, bk), never (B, H, T, S));
* ``kernel`` — the Pallas flash kernel (TPU execution path).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from ..kernels import ref as kref
from .config import ArchConfig, Block
from .sharding import shard

Params = Dict[str, Any]

_INIT_SCALE = 1.0


def _dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    std = _INIT_SCALE / np.sqrt(in_axis_size)
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, key) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,))}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,)), "bias": jnp.zeros((cfg.d_model,))}
    if cfg.norm == "nonparam_ln":  # OLMo: LN without learnable params
        return {}
    raise ValueError(cfg.norm)


def apply_norm(cfg: ArchConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
        return (xf / rms * p["scale"]).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) / jnp.sqrt(var + 1e-5)
    if cfg.norm == "layernorm":
        out = out * p["scale"] + p["bias"]
    return out.astype(x.dtype)


def _rms_headwise(x: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    rms = jnp.sqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + 1e-6)
    return (xf / rms * scale).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embedding
# ---------------------------------------------------------------------------

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, H, T, Dh); positions: (B, T) or (T,)."""
    Dh = x.shape[-1]
    half = Dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,T,half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key) -> Params:
    d, H, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, Dh), d),
        "wk": _dense_init(ks[1], (d, Hkv, Dh), d),
        "wv": _dense_init(ks[2], (d, Hkv, Dh), d),
        "wo": _dense_init(ks[3], (H, Dh, d), H * Dh),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((Dh,))
        p["k_scale"] = jnp.ones((Dh,))
    return p


def chunked_attention(
    q, k, v, causal: bool, window: Optional[int], q_offset: int,
    block_q: int = 512, block_k: int = 512,
) -> jnp.ndarray:
    """Online-softmax attention in pure jnp: double lax.scan over q and kv
    blocks; temporaries are (B, H, bq, bk).  Matches kref.attention_ref."""
    B, Hq, T, Dh = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    scale = Dh**-0.5
    bq = min(block_q, T)
    bk = min(block_k, S)
    Tp, Sp = -(-T // bq) * bq, -(-S // bk) * bk
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    nq, nk = Tp // bq, Sp // bk
    qb = q.reshape(B, Hkv, group, nq, bq, Dh).astype(jnp.float32)
    kb = k.reshape(B, Hkv, nk, bk, Dh).astype(jnp.float32)
    vb = v.reshape(B, Hkv, nk, bk, Dh).astype(jnp.float32)

    def q_step(_, qi):
        qc = qb[:, :, :, qi]  # (B, Hkv, G, bq, Dh)
        q_pos = qi * bq + jnp.arange(bq)[:, None] + q_offset  # (bq, 1)

        @functools.partial(
            jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable
        )
        def kv_step(carry, ki):
            m, l, acc = carry
            kc, vc = kb[:, :, ki], vb[:, :, ki]  # (B, Hkv, bk, Dh)
            s = jnp.einsum("bkgqd,bksd->bkgqs", qc, kc) * scale
            k_pos = ki * bk + jnp.arange(bk)[None, :]  # (1, bk)
            mask = k_pos < S
            if causal:
                mask = mask & (k_pos <= q_pos)
            if window is not None:
                mask = mask & (k_pos > q_pos - window)
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            alpha = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            p = jnp.exp(s - m_safe)
            p = jnp.where(mask[None, None, None], p, 0.0)
            l_new = alpha * l + p.sum(axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bkgqs,bksd->bkgqd", p, vc)
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, Hkv, group, bq, 1), -jnp.inf),
            jnp.zeros((B, Hkv, group, bq, 1)),
            jnp.zeros((B, Hkv, group, bq, Dh)),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.where(l == 0.0, 1.0, l)
        return None, out

    # checkpoint at both scan levels: the backward pass recomputes each
    # (q, kv) tile's logits instead of stacking (nq, nk, ..., bq, bk) f32
    # score tensors — the flash-attention recompute strategy, in jnp.
    q_step = jax.checkpoint(q_step, policy=jax.checkpoint_policies.nothing_saveable)
    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq, B, Hkv, G, bq, Dh)
    out = jnp.moveaxis(outs, 0, 3).reshape(B, Hkv, group, Tp, Dh)
    out = out.reshape(B, Hq, Tp, Dh)[:, :, :T]
    return out.astype(q.dtype)


#: attention strategy thresholds (elements of the dense logits tensor)
_DENSE_LOGITS_LIMIT = 1 << 27  # ~134M f32 logits = 512 MB


def attention_fwd(
    cfg: ArchConfig,
    blk: Block,
    p: Params,
    x: jnp.ndarray,  # (B, T, d)
    positions: jnp.ndarray,  # (B, T)
    cache: Optional[Dict] = None,
    use_kernel: bool = False,
    mode: str = "train",  # train | prefill | decode
    max_cache_len: Optional[int] = None,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, T, d = x.shape
    q = jnp.einsum("btd,dhk->bhtk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bhtk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bhtk", x, p["wv"])
    q = shard(q, "act_batch", "act_heads", "act_seq", None)
    k = shard(k, "act_batch", "act_kv_heads", "act_seq", None)
    v = shard(v, "act_batch", "act_kv_heads", "act_seq", None)
    if cfg.qk_norm:
        q = _rms_headwise(q, p["q_scale"])
        k = _rms_headwise(k, p["k_scale"])
    if blk.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if mode == "decode":
        # per-row write positions: each batch row (serving slot) may sit at
        # a different absolute position — required for continuous batching.
        assert cache is not None
        quantized = "k_scale" in cache
        Hkv = k.shape[1]
        b_idx = jnp.arange(B)[:, None, None]
        h_idx = jnp.arange(Hkv)[None, :, None]
        pos_idx = positions[:, None, :]  # (B, 1, T)
        if quantized:
            # int8 KV cache (§Perf): halves the per-token cache sweep.
            kq, ks = _quant_kv(k)
            vq, vs = _quant_kv(v)
            k_all = cache["k"].at[b_idx, h_idx, pos_idx].set(kq)
            v_all = cache["v"].at[b_idx, h_idx, pos_idx].set(vq)
            ks_all = cache["k_scale"].at[b_idx, h_idx, pos_idx].set(ks)
            vs_all = cache["v_scale"].at[b_idx, h_idx, pos_idx].set(vs)
            new_cache = {"k": k_all, "v": v_all,
                         "k_scale": ks_all, "v_scale": vs_all}
            k = _dequant_kv(k_all, ks_all, x.dtype)
            v = _dequant_kv(v_all, vs_all, x.dtype)
        else:
            k_all = cache["k"].at[b_idx, h_idx, pos_idx].set(k)
            v_all = cache["v"].at[b_idx, h_idx, pos_idx].set(v)
            new_cache = {"k": k_all, "v": v_all}
            k, v = k_all, v_all
    elif mode == "prefill":
        S_max = max_cache_len or T
        pad = S_max - T
        kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else k
        vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else v
        new_cache = {"k": kc, "v": vc}

    S = k.shape[2]
    dense_cost = B * cfg.n_heads * T * S
    if mode == "decode":
        # decode path: T is tiny; dense einsum over the cache, masked by
        # each row's absolute positions (traced).
        out = _decode_attention(q, k, v, positions, blk.window)
    elif use_kernel:
        out = kops.attention(
            q, k, v, causal=True, window=blk.window, q_offset=0
        )
    elif dense_cost <= _DENSE_LOGITS_LIMIT:
        out = kref.attention_ref(q, k, v, causal=True, window=blk.window)
    else:
        out = chunked_attention(q, k, v, True, blk.window, 0)
    out = shard(out, "act_batch", "act_heads", "act_seq", None)
    y = jnp.einsum("bhtk,hkd->btd", out, p["wo"])
    return shard(y, "act_batch", "act_seq", "act_embed"), new_cache


def _quant_kv(x: jnp.ndarray):
    """Per-(row, head, position) int8 quantization over the head dim."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def _dequant_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _decode_attention(q, k, v, positions, window):
    """Dense attention against a (zero-padded) cache; ``positions`` (B, T)
    are the traced absolute positions of the queries (per serving slot)."""
    B, Hq, Tq, Dh = q.shape
    _, Hkv, S, _ = k.shape
    group = Hq // Hkv
    scale = Dh**-0.5
    qg = q.reshape(B, Hkv, group, Tq, Dh).astype(jnp.float32)
    s = jnp.einsum("bkgtd,bksd->bkgts", qg, k.astype(jnp.float32)) * scale
    q_pos = positions[:, :, None]  # (B, T, 1)
    k_pos = jnp.arange(S)[None, None, :]
    mask = k_pos <= q_pos  # (B, T, S)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bksd->bkgtd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, Tq, Dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f), d),
        "w_up": _dense_init(ks[1], (d, f), d),
        "w_down": _dense_init(ks[2], (f, d), f),
    }


def _act(cfg: ArchConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def mlp_fwd(cfg: ArchConfig, p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("btd,df->btf", x, p["w_gate"])
    u = jnp.einsum("btd,df->btf", x, p["w_up"])
    h = shard(_act(cfg, g) * u, "act_batch", "act_seq", "act_ffn")
    y = jnp.einsum("btf,fd->btd", h, p["w_down"])
    return shard(y, "act_batch", "act_seq", "act_embed")


# ---------------------------------------------------------------------------
# MoE (expert-parallel, capacity-dropped, optionally geo-planned)
# ---------------------------------------------------------------------------

def _pad_experts(cfg: ArchConfig, tp: int) -> int:
    """Experts padded up to a multiple of the TP degree (zero router mass)."""
    E = cfg.n_experts
    return -(-E // tp) * tp


def init_moe(cfg: ArchConfig, key, tp: int = 1) -> Params:
    d, f = cfg.d_model, cfg.expert_d_ff_
    Ep = _pad_experts(cfg, tp)
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, Ep), d),
        "w_gate": _dense_init(ks[1], (Ep, d, f), d),
        "w_up": _dense_init(ks[2], (Ep, d, f), d),
        "w_down": _dense_init(ks[3], (Ep, f, d), f),
        # planned per-expert capacity fractions / router bias (repro.core.
        # moe_plan): identity by default, loaded by the launcher when a
        # dispatch plan is active.  Padding experts (beyond n_experts) are
        # masked with a -inf-ish bias so they never receive tokens — padding
        # is exact, only the wasted FLOPs show up in the roofline ratio.
        "plan_bias": jnp.where(jnp.arange(Ep) < cfg.n_experts, 0.0, -1e9),
        "plan_capacity": jnp.ones((Ep,)),
    }


def _moe_local(cfg: ArchConfig, p: Params, x2d: jnp.ndarray):
    """Token dispatch + expert FFN over all experts on one device.

    x2d: (N, d) tokens.  Returns (y (N, d), aux_loss).
    """
    N, d = x2d.shape
    E_here = p["w_gate"].shape[0]
    logits = x2d @ p["router"] + p["plan_bias"]
    # mask padded experts (zero-initialized plan_capacity == 1; padded
    # experts carry -inf bias set at init-load time via router masking)
    gates_all = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_gates, top_ids = jax.lax.top_k(gates_all, cfg.top_k)  # (N, k)
    top_gates = top_gates / jnp.maximum(
        top_gates.sum(axis=-1, keepdims=True), 1e-9
    )
    # load-balance aux loss (Switch): E * Σ_e f_e · P_e
    pe = gates_all.mean(axis=0)
    fe = jnp.zeros((E_here,)).at[top_ids.reshape(-1)].add(
        jnp.ones((N * cfg.top_k,)) / (N * cfg.top_k)
    )
    aux = E_here * jnp.sum(pe * fe)

    cap = jnp.asarray(p["plan_capacity"][:E_here])
    C = int(np.ceil(N * cfg.top_k / E_here * cfg.capacity_factor))
    C = max(C, cfg.top_k)
    flat_ids = top_ids.reshape(-1)  # (N*k,)
    flat_gates = top_gates.reshape(-1)
    tok_idx = jnp.repeat(jnp.arange(N), cfg.top_k)
    slots = kops.compute_slots(flat_ids, E_here)
    # planned capacity: expert e accepts plan_capacity[e] * C tokens
    cap_e = jnp.clip(jnp.round(cap * C), 1, None).astype(jnp.int32)
    keep = slots < cap_e[flat_ids]
    buf = jnp.zeros((E_here, C, d), x2d.dtype)
    safe_ids = jnp.where(keep, flat_ids, 0)
    safe_slots = jnp.where(keep, jnp.minimum(slots, C - 1), 0)
    buf = buf.at[safe_ids, safe_slots].add(
        jnp.where(keep[:, None], x2d[tok_idx], 0.0)
    )
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = _act(cfg, h) * u
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    back = out[safe_ids, safe_slots]
    back = jnp.where(keep[:, None], back, 0.0) * flat_gates[:, None]
    y = jnp.zeros((N, d), x2d.dtype).at[tok_idx].add(back.astype(x2d.dtype))
    return y, aux


def moe_fwd(
    cfg: ArchConfig,
    p: Params,
    x: jnp.ndarray,  # (B, T, d)
    mesh=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE.  With a mesh: shard_map over (pod, data) for
    tokens and 'model' for experts — every device dispatches its local
    tokens to its local experts and contributions are psum'd over 'model'
    (deterministic EP without all_to_all; the dispatch *plan* from
    repro.core.moe_plan reweights per-expert capacity).  Without a mesh:
    single-device dispatch over all experts."""
    B, T, d = x.shape
    if mesh is None or "model" not in mesh.axis_names:
        y2d, aux = _moe_local(cfg, p, x.reshape(B * T, d))
        return y2d.reshape(B, T, d), aux

    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map

    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local(xl, router, bias, capf, wg, wu, wd):
        Bl, Tl, _ = xl.shape
        pl = {
            "router": router, "plan_bias": bias, "plan_capacity": capf,
            "w_gate": wg, "w_up": wu, "w_down": wd,
        }
        # router over *all* experts, dispatch to the local shard only:
        # tokens whose expert lives elsewhere contribute nothing here and
        # are summed in via the psum.
        E = router.shape[1]
        El = wg.shape[0]
        shard_idx = jax.lax.axis_index("model")
        lo = shard_idx * El
        logits = (xl.reshape(Bl * Tl, d) @ router) + bias
        gates_all = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top_gates, top_ids = jax.lax.top_k(gates_all, cfg.top_k)
        top_gates = top_gates / jnp.maximum(
            top_gates.sum(axis=-1, keepdims=True), 1e-9
        )
        pe = gates_all.mean(axis=0)
        N = Bl * Tl
        fe = jnp.zeros((E,)).at[top_ids.reshape(-1)].add(
            jnp.ones((N * cfg.top_k,)) / (N * cfg.top_k)
        )
        # aggregate the load statistics over the data shards FIRST, so the
        # aux loss equals the single-device (global-batch) definition
        if batch_axes:
            pe = jax.lax.pmean(pe, batch_axes)
            fe = jax.lax.pmean(fe, batch_axes)
        aux = E * jnp.sum(pe * fe)
        # localize: expert ids relative to this shard; non-local -> dropped
        flat_ids = top_ids.reshape(-1) - lo
        local_mask = (flat_ids >= 0) & (flat_ids < El)
        flat_gates = jnp.where(local_mask, top_gates.reshape(-1), 0.0)
        flat_ids = jnp.clip(flat_ids, 0, El - 1)
        tok_idx = jnp.repeat(jnp.arange(N), cfg.top_k)
        C = int(np.ceil(N * cfg.top_k / E * cfg.capacity_factor))
        C = max(C, cfg.top_k)
        cap_e = jnp.clip(jnp.round(capf * C), 1, None).astype(jnp.int32)
        # slots computed over local assignment stream (masked entries get
        # slot C so they never land)
        ids_for_slots = jnp.where(local_mask, flat_ids, El)
        slots = kops.compute_slots(ids_for_slots, El + 1)
        keep = local_mask & (slots < cap_e[flat_ids])
        safe_slots = jnp.where(keep, jnp.minimum(slots, C - 1), 0)
        safe_ids = jnp.where(keep, flat_ids, 0)
        buf = jnp.zeros((El, C, d), xl.dtype)
        buf = buf.at[safe_ids, safe_slots].add(
            jnp.where(keep[:, None], xl.reshape(N, d)[tok_idx], 0.0)
        )
        h = _act(cfg, jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
            "ecd,edf->ecf", buf, wu
        )
        out = jnp.einsum("ecf,efd->ecd", h, wd)
        back = out[safe_ids, safe_slots]
        back = jnp.where(keep[:, None], back, 0.0) * flat_gates[:, None]
        y = jnp.zeros((N, d), xl.dtype).at[tok_idx].add(back.astype(xl.dtype))
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, "model")  # identical on every model shard
        return y.reshape(Bl, Tl, d), aux

    bspec = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    yl, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None), P(None), P("model"),
            P("model", None, None), P("model", None, None), P("model", None, None),
        ),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(
        x, p["router"], p["plan_bias"], p["plan_capacity"],
        p["w_gate"], p["w_up"], p["w_down"],
    )
    return yl, aux


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------

def init_mamba(cfg: ArchConfig, key) -> Params:
    d, di, ds, dtr = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_dt_rank_
    ks = jax.random.split(key, 6)
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di), d),
        "conv": _dense_init(ks[1], (cfg.ssm_conv, di), cfg.ssm_conv),
        "x_proj": _dense_init(ks[2], (di, dtr + 2 * ds), di),
        "dt_proj": _dense_init(ks[3], (dtr, di), dtr),
        "dt_bias": jnp.zeros((di,)) + jnp.log(jnp.expm1(0.01)),  # softplus^-1
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
        ),
        "D": jnp.ones((di,)),
        "out_proj": _dense_init(ks[5], (di, d), di),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, prev: Optional[jnp.ndarray]):
    """Depthwise causal conv along time.  x: (B, T, C); w: (K, C);
    prev: (B, K-1, C) carried context (decode) or None (zeros)."""
    B, T, C = x.shape
    K = w.shape[0]
    if prev is None:
        prev = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)  # (B, T+K-1, C)
    out = jnp.zeros((B, T, C), x.dtype)
    for i in range(K):  # K is tiny (4): unrolled taps, no conv primitive
        out = out + xp[:, i : i + T] * w[i]
    new_prev = xp[:, -(K - 1):] if K > 1 else prev
    return out, new_prev


def mamba_fwd(
    cfg: ArchConfig,
    p: Params,
    x: jnp.ndarray,  # (B, T, d)
    state: Optional[Dict] = None,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, T, d = x.shape
    di, ds, dtr = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_dt_rank_
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xz = shard(xz, "act_batch", "act_seq", "act_ffn")
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_prev = state["conv"] if state is not None else None
    xi, conv_new = _causal_conv(xi, p["conv"], conv_prev)
    xi = jax.nn.silu(xi)
    proj = jnp.einsum("bti,ie->bte", xi, p["x_proj"])
    dt, Bc, Cc = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    delta = jax.nn.softplus(jnp.einsum("btr,ri->bti", dt, p["dt_proj"]) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h0 = state["h"] if state is not None else None
    y, hT = kops.ssm_scan(xi, delta, A, Bc, Cc, p["D"], h0, use_kernel=use_kernel)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bti,id->btd", y, p["out_proj"])
    out = shard(out, "act_batch", "act_seq", "act_embed")
    new_state = {"h": hT, "conv": conv_new} if state is not None else None
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU block (RecurrentGemma recurrent block)
# ---------------------------------------------------------------------------

def init_rglru(cfg: ArchConfig, key) -> Params:
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(key, 5)
    return {
        "in_x": _dense_init(ks[0], (d, w), d),
        "in_gate": _dense_init(ks[1], (d, w), d),
        "conv": _dense_init(ks[2], (4, w), 4),
        "a_gate_w": _dense_init(ks[3], (w,), 1),  # diagonal gates (RG-LRU)
        "a_gate_b": jnp.zeros((w,)) + 2.0,  # init a ≈ sigmoid(2) ≈ .88
        "x_gate_w": _dense_init(ks[4], (w,), 1),
        "out_proj": _dense_init(jax.random.fold_in(key, 7), (w, d), w),
    }


def rglru_fwd(
    cfg: ArchConfig,
    p: Params,
    x: jnp.ndarray,  # (B, T, d)
    state: Optional[Dict] = None,
    use_kernel: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    xb = jnp.einsum("btd,dw->btw", x, p["in_x"])
    gb = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["in_gate"]))
    xb = shard(xb, "act_batch", "act_seq", "act_ffn")
    conv_prev = state["conv"] if state is not None else None
    xb, conv_new = _causal_conv(xb, p["conv"], conv_prev)
    # diagonal recurrence and input gates
    a = jax.nn.sigmoid(xb * p["a_gate_w"] + p["a_gate_b"])
    gate_x = jax.nn.sigmoid(xb * p["x_gate_w"])
    h0 = state["h"] if state is not None else None
    h, hT = kops.gated_linear_recurrence(
        xb * gate_x, a, h0, use_kernel=use_kernel
    )
    y = h * gb
    out = jnp.einsum("btw,wd->btd", y, p["out_proj"])
    out = shard(out, "act_batch", "act_seq", "act_embed")
    new_state = {"h": hT, "conv": conv_new} if state is not None else None
    return out, new_state
