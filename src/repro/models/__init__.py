"""LM substrate: configs, layers, models, sharding."""
from .config import ArchConfig, Block
from . import layers, model, sharding

__all__ = ["ArchConfig", "Block", "layers", "model", "sharding"]
