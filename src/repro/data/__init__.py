from .pipeline import GeoDataPipeline, synthetic_lm_batch
from .tokenizer import ByteTokenizer

__all__ = ["GeoDataPipeline", "synthetic_lm_batch", "ByteTokenizer"]
