"""Byte-level tokenizer (tokenizer-lite).

Deterministic, vocabulary = 256 bytes + specials.  Enough substrate for the
MapReduce text applications and for end-to-end text training demos without
external model files.
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

__all__ = ["ByteTokenizer"]


class ByteTokenizer:
    PAD = 256
    BOS = 257
    EOS = 258
    vocab_size = 259

    def encode(self, text: str, bos: bool = True, eos: bool = True) -> np.ndarray:
        ids = list(text.encode("utf-8"))
        if bos:
            ids = [self.BOS] + ids
        if eos:
            ids = ids + [self.EOS]
        return np.asarray(ids, dtype=np.int32)

    def decode(self, ids: Iterable[int]) -> str:
        by = bytes(i for i in ids if 0 <= int(i) < 256)
        return by.decode("utf-8", errors="replace")

    def pad_batch(self, seqs: List[np.ndarray], length: int) -> np.ndarray:
        out = np.full((len(seqs), length), self.PAD, dtype=np.int32)
        for i, s in enumerate(seqs):
            out[i, : min(len(s), length)] = s[:length]
        return out
