"""Geo-planned data pipeline.

Two layers:

* ``synthetic_lm_batch`` — deterministic per-(seed, step) synthetic token
  batches.  Determinism keyed by step makes the pipeline
  **checkpoint-consistent**: a restart at step k regenerates exactly the
  batches a non-failed run would have seen (no data-order drift after
  recovery).

* ``GeoDataPipeline`` — the paper's *push phase* applied to training-data
  ingestion.  Corpus shards originate at distributed sources (cells /
  object-store regions); the pipeline builds the tripartite platform (data
  sources → pod ingest hosts), asks :func:`repro.core.optimize.optimize_plan`
  for an end-to-end placement (rather than a myopic nearest-source pull),
  and exposes per-pod source assignments plus modeled ingest time.  A
  double-buffered background prefetch thread overlaps host ingest with the
  accelerator step — the paper's push/compute pipelining at the data layer.
  Redundant-dispatch straggler mitigation: each shard is assigned a backup
  source ranked by bandwidth, used when the primary lags (mirrors the
  simulator's speculation).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from ..core.makespan import BARRIERS_ALL_PIPELINED
from ..core.optimize import optimize_plan
from ..core.plan import ExecutionPlan
from ..core.platform import Platform

__all__ = ["synthetic_lm_batch", "GeoDataPipeline"]


def synthetic_lm_batch(
    vocab: int, batch: int, seq: int, step: int, seed: int = 0,
    d_model: Optional[int] = None, embeds: bool = False,
) -> Dict[str, np.ndarray]:
    """Deterministic synthetic batch for step ``step``.  Token streams are
    Zipf-ish (realistic softmax pressure) with next-token labels."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Zipf-like marginal over the vocab
    ranks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    tokens = np.minimum(ranks - 1, vocab - 1).astype(np.int32)
    out: Dict[str, np.ndarray] = {"labels": tokens[:, 1:].copy()}
    if embeds:
        assert d_model is not None
        out["embeds"] = rng.standard_normal(
            (batch, seq, d_model), dtype=np.float32
        )
    else:
        out["tokens"] = tokens[:, :-1].copy()
    return out


@dataclasses.dataclass
class IngestAssignment:
    """Which fraction of each source's corpus a pod ingests, plus a backup
    source order for straggler re-dispatch."""

    pod: int
    fractions: np.ndarray  # (n_sources,) — row of x^T
    backup_order: np.ndarray  # sources sorted by descending bandwidth


class GeoDataPipeline:
    def __init__(
        self,
        platform: Platform,
        vocab: int,
        batch: int,
        seq: int,
        seed: int = 0,
        plan: Optional[ExecutionPlan] = None,
        mode: str = "e2e_push",
        prefetch: int = 2,
        d_model: Optional[int] = None,
        embeds: bool = False,
    ):
        self.platform = platform
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        self.d_model, self.embeds = d_model, embeds
        if plan is None:
            plan = optimize_plan(
                platform, mode=mode, barriers=BARRIERS_ALL_PIPELINED,
                n_restarts=8, steps=300,
            ).plan
        self.plan = plan
        self.assignments = [
            IngestAssignment(
                pod=j,
                fractions=plan.x[:, j].copy(),
                backup_order=np.argsort(-platform.B_sm[:, j]),
            )
            for j in range(platform.nM)
        ]
        self._queue: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._next_step = 0

    # -- modeled ingest ---------------------------------------------------
    def modeled_ingest_time(self) -> float:
        """Push-phase duration of the chosen plan (seconds, modeled)."""
        D, B_sm = self.platform.D, self.platform.B_sm
        t = (D[:, None] * self.plan.x) / B_sm
        return float(t.max())

    # -- batches ------------------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return synthetic_lm_batch(
            self.vocab, self.batch, self.seq, step, self.seed,
            d_model=self.d_model, embeds=self.embeds,
        )

    def start(self, from_step: int = 0):
        """Begin background prefetch from ``from_step`` (post-restore)."""
        self.stop()
        self._stop.clear()
        self._next_step = from_step

        def work():
            s = from_step
            while not self._stop.is_set():
                b = self.batch_at(s)
                while not self._stop.is_set():
                    try:
                        self._queue.put((s, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return self

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._thread is None:
            s = self._next_step
            self._next_step += 1
            return s, self.batch_at(s)
        return self._queue.get()

    def stop(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None
