"""`GeoJob` — the unified planning/execution facade.

The paper's core claim is that *end-to-end, multi-phase* optimization beats
myopic per-phase decisions.  This module exposes that whole loop — model a
platform, optimize a plan, execute (or simulate) it, and compare modeled
against measured timings — as one job-level API built on a single shared
cost model (:class:`repro.core.makespan.CostModel`):

    from repro.api import GeoJob, split_sources
    from repro.core import BARRIERS_GGL, planetlab_platform
    from repro.mapreduce.apps import generate_documents, word_count

    platform = planetlab_platform(8, alpha=1.0, seed=0)
    sources = split_sources(*generate_documents(800, 60), platform.nS)

    report = (
        GeoJob(platform, word_count())
        .calibrate(sources)                # probe-measure the app's alpha
        .plan(mode="e2e_multi", barriers=BARRIERS_GGL)
        .execute(sources)                  # real maps/reduces, real bytes
    )
    print(report.summary())                # modeled vs measured makespan

Every planner name registered via
:func:`repro.core.optimize.register_planner` is usable as ``mode``, so new
strategies plug into the facade without touching it.  Jobs without an
application can still :meth:`GeoJob.simulate` their plan on the
discrete-event executor.

Concurrent jobs contending for the same WAN links and compute lift the same
loop one level up — :class:`GeoSchedule` plans N jobs *together* on their
shared :class:`repro.core.platform.Substrate` (policies: ``independent`` /
``sequential`` / ``joint``) and executes or simulates them with real
resource contention:

    sub = Substrate.of(platform)
    jobs = [GeoJob(sub.view(D_a, alpha), app_a), GeoJob(sub.view(D_b, alpha))]
    report = GeoSchedule(jobs).plan(policy="joint").simulate()
    print(report.summary())               # aggregate makespan + hot links

And when the world refuses to hold still — jobs streaming in after t=0,
WAN capacities drifting mid-run — the schedule becomes a *controller*:
:meth:`GeoSchedule.run_online` closes the plan→observe→re-plan loop,
pausing the executor at decision points, re-planning each job's residual
work against the capacities then in force, and swapping improved plans in
for the chunks not yet committed:

    report = GeoSchedule([job_a]).plan(policy="joint").run_online(
        policy="reactive", arrivals=[Arrival(job_b, time=50.0)])
    print(report.summary())               # online vs frozen-plan makespan
    print(report.timeline())              # the per-decision audit trail
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .analysis.validate import validate_plan_shapes
from .core.makespan import BARRIERS_GGL, CostModel, attribute_phases
from .core.optimize import (
    OnlineConfig,
    PipelinePlanResult,
    PlanResult,
    SchedulePlanResult,
    SolveTimeEMA,
    _pipeline_result,
    _shared_schedule_result,
    available_modes,
    get_online_config,
    get_online_policy,
    optimize_pipeline,
    optimize_plan,
    optimize_schedule,
    replan,
    replan_batch,
    replan_schedule,
    solver_cache_stats,
    swap_charge,
)
from .core.fluid import fluid_score_residual
from .core.pipeline import PipelineSpec, StageSpec
from .core.plan import ExecutionPlan, uniform_plan
from .core.platform import Platform, Substrate
from .core.simulate import (
    ResourceStats,
    ScheduleSimResult,
    SimConfig,
    SimResult,
    open_schedule,
    simulate,
    simulate_schedule,
)
from .mapreduce.engine import GeoMapReduce, MRApp, PhaseStats, Records

__all__ = ["Arrival", "Decision", "GeoJob", "GeoPipeline", "GeoSchedule",
           "JobReport", "OnlineConfig", "OnlineReport", "PipelineReport",
           "ScheduleReport", "split_sources"]


def split_sources(keys: np.ndarray, values: np.ndarray, n_sources: int) -> List[Records]:
    """Partition a flat ``(keys, values)`` corpus into per-source record sets
    (one contiguous slice per data source)."""
    return list(zip(np.array_split(keys, n_sources),
                    np.array_split(values, n_sources)))


@dataclasses.dataclass(frozen=True)
class JobReport:
    """The outcome of one planned, executed job: the plan that ran, the
    measured byte movement, and modeled-vs-measured phase timings priced
    through the same cost model."""

    result: PlanResult
    stats: PhaseStats
    #: analytic phase breakdown of the plan (model side), seconds
    modeled: Dict[str, float]
    #: measured byte volumes priced through the identical equations, seconds
    measured: Dict[str, float]
    #: per-reducer ``(keys, values)`` outputs of the application
    outputs: List[Records]
    barriers: Tuple[str, str, str]

    @property
    def plan(self) -> ExecutionPlan:
        return self.result.plan

    @property
    def makespan_modeled(self) -> float:
        return self.modeled["makespan"]

    @property
    def makespan_measured(self) -> float:
        return self.measured["makespan"]

    def deltas(self) -> Dict[str, float]:
        """Measured − modeled seconds per phase (positive: the model was
        optimistic — e.g. the app's real α differs from the planning α)."""
        return {k: self.measured[k] - self.modeled[k] for k in self.modeled}

    def model_error(self) -> float:
        """Relative modeled-vs-measured makespan error."""
        return (self.makespan_modeled - self.makespan_measured) / max(
            self.makespan_measured, 1e-12
        )

    def summary(self) -> str:
        phases = " ".join(
            f"{k}={self.measured[k]:.1f}s" for k in ("push", "map", "shuffle", "reduce")
        )
        return (
            f"{self.result.mode}[{''.join(self.barriers)}] "
            f"measured={self.makespan_measured:.1f}s "
            f"modeled={self.makespan_modeled:.1f}s "
            f"(error {self.model_error():+.1%})  {phases}"
        )


class GeoJob:
    """A geo-distributed MapReduce job: platform + application + plan.

    The facade is fluent — ``plan(...)`` stores a :class:`PlanResult` and
    returns the job, so the whole loop reads
    ``GeoJob(platform, app).plan(mode=...).execute(per_source)``.
    """

    def __init__(
        self,
        platform: Platform,
        app: Optional[MRApp] = None,
        *,
        n_buckets: int = 512,
    ):
        self.platform = platform
        self.app = app
        self.n_buckets = n_buckets
        self._result: Optional[PlanResult] = None

    def __repr__(self):
        app = self.app.name if self.app is not None else None
        planned = repr(self._result) if self._result is not None else "unplanned"
        return f"GeoJob({self.platform.name}, app={app}, {planned})"

    # -- planning ------------------------------------------------------------
    def plan(
        self,
        mode: str = "e2e_multi",
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
        **solver_kwargs,
    ) -> "GeoJob":
        """Produce and adopt an execution plan with any registered planner
        (see :func:`repro.core.optimize.available_modes`); extra keyword
        arguments (``n_restarts``, ``steps``, ``seed``, ``fixed_x``) reach
        the solver."""
        self._result = optimize_plan(
            self.platform, mode, barriers=tuple(barriers), **solver_kwargs
        )
        return self

    def with_plan(
        self,
        plan: ExecutionPlan,
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
    ) -> "GeoJob":
        """Adopt an externally built plan (a baseline, a replayed plan, …),
        pricing it through the shared cost model."""
        validate_plan_shapes(
            (plan.nS, plan.nM, plan.nR),
            (self.platform.nS, self.platform.nM, self.platform.nR),
            context=f"plan {plan.meta or 'external'!r}",
        )
        cm = CostModel(self.platform, tuple(barriers))
        breakdown = cm.breakdown(plan)
        self._result = PlanResult(
            plan=plan,
            makespan=breakdown["makespan"],
            breakdown=breakdown,
            mode=plan.meta or "external",
            barriers=cm.barriers,
            objective=breakdown["makespan"],
        )
        return self

    @property
    def planned(self) -> PlanResult:
        if self._result is None:
            raise RuntimeError(
                "job has no plan yet — call .plan(mode=...) or .with_plan(...) "
                f"first (registered modes: {available_modes()})"
            )
        return self._result

    @property
    def cost_model(self) -> CostModel:
        """The cost model pricing this job (platform + planned barriers)."""
        barriers = self.planned.barriers if self._result is not None else BARRIERS_GGL
        return CostModel(self.platform, barriers)

    # -- calibration ---------------------------------------------------------
    def calibrate(
        self, per_source: Sequence[Records], alpha_floor: float = 0.01
    ) -> "GeoJob":
        """Probe-run the application under a uniform plan to measure its real
        expansion factor α *and* the per-source input volume, and return a
        job whose platform plans with them (the §3.2 probe).  Calibrating
        makes the modeled and measured sides of a :class:`JobReport`
        directly comparable; any existing plan is dropped as stale."""
        if self.app is None:
            raise RuntimeError("calibrate() needs an application (app=None)")
        probe = GeoMapReduce(
            self.platform, uniform_plan(self.platform), self.app,
            n_buckets=self.n_buckets,
        )
        _, stats = probe.run(per_source)
        D_mb = np.array(
            [k.shape[0] * self.app.record_bytes for k, _ in per_source],
            dtype=np.float64,
        ) / 1e6
        platform = dataclasses.replace(
            self.platform,
            D=np.maximum(D_mb, 1e-9),
            alpha=max(stats.alpha_measured, alpha_floor),
        )
        return GeoJob(platform, self.app, n_buckets=self.n_buckets)

    # -- execution -----------------------------------------------------------
    def execute(self, per_source: Sequence[Records]) -> JobReport:
        """Run the application under the planned execution plan, price the
        measured byte movement through the same cost model the planner used,
        and report modeled-vs-measured timings."""
        if self.app is None:
            raise RuntimeError(
                "execute() needs an application — construct GeoJob(platform, app) "
                "or use .simulate() for a model-only run"
            )
        result = self.planned
        engine = GeoMapReduce(
            self.platform, result.plan, self.app, n_buckets=self.n_buckets
        )
        outputs, stats = engine.run(per_source)
        cm = CostModel(self.platform, result.barriers)
        return JobReport(
            result=result,
            stats=stats,
            modeled=result.breakdown,
            measured=cm.breakdown_volumes(*stats.volumes_mb()),
            outputs=outputs,
            barriers=result.barriers,
        )

    def simulate(self, cfg: Optional[SimConfig] = None, **cfg_kwargs) -> SimResult:
        """Execute the planned job on the chunk-granular discrete-event
        executor (no application needed); defaults to the plan's barriers."""
        result = self.planned
        if cfg is None:
            cfg_kwargs.setdefault("barriers", result.barriers)
            cfg = SimConfig(**cfg_kwargs)
        elif cfg_kwargs:
            raise TypeError("pass either cfg or keyword overrides, not both")
        return simulate(self.platform, result.plan, cfg)


# ---------------------------------------------------------------------------
# multi-stage pipelines
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PipelineReport:
    """The outcome of one planned pipeline: per-stage plans priced end to
    end through the shared cost model, the discrete-event execution with
    real inter-stage release gating (:meth:`GeoPipeline.simulate`), and —
    after :meth:`GeoPipeline.execute` — per-stage application runs with
    measured byte movement chained stage to stage."""

    result: PipelinePlanResult
    barriers: Tuple[str, str, str]
    #: the concurrent stage execution (simulate()/execute() paths)
    sim: Optional[ScheduleSimResult] = None
    #: per-stage application reports (only from execute())
    jobs: Optional[Tuple[JobReport, ...]] = None
    #: measured per-stage timings composed along the DAG (only execute())
    measured: Optional[Dict[str, object]] = None

    @property
    def plans(self) -> Tuple[ExecutionPlan, ...]:
        return self.result.plans

    @property
    def sims(self) -> Optional[Tuple[SimResult, ...]]:
        """Per-stage discrete-event results."""
        return tuple(self.sim.jobs) if self.sim is not None else None

    @property
    def makespan_modeled(self) -> float:
        """Modeled end-to-end makespan along the DAG's critical path."""
        return self.result.makespan

    @property
    def makespan_sim(self) -> Optional[float]:
        """Simulated end-to-end makespan (absolute finish of the last
        stage, inter-stage gating included)."""
        return self.sim.makespan if self.sim is not None else None

    @property
    def makespan_measured(self) -> Optional[float]:
        """Measured end-to-end makespan (execute() path), else ``None``."""
        if self.measured is None:
            return None
        return float(self.measured["makespan"])

    def as_dict(self) -> Dict[str, object]:
        """Stable, JSON-round-trippable form: modeled per-stage spans and
        DAG composition, plus the simulated/measured sides when present."""
        out: Dict[str, object] = {
            "mode": self.result.mode,
            "barriers": "".join(self.barriers),
            "makespan": self.result.makespan,
            "stages": [
                {"makespan": r.makespan, **{k: float(v) for k, v
                                            in r.breakdown.items()}}
                for r in self.result.results
            ],
            "start": [float(t) for t in self.result.starts],
            "finish": [float(t) for t in self.result.finishes],
        }
        if self.sim is not None:
            out["simulated"] = self.sim.as_dict()
        if self.measured is not None:
            out["measured"] = self.measured
        return out

    def summary(self) -> str:
        extra = ""
        if self.makespan_sim is not None:
            extra += f" simulated={self.makespan_sim:.1f}s"
        if self.makespan_measured is not None:
            extra += f" measured={self.makespan_measured:.1f}s"
        stages = " ".join(
            f"{r.makespan:.0f}s" for r in self.result.results
        )
        return (
            f"pipeline[{self.result.mode}/{''.join(self.barriers)}] "
            f"{len(self.result.results)} stages "
            f"modeled={self.makespan_modeled:.1f}s{extra}  [{stages}]"
        )


class GeoPipeline:
    """A DAG of MapReduce stages where each downstream stage consumes its
    upstream stages' reduce output — the paper's end-to-end-beats-myopic
    argument lifted across *stages*.

    ``stages`` are per-stage :class:`GeoJob`\\ s on one shared substrate;
    only root stages' ``D`` is authoritative (a downstream stage's source
    vector is derived from its upstream reducers' placement).  ``edges``
    is a list of ``(upstream, downstream)`` stage-index pairs, defaulting
    to the linear chain; ``out_scales[k]`` is stage ``k``'s reduce-output
    MB per reduce-input MB.

    The facade mirrors :class:`GeoJob`:
    ``GeoPipeline(stages).plan(mode=...).simulate()`` — ``mode`` is any
    registered pipeline planner (``stagewise`` / ``end_to_end`` built in),
    ``stage_mode`` the per-stage planner it builds on.  Planning adopts
    each stage's shared-priced :class:`PlanResult` (and its derived-``D``
    platform view) into the stage job, so stages remain usable job facades
    afterwards.  A pipeline can be scheduled alongside plain jobs inside
    :class:`GeoSchedule` — including :meth:`GeoSchedule.run_online`, whose
    snapshot/swap machinery steers the not-yet-started stages of a live
    pipeline."""

    def __init__(
        self,
        stages: Sequence[GeoJob],
        edges: Optional[Sequence[Tuple[int, int]]] = None,
        out_scales: Optional[Sequence[float]] = None,
        name: str = "pipeline",
    ):
        if not stages:
            raise ValueError("GeoPipeline needs at least one stage")
        self.stages = list(stages)
        self.name = name
        n = len(self.stages)
        if edges is None:
            edges = [(k - 1, k) for k in range(1, n)]
        if out_scales is None:
            out_scales = [1.0] * n
        if len(out_scales) != n:
            raise ValueError("one out_scale per stage")
        deps: List[List[int]] = [[] for _ in range(n)]
        for u, v in edges:
            u, v = int(u), int(v)
            if not (0 <= u < n and 0 <= v < n):
                raise ValueError(f"edge ({u}, {v}) references unknown stages")
            deps[v].append(u)
        #: the validated stage DAG (cycles rejected here, at construction)
        self.spec = PipelineSpec(stages=tuple(
            StageSpec(
                platform=job.platform,
                deps=tuple(deps[k]),
                out_scale=float(out_scales[k]),
                name=f"{name}/stage{k}",
            )
            for k, job in enumerate(self.stages)
        ))
        self.substrate = self.spec.substrate
        self._result: Optional[PipelinePlanResult] = None

    def __repr__(self):
        planned = repr(self._result) if self._result is not None \
            else "unplanned"
        return (
            f"GeoPipeline({self.name}: {len(self.stages)} stages on "
            f"{self.substrate.name}, {planned})"
        )

    def stage_links(self) -> Dict[int, List[Tuple[int, float]]]:
        """Executor stage-linkage: ``{stage: [(upstream, out_scale), ...]}``
        (the upstream's own out_scale — what its reducers emit)."""
        return {
            k: [(u, self.spec.stages[u].out_scale) for u in stage.deps]
            for k, stage in enumerate(self.spec.stages)
            if stage.deps
        }

    # -- planning ------------------------------------------------------------
    def plan(
        self,
        mode: str = "end_to_end",
        stage_mode: str = "e2e_multi",
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
        **solver_kwargs,
    ) -> "GeoPipeline":
        """Plan all stages with any registered pipeline planner
        (``stagewise`` — the per-stage-myopic baseline — or ``end_to_end``
        — one solve over all stages with gradients through the inter-stage
        coupling; see
        :func:`repro.core.optimize.available_pipeline_modes`)."""
        self._result = optimize_pipeline(
            self.spec, mode=mode, stage_mode=stage_mode,
            barriers=tuple(barriers), **solver_kwargs,
        )
        self._adopt(self._result)
        return self

    def with_plans(self) -> "GeoPipeline":
        """Adopt every stage's existing plan (set via :meth:`GeoJob.plan`
        or :meth:`GeoJob.with_plan`) as the pipeline plan, re-priced end to
        end — the pipeline analogue of :meth:`GeoJob.with_plan` for
        baselines and replays."""
        barriers = self.stages[0].planned.barriers
        for job in self.stages[1:]:
            if job.planned.barriers != barriers:
                raise ValueError(
                    "with_plans() needs every stage planned under the same "
                    f"barriers, got {job.planned.barriers} vs {barriers}"
                )
        plans = [job.planned.plan for job in self.stages]
        res = _pipeline_result(
            self.spec, plans, barriers, "external", "external", 0.0
        )
        self._result = dataclasses.replace(res, objective=res.makespan)
        self._adopt(self._result)
        return self

    def _adopt(self, result: PipelinePlanResult) -> None:
        """Give every stage job its derived-``D`` platform view and its
        end-to-end-priced :class:`PlanResult`."""
        for job, platform, res in zip(
            self.stages, self.spec.stage_platforms(result.plans),
            result.results,
        ):
            job.platform = platform
            job._result = res

    @property
    def planned(self) -> PipelinePlanResult:
        if self._result is None:
            raise RuntimeError(
                "pipeline has no plan yet — call .plan(mode=...) or "
                ".with_plans() first"
            )
        return self._result

    # -- execution -----------------------------------------------------------
    def _stage_cfgs(self, cfg, cfg_kwargs) -> List[SimConfig]:
        result = self.planned
        if cfg is None:
            cfg_kwargs.setdefault("barriers", result.barriers)
            cfg = SimConfig(**cfg_kwargs)
        elif cfg_kwargs:
            raise TypeError("pass either cfg or keyword overrides, not both")
        cfgs = [cfg] * len(self.stages) if isinstance(cfg, SimConfig) \
            else list(cfg)
        if len(cfgs) != len(self.stages):
            raise ValueError("one SimConfig per stage (or a single shared one)")
        return cfgs

    def simulate(self, cfg=None, **cfg_kwargs) -> PipelineReport:
        """Execute the planned pipeline on the chunk-granular executor:
        all stages run through the shared resource engine, and a downstream
        stage's push chunks at source ``s`` release only when the upstream
        reduce output destined for ``s`` lands (real inter-stage gating,
        real contention between overlapping stages)."""
        result = self.planned
        cfgs = self._stage_cfgs(cfg, cfg_kwargs)
        entries = [
            (job.platform, res.plan, c)
            for job, res, c in zip(self.stages, result.results, cfgs)
        ]
        sim = simulate_schedule(entries, substrate=self.substrate,
                                stage_links=self.stage_links())
        return PipelineReport(result=result, barriers=result.barriers,
                              sim=sim)

    def execute(self, per_source) -> PipelineReport:
        """Run every stage's application, chaining real records: a
        downstream stage's source ``s`` consumes the concatenated reducer-
        ``s`` outputs of its upstream stages.  ``per_source`` is the root
        stage's per-source record sets (or ``{stage_idx: record_sets}``
        when the DAG has several roots).  Measured per-stage byte movement
        is priced through the identical cost model and composed along the
        same critical path as the modeled side."""
        result = self.planned
        roots = [k for k, s in enumerate(self.spec.stages) if not s.deps]
        if isinstance(per_source, dict):
            root_sources = {int(k): v for k, v in per_source.items()}
        elif len(roots) == 1:
            root_sources = {roots[0]: per_source}
        else:
            raise ValueError(
                f"pipeline has {len(roots)} root stages — pass "
                "per_source as {stage_idx: record_sets}"
            )
        if set(root_sources) != set(roots):
            raise ValueError(
                f"per_source covers stages {sorted(root_sources)} but the "
                f"roots are {roots}"
            )
        for job in self.stages:
            if job.app is None:
                raise RuntimeError(
                    "execute() needs every stage to carry an application — "
                    "use .simulate() for a model-only run"
                )
        n = len(self.stages)
        outputs: List[Optional[List[Records]]] = [None] * n
        reports: List[Optional[JobReport]] = [None] * n
        stage_measured: List[Optional[Dict[str, float]]] = [None] * n
        for k in self.spec.topo_order():
            stage, job, res = self.spec.stages[k], self.stages[k], \
                result.results[k]
            if stage.deps:
                srcs = [
                    (
                        np.concatenate([outputs[u][s][0]
                                        for u in stage.deps]),
                        np.concatenate([outputs[u][s][1]
                                        for u in stage.deps]),
                    )
                    for s in range(job.platform.nS)
                ]
            else:
                srcs = root_sources[k]
            engine = GeoMapReduce(
                job.platform, res.plan, job.app, n_buckets=job.n_buckets
            )
            outs, stats = engine.run(srcs)
            outputs[k] = outs
            cm = CostModel(job.platform, result.barriers)
            measured = cm.breakdown_volumes(*stats.volumes_mb())
            stage_measured[k] = measured
            reports[k] = JobReport(
                result=res, stats=stats, modeled=res.breakdown,
                measured=measured, outputs=outs, barriers=result.barriers,
            )
        # compose the measured stage spans along the same critical path
        start = [0.0] * n
        finish = [0.0] * n
        for k in self.spec.topo_order():
            start[k] = max(
                (finish[u] for u in self.spec.stages[k].deps), default=0.0
            )
            finish[k] = start[k] + stage_measured[k]["makespan"]
        measured_doc: Dict[str, object] = {
            "stages": [dict(m) for m in stage_measured],
            "start": start,
            "finish": finish,
            "makespan": max(finish),
        }
        cfgs = self._stage_cfgs(None, {})
        sim = simulate_schedule(
            [(job.platform, res.plan, c)
             for job, res, c in zip(self.stages, result.results, cfgs)],
            substrate=self.substrate, stage_links=self.stage_links(),
        )
        return PipelineReport(
            result=result, barriers=result.barriers, sim=sim,
            jobs=tuple(reports), measured=measured_doc,
        )


# ---------------------------------------------------------------------------
# multi-job scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleReport:
    """The outcome of one planned, concurrently executed schedule: per-job
    plans priced under shared-capacity contention, the discrete-event
    execution of all jobs on the shared substrate, per-resource
    utilization/contention accounting, and (after :meth:`GeoSchedule.execute`)
    per-job :class:`JobReport`\\ s with real measured byte movement."""

    result: SchedulePlanResult
    #: the concurrent discrete-event execution (always present — execute()
    #: runs the modeled schedule too, for the resource accounting)
    sim: ScheduleSimResult
    barriers: Tuple[str, str, str]
    #: per-job application reports (only from execute())
    jobs: Optional[Tuple[JobReport, ...]] = None

    @property
    def policy(self) -> str:
        return self.result.policy

    @property
    def plans(self) -> Tuple[ExecutionPlan, ...]:
        return self.result.plans

    @property
    def sims(self) -> Tuple[SimResult, ...]:
        """Per-job discrete-event results."""
        return tuple(self.sim.jobs)

    @property
    def resources(self) -> Dict[str, ResourceStats]:
        """Named substrate resources -> service accounting."""
        return self.sim.resources

    @property
    def makespan_modeled(self) -> float:
        """Aggregate modeled makespan (shared-capacity pricing, max over
        jobs)."""
        return self.result.makespan

    @property
    def makespan_sim(self) -> float:
        """Aggregate discrete-event makespan (absolute finish of the last
        job)."""
        return self.sim.makespan

    @property
    def makespan_measured(self) -> Optional[float]:
        """Aggregate measured makespan (execute() path), else ``None``."""
        if self.jobs is None:
            return None
        return max(job.makespan_measured for job in self.jobs)

    def utilization(self) -> Dict[str, float]:
        """Busy fraction of the schedule horizon per named resource."""
        return self.sim.utilization()

    def contended(self) -> Dict[str, ResourceStats]:
        """Resources that served chunks of more than one job."""
        return self.sim.contended()

    def hotspots(
        self,
        utilization_above: Optional[float] = None,
        backlog_age_above_s: Optional[float] = None,
    ) -> Dict[str, List[str]]:
        """Resources whose load crossed a warning threshold (utilization or
        mean queue delay), with human-readable violations — see
        :meth:`ScheduleSimResult.hotspots`."""
        return self.sim.hotspots(utilization_above, backlog_age_above_s)

    def as_dict(self) -> Dict[str, object]:
        """JSON-pure report of the schedule outcome: barrier configuration,
        policy, modeled/simulated (and, after execute(), measured)
        makespans, the full execution accounting, and the load hotspots
        that crossed the :class:`ResourceStats` warning thresholds."""
        out: Dict[str, object] = {
            "policy": str(self.policy),
            "barriers": "".join(self.barriers),
            "makespan_modeled": float(self.makespan_modeled),
            "makespan_sim": float(self.makespan_sim),
            "sim": self.sim.as_dict(),
            "hotspots": self.hotspots(),
        }
        if self.jobs is not None:
            out["makespan_measured"] = float(self.makespan_measured)
        return out

    def summary(self) -> str:
        measured = (
            f" measured={self.makespan_measured:.1f}s"
            if self.jobs is not None else ""
        )
        util = self.utilization()
        hot = " ".join(
            f"{n}={util[n]:.0%}"
            for n in sorted(util, key=lambda n: -util[n])[:3]
        )
        return (
            f"{self.policy}[{''.join(self.barriers)}] {len(self.sims)} jobs "
            f"modeled={self.makespan_modeled:.1f}s "
            f"simulated={self.makespan_sim:.1f}s{measured} "
            f"contended={len(self.contended())} hottest: {hot}"
        )


@dataclasses.dataclass(frozen=True)
class Arrival:
    """A job that streams in after t=0: the online control plane learns of
    it only at ``time``.  If ``job`` is unplanned, a *frozen* offline plan
    is produced with planner ``mode`` against the nominal substrate (what a
    static scheduler would have committed to); online policies may replace
    it at arrival against the capacities then in force.  ``cfg`` overrides
    the schedule-wide :class:`SimConfig` template for this job (its
    ``start_time`` is always forced to ``time``)."""

    job: "GeoJob"
    time: float
    mode: str = "e2e_multi"
    cfg: Optional[SimConfig] = None


@dataclasses.dataclass(frozen=True)
class Decision:
    """One entry of an online run's control timeline."""

    time: float
    event: str  # "arrival" | "drift" | "failure" | "tick"
    job: int
    #: "inject" | "swap" | "keep" | "reject" — "reject" is a candidate swap
    #: whose modeled savings did not clear its hysteresis-weighted charge
    action: str
    #: modeled remaining seconds under the incumbent plan at decision time
    modeled_before: float
    #: modeled remaining seconds under the adopted plan (== before on
    #: keep/reject — a rejected candidate is not adopted)
    modeled_after: float
    #: the replan cost charged against the candidate swap (solver estimate
    #: + modeled data movement, seconds; 0 outside cost-aware policies)
    charge: float = 0.0

    def __repr__(self):
        charged = f" charge={self.charge:.1f}s" if self.charge else ""
        return (
            f"Decision(t={self.time:.1f}s {self.event}: job {self.job} "
            f"{self.action} {self.modeled_before:.1f}s->"
            f"{self.modeled_after:.1f}s{charged})"
        )


@dataclasses.dataclass(frozen=True)
class OnlineReport:
    """The outcome of one online-controlled schedule: the steered execution,
    the frozen-plan baseline on the *same* arrivals and capacity drift, and
    the per-decision timeline that separates them."""

    policy: str
    sim: ScheduleSimResult
    static_sim: ScheduleSimResult
    decisions: Tuple[Decision, ...]
    #: each job's plan when the run finished (arrivals included, in
    #: injection order after the initial jobs)
    plans: Tuple[ExecutionPlan, ...]
    barriers: Tuple[str, str, str]

    @property
    def makespan_online(self) -> float:
        """Aggregate simulated makespan of the steered execution."""
        return self.sim.makespan

    @property
    def makespan_static(self) -> float:
        """Aggregate simulated makespan of the frozen-plan baseline."""
        return self.static_sim.makespan

    @property
    def improvement(self) -> float:
        """Fraction of the frozen baseline's makespan the online policy
        removed (0 = no better, 0.4 = 40% faster)."""
        if self.makespan_static <= 0:
            return 0.0
        return 1.0 - self.makespan_online / self.makespan_static

    @property
    def swaps(self) -> Tuple[Decision, ...]:
        """Accepted swaps — candidate plans actually adopted."""
        return tuple(d for d in self.decisions if d.action == "swap")

    @property
    def rejected(self) -> Tuple[Decision, ...]:
        """Candidate swaps the replan-cost hysteresis declined."""
        return tuple(d for d in self.decisions if d.action == "reject")

    @property
    def charged_s(self) -> float:
        """Total replan cost charged against candidate swaps (accepted and
        rejected), modeled seconds."""
        return sum(d.charge for d in self.decisions)

    def as_dict(self) -> Dict[str, object]:
        """JSON-pure report of the online run: policy, the steered/frozen
        makespans and their gap, decision-timeline aggregates, and the flat
        per-decision records (mirrors :meth:`ScheduleReport.as_dict`)."""
        return {
            "policy": str(self.policy),
            "barriers": "".join(self.barriers),
            "makespan_online": float(self.makespan_online),
            "makespan_static": float(self.makespan_static),
            "improvement": float(self.improvement),
            "n_decisions": len(self.decisions),
            "n_swaps": len(self.swaps),
            "n_rejected": len(self.rejected),
            "n_failures_observed": len(
                [d for d in self.decisions if d.event == "failure"]
            ),
            "charged_s": float(self.charged_s),
            "decisions": [
                {
                    "time": float(d.time),
                    "event": str(d.event),
                    "job": int(d.job),
                    "action": str(d.action),
                    "modeled_before": float(d.modeled_before),
                    "modeled_after": float(d.modeled_after),
                    "charge": float(d.charge),
                }
                for d in self.decisions
            ],
            "sim": self.sim.as_dict(),
            "static_sim": self.static_sim.as_dict(),
        }

    def timeline(self) -> str:
        if not self.decisions:
            return "(no decisions)"
        return "\n".join(
            f"  t={d.time:8.1f}s  {d.event:8s} job {d.job}: {d.action:6s} "
            f"remaining {d.modeled_before:8.1f}s -> {d.modeled_after:8.1f}s"
            + (f"  (charged {d.charge:.1f}s)" if d.charge else "")
            for d in self.decisions
        )

    def summary(self) -> str:
        rejected = (
            f", {len(self.rejected)} rejected" if self.rejected else ""
        )
        return (
            f"online[{self.policy}] {len(self.sim.jobs)} jobs "
            f"online={self.makespan_online:.1f}s "
            f"static={self.makespan_static:.1f}s "
            f"({self.improvement:+.0%} vs frozen, "
            f"{len(self.swaps)} swaps{rejected}/"
            f"{len(self.decisions)} decisions)"
        )


class GeoSchedule:
    """N concurrent :class:`GeoJob`\\ s contending for one shared
    :class:`Substrate` — the end-to-end-beats-myopic argument lifted across
    jobs.

    The facade mirrors :class:`GeoJob`:
    ``GeoSchedule(jobs).plan(policy=...).simulate()`` (or ``.execute(...)``
    when every job carries an application).  All job platforms must be
    views of the same substrate (:meth:`Substrate.view`); planning adopts
    each per-job plan into its :class:`GeoJob`, so individual jobs remain
    usable facades afterwards.
    """

    def __init__(self, jobs: Sequence):
        if not jobs:
            raise ValueError("GeoSchedule needs at least one job")
        #: the user's members (GeoJob or GeoPipeline), in order
        self.members = list(jobs)
        #: the flat job list the engine runs — pipelines contribute their
        #: stage jobs, linked through ``_links``
        self.jobs: List[GeoJob] = []
        self._links: Dict[int, List[Tuple[int, float]]] = {}
        self._pipelines: List[Tuple[GeoPipeline, int]] = []
        for member in self.members:
            if isinstance(member, GeoPipeline):
                base = len(self.jobs)
                self._pipelines.append((member, base))
                self.jobs.extend(member.stages)
                for child, parents in member.stage_links().items():
                    self._links[base + child] = [
                        (base + p, s) for p, s in parents
                    ]
            else:
                self.jobs.append(member)
        self.substrate = Substrate.of(self.jobs[0].platform)
        for job in self.jobs[1:]:
            if not self.substrate.compatible(Substrate.of(job.platform)):
                raise ValueError(
                    f"job platform {job.platform.name!r} does not share the "
                    "substrate — build job platforms with Substrate.view()"
                )
        self._result: Optional[SchedulePlanResult] = None

    def __repr__(self):
        planned = repr(self._result) if self._result is not None else "unplanned"
        return f"GeoSchedule({len(self.jobs)} jobs on {self.substrate.name}, {planned})"

    # -- planning ------------------------------------------------------------
    def plan(
        self,
        policy: str = "joint",
        mode: str = "e2e_multi",
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
        pipeline_mode: str = "end_to_end",
        **solver_kwargs,
    ) -> "GeoSchedule":
        """Plan all jobs together with any registered schedule policy
        (``independent`` / ``sequential`` / ``joint`` built in — see
        :func:`repro.core.optimize.available_policies`); ``mode`` is the
        per-job planner the policy builds on.  Each job adopts its
        shared-priced :class:`PlanResult`.

        :class:`GeoPipeline` members are planned with ``pipeline_mode``
        (cross-stage, per pipeline — stage ``mode`` underneath); plain
        jobs go through the schedule ``policy``, and the whole flat stack
        (stages included, on their derived-``D`` views) is re-priced
        under shared capacity."""
        barriers = tuple(barriers)
        if not self._pipelines:
            self._result = optimize_schedule(
                [job.platform for job in self.jobs],
                policy=policy, mode=mode, barriers=barriers,
                **solver_kwargs,
            )
            for job, res in zip(self.jobs, self._result.results):
                job._result = res
            return self
        # only the generic solver knobs reach the pipeline planner —
        # schedule-level kwargs (e.g. objective=) stay with the policy
        pipe_kwargs = {
            k: v for k, v in solver_kwargs.items()
            if k in ("n_restarts", "steps", "seed")
        }
        for pipe, _ in self._pipelines:
            pipe.plan(mode=pipeline_mode, stage_mode=mode,
                      barriers=barriers, **pipe_kwargs)
        staged = {
            base + k
            for pipe, base in self._pipelines
            for k in range(len(pipe.stages))
        }
        plain = [i for i in range(len(self.jobs)) if i not in staged]
        if plain:
            sub_result = optimize_schedule(
                [self.jobs[i].platform for i in plain],
                policy=policy, mode=mode, barriers=barriers,
                **solver_kwargs,
            )
            for i, res in zip(plain, sub_result.results):
                self.jobs[i]._result = res
        self._result = _shared_schedule_result(
            [job.platform for job in self.jobs],
            [job.planned.plan for job in self.jobs],
            barriers, policy=policy, mode=mode,
        )
        for job, res in zip(self.jobs, self._result.results):
            job._result = res
        return self

    def with_plans(self) -> "GeoSchedule":
        """Adopt every job's existing plan (set via :meth:`GeoJob.plan` or
        :meth:`GeoJob.with_plan`) as the schedule plan, re-priced under
        shared capacity — the schedule analogue of :meth:`GeoJob.with_plan`
        for baselines and replays."""
        barriers = self.jobs[0].planned.barriers
        for job in self.jobs[1:]:
            if job.planned.barriers != barriers:
                raise ValueError(
                    "with_plans() needs every job planned under the same "
                    f"barriers, got {job.planned.barriers} vs {barriers}"
                )
        self._result = _shared_schedule_result(
            [job.platform for job in self.jobs],
            [job.planned.plan for job in self.jobs],
            barriers, policy="external", mode="external",
        )
        for job, res in zip(self.jobs, self._result.results):
            job._result = res
        return self

    @property
    def planned(self) -> SchedulePlanResult:
        if self._result is None:
            raise RuntimeError(
                "schedule has no plan yet — call .plan(policy=...) first"
            )
        return self._result

    # -- execution -----------------------------------------------------------
    def _sim_entries(self, cfg: Optional[SimConfig], cfg_kwargs):
        result = self.planned
        if cfg is None and not cfg_kwargs:
            cfg = SimConfig(barriers=result.barriers)
        elif cfg is None:
            cfg_kwargs.setdefault("barriers", result.barriers)
            cfg = SimConfig(**cfg_kwargs)
        elif cfg_kwargs:
            raise TypeError("pass either cfg or keyword overrides, not both")
        cfgs = [cfg] * len(self.jobs) if isinstance(cfg, SimConfig) else list(cfg)
        if len(cfgs) != len(self.jobs):
            raise ValueError("one SimConfig per job (or a single shared one)")
        return [
            (job.platform, res.plan, c)
            for job, res, c in zip(self.jobs, result.results, cfgs)
        ]

    def simulate(self, cfg=None, **cfg_kwargs) -> ScheduleReport:
        """Execute all planned jobs concurrently on the chunk-granular
        executor — chunks of different jobs contend for the same link and
        compute resources.  ``cfg`` is a shared :class:`SimConfig`, a
        per-job sequence of them, or keyword overrides; barriers default to
        the planned ones."""
        entries = self._sim_entries(cfg, cfg_kwargs)
        sim = simulate_schedule(entries, substrate=self.substrate,
                                stage_links=self._links or None)
        return ScheduleReport(
            result=self.planned,
            sim=sim,
            barriers=self.planned.barriers,
        )

    def execute(self, per_source: Sequence[Sequence[Records]]) -> ScheduleReport:
        """Run every job's application under its planned slice of the
        schedule, price each job's *measured* byte movement under the same
        shared-capacity equations the policy optimized, and report per-job
        modeled-vs-measured timings plus the substrate's resource
        accounting (from the modeled concurrent execution).

        ``per_source[g]`` is job ``g``'s per-source record sets."""
        result = self.planned
        if self._links:
            raise RuntimeError(
                "execute() on a schedule containing pipelines is not "
                "supported — run GeoPipeline.execute() per pipeline (real "
                "record chaining), or use .simulate() for the whole "
                "schedule"
            )
        if len(per_source) != len(self.jobs):
            raise ValueError("one per-source record set per job")
        for job in self.jobs:
            if job.app is None:
                raise RuntimeError(
                    "execute() needs every job to carry an application — "
                    "use .simulate() for a model-only run"
                )
        stats_list: List[PhaseStats] = []
        outputs_list: List[List[Records]] = []
        for job, res, srcs in zip(self.jobs, result.results, per_source):
            engine = GeoMapReduce(
                job.platform, res.plan, job.app, n_buckets=job.n_buckets
            )
            outputs, stats = engine.run(srcs)
            stats_list.append(stats)
            outputs_list.append(outputs)
        cm = CostModel(self.jobs[0].platform, result.barriers)
        measured = cm.price_shared(
            [stats.volumes_mb() for stats in stats_list], result.barriers
        )
        reports = tuple(
            JobReport(
                result=res,
                stats=stats,
                modeled=res.breakdown,
                measured=attribute_phases(out),
                outputs=outputs,
                barriers=result.barriers,
            )
            for res, stats, out, outputs in zip(
                result.results, stats_list, measured, outputs_list
            )
        )
        sim = simulate_schedule(
            self._sim_entries(None, {}), substrate=self.substrate
        )
        return ScheduleReport(
            result=result,
            sim=sim,
            barriers=result.barriers,
            jobs=reports,
        )

    # -- online control ------------------------------------------------------
    def run_online(
        self,
        policy: str = "reactive",
        arrivals: Sequence[Arrival] = (),
        cfg: Optional[SimConfig] = None,
        replan_dt: Optional[float] = None,
        n_restarts: int = 8,
        steps: int = 200,
        seed: int = 0,
        online: Optional[OnlineConfig] = None,
    ) -> OnlineReport:
        """Execute the planned schedule under a closed plan→observe→re-plan
        loop, with ``arrivals`` streaming in after t=0 and any capacity
        drift of the substrate's :class:`repro.core.platform.CapacityTrace`\\ s
        applied live.

        ``policy`` is any name registered via
        :func:`repro.core.optimize.register_online_policy` — built in:
        ``static`` (never re-plan: reproduces the frozen offline pipeline
        exactly), ``reactive`` (re-plan on every arrival / failure /
        capacity-drift event), ``horizon`` (re-plan every ``replan_dt``
        seconds), their schedule-aware, cost-aware variants
        ``reactive_shared`` / ``horizon_shared``,
        ``reactive_incremental`` (shared triggers with warm-started
        incremental solves charged at measured cost), and
        ``reactive_fluid`` (incremental solves with the replan gate
        scored by a drift-aware fluid rollout —
        ``OnlineConfig(candidate_pricing="fluid")`` — so a decision's
        pricing cost scales with flows, not chunks).  At each decision
        point
        the executor is paused and a
        :class:`~repro.core.simulate.ProgressSnapshot` captured; how the
        residuals are then re-planned is the policy's
        :class:`~repro.core.optimize.OnlineConfig` (overridable via
        ``online``):

        * solo (default): each active job re-planned alone against the
          capacities then in force (:func:`repro.core.optimize.replan`,
          warm-started from the incumbent plan), any improving plan
          swapped in for the job's not-yet-committed chunks;
        * ``shared=True``: all live jobs co-replanned *jointly* against
          shared-capacity residual pricing
          (:func:`repro.core.optimize.replan_schedule`) — no job grabs a
          fast link the model knows the others also need;
        * ``hysteresis > 0``: each candidate swap is charged its replan
          cost (:func:`repro.core.optimize.swap_charge`: solver wall-clock
          — a measured EMA of this run's solve times unless the config
          pins ``solver_cost_s`` — plus the modeled data movement of
          re-routing its queued bytes) and fires
          only when modeled savings exceed ``hysteresis ×`` the charge —
          rejected candidates land in the timeline as ``reject`` entries
          with the charge that gated them.  ``hysteresis=inf`` never
          swaps, reproducing ``static`` byte-for-byte.

        The returned :class:`OnlineReport` carries the steered execution,
        the frozen-plan baseline run on the *same* arrivals and drift, and
        the per-decision timeline (with per-swap charge accounting).
        """
        policy_fn = get_online_policy(policy)
        ocfg = online if online is not None else get_online_config(policy)
        # hysteresis=inf can never accept a swap: skip the solves entirely
        # (the run is the frozen pipeline either way)
        gate_open = bool(np.isfinite(ocfg.hysteresis))
        if replan_dt is not None and replan_dt <= 0:
            raise ValueError(f"replan_dt must be > 0, got {replan_dt}")
        if policy in ("horizon", "horizon_shared") and replan_dt is None:
            raise ValueError(
                f"policy={policy!r} replans only on ticks — pass replan_dt "
                "(seconds between re-planning decisions)"
            )
        result = self.planned
        entries = self._sim_entries(cfg, {})
        template = entries[0][2]

        # frozen offline plans for the arrivals (planned on the nominal
        # substrate — what a static scheduler would have committed to)
        arrivals = sorted(arrivals, key=lambda a: a.time)
        arrival_entries = []
        for n, a in enumerate(arrivals):
            if a.job._result is None:
                a.job.plan(
                    mode=a.mode, barriers=result.barriers,
                    n_restarts=n_restarts, steps=steps, seed=seed + 101 * n,
                )
            acfg = dataclasses.replace(
                a.cfg if a.cfg is not None else template, start_time=a.time
            )
            arrival_entries.append((a.job.platform, a.job.planned.plan, acfg))

        # the frozen baseline: identical jobs, releases and drift — no loop
        static_sim = simulate_schedule(
            entries + arrival_entries, substrate=self.substrate,
            stage_links=self._links or None,
        )

        # candidate decision points (arrivals first among equal times, so a
        # newcomer is admitted before the policy reacts to the same instant)
        events: List[Tuple[float, str, list]] = []
        for t_a in sorted({e[2].start_time for e in arrival_entries}):
            group = [e for e in arrival_entries if e[2].start_time == t_a]
            events.append((t_a, "arrival", group))
        for t_d in self.substrate.drift_times():
            events.append((t_d, "drift", []))
        fail_times = set()
        for _, _, c in entries + arrival_entries:
            for ev in c.failures:
                # the decision never pre-dates the job: a failure timed
                # before an arrival's release is observed at the release
                fail_times.add(max(float(ev.time), c.start_time))
        # substrate-wide faults (and their repairs — restored capacity is
        # as much a re-planning trigger as lost capacity)
        fail_times.update(self.substrate.failure_times())
        for t_f in sorted(fail_times):
            events.append((t_f, "failure", []))
        events.sort(key=lambda e: (e[0], 0 if e[1] == "arrival" else 1))

        eng = open_schedule(entries, substrate=self.substrate,
                            stage_links=self._links or None)
        decisions: List[Decision] = []
        n_replans = 0
        # the charged solver cost: a fixed estimate when the config pins
        # one, otherwise the measured EMA of this run's solve times (cold
        # compiles excluded — paid once per shape, not per decision)
        ema = SolveTimeEMA(fixed=ocfg.solver_cost_s)

        def timed(fn, *args, **kwargs):
            c0 = solver_cache_stats()["compiles"]
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            ema.observe(time.perf_counter() - t0,
                        compiled=solver_cache_stats()["compiles"] > c0)
            return out

        def replan_solo(kind, t, sub_t, snap, injected):
            """Solo decision path: every live job re-planned independently
            — but solved as ONE batched dispatch (same shapes vmap into a
            single compiled call), with per-job seeds matching the old
            sequential loop exactly."""
            nonlocal n_replans
            live = [jp for jp in snap.jobs
                    if not jp.done and jp.job not in injected]
            if not live:
                return
            runs = [eng.runs[jp.job] for jp in live]
            views = [
                sub_t.view(g.p.D, g.p.alpha, name=f"{g.p.name}@{t:g}s")
                for g in runs
            ]
            befores = [
                CostModel(view, g.cfg.barriers).residual_makespan(jp, g.plan)
                for view, g, jp in zip(views, runs, live)
            ]
            seeds = [seed + 977 * (n_replans + 1 + i)
                     for i in range(len(live))]
            n_replans += len(live)
            results: List[Optional[PlanResult]] = [None] * len(live)
            by_barriers: Dict[str, List[int]] = {}
            for i, g in enumerate(runs):
                by_barriers.setdefault(g.cfg.barriers, []).append(i)
            for barriers, idxs in by_barriers.items():
                group = timed(
                    replan_batch,
                    [views[i] for i in idxs], [runs[i].plan for i in idxs],
                    progresses=[live[i] for i in idxs], barriers=barriers,
                    n_restarts=n_restarts, steps=steps,
                    seeds=[seeds[i] for i in idxs],
                    incremental=ocfg.incremental,
                )
                for i, res in zip(idxs, group):
                    results[i] = res
            for jp, g, view, before, res in zip(
                live, runs, views, befores, results
            ):
                charge = 0.0
                if res.plan is g.plan:
                    # the incumbent won: replan only returns a different
                    # object when it is strictly better in float64
                    action = "keep"
                elif ocfg.hysteresis == 0.0:
                    eng.swap_plan(jp.job, res.plan)
                    action = "swap"
                else:
                    # cost-aware solo policy: the same hysteresis gate the
                    # shared path applies
                    charge = swap_charge(view, jp, g.plan, res.plan,
                                         ema.charge_s())
                    savings = before - res.makespan
                    if np.isfinite(ocfg.hysteresis) \
                            and savings > ocfg.hysteresis * charge:
                        eng.swap_plan(jp.job, res.plan)
                        action = "swap"
                    else:
                        action = "reject"
                decisions.append(Decision(
                    time=t, event=kind, job=jp.job, action=action,
                    modeled_before=before,
                    modeled_after=(before if action == "reject"
                                   else res.makespan),
                    charge=charge,
                ))

        def co_replan(kind, t, sub_t, snap, fresh=frozenset()):
            """Schedule-aware decision: co-replan every live job's residual
            jointly, then adopt the stack **as a unit** iff its aggregate
            modeled savings clear the hysteresis-weighted total charge.
            The stack's pricing (and its never-modeled-worse guarantee) is
            joint, so partial adoption would execute a mix the solver never
            scored — and a sacrificial swap that worsens one job's own span
            to cut the bottleneck's must not be vetoed job-by-job.
            ``fresh`` holds job indices injected at this very instant —
            their queued bytes have not begun moving, so they contribute no
            data-movement charge (like the solo arrival path)."""
            nonlocal n_replans
            live = snap.residual_view()
            if not live:
                return
            incumbents = [eng.runs[idx].plan for idx, _ in live]
            progs = [jp for _, jp in live]
            n_replans += 1
            res = timed(
                replan_schedule, sub_t, incumbents, progs,
                barriers=result.barriers, n_restarts=n_restarts,
                steps=steps, seed=seed + 977 * n_replans,
                incremental=ocfg.incremental,
            )
            # replan_schedule returns either the incumbent objects (the
            # stack won) or one whole new stack — changed is all-or-nothing
            changed = [slot for slot in range(len(live))
                       if res.plans[slot] is not incumbents[slot]]
            charges = [0.0] * len(live)
            for slot in changed:
                idx, jp = live[slot]
                move = 0.0 if idx in fresh else swap_charge(
                    sub_t, jp, incumbents[slot], res.plans[slot],
                    solver_cost_s=0.0,
                )
                # one joint solve serves every job: its wall-clock charge
                # is counted once, pro-rated across the changed records
                charges[slot] = move + ema.charge_s() / len(changed)
            before_spans = list(res.before)
            after_spans = list(res.after)
            savings = max(res.before) - res.makespan
            strictly_better = bool(changed)
            if changed and ocfg.candidate_pricing == "fluid":
                # fluid-rollout gate: price BOTH stacks with the same
                # drift-aware float64 fluid drain from this instant, and
                # adopt only on a strict fluid improvement — the
                # incumbent competes under the pricing in force, so the
                # never-priced-worse guarantee survives the switch
                f_entries = [
                    (eng.runs[idx].p, incumbents[slot],
                     eng.runs[idx].cfg, jp)
                    for slot, (idx, jp) in enumerate(live)
                ]
                f_before = fluid_score_residual(
                    self.substrate, f_entries, now=t
                )
                f_after = fluid_score_residual(
                    self.substrate,
                    [(p, res.plans[slot], c, jp)
                     for slot, (p, _, c, jp) in enumerate(f_entries)],
                    now=t,
                )
                before_spans, after_spans = f_before, f_after
                savings = max(f_before) - max(f_after)
                strictly_better = max(f_after) < max(f_before)
            adopt = bool(
                changed and strictly_better
                and np.isfinite(ocfg.hysteresis)
                and savings > ocfg.hysteresis * sum(charges)
            )
            for slot, (idx, jp) in enumerate(live):
                if slot not in changed:
                    decisions.append(Decision(
                        time=t, event=kind, job=idx, action="keep",
                        modeled_before=before_spans[slot],
                        modeled_after=before_spans[slot],
                    ))
                    continue
                if adopt:
                    eng.swap_plan(idx, res.plans[slot])
                decisions.append(Decision(
                    time=t, event=kind, job=idx,
                    action="swap" if adopt else "reject",
                    modeled_before=before_spans[slot],
                    modeled_after=(after_spans[slot] if adopt
                                   else before_spans[slot]),
                    charge=charges[slot],
                ))

        ei = 0
        next_tick = replan_dt
        while True:
            t_next, kind, payload = None, None, []
            if ei < len(events):
                t_next, kind, payload = events[ei]
            if next_tick is not None and (t_next is None or next_tick < t_next):
                t_next, kind, payload = next_tick, "tick", []
            if t_next is None:
                break
            more_arrivals = any(k == "arrival" for _, k, _ in events[ei:])
            if eng.finished and not more_arrivals:
                break  # nothing left to steer; ticks would spin forever
            # a failure decision must observe the failure itself: drain the
            # events AT the instant before snapshotting (arrivals instead
            # act before same-time events, matching the offline seed order)
            eng.run_until(t_next, inclusive=(kind == "failure"))
            if kind == "tick":
                next_tick = t_next + replan_dt
            else:
                ei += 1
            snap = eng.snapshot()
            decide = policy_fn(kind, snap)
            sub_t = self.substrate.at(t_next) if (decide or payload) \
                else self.substrate
            injected = set()
            if kind == "arrival":
                for platform, frozen, acfg in payload:
                    view = sub_t.view(platform.D, platform.alpha,
                                      name=f"{platform.name}@{t_next:g}s")
                    cm_t = CostModel(view, acfg.barriers)
                    plan = frozen
                    arrival_charge, arrival_rejected = 0.0, None
                    if decide and not ocfg.shared and gate_open:
                        # plan the newcomer against the capacities in force
                        # (solo path; the shared path injects the frozen
                        # plan and lets the joint co-replan — which models
                        # the newcomer's contention — steer it, gated by
                        # the same hysteresis as everyone else).  The
                        # newcomer has nothing queued yet, so its charge is
                        # the solver estimate alone.
                        res = timed(
                            replan, view, frozen, progress=None,
                            barriers=acfg.barriers, n_restarts=n_restarts,
                            steps=steps, seed=seed + 977 * len(decisions),
                            incremental=ocfg.incremental,
                        )
                        if res.plan is not frozen:
                            if (cm_t.makespan(frozen) - res.makespan
                                    > ocfg.hysteresis * ema.charge_s()):
                                plan = res.plan
                                # charged only under cost-aware gating, so
                                # hysteresis=0 keeps its zero-charge records
                                if ocfg.hysteresis > 0:
                                    arrival_charge = ema.charge_s()
                            else:
                                arrival_rejected = ema.charge_s()
                    idx = eng.inject([(platform, plan, acfg)])[0]
                    injected.add(idx)
                    before = cm_t.makespan(frozen)
                    decisions.append(Decision(
                        time=t_next, event="arrival", job=idx,
                        action="inject", modeled_before=before,
                        modeled_after=(before if plan is frozen
                                       else cm_t.makespan(plan)),
                        charge=arrival_charge,
                    ))
                    if arrival_rejected is not None:
                        # the gate declined the newcomer's better plan: on
                        # the record, like any other rejected candidate
                        decisions.append(Decision(
                            time=t_next, event="arrival", job=idx,
                            action="reject", modeled_before=before,
                            modeled_after=before, charge=arrival_rejected,
                        ))
            if decide and gate_open and kind == "failure" \
                    and ocfg.speculation is not None:
                # the policy's fault-reaction knob: flip speculative
                # execution for every live job the instant a failure is
                # observed (recovery traffic creates the stragglers
                # speculation hedges)
                for jp in snap.jobs:
                    if not jp.done and jp.released:
                        eng.set_speculation(jp.job, ocfg.speculation)
            if decide and gate_open:
                if injected:
                    snap = eng.snapshot()  # include the newcomers' state
                if ocfg.shared:
                    # newcomers are NOT skipped here: the joint residual
                    # objective prices their contention alongside everyone
                    # else's, which is the point of co-replanning
                    co_replan(kind, t_next, sub_t, snap, fresh=injected)
                else:
                    replan_solo(kind, t_next, sub_t, snap, injected)

        sim = eng.run()
        return OnlineReport(
            policy=policy,
            sim=sim,
            static_sim=static_sim,
            decisions=tuple(decisions),
            plans=tuple(g.plan for g in eng.runs),
            barriers=result.barriers,
        )
