"""`GeoJob` — the unified planning/execution facade.

The paper's core claim is that *end-to-end, multi-phase* optimization beats
myopic per-phase decisions.  This module exposes that whole loop — model a
platform, optimize a plan, execute (or simulate) it, and compare modeled
against measured timings — as one job-level API built on a single shared
cost model (:class:`repro.core.makespan.CostModel`):

    from repro.api import GeoJob, split_sources
    from repro.core import BARRIERS_GGL, planetlab_platform
    from repro.mapreduce.apps import generate_documents, word_count

    platform = planetlab_platform(8, alpha=1.0, seed=0)
    sources = split_sources(*generate_documents(800, 60), platform.nS)

    report = (
        GeoJob(platform, word_count())
        .calibrate(sources)                # probe-measure the app's alpha
        .plan(mode="e2e_multi", barriers=BARRIERS_GGL)
        .execute(sources)                  # real maps/reduces, real bytes
    )
    print(report.summary())                # modeled vs measured makespan

Every planner name registered via
:func:`repro.core.optimize.register_planner` is usable as ``mode``, so new
strategies plug into the facade without touching it.  Jobs without an
application can still :meth:`GeoJob.simulate` their plan on the
discrete-event executor.

Concurrent jobs contending for the same WAN links and compute lift the same
loop one level up — :class:`GeoSchedule` plans N jobs *together* on their
shared :class:`repro.core.platform.Substrate` (policies: ``independent`` /
``sequential`` / ``joint``) and executes or simulates them with real
resource contention:

    sub = Substrate.of(platform)
    jobs = [GeoJob(sub.view(D_a, alpha), app_a), GeoJob(sub.view(D_b, alpha))]
    report = GeoSchedule(jobs).plan(policy="joint").simulate()
    print(report.summary())               # aggregate makespan + hot links
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .core.makespan import BARRIERS_GGL, CostModel, attribute_phases
from .core.optimize import (
    PlanResult,
    SchedulePlanResult,
    available_modes,
    optimize_plan,
    optimize_schedule,
)
from .core.plan import ExecutionPlan, uniform_plan
from .core.platform import Platform, Substrate
from .core.simulate import (
    ResourceStats,
    ScheduleSimResult,
    SimConfig,
    SimResult,
    simulate,
    simulate_schedule,
)
from .mapreduce.engine import GeoMapReduce, MRApp, PhaseStats, Records

__all__ = ["GeoJob", "GeoSchedule", "JobReport", "ScheduleReport",
           "split_sources"]


def split_sources(keys: np.ndarray, values: np.ndarray, n_sources: int) -> List[Records]:
    """Partition a flat ``(keys, values)`` corpus into per-source record sets
    (one contiguous slice per data source)."""
    return list(zip(np.array_split(keys, n_sources),
                    np.array_split(values, n_sources)))


@dataclasses.dataclass(frozen=True)
class JobReport:
    """The outcome of one planned, executed job: the plan that ran, the
    measured byte movement, and modeled-vs-measured phase timings priced
    through the same cost model."""

    result: PlanResult
    stats: PhaseStats
    #: analytic phase breakdown of the plan (model side), seconds
    modeled: Dict[str, float]
    #: measured byte volumes priced through the identical equations, seconds
    measured: Dict[str, float]
    #: per-reducer ``(keys, values)`` outputs of the application
    outputs: List[Records]
    barriers: Tuple[str, str, str]

    @property
    def plan(self) -> ExecutionPlan:
        return self.result.plan

    @property
    def makespan_modeled(self) -> float:
        return self.modeled["makespan"]

    @property
    def makespan_measured(self) -> float:
        return self.measured["makespan"]

    def deltas(self) -> Dict[str, float]:
        """Measured − modeled seconds per phase (positive: the model was
        optimistic — e.g. the app's real α differs from the planning α)."""
        return {k: self.measured[k] - self.modeled[k] for k in self.modeled}

    def model_error(self) -> float:
        """Relative modeled-vs-measured makespan error."""
        return (self.makespan_modeled - self.makespan_measured) / max(
            self.makespan_measured, 1e-12
        )

    def summary(self) -> str:
        phases = " ".join(
            f"{k}={self.measured[k]:.1f}s" for k in ("push", "map", "shuffle", "reduce")
        )
        return (
            f"{self.result.mode}[{''.join(self.barriers)}] "
            f"measured={self.makespan_measured:.1f}s "
            f"modeled={self.makespan_modeled:.1f}s "
            f"(error {self.model_error():+.1%})  {phases}"
        )


class GeoJob:
    """A geo-distributed MapReduce job: platform + application + plan.

    The facade is fluent — ``plan(...)`` stores a :class:`PlanResult` and
    returns the job, so the whole loop reads
    ``GeoJob(platform, app).plan(mode=...).execute(per_source)``.
    """

    def __init__(
        self,
        platform: Platform,
        app: Optional[MRApp] = None,
        *,
        n_buckets: int = 512,
    ):
        self.platform = platform
        self.app = app
        self.n_buckets = n_buckets
        self._result: Optional[PlanResult] = None

    def __repr__(self):
        app = self.app.name if self.app is not None else None
        planned = repr(self._result) if self._result is not None else "unplanned"
        return f"GeoJob({self.platform.name}, app={app}, {planned})"

    # -- planning ------------------------------------------------------------
    def plan(
        self,
        mode: str = "e2e_multi",
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
        **solver_kwargs,
    ) -> "GeoJob":
        """Produce and adopt an execution plan with any registered planner
        (see :func:`repro.core.optimize.available_modes`); extra keyword
        arguments (``n_restarts``, ``steps``, ``seed``, ``fixed_x``) reach
        the solver."""
        self._result = optimize_plan(
            self.platform, mode, barriers=tuple(barriers), **solver_kwargs
        )
        return self

    def with_plan(
        self,
        plan: ExecutionPlan,
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
    ) -> "GeoJob":
        """Adopt an externally built plan (a baseline, a replayed plan, …),
        pricing it through the shared cost model."""
        cm = CostModel(self.platform, tuple(barriers))
        breakdown = cm.breakdown(plan)
        self._result = PlanResult(
            plan=plan,
            makespan=breakdown["makespan"],
            breakdown=breakdown,
            mode=plan.meta or "external",
            barriers=cm.barriers,
            objective=breakdown["makespan"],
        )
        return self

    @property
    def planned(self) -> PlanResult:
        if self._result is None:
            raise RuntimeError(
                "job has no plan yet — call .plan(mode=...) or .with_plan(...) "
                f"first (registered modes: {available_modes()})"
            )
        return self._result

    @property
    def cost_model(self) -> CostModel:
        """The cost model pricing this job (platform + planned barriers)."""
        barriers = self.planned.barriers if self._result is not None else BARRIERS_GGL
        return CostModel(self.platform, barriers)

    # -- calibration ---------------------------------------------------------
    def calibrate(
        self, per_source: Sequence[Records], alpha_floor: float = 0.01
    ) -> "GeoJob":
        """Probe-run the application under a uniform plan to measure its real
        expansion factor α *and* the per-source input volume, and return a
        job whose platform plans with them (the §3.2 probe).  Calibrating
        makes the modeled and measured sides of a :class:`JobReport`
        directly comparable; any existing plan is dropped as stale."""
        if self.app is None:
            raise RuntimeError("calibrate() needs an application (app=None)")
        probe = GeoMapReduce(
            self.platform, uniform_plan(self.platform), self.app,
            n_buckets=self.n_buckets,
        )
        _, stats = probe.run(per_source)
        D_mb = np.array(
            [k.shape[0] * self.app.record_bytes for k, _ in per_source],
            dtype=np.float64,
        ) / 1e6
        platform = dataclasses.replace(
            self.platform,
            D=np.maximum(D_mb, 1e-9),
            alpha=max(stats.alpha_measured, alpha_floor),
        )
        return GeoJob(platform, self.app, n_buckets=self.n_buckets)

    # -- execution -----------------------------------------------------------
    def execute(self, per_source: Sequence[Records]) -> JobReport:
        """Run the application under the planned execution plan, price the
        measured byte movement through the same cost model the planner used,
        and report modeled-vs-measured timings."""
        if self.app is None:
            raise RuntimeError(
                "execute() needs an application — construct GeoJob(platform, app) "
                "or use .simulate() for a model-only run"
            )
        result = self.planned
        engine = GeoMapReduce(
            self.platform, result.plan, self.app, n_buckets=self.n_buckets
        )
        outputs, stats = engine.run(per_source)
        cm = CostModel(self.platform, result.barriers)
        return JobReport(
            result=result,
            stats=stats,
            modeled=result.breakdown,
            measured=cm.breakdown_volumes(*stats.volumes_mb()),
            outputs=outputs,
            barriers=result.barriers,
        )

    def simulate(self, cfg: Optional[SimConfig] = None, **cfg_kwargs) -> SimResult:
        """Execute the planned job on the chunk-granular discrete-event
        executor (no application needed); defaults to the plan's barriers."""
        result = self.planned
        if cfg is None:
            cfg_kwargs.setdefault("barriers", result.barriers)
            cfg = SimConfig(**cfg_kwargs)
        elif cfg_kwargs:
            raise TypeError("pass either cfg or keyword overrides, not both")
        return simulate(self.platform, result.plan, cfg)


# ---------------------------------------------------------------------------
# multi-job scheduling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScheduleReport:
    """The outcome of one planned, concurrently executed schedule: per-job
    plans priced under shared-capacity contention, the discrete-event
    execution of all jobs on the shared substrate, per-resource
    utilization/contention accounting, and (after :meth:`GeoSchedule.execute`)
    per-job :class:`JobReport`\\ s with real measured byte movement."""

    result: SchedulePlanResult
    #: the concurrent discrete-event execution (always present — execute()
    #: runs the modeled schedule too, for the resource accounting)
    sim: ScheduleSimResult
    barriers: Tuple[str, str, str]
    #: per-job application reports (only from execute())
    jobs: Optional[Tuple[JobReport, ...]] = None

    @property
    def policy(self) -> str:
        return self.result.policy

    @property
    def plans(self) -> Tuple[ExecutionPlan, ...]:
        return self.result.plans

    @property
    def sims(self) -> Tuple[SimResult, ...]:
        """Per-job discrete-event results."""
        return tuple(self.sim.jobs)

    @property
    def resources(self) -> Dict[str, ResourceStats]:
        """Named substrate resources -> service accounting."""
        return self.sim.resources

    @property
    def makespan_modeled(self) -> float:
        """Aggregate modeled makespan (shared-capacity pricing, max over
        jobs)."""
        return self.result.makespan

    @property
    def makespan_sim(self) -> float:
        """Aggregate discrete-event makespan (absolute finish of the last
        job)."""
        return self.sim.makespan

    @property
    def makespan_measured(self) -> Optional[float]:
        """Aggregate measured makespan (execute() path), else ``None``."""
        if self.jobs is None:
            return None
        return max(job.makespan_measured for job in self.jobs)

    def utilization(self) -> Dict[str, float]:
        """Busy fraction of the schedule horizon per named resource."""
        return self.sim.utilization()

    def contended(self) -> Dict[str, ResourceStats]:
        """Resources that served chunks of more than one job."""
        return self.sim.contended()

    def summary(self) -> str:
        measured = (
            f" measured={self.makespan_measured:.1f}s"
            if self.jobs is not None else ""
        )
        util = self.utilization()
        hot = " ".join(
            f"{n}={util[n]:.0%}"
            for n in sorted(util, key=lambda n: -util[n])[:3]
        )
        return (
            f"{self.policy}[{''.join(self.barriers)}] {len(self.sims)} jobs "
            f"modeled={self.makespan_modeled:.1f}s "
            f"simulated={self.makespan_sim:.1f}s{measured} "
            f"contended={len(self.contended())} hottest: {hot}"
        )


class GeoSchedule:
    """N concurrent :class:`GeoJob`\\ s contending for one shared
    :class:`Substrate` — the end-to-end-beats-myopic argument lifted across
    jobs.

    The facade mirrors :class:`GeoJob`:
    ``GeoSchedule(jobs).plan(policy=...).simulate()`` (or ``.execute(...)``
    when every job carries an application).  All job platforms must be
    views of the same substrate (:meth:`Substrate.view`); planning adopts
    each per-job plan into its :class:`GeoJob`, so individual jobs remain
    usable facades afterwards.
    """

    def __init__(self, jobs: Sequence[GeoJob]):
        if not jobs:
            raise ValueError("GeoSchedule needs at least one job")
        self.jobs = list(jobs)
        self.substrate = Substrate.of(self.jobs[0].platform)
        for job in self.jobs[1:]:
            if not self.substrate.compatible(Substrate.of(job.platform)):
                raise ValueError(
                    f"job platform {job.platform.name!r} does not share the "
                    "substrate — build job platforms with Substrate.view()"
                )
        self._result: Optional[SchedulePlanResult] = None

    def __repr__(self):
        planned = repr(self._result) if self._result is not None else "unplanned"
        return f"GeoSchedule({len(self.jobs)} jobs on {self.substrate.name}, {planned})"

    # -- planning ------------------------------------------------------------
    def plan(
        self,
        policy: str = "joint",
        mode: str = "e2e_multi",
        barriers: Tuple[str, str, str] = BARRIERS_GGL,
        **solver_kwargs,
    ) -> "GeoSchedule":
        """Plan all jobs together with any registered schedule policy
        (``independent`` / ``sequential`` / ``joint`` built in — see
        :func:`repro.core.optimize.available_policies`); ``mode`` is the
        per-job planner the policy builds on.  Each job adopts its
        shared-priced :class:`PlanResult`."""
        self._result = optimize_schedule(
            [job.platform for job in self.jobs],
            policy=policy, mode=mode, barriers=tuple(barriers),
            **solver_kwargs,
        )
        for job, res in zip(self.jobs, self._result.results):
            job._result = res
        return self

    @property
    def planned(self) -> SchedulePlanResult:
        if self._result is None:
            raise RuntimeError(
                "schedule has no plan yet — call .plan(policy=...) first"
            )
        return self._result

    # -- execution -----------------------------------------------------------
    def _sim_entries(self, cfg: Optional[SimConfig], cfg_kwargs):
        result = self.planned
        if cfg is None and not cfg_kwargs:
            cfg = SimConfig(barriers=result.barriers)
        elif cfg is None:
            cfg_kwargs.setdefault("barriers", result.barriers)
            cfg = SimConfig(**cfg_kwargs)
        elif cfg_kwargs:
            raise TypeError("pass either cfg or keyword overrides, not both")
        cfgs = [cfg] * len(self.jobs) if isinstance(cfg, SimConfig) else list(cfg)
        if len(cfgs) != len(self.jobs):
            raise ValueError("one SimConfig per job (or a single shared one)")
        return [
            (job.platform, res.plan, c)
            for job, res, c in zip(self.jobs, result.results, cfgs)
        ]

    def simulate(self, cfg=None, **cfg_kwargs) -> ScheduleReport:
        """Execute all planned jobs concurrently on the chunk-granular
        executor — chunks of different jobs contend for the same link and
        compute resources.  ``cfg`` is a shared :class:`SimConfig`, a
        per-job sequence of them, or keyword overrides; barriers default to
        the planned ones."""
        entries = self._sim_entries(cfg, cfg_kwargs)
        sim = simulate_schedule(entries, substrate=self.substrate)
        return ScheduleReport(
            result=self.planned,
            sim=sim,
            barriers=self.planned.barriers,
        )

    def execute(self, per_source: Sequence[Sequence[Records]]) -> ScheduleReport:
        """Run every job's application under its planned slice of the
        schedule, price each job's *measured* byte movement under the same
        shared-capacity equations the policy optimized, and report per-job
        modeled-vs-measured timings plus the substrate's resource
        accounting (from the modeled concurrent execution).

        ``per_source[g]`` is job ``g``'s per-source record sets."""
        result = self.planned
        if len(per_source) != len(self.jobs):
            raise ValueError("one per-source record set per job")
        for job in self.jobs:
            if job.app is None:
                raise RuntimeError(
                    "execute() needs every job to carry an application — "
                    "use .simulate() for a model-only run"
                )
        stats_list: List[PhaseStats] = []
        outputs_list: List[List[Records]] = []
        for job, res, srcs in zip(self.jobs, result.results, per_source):
            engine = GeoMapReduce(
                job.platform, res.plan, job.app, n_buckets=job.n_buckets
            )
            outputs, stats = engine.run(srcs)
            stats_list.append(stats)
            outputs_list.append(outputs)
        cm = CostModel(self.jobs[0].platform, result.barriers)
        measured = cm.price_shared(
            [stats.volumes_mb() for stats in stats_list], result.barriers
        )
        reports = tuple(
            JobReport(
                result=res,
                stats=stats,
                modeled=res.breakdown,
                measured=attribute_phases(out),
                outputs=outputs,
                barriers=result.barriers,
            )
            for res, stats, out, outputs in zip(
                result.results, stats_list, measured, outputs_list
            )
        )
        sim = simulate_schedule(
            self._sim_entries(None, {}), substrate=self.substrate
        )
        return ScheduleReport(
            result=result,
            sim=sim,
            barriers=result.barriers,
            jobs=reports,
        )
