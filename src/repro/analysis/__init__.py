"""Repo-specific static analysis and runtime auditing.

Three layers (see ``python -m repro.analysis --help``):

* :mod:`repro.analysis.lint` — AST lint rules no general-purpose linter
  expresses (float64 pricing purity, event tie-break discipline, registry
  coverage, ``as_dict`` JSON-ability).
* :mod:`repro.analysis.audit` — runtime conservation + determinism audits
  of the discrete-event executor.
* :mod:`repro.analysis.validate` — structural input validators shared with
  the core model layers and the :mod:`repro.api` front door.

``validate`` is imported eagerly (it is a numpy-only leaf that
:mod:`repro.core` itself depends on); ``lint`` and ``audit`` are exposed
lazily because ``audit`` imports the executor, which would otherwise close
an import cycle through this package.
"""
from __future__ import annotations

from . import validate  # noqa: F401  (leaf; safe eager import)

__all__ = ["audit", "lint", "validate"]


def __getattr__(name):
    if name in ("audit", "lint"):
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
