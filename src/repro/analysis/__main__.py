"""``python -m repro.analysis`` — lint ``src/`` with the repo-specific
rules, then audit the executor on the quick scenarios (conservation,
snapshot sanity, determinism under permuted tie-breaks).  Exits nonzero on
any finding.  ``repro-analyze`` is the console-script alias.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import audit, lint


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="repro-analyze", description=__doc__
    )
    parser.add_argument(
        "--root", type=Path, default=Path.cwd(),
        help="repo root holding src/, tests/ and README.md (default: cwd)",
    )
    parser.add_argument(
        "--lint-only", action="store_true", help="skip the runtime audits"
    )
    parser.add_argument(
        "--audit-only", action="store_true", help="skip the lint pass"
    )
    parser.add_argument(
        "-k", "--permutations", type=int, default=5, metavar="K",
        help="tie-break permutations per determinism audit (default: 5)",
    )
    args = parser.parse_args(argv)

    rc = 0
    if not args.audit_only:
        findings = lint.lint_project(args.root)
        for f in findings:
            print(f)
        print(f"lint: {len(findings)} finding(s)")
        rc |= bool(findings)

    if not args.lint_only:
        report = audit.run_all(k=args.permutations)
        for line in report.lines():
            print(line)
        n_scen = len(audit.QUICK_SCENARIOS)
        print(
            f"audit: {n_scen} scenarios + swap path, "
            f"{args.permutations} tie-break permutations each: "
            + ("ok" if report.ok else "FAILED")
        )
        rc |= not report.ok

    return rc


if __name__ == "__main__":
    sys.exit(main())
