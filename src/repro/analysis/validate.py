"""Static structural validation of planner/executor inputs.

The solvers and the executor assume their matrix inputs are *well-formed*:
plan rows are simplexes, capacities are finite and strictly positive, byte
volumes are finite and non-negative, and a pipeline stage's shape couples
to its upstream stages (reducer ``r`` feeds source ``r``).  Violations used
to surface deep inside ``_adam_anneal`` or the event loop as NaN makespans
or broadcast errors; the checkers here fail **at construction** with a
message naming the offending entry.

This module is deliberately a *leaf*: it imports numpy only, so the core
model modules (:mod:`repro.core.plan`, :mod:`repro.core.platform`,
:mod:`repro.core.makespan`) and the :mod:`repro.api` front door can all
share it without an import cycle.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "require_finite",
    "require_nonnegative",
    "require_positive",
    "require_row_stochastic",
    "validate_capacities",
    "validate_plan_arrays",
    "validate_plan_shapes",
    "validate_stage_coupling",
    "validate_volumes",
]


def _offenders(mask: np.ndarray, limit: int = 4) -> str:
    """The first few offending indices of a boolean mask, for messages."""
    idx = np.argwhere(np.asarray(mask))
    shown = ", ".join(str(tuple(int(v) for v in row)) for row in idx[:limit])
    more = f" (+{len(idx) - limit} more)" if len(idx) > limit else ""
    return f"at {shown}{more}"


def require_finite(name: str, arr) -> np.ndarray:
    """``arr`` as float64, raising if any entry is NaN or infinite."""
    arr = np.asarray(arr, dtype=np.float64)
    bad = ~np.isfinite(arr)
    if np.any(bad):
        raise ValueError(
            f"{name} contains non-finite entries {_offenders(bad)}"
        )
    return arr


def require_nonnegative(name: str, arr, atol: float = 0.0) -> np.ndarray:
    """Finite and ``>= -atol`` everywhere."""
    arr = require_finite(name, arr)
    bad = arr < -atol
    if np.any(bad):
        raise ValueError(
            f"{name} contains negative entries {_offenders(bad)}"
        )
    return arr


def require_positive(name: str, arr) -> np.ndarray:
    """Finite and strictly positive everywhere (a capacity of 0 or NaN
    turns into a division blow-up inside the phase equations)."""
    arr = require_finite(name, arr)
    bad = arr <= 0
    if np.any(bad):
        raise ValueError(f"{name} must be strictly positive {_offenders(bad)}")
    return arr


def require_row_stochastic(
    name: str, arr, atol: float = 1e-6
) -> np.ndarray:
    """Finite, entries in ``[0, 1]`` and rows summing to 1 (a 1-D array is
    one row — the shuffle simplex ``y``)."""
    arr = require_finite(name, arr)
    if np.any(arr < -atol) or np.any(arr > 1 + atol):
        bad = (arr < -atol) | (arr > 1 + atol)
        raise ValueError(
            f"{name} fractions outside [0, 1] {_offenders(bad)}"
        )
    sums = arr.sum(axis=-1)
    if not np.allclose(sums, 1.0, atol=atol):
        raise ValueError(
            f"{name} rows do not sum to 1: {np.atleast_1d(sums)}"
        )
    return arr


def validate_plan_shapes(
    plan_dims: Tuple[int, int, int],
    platform_dims: Tuple[int, int, int],
    context: str = "plan",
) -> None:
    """A plan's ``(nS, nM, nR)`` must match its platform's — adopted plans
    from another platform used to fail later as broadcast errors deep in
    pricing or the executor."""
    if tuple(plan_dims) != tuple(platform_dims):
        raise ValueError(
            f"{context} shape (nS, nM, nR)={tuple(plan_dims)} does not match "
            f"the platform's {tuple(platform_dims)}"
        )


def validate_plan_arrays(x, y, atol: float = 1e-6) -> None:
    """Equations 1–3 plus finiteness: ``x`` a (nS, nM) row-stochastic
    matrix, ``y`` an (nR,) simplex."""
    x = np.asarray(x)
    y = np.asarray(y)
    if x.ndim != 2 or y.ndim != 1:
        raise ValueError(f"bad plan shapes x{x.shape} y{y.shape}")
    require_row_stochastic("x", x, atol=atol)
    require_row_stochastic("y", y, atol=atol)


def validate_capacities(
    B_sm, B_mr, C_m, C_r, D=None, context: str = "platform"
) -> None:
    """Finite, strictly-positive capacity arrays with coupled shapes, plus
    an optional finite non-negative data vector ``D``."""
    B_sm = require_positive(f"{context}.B_sm", B_sm)
    B_mr = require_positive(f"{context}.B_mr", B_mr)
    C_m = require_positive(f"{context}.C_m", C_m)
    C_r = require_positive(f"{context}.C_r", C_r)
    nS, nM = B_sm.shape
    nM2, nR = B_mr.shape
    if nM != nM2:
        raise ValueError(
            f"{context}: B_sm/B_mr mapper dims disagree: {nM} vs {nM2}"
        )
    if C_m.shape != (nM,):
        raise ValueError(f"{context}: C_m shape {C_m.shape} != ({nM},)")
    if C_r.shape != (nR,):
        raise ValueError(f"{context}: C_r shape {C_r.shape} != ({nR},)")
    if D is not None:
        D = require_nonnegative(f"{context}.D", D)
        if D.shape != (nS,):
            raise ValueError(f"{context}: D shape {D.shape} != ({nS},)")


def validate_volumes(
    V_push, V_map, V_shuffle, V_reduce,
    dims: Optional[Tuple[int, int, int]] = None,
    atol: float = 1e-9,
) -> None:
    """Per-phase byte volumes must be finite and non-negative (and, when
    ``dims`` is given, shaped like the platform) before they are priced —
    a NaN volume otherwise propagates silently into every phase end.
    ``atol`` absorbs the ~1e-18 MB negatives that residual-snapshot
    subtraction can leave behind."""
    V_push = require_nonnegative("V_push", V_push, atol=atol)
    V_map = require_nonnegative("V_map", V_map, atol=atol)
    V_shuffle = require_nonnegative("V_shuffle", V_shuffle, atol=atol)
    V_reduce = require_nonnegative("V_reduce", V_reduce, atol=atol)
    if dims is not None:
        nS, nM, nR = dims
        want = {
            "V_push": ((nS, nM), V_push.shape),
            "V_map": ((nM,), V_map.shape),
            "V_shuffle": ((nM, nR), V_shuffle.shape),
            "V_reduce": ((nR,), V_reduce.shape),
        }
        for name, (expect, got) in want.items():
            if got != expect:
                raise ValueError(
                    f"{name} shape {got} does not match the platform's "
                    f"{expect}"
                )


def validate_stage_coupling(
    stage: int, nS: int, nR: int, deps: Sequence[int], n_stages: int
) -> None:
    """A dependent pipeline stage's sources are its upstream reducer nodes,
    so it needs ``nS == nR``; dep indices must name existing, distinct,
    non-self stages."""
    deps = [int(d) for d in deps]
    if len(set(deps)) != len(deps):
        raise ValueError(f"stage {stage} has duplicate deps {tuple(deps)}")
    for d in deps:
        if not 0 <= d < n_stages:
            raise ValueError(
                f"stage {stage} depends on unknown stage {d} "
                f"(pipeline has {n_stages} stages)"
            )
        if d == stage:
            raise ValueError(f"stage {stage} depends on itself")
    if deps and nS != nR:
        raise ValueError(
            f"stage {stage} has upstream deps but nS={nS} != nR={nR} — a "
            "dependent stage's sources must be the upstream reducer nodes"
        )
