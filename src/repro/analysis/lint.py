"""Repo-specific AST lint rules.

General-purpose linters check style; these rules check the invariants this
reproduction's *results* rest on:

``f64-pricing-purity``
    Nothing reachable from ``volume_model`` / ``price_*`` may touch
    ``jax``/``jnp`` or float32, and every call to an ``xp``-parameterized
    model function must pass ``xp=np`` explicitly (the parameter defaults
    to jnp for the solver path).  The 1e-9 model-vs-measured parity across
    all 27 barrier triples depends on the pricing path staying float64
    numpy end to end.

``no-bare-heappush``
    Every event insertion must go through ``_MultiSim.at()``, which is the
    single home of the ``(time, seq)`` tie-break discipline.  A bare
    ``heapq.heappush`` elsewhere can silently break determinism.

``registry-coverage``
    Every name registered via ``register_planner`` /
    ``register_schedule_planner`` / ``register_online_policy`` /
    ``register_pipeline_planner`` must be referenced in ``tests/`` and in
    the README — an unregistered-in-docs mode is dead surface area.

``as-dict-json``
    Public ``as_dict()`` methods feed ``json.dump`` in the benchmark
    emitters; they must build values from JSON-serializable literals and
    comprehensions only (no sets, bytes, or raw ndarray constructors).

``solver-compile-counters``
    Every module-level ``_solve*`` function (the jitted solver kernels)
    must be decorated with ``_counted_solver`` rather than bare
    ``jax.jit`` — the shape-keyed cache hit/miss/compile counters feed
    the cache-semantics tests, ``swap_charge``'s compile-excluded solve
    timing, and bench provenance; a solver that bypasses them silently
    corrupts all three.

Findings print as ``file:line: RULE message``.  Waive a single line with a
``# lint: ignore[rule-name]`` comment (bare ``# lint: ignore`` waives all
rules on that line).
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Set

__all__ = [
    "FILE_RULES",
    "PROJECT_RULES",
    "Finding",
    "LintedFile",
    "lint_file",
    "lint_project",
    "main",
]

REGISTRY_FNS = (
    "register_planner",
    "register_schedule_planner",
    "register_online_policy",
    "register_pipeline_planner",
)

_PRICING_ENTRY = re.compile(r"^(volume_model|price_\w+)$")
_WAIVER = re.compile(r"#\s*lint:\s*ignore(?:\[([\w,\s-]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


@dataclasses.dataclass
class LintedFile:
    """One parsed source file handed to file-scope rules."""

    path: Path
    rel: str
    source: str
    tree: ast.AST

    @classmethod
    def parse(cls, path: Path, rel: Optional[str] = None) -> "LintedFile":
        source = path.read_text()
        return cls(path=path, rel=rel or str(path), source=source,
                   tree=ast.parse(source, filename=str(path)))

    def lines(self) -> List[str]:
        return self.source.splitlines()


FileRule = Callable[[LintedFile], List[Finding]]
FILE_RULES: Dict[str, FileRule] = {}
ProjectRule = Callable[["Project"], List[Finding]]
PROJECT_RULES: Dict[str, ProjectRule] = {}


def _file_rule(name: str):
    def deco(fn: FileRule) -> FileRule:
        FILE_RULES[name] = fn
        return fn
    return deco


def _project_rule(name: str):
    def deco(fn: ProjectRule) -> ProjectRule:
        PROJECT_RULES[name] = fn
        return fn
    return deco


# ---------------------------------------------------------------------------
# rule: f64-pricing-purity
# ---------------------------------------------------------------------------


def _collect_functions(tree: ast.AST):
    """(module functions, methods), each keyed by bare name.  Nested
    functions are deliberately excluded: a call to ``mx``/``pmax`` inside
    ``volume_model`` targets the *parameter*, not the jax-flavoured nested
    defs of the same name inside ``smooth_ops``.  Methods are kept separate
    so ``self.analytic_volumes(...)`` resolves to the method, not the
    same-named module function."""
    module_fns: Dict[str, ast.FunctionDef] = {}
    methods: Dict[str, ast.FunctionDef] = {}
    for node in getattr(tree, "body", []):
        if isinstance(node, ast.FunctionDef):
            module_fns.setdefault(node.name, node)
        elif isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, ast.FunctionDef):
                    methods.setdefault(item.name, item)
    return module_fns, methods


def _param_names(fn: ast.FunctionDef) -> Set[str]:
    a = fn.args
    names = {p.arg for p in a.args + a.kwonlyargs + a.posonlyargs}
    if a.vararg:
        names.add(a.vararg.arg)
    if a.kwarg:
        names.add(a.kwarg.arg)
    return names


def _callee_name(call: ast.Call) -> Optional[str]:
    """Bare name of a call target: ``f(...)``, ``self.f(...)``, ``M.f(...)``."""
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return None


def _body_walk(fn: ast.FunctionDef) -> Iterable[ast.AST]:
    """Every node in the function *body* — excludes the signature (arg
    defaults and annotations), where ``xp=jnp`` defaults legitimately live,
    and the decorator list."""
    for stmt in fn.body:
        yield from ast.walk(stmt)


def _takes_xp(fn: ast.FunctionDef) -> bool:
    a = fn.args
    return any(p.arg == "xp" for p in a.args + a.kwonlyargs + a.posonlyargs)


@_file_rule("f64-pricing-purity")
def _rule_pricing_purity(file: LintedFile) -> List[Finding]:
    module_fns, methods = _collect_functions(file.tree)

    def resolve(call: ast.Call, shadowed: Set[str]):
        """(key, FunctionDef) for a same-file call target, else (None, None).
        ``self.f(...)``/``cls.f(...)`` resolves to the method; a bare name
        to the module function, unless a parameter shadows it."""
        f = call.func
        if isinstance(f, ast.Name):
            if f.id not in shadowed and f.id in module_fns:
                return f.id, module_fns[f.id]
        elif isinstance(f, ast.Attribute):
            if (isinstance(f.value, ast.Name)
                    and f.value.id in ("self", "cls")
                    and f.attr in methods):
                return f"method:{f.attr}", methods[f.attr]
        return None, None

    entries = {
        **{n: fn for n, fn in module_fns.items() if _PRICING_ENTRY.match(n)},
        **{f"method:{n}": fn for n, fn in methods.items()
           if _PRICING_ENTRY.match(n)},
    }
    if not entries:
        return []

    # call-graph closure over same-file functions, body-only
    reachable: Dict[str, ast.FunctionDef] = {}
    work = list(entries.items())
    while work:
        key, fn = work.pop()
        if key in reachable:
            continue
        reachable[key] = fn
        shadowed = _param_names(fn)
        for node in _body_walk(fn):
            if isinstance(node, ast.Call):
                ckey, cfn = resolve(node, shadowed)
                if ckey is not None and ckey not in reachable:
                    work.append((ckey, cfn))

    findings: List[Finding] = []

    def flag(node: ast.AST, msg: str) -> None:
        findings.append(Finding(file.rel, getattr(node, "lineno", 0),
                                "f64-pricing-purity", msg))

    for key in sorted(reachable):
        fn = reachable[key]
        name = fn.name
        shadowed = _param_names(fn)
        for node in _body_walk(fn):
            if isinstance(node, ast.Name) and node.id in ("jax", "jnp"):
                flag(node, f"`{node.id}` used in `{name}`, which is "
                     "reachable from the float64 pricing path")
            elif isinstance(node, ast.Name) and node.id == "float32":
                flag(node, f"float32 used in pricing-reachable `{name}`")
            elif isinstance(node, ast.Attribute) and node.attr == "float32":
                flag(node, f"float32 used in pricing-reachable `{name}`")
            elif (isinstance(node, ast.Constant)
                  and node.value == "float32"):
                flag(node, f"'float32' dtype literal in pricing-reachable "
                     f"`{name}`")
            elif isinstance(node, ast.Call):
                _, cfn = resolve(node, shadowed)
                if cfn is not None and _takes_xp(cfn):
                    xp_kw = next(
                        (kw for kw in node.keywords if kw.arg == "xp"), None
                    )
                    if xp_kw is None:
                        flag(node, f"`{name}` calls `{cfn.name}` without "
                             "pinning xp=np — the backend defaults to jnp")
                    else:
                        v = xp_kw.value
                        ok = (isinstance(v, ast.Name)
                              and v.id in ("np", "numpy"))
                        if not ok:
                            flag(node, f"`{name}` calls `{cfn.name}` with "
                                 "a non-numpy xp backend")
    return findings


# ---------------------------------------------------------------------------
# rule: no-bare-heappush
# ---------------------------------------------------------------------------


@_file_rule("no-bare-heappush")
def _rule_no_bare_heappush(file: LintedFile) -> List[Finding]:
    findings: List[Finding] = []

    def is_heappush(call: ast.Call) -> bool:
        f = call.func
        if isinstance(f, ast.Name):
            return f.id == "heappush"
        return (isinstance(f, ast.Attribute) and f.attr == "heappush"
                and isinstance(f.value, ast.Name) and f.value.id == "heapq")

    def visit(node: ast.AST, inside_at: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            inside_at = node.name == "at"
        if isinstance(node, ast.Call) and is_heappush(node) and not inside_at:
            findings.append(Finding(
                file.rel, node.lineno, "no-bare-heappush",
                "event pushed outside `at()` — all insertions must go "
                "through the `(time, seq)` tie-break in `_MultiSim.at()`"))
        for child in ast.iter_child_nodes(node):
            visit(child, inside_at)

    visit(file.tree, False)
    return findings


# ---------------------------------------------------------------------------
# rule: as-dict-json
# ---------------------------------------------------------------------------

_JSON_CASTS = {"float", "int", "str", "bool", "list", "dict", "tuple",
               "sorted", "len", "abs", "round", "min", "max", "sum"}
_JSON_METHODS = {"tolist", "item", "as_dict", "phases", "utilization",
                 "items", "keys", "values", "get", "join", "format"}
_BANNED_CALLS = {"set", "frozenset", "bytes", "bytearray", "complex"}
_NDARRAY_CTORS = {"asarray", "array", "zeros", "ones", "full", "arange",
                  "atleast_1d", "atleast_2d"}


@_file_rule("as-dict-json")
def _rule_as_dict_json(file: LintedFile) -> List[Finding]:
    findings: List[Finding] = []

    def flag(node: ast.AST, msg: str) -> None:
        findings.append(Finding(file.rel, getattr(node, "lineno", 0),
                                "as-dict-json", msg))

    def check(node: ast.AST, wrapped: bool) -> None:
        """``wrapped`` = inside a JSON-coercing conversion (float()/list()/
        .tolist()/...), where an ndarray intermediate is fine."""
        if isinstance(node, (ast.Set, ast.SetComp)):
            flag(node, "set is not JSON-serializable")
        elif isinstance(node, ast.Constant) and isinstance(node.value, bytes):
            flag(node, "bytes literal is not JSON-serializable")
        elif isinstance(node, ast.Call):
            callee = _callee_name(node)
            if isinstance(node.func, ast.Name) and callee in _BANNED_CALLS:
                flag(node, f"`{callee}(...)` is not JSON-serializable")
            elif (isinstance(node.func, ast.Attribute)
                  and isinstance(node.func.value, ast.Name)
                  and node.func.value.id in ("np", "numpy", "jnp")
                  and callee in _NDARRAY_CTORS and not wrapped):
                flag(node, f"raw ndarray from `{node.func.value.id}."
                     f"{callee}(...)` — convert with .tolist() or float()")
            wrapped = wrapped or (
                (isinstance(node.func, ast.Name) and callee in _JSON_CASTS)
                or (isinstance(node.func, ast.Attribute)
                    and callee in _JSON_METHODS))
        for child in ast.iter_child_nodes(node):
            check(child, wrapped)

    for node in ast.walk(file.tree):
        if (isinstance(node, ast.FunctionDef) and node.name == "as_dict"):
            for stmt in node.body:
                check(stmt, False)
    return findings


# ---------------------------------------------------------------------------
# rule: solver-compile-counters
# ---------------------------------------------------------------------------


def _decorator_names(fn: ast.FunctionDef) -> Set[str]:
    """Bare names of a function's decorators: ``@f``, ``@f(...)``,
    ``@mod.f`` and ``@mod.f(...)`` all yield ``f``."""
    names: Set[str] = set()
    for deco in fn.decorator_list:
        node = deco.func if isinstance(deco, ast.Call) else deco
        if isinstance(node, ast.Name):
            names.add(node.id)
        elif isinstance(node, ast.Attribute):
            names.add(node.attr)
    return names


@_file_rule("solver-compile-counters")
def _rule_solver_compile_counters(file: LintedFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in getattr(file.tree, "body", []):
        if not (isinstance(node, ast.FunctionDef)
                and node.name.startswith("_solve")):
            continue
        if "_counted_solver" not in _decorator_names(node):
            findings.append(Finding(
                file.rel, node.lineno, "solver-compile-counters",
                f"solver `{node.name}` is not decorated with "
                "`_counted_solver` — its compiles/hits would be invisible "
                "to the cache counters, swap_charge's compile-excluded "
                "solve timing, and bench provenance"))
    return findings


# ---------------------------------------------------------------------------
# rule: registry-coverage (project scope)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Project:
    """The whole-tree view handed to project-scope rules."""

    src_files: List[LintedFile]
    tests_text: str
    readme_text: str


@_project_rule("registry-coverage")
def _rule_registry_coverage(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for file in project.src_files:
        for node in ast.walk(file.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _callee_name(node)
            if callee not in REGISTRY_FNS:
                continue
            if not (node.args and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            name = node.args[0].value
            word = re.compile(rf"\b{re.escape(name)}\b")
            missing = [
                where for where, text in
                (("tests/", project.tests_text),
                 ("README", project.readme_text))
                if not word.search(text)
            ]
            if missing:
                findings.append(Finding(
                    file.rel, node.lineno, "registry-coverage",
                    f"registered mode '{name}' ({callee}) is not "
                    f"referenced in {' or '.join(missing)}"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def _apply_waivers(findings: List[Finding],
                   files: Dict[str, LintedFile]) -> List[Finding]:
    kept = []
    for f in findings:
        file = files.get(f.path)
        if file is not None and 1 <= f.line <= len(file.lines()):
            m = _WAIVER.search(file.lines()[f.line - 1])
            if m and (m.group(1) is None
                      or f.rule in re.split(r"[,\s]+", m.group(1))):
                continue
        kept.append(f)
    return kept


def lint_file(path: Path, rel: Optional[str] = None,
              rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the file-scope rules (all by default) on one source file."""
    file = LintedFile.parse(Path(path), rel)
    findings: List[Finding] = []
    for name, fn in FILE_RULES.items():
        if rules is None or name in rules:
            findings.extend(fn(file))
    return _apply_waivers(findings, {file.rel: file})


def lint_project(root: Path, src: str = "src", tests: str = "tests",
                 readme: str = "README.md",
                 rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint every ``.py`` under ``root/src`` with the file rules, then run
    the project rules against ``root/tests`` + the README."""
    root = Path(root)
    files: Dict[str, LintedFile] = {}
    for path in sorted((root / src).rglob("*.py")):
        rel = str(path.relative_to(root))
        files[rel] = LintedFile.parse(path, rel)

    findings: List[Finding] = []
    for file in files.values():
        for name, fn in FILE_RULES.items():
            if rules is None or name in rules:
                findings.extend(fn(file))

    tests_dir = root / tests
    tests_text = "\n".join(
        p.read_text() for p in sorted(tests_dir.rglob("*.py"))
    ) if tests_dir.is_dir() else ""
    readme_path = root / readme
    readme_text = readme_path.read_text() if readme_path.is_file() else ""
    project = Project(src_files=list(files.values()),
                      tests_text=tests_text, readme_text=readme_text)
    for name, fn in PROJECT_RULES.items():
        if rules is None or name in rules:
            findings.extend(fn(project))

    findings = _apply_waivers(findings, files)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def main(root: Path, quiet: bool = False) -> int:
    findings = lint_project(root)
    for f in findings:
        print(f)
    if not quiet:
        n_files = len(list((Path(root) / "src").rglob("*.py")))
        rules = sorted(set(FILE_RULES) | set(PROJECT_RULES))
        print(f"lint: {len(findings)} finding(s) across {n_files} files "
              f"({', '.join(rules)})")
    return 1 if findings else 0
