"""Runtime sanitizer for the discrete-event executor.

Three audits over small, solver-free scenarios that cover the executor's
surface (barrier triples, shared multi-job substrates with capacity drift
and staggered releases, stage-linked pipelines, and the flow-level fluid
executor crossing rate-change events):

* **conservation** — run with ``SimConfig(audit=True)``: the engine checks
  gate-counter sanity after every event and byte conservation (pushed ==
  landed == mapped, shuffle created == landed == reduced) at completion.
* **snapshot sanity** — :class:`~repro.core.simulate.ProgressSnapshot`
  residuals must be non-negative always, and monotone non-increasing for
  runs where no mechanism re-adds work (no failure recovery, no
  stage-linked sources still being fed).
* **determinism** — re-run a scenario K times with *permuted*
  same-timestamp event tie-breaks and compare a per-timestamp canonical
  state digest.  The engine's ``(time, seq)`` discipline makes runs
  reproducible; this audit proves the stronger property that same-time
  event order does not leak into the trajectory.  Any divergence is an
  event-order race, reported with the offending timestamp and the two
  event batches.

The state digest is deliberately *canonical*: resource queues enter as
multisets of ``(job, size, kind)`` (no chunk ids, no insertion order), so
benign reorderings of identical work hash identically while any
order-dependent state change is caught.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.plan import ExecutionPlan
from ..core.platform import CapacityTrace, FailureEvent, Platform, \
    Substrate, planetlab_platform
from ..core.simulate import SimConfig, _MultiSim, open_schedule

__all__ = [
    "AuditReport",
    "Divergence",
    "QUICK_SCENARIOS",
    "conservation_audit",
    "determinism_audit",
    "locality_plan",
    "raced_engine",
    "run_all",
    "snapshot_audit",
    "swap_conservation_audit",
    "trajectory",
    "uniform_plan",
]


# ---------------------------------------------------------------------------
# heuristic plans (closed-form: the audits must not depend on the solver)
# ---------------------------------------------------------------------------


def uniform_plan(p: Platform) -> ExecutionPlan:
    """Spread everything evenly — exercises every link."""
    return ExecutionPlan(
        x=np.full((p.nS, p.nM), 1.0 / p.nM),
        y=np.full(p.nR, 1.0 / p.nR),
    )


def locality_plan(p: Platform) -> ExecutionPlan:
    """Each source pushes over its best link; reducers weighted by rate —
    one-hot rows and unequal chunk sizes, the shape a solver plan has."""
    x = np.zeros((p.nS, p.nM))
    x[np.arange(p.nS), np.argmax(np.asarray(p.B_sm), axis=1)] = 1.0
    y = np.asarray(p.C_r, dtype=np.float64)
    return ExecutionPlan(x=x, y=y / y.sum())


# ---------------------------------------------------------------------------
# quick scenarios (shared by the CLI, the regression tests and CI)
# ---------------------------------------------------------------------------


def _planetlab_engine(barriers: Tuple[str, str, str]) -> _MultiSim:
    p = planetlab_platform(4, alpha=1.7, seed=2)
    cfg = SimConfig(barriers=barriers, audit=True)
    return open_schedule([(p, uniform_plan(p), cfg)])


def _shared_online_substrate() -> Substrate:
    """Two 2-node clusters joined by thin WAN links, with a reducer
    brown-out and two push links degrading over time — the
    ``schedule_online_shared`` benchmark geometry."""
    return Substrate(
        B_sm=np.array([[200.0, 200, 1, 1], [200, 200, 1, 1],
                       [1, 1, 200, 200], [1, 1, 200, 200]]),
        B_mr=np.array([[200.0, 200], [200, 200], [1, 200], [1, 200]]),
        C_m=np.array([100.0, 100, 100, 100]),
        C_r=np.array([300.0, 60]),
        cluster_s=np.array([0, 0, 1, 1]),
        cluster_m=np.array([0, 0, 1, 1]),
        cluster_r=np.array([0, 1]),
        name="audit-shared",
        traces={
            "reduce[r0]": CapacityTrace.step(300.0, 40.0, 110.0),
            "push[s0->m2]": CapacityTrace.step(1.0, 0.9, 150.0),
            "push[s1->m2]": CapacityTrace.step(1.0, 0.9, 180.0),
        },
    )


def _shared_online_engine() -> _MultiSim:
    sub = _shared_online_substrate()
    steady = sub.view(np.array([8000.0, 8000, 0, 0]), 1.0, name="steady")
    late = sub.view(np.array([0.0, 0, 6000, 6000]), 1.0, name="late")
    return open_schedule(
        [
            (steady, locality_plan(steady), SimConfig(audit=True)),
            (late, locality_plan(late),
             SimConfig(audit=True, start_time=50.0)),
        ],
        substrate=sub,
    )


def _pipeline_engine() -> _MultiSim:
    """A 3-stage chain (ingest -> transform -> aggregate) with real
    per-source release gating — the ``pipeline_chain`` geometry."""
    sub = Substrate(
        B_sm=np.array([[4.0, 4], [200, 200]]),
        B_mr=np.array([[200.0, 200], [200, 200]]),
        C_m=np.array([100.0, 100]),
        C_r=np.array([300.0, 60]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="audit-pipeline",
    )
    ingest = sub.view(np.array([0.0, 6000]), 1.0, name="ingest")
    transform = sub.view(np.zeros(2), 1.0, name="transform")
    aggregate = sub.view(np.zeros(2), 0.5, name="aggregate")
    jobs = [
        (ingest, locality_plan(ingest), SimConfig(audit=True)),
        (transform, uniform_plan(transform), SimConfig(audit=True)),
        (aggregate, uniform_plan(aggregate), SimConfig(audit=True)),
    ]
    return open_schedule(jobs, substrate=sub,
                         stage_links={1: [(0, 1.0)], 2: [(1, 1.0)]})


def _failover_engine() -> _MultiSim:
    """Every failure mechanism at once under replication: a per-job mapper
    kill (replica promotion), a substrate-wide reducer kill (claw-back +
    re-emission) and a cluster partition with repair (doomed transfers,
    park/resume) — the ``schedule_failover`` benchmark's fault surface.
    Failure times are deliberately non-round so they never tie with chunk
    completions under the permuted tie-break audit."""
    sub = _shared_online_substrate().with_failures([
        FailureEvent.reducer_kill(1, 97.0),
        FailureEvent.cluster_partition(0, 141.3, 191.3),
    ])
    steady = sub.view(np.array([8000.0, 8000, 0, 0]), 1.0, name="steady")
    late = sub.view(np.array([0.0, 0, 6000, 6000]), 1.0, name="late")
    return open_schedule(
        [
            (steady, locality_plan(steady),
             SimConfig(audit=True, replication=2,
                       failures=(FailureEvent.mapper_kill(0, 41.3),))),
            (late, locality_plan(late),
             SimConfig(audit=True, replication=2, start_time=50.0)),
        ],
        substrate=sub,
    )


def _traced_fluid_engine():
    """The shared-online geometry in fluid mode: the same reducer
    brown-out and push-link decays now hit the flow executor as
    rate-change events on its event horizon, so the fluid byte ledger
    and the split-invariance digests both cross capacity drift."""
    sub = _shared_online_substrate()
    steady = sub.view(np.array([8000.0, 8000, 0, 0]), 1.0, name="steady")
    late = sub.view(np.array([0.0, 0, 6000, 6000]), 1.0, name="late")
    return open_schedule(
        [
            (steady, locality_plan(steady),
             SimConfig(mode="fluid", audit=True)),
            (late, locality_plan(late),
             SimConfig(mode="fluid", audit=True, start_time=50.0)),
        ],
        substrate=sub,
    )


QUICK_SCENARIOS: Tuple[Tuple[str, Callable[[], _MultiSim]], ...] = (
    ("planetlab_GGL", lambda: _planetlab_engine(("G", "G", "L"))),
    ("planetlab_PPP", lambda: _planetlab_engine(("P", "P", "P"))),
    ("planetlab_LGP", lambda: _planetlab_engine(("L", "G", "P"))),
    ("shared_online", _shared_online_engine),
    ("pipeline_chain", _pipeline_engine),
    ("failover", _failover_engine),
    ("traced_fluid", _traced_fluid_engine),
)


def raced_engine() -> _MultiSim:
    """A deliberately raced fixture: two different-size chunks arrive at
    the *same mapper at the same instant* over two links (40 MB @ 10 MB/s
    and 80 MB @ 20 MB/s both land at t=4), so the mapper's service order —
    and the whole downstream trajectory — depends on the same-timestamp
    tie-break.  The determinism audit must flag it."""
    sub = Substrate(
        B_sm=np.array([[10.0], [20.0]]),
        B_mr=np.array([[50.0]]),
        C_m=np.array([100.0]),
        C_r=np.array([100.0]),
        cluster_s=np.zeros(2, dtype=int),
        cluster_m=np.zeros(1, dtype=int),
        cluster_r=np.zeros(1, dtype=int),
        name="raced",
    )
    p = sub.view(np.array([40.0, 80.0]), 1.0, name="raced-job")
    plan = ExecutionPlan(x=np.ones((2, 1)), y=np.ones(1))
    cfg = SimConfig(chunk_mb=128.0, barriers=("P", "P", "P"), audit=True)
    return open_schedule([(p, plan, cfg)], substrate=sub)


# ---------------------------------------------------------------------------
# determinism: permuted tie-breaks + canonical trajectory digest
# ---------------------------------------------------------------------------


def patch_tiebreak(eng: _MultiSim, rng: np.random.Generator) -> _MultiSim:
    """Replace the engine's seq tie-break with a random key: events at the
    same timestamp now pop in a permuted (but still total) order.  The
    dispatcher only reads slots 0/2/3, so the key shape is free."""

    def at(t: float, fn: str, *args):
        heapq.heappush(
            eng._heap, (t, (rng.random(), next(eng._seq)), fn, args)
        )

    eng.at = at
    return eng


def _digest(eng: _MultiSim) -> str:
    """Canonical state digest at the current instant.  Queue contents enter
    as sorted multisets of ``(job, size, kind)`` — chunk ids, sources and
    insertion order are deliberately excluded so benign same-timestamp
    reorderings of identical work hash identically."""
    parts: List[object] = [repr(eng.now)]
    for g in eng.runs:
        parts.append((
            g.idx, g.seeded,
            repr((g.pushed_mb, g.landed_mb, g.mapped_mb, g.shuf_created_mb,
                  g.shuf_landed_mb, g.reduced_mb)),
            repr((g.push_end, g.map_end, g.shuffle_end, g.reduce_end,
                  g.wasted_mb)),
            repr((g.lost_mb, g.reexec_mb)),
            g.recovered, g.total_map_chunks,
            tuple(g.push_inflight.tolist()),
            tuple(g.map_unfinished.tolist()),
            tuple(g.shuf_inflight.tolist()),
            tuple(g.reduce_outstanding.tolist()),
            tuple(g.map_alive.tolist()),
            tuple(g.red_alive.tolist()),
            tuple(g.reducer_final.tolist()),
            # provenance enters as per-reducer sorted multisets: *which*
            # equal-size chunk a reducer served first is a benign
            # same-timestamp reordering (sources are excluded from the
            # canon), and even column *sums* pick up ULP noise from
            # accumulation order — the multiset is exact
            tuple(tuple(sorted(repr(v) for v in col))
                  for col in np.asarray(g.reduced_by).T.tolist()),
            repr(tuple(g.dep_landed.tolist())),
            repr(tuple(g.delivered_out.tolist())),
            tuple(sorted((i, tuple(sorted(s)))
                         for i, s in g.dep_pending.items())),
            tuple(tuple(sorted(repr(c.size) for c in gated))
                  for gated in g.map_gated),
            tuple(tuple(sorted((k, repr(sc.size)) for k, sc in gated))
                  for gated in g.shuf_gated),
            tuple(tuple(sorted(repr(sc.size) for sc in gated))
                  for gated in g.red_gated),
        ))

    def link_state(link):
        cur = link.current
        return (
            link.name, link.busy, link.down,
            None if cur is None else (cur.run.idx, repr(cur.size), cur.fn),
            tuple(sorted((tr.run.idx, repr(tr.size), tr.fn)
                         for tr in link.queue)),
            repr((link.stats.busy_s, link.stats.waited_s,
                  link.stats.volume_mb, link.stats.n_chunks)),
        )

    def node_state(node):
        return (
            node.name, node.busy,
            None if node.current is None else (
                node.current.idx,
                repr(node.current_chunk.size)
                if node.current_chunk is not None else None,
            ),
            tuple(sorted((h.idx, repr(c.size)) for h, c, _ in node.queue)),
            repr((node.stats.busy_s, node.stats.waited_s,
                  node.stats.volume_mb, node.stats.n_chunks)),
        )

    for row in eng.push_links + eng.shuf_links:
        parts.extend(link_state(link) for link in row)
    parts.extend(node_state(n) for n in eng.mappers + eng.reducers)
    return hashlib.sha256(repr(parts).encode()).hexdigest()


#: one drained timestamp: (time, state digest, sorted event-name batch)
Step = Tuple[float, str, Tuple[str, ...]]


def trajectory(eng: _MultiSim) -> List[Step]:
    """Drain the engine, emitting one canonical state digest per distinct
    event timestamp (all same-time events are processed before hashing)."""
    eng._start()
    steps: List[Step] = []
    while eng._heap:
        t = eng._heap[0][0]
        batch: List[str] = []
        while eng._heap and eng._heap[0][0] == t:
            batch.append(eng._heap[0][2])
            eng._dispatch()
        steps.append((t, _digest(eng), tuple(sorted(batch))))
    if eng._audit:
        eng._audit_final()
    return steps


@dataclasses.dataclass(frozen=True)
class Divergence:
    """One detected event-order race."""

    scenario: str
    permutation: int
    time: float
    detail: str

    def __str__(self) -> str:
        return (f"{self.scenario}: permutation {self.permutation} diverges "
                f"at t={self.time:.6f}: {self.detail}")


def _compare(scenario: str, perm: int, base: List[Step],
             other: List[Step]) -> Optional[Divergence]:
    for i, ((ta, ha, ea), (tb, hb, eb)) in enumerate(zip(base, other)):
        if ta != tb or ha != hb:
            return Divergence(
                scenario, perm, min(ta, tb),
                f"step {i}: t={ta:.6f} events={list(ea)} vs "
                f"t={tb:.6f} events={list(eb)}",
            )
    if len(base) != len(other):
        i = min(len(base), len(other))
        longer = base if len(base) > len(other) else other
        return Divergence(
            scenario, perm, longer[i][0],
            f"trajectory lengths differ: {len(base)} vs {len(other)} steps",
        )
    return None


def _canon9(v):
    """Canonicalize floats to 9 significant digits: fluid state evolves by
    ``rem -= rate * dt``, so splitting an interval at a steering boundary
    legitimately perturbs the last ULP — a real steering leak is
    macroscopic, so 9 digits keeps the digest byte-stable without hiding
    one."""
    if isinstance(v, float):
        return f"{v:.9g}"
    if isinstance(v, dict):
        return tuple(sorted((k, _canon9(x)) for k, x in v.items()))
    if isinstance(v, (list, tuple)):
        return tuple(_canon9(x) for x in v)
    return v


def _fluid_digest(snap) -> str:
    """Canonical fluid-state digest: every residual bucket of every job,
    plus the per-resource backlog."""
    parts: List[object] = [_canon9(float(snap.time))]
    for pr in snap.jobs:
        parts.append((
            pr.job, pr.released, pr.done,
            _canon9(pr.resid_push.tolist()),
            _canon9(pr.committed_push.tolist()),
            _canon9(pr.at_mapper.tolist()),
            _canon9(pr.shuffle_pool.tolist()),
            _canon9(pr.committed_shuffle.tolist()),
            _canon9(pr.at_reducer.tolist()),
        ))
    parts.append(_canon9(dict(snap.backlog)))
    return hashlib.sha256(repr(parts).encode()).hexdigest()


def _fluid_trajectory(build, cuts: Tuple[float, ...] = ()):
    """Drain a fluid engine, digesting its state on a fixed observation
    grid that brackets every capacity-drift step.  Extra steering ``cuts``
    are run_until boundaries only — they must not change any digest."""
    eng = build()
    drift = tuple(getattr(eng.sub, "drift_times", tuple)())
    grid = sorted({30.0} | set(drift) | {t + 7.5 for t in drift})
    steps: List[Step] = []
    for t in sorted(set(grid) | set(cuts)):
        eng.run_until(t)
        if t in grid:
            steps.append((t, _fluid_digest(eng.snapshot()), ("observe",)))
    res = eng.run()
    final = hashlib.sha256(
        repr(_canon9(res.as_dict())).encode()).hexdigest()
    steps.append((round(res.makespan, 6), final, ("final",)))
    return steps, res.makespan


def _fluid_split_audit(
    name: str, build, k: int, seed: int
) -> List[Divergence]:
    """Determinism for the flow executor: there are no same-timestamp
    tie-breaks to permute, so the audited property is *split invariance* —
    ``k`` runs steered through random ``run_until`` boundaries (which
    straddle the drift steps) must reproduce the unsteered digests and
    final result exactly."""
    base, makespan = _fluid_trajectory(build)
    out: List[Divergence] = []
    rng = np.random.default_rng(seed)
    for i in range(1, k + 1):
        cuts = tuple(float(c) for c in rng.uniform(0.0, makespan, size=3))
        div = _compare(name, i, base, _fluid_trajectory(build, cuts)[0])
        if div is not None:
            out.append(div)
    return out


def determinism_audit(
    name: str, build: Callable[[], _MultiSim], k: int = 5, seed: int = 0
) -> List[Divergence]:
    """Run ``build()`` once in natural order and ``k`` times with permuted
    same-timestamp tie-breaks; report every trajectory divergence.  Fluid
    engines have no event heap to permute — they get the split-invariance
    audit of :func:`_fluid_split_audit` instead."""
    if not hasattr(build(), "_dispatch"):  # a FluidSim
        return _fluid_split_audit(name, build, k=k, seed=seed)
    base = trajectory(build())
    out: List[Divergence] = []
    for i in range(1, k + 1):
        eng = patch_tiebreak(build(), np.random.default_rng(seed + i))
        div = _compare(name, i, base, trajectory(eng))
        if div is not None:
            out.append(div)
    return out


# ---------------------------------------------------------------------------
# conservation + snapshot audits
# ---------------------------------------------------------------------------


def conservation_audit(build: Callable[[], _MultiSim]) -> List[str]:
    """Drain a fresh engine and return its runtime-audit violations
    (the builder's ``SimConfig(audit=True)`` does the checking)."""
    return build().run().violations


def swap_conservation_audit() -> List[str]:
    """Conservation through the steered path: run the shared-online
    scenario to t=120 (past the reducer brown-out), swap job 0 onto a
    re-balanced plan — exercising the pull-back/re-split ledger — and
    drain."""
    eng = _shared_online_engine()
    eng.run_until(120.0)
    steady = eng.runs[0].p
    nM, nR = steady.nM, steady.nR
    x = np.zeros((steady.nS, nM))
    x[0], x[1] = (0.5, 0.5, 0.0, 0.0), (0.5, 0.5, 0.0, 0.0)
    x[2], x[3] = (0.0, 0.0, 0.5, 0.5), (0.0, 0.0, 0.5, 0.5)
    eng.swap_plan(0, ExecutionPlan(x=x, y=np.full(nR, 1.0 / nR)))
    return eng.run().violations


def snapshot_audit(
    build: Callable[[], _MultiSim], dt: float = 10.0, horizon: float = 1e5
) -> List[str]:
    """Sample :meth:`_MultiSim.snapshot` on a fixed grid: residual buckets
    must be non-negative always, and monotone non-increasing for jobs where
    nothing re-adds work (not stage-linked, no failure injection)."""
    eng = build()
    problems: List[str] = []
    last: Dict[int, Dict[str, float]] = {}
    t = 0.0
    eng.run_until(0.0)
    while not eng.finished and t < horizon:
        snap = eng.snapshot()
        for prog in snap.jobs:
            rem = prog.remaining_mb()
            g = eng.runs[prog.job]
            for phase, mb in rem.items():
                if mb < -1e-6:
                    problems.append(
                        f"t={snap.time:.1f}: job {prog.job}: negative "
                        f"{phase} residual {mb:.6f}"
                    )
            monotone = (not getattr(g, "stage_deps", None)
                        and not g.cfg.failures
                        and not getattr(eng.sub, "failures", None))
            if monotone and prog.job in last:
                for phase, mb in rem.items():
                    if mb > last[prog.job][phase] + 1e-6:
                        problems.append(
                            f"t={snap.time:.1f}: job {prog.job}: {phase} "
                            f"residual grew {last[prog.job][phase]:.3f} -> "
                            f"{mb:.3f}"
                        )
            last[prog.job] = rem
        t += dt
        eng.run_until(t)
    return problems


# ---------------------------------------------------------------------------
# the full audit suite
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AuditReport:
    """Everything the ``python -m repro.analysis`` audit stage produces."""

    violations: Dict[str, List[str]]
    divergences: List[Divergence]
    race_detected: bool  # the deliberately-raced fixture must diverge

    @property
    def ok(self) -> bool:
        return (not any(self.violations.values())
                and not self.divergences and self.race_detected)

    def lines(self) -> List[str]:
        out = []
        for name, probs in self.violations.items():
            out.extend(f"{name}: {p}" for p in probs)
        out.extend(str(d) for d in self.divergences)
        if not self.race_detected:
            out.append(
                "raced fixture: determinism audit failed to detect the "
                "planted same-timestamp race"
            )
        return out


def run_all(k: int = 5, seed: int = 0) -> AuditReport:
    """Conservation + snapshot + determinism over every quick scenario,
    the steered swap path, and the planted-race self-check."""
    violations: Dict[str, List[str]] = {}
    divergences: List[Divergence] = []
    for name, build in QUICK_SCENARIOS:
        probs = conservation_audit(build)
        probs.extend(snapshot_audit(build))
        violations[name] = probs
        divergences.extend(determinism_audit(name, build, k=k, seed=seed))
    violations["shared_online_swap"] = swap_conservation_audit()
    race = determinism_audit("raced_fixture", raced_engine, k=k, seed=seed)
    return AuditReport(
        violations=violations,
        divergences=divergences,
        race_detected=bool(race),
    )
