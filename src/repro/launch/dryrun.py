import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede any jax import/init: jax locks the device count on first use.

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh).

For each cell this script

  1. builds the production mesh (16×16 single pod / 2×16×16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for the inputs (and the decode
     cache / train state) — no device allocation ever happens,
  3. jits the right step function (train_step / prefill / serve_step) with
     explicit in/out shardings,
  4. ``lower().compile()`` — a sharding mismatch, compile-time OOM or
     unsupported collective here is a bug in the framework,
  5. records ``memory_analysis()`` / ``cost_analysis()`` and the collective
     traffic parsed from the partitioned HLO into a JSON report that the
     roofline analysis (benchmarks/roofline.py, EXPERIMENTS.md) consumes.

Usage::

    python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both --out reports/
"""
import argparse
import functools
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    SHAPES, cache_specs, cells, get_config, input_specs, padded_for_tp,
)
from repro.launch.mesh import batch_axes, make_production_mesh
from repro.models import model as M
from repro.models.sharding import DEFAULT_RULES, axis_rules
from repro.train.train_step import TrainConfig, init_state, make_train_step, state_shardings

__all__ = ["run_cell", "collective_bytes_from_hlo"]

_COLL_RE = re.compile(
    r"(?P<shapes>(?:\(?\s*(?:[a-z0-9]+)\[[0-9,]*\][^=]*?)) "
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Per-device bytes transported by each collective kind, from the
    *partitioned* HLO (shapes in the SPMD module are per-partition).

    Ring-model accounting per op (S = per-partition result bytes, G =
    replica-group size): all-reduce 2·S·(G−1)/G, all-gather S·(G−1)/G,
    reduce-scatter S·(G−1) (operand = G·S), all-to-all S·(G−1)/G,
    collective-permute S.
    """
    out: Dict[str, float] = {
        "all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
        "all-to-all": 0.0, "collective-permute": 0.0,
    }
    for line in hlo.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        _, _, rhs = line.partition("=")
        # HLO: %name = <result-type> <opcode>(operands...); the result type
        # may itself be a tuple "(f32[..], ..)" so locate the opcode token
        # directly and take every shape that precedes it.
        om = re.match(
            r"(?P<res>[^=]*?)\s(?P<op>all-reduce|all-gather|reduce-scatter|"
            r"all-to-all|collective-permute)(?P<start>-start)?\(",
            rhs,
        )
        if om is None:
            continue
        m = om.group("op")
        shapes = _SHAPE_RE.findall(om.group("res"))
        if not shapes:
            continue
        size = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = 1
        gm = _GROUP_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm2 = _GROUP_RE2.search(line)
            if gm2:
                g = int(gm2.group(2))
        if g <= 1:
            continue
        if m == "all-reduce":
            out[m] += 2.0 * size * (g - 1) / g
        elif m == "all-gather":
            out[m] += size * (g - 1) / g
        elif m == "reduce-scatter":
            out[m] += float(size) * (g - 1)
        elif m == "all-to-all":
            out[m] += size * (g - 1) / g
        else:  # collective-permute
            out[m] += float(size)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _cost_dict(cost):
    """``Compiled.cost_analysis()`` returns a dict on newer jax and a list
    of per-device dicts on older jax — normalize to one dict."""
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost or {}


def _batch_shardings(specs: Dict[str, jax.ShapeDtypeStruct], mesh):
    """Input shardings: batch dim over (pod, data) when divisible."""
    baxes = batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def sh(s):
        dims: list = [None] * len(s.shape)
        if len(s.shape) >= 1 and s.shape[0] % nb == 0 and nb > 1:
            dims[0] = bspec
        return NamedSharding(mesh, P(*dims))

    return {k: sh(v) for k, v in specs.items()}


def _cache_shardings(cache_shape, mesh, B: int):
    """Decode-cache shardings: batch over (pod, data) when divisible, the
    head/feature dim over 'model'."""
    baxes = batch_axes(mesh)
    nb = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    bspec = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def sh(leaf):
        dims = [None] * len(leaf.shape)
        # leaves: k/v (G,B,H,S,D); ssm h (G,B,Di,Ds); conv (G,B,K,Di|w)
        if len(leaf.shape) >= 2 and leaf.shape[1] == B and B % nb == 0 and nb > 1:
            dims[1] = bspec
        if len(leaf.shape) == 5:  # attn kv: shard heads over model
            if leaf.shape[2] % mesh.shape["model"] == 0:
                dims[2] = "model"
        elif len(leaf.shape) == 4:  # ssm h: (G,B,Di,Ds) — Di over model
            if leaf.shape[2] % mesh.shape["model"] == 0:
                dims[2] = "model"
        elif len(leaf.shape) == 3:  # conv (G?,B,..) fallback replicate tail
            pass
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(sh, cache_shape)


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool,
    compute_dtype=jnp.bfloat16,
    donate: bool = True,
    mesh=None,
    reduced: bool = False,
    analysis: bool = True,
    variant: str = "baseline",  # baseline | infer_tp | kv_int8 | infer_tp+kv_int8
    microbatches: int = 1,
) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh) cell; return the report.

    ``mesh``/``reduced`` exist for the CI-scale smoke path (tiny mesh on a
    handful of fake devices); the deliverable sweep uses the production
    meshes."""
    cfg_orig = get_config(arch)
    if reduced:
        cfg_orig = cfg_orig.reduced()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    # TP-divisibility padding (exact semantics; waste shows up in the
    # MODEL_FLOPS/HLO_FLOPS roofline ratio, which uses the ORIGINAL config).
    cfg = padded_for_tp(cfg_orig, mesh.shape["model"])
    spec = SHAPES[shape]
    report: Dict[str, Any] = {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "kind": spec.kind,
        "model_params": cfg_orig.n_params(),
        "model_active_params": cfg_orig.n_active_params(),
        "padded_params": cfg.n_params(),
        "padded_active_params": cfg.n_active_params(),
    }
    from repro.launch.analysis import attention_flops

    report["attn_flops_total"] = attention_flops(
        cfg, spec.kind,
        B=spec.global_batch,
        T=spec.seq_len if spec.kind != "decode" else 1,
        cache_len=spec.seq_len if spec.kind == "decode" else 0,
    )
    report["variant"] = variant
    report["microbatches"] = microbatches
    kv_int8 = "kv_int8" in variant
    rules = DEFAULT_RULES
    if "infer_tp" in variant and spec.kind != "train":
        from repro.models.sharding import INFERENCE_RULES

        rules = INFERENCE_RULES
    t0 = time.time()
    with axis_rules(mesh, rules):
        specs = input_specs(cfg, shape, dtype=compute_dtype)
        in_sh_batch = _batch_shardings(specs, mesh)

        def build(unroll: bool):
            if spec.kind == "train":
                tcfg = TrainConfig(compute_dtype=compute_dtype, remat=True,
                                   use_kernels=False, unroll_groups=unroll,
                                   microbatches=microbatches)
                step = make_train_step(cfg, tcfg, mesh=mesh)
                params_shape = jax.eval_shape(
                    functools.partial(M.init, cfg, tp=mesh.shape["model"]),
                    jax.random.PRNGKey(0),
                )
                state_shape = jax.eval_shape(
                    functools.partial(init_state, cfg), params_shape
                )
                st_sh = state_shardings(cfg, state_shape, mesh)
                return jax.jit(
                    step,
                    in_shardings=(st_sh, in_sh_batch),
                    donate_argnums=(0,) if donate else (),
                ).lower(state_shape, specs)
            params_shape = jax.eval_shape(
                functools.partial(M.init, cfg, tp=mesh.shape["model"]),
                jax.random.PRNGKey(0),
            )
            p_sh = jax.tree.map(
                lambda s: NamedSharding(mesh, s),
                M.param_shardings(cfg, params_shape),
            )
            if spec.kind == "prefill":
                last_only = "last_only" in variant

                def prefill_fn(params, batch):
                    logits, cache, _ = M.prefill(
                        cfg, params, batch, max_cache_len=spec.seq_len,
                        mesh=mesh, compute_dtype=compute_dtype,
                        unroll_groups=unroll, last_only=last_only,
                    )
                    return logits[:, -1], cache

                return jax.jit(
                    prefill_fn, in_shardings=(p_sh, in_sh_batch)
                ).lower(params_shape, specs)
            # decode (serve_step: one token against a seq_len cache)
            cache_shape = cache_specs(cfg, shape, dtype=compute_dtype,
                                      kv_int8=kv_int8)
            c_sh = _cache_shardings(cache_shape, mesh, spec.global_batch)

            def serve_step(params, batch, cache):
                logits, new_cache, _ = M.decode_step(
                    cfg, params, batch, cache, mesh=mesh,
                    compute_dtype=compute_dtype, unroll_groups=unroll,
                )
                return logits[:, -1], new_cache

            return jax.jit(
                serve_step,
                in_shardings=(p_sh, in_sh_batch, c_sh),
                donate_argnums=(2,) if donate else (),
            ).lower(params_shape, specs, cache_shape)

        # --- production build (rolled scan): memory truth --------------------
        lowered = build(unroll=False)
        report["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        report["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        if mem is not None:
            for attr in (
                "temp_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                v = getattr(mem, attr, None)
                if v is not None:
                    report[attr] = int(v)
            total = sum(
                report.get(k, 0)
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes")
            ) - report.get("alias_size_in_bytes", 0)
            report["per_device_bytes"] = int(total)
        cost = _cost_dict(compiled.cost_analysis())
        if cost:
            report["hlo_flops_per_device_rolled"] = float(cost.get("flops", -1))
            report["hlo_bytes_per_device_rolled"] = float(
                cost.get("bytes accessed", -1)
            )
        hlo = compiled.as_text()
        report["collectives_per_device_bytes_rolled"] = (
            collective_bytes_from_hlo(hlo)
        )
        report["hlo_size_chars"] = len(hlo)

        # --- analysis build (group scan unrolled): flop/traffic truth --------
        # XLA's cost_analysis counts while-loop bodies ONCE (verified in
        # EXPERIMENTS.md §Dry-run); unrolling the layer-group scan makes
        # FLOPs/bytes/collectives per-layer-correct.  The chunked-attention
        # inner scans remain rolled; their matmul FLOPs are added
        # analytically by benchmarks/roofline.py.
        if analysis:
            t2 = time.time()
            compiled_u = build(unroll=True).compile()
            report["analysis_compile_s"] = round(time.time() - t2, 2)
            cost_u = _cost_dict(compiled_u.cost_analysis())
            if cost_u:
                report["hlo_flops_per_device"] = float(cost_u.get("flops", -1))
                report["hlo_bytes_per_device"] = float(
                    cost_u.get("bytes accessed", -1)
                )
            report["collectives_per_device_bytes"] = collective_bytes_from_hlo(
                compiled_u.as_text()
            )
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod
    ]

    failures = []
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_done and os.path.exists(path):
                print(f"[skip] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                # the unrolled analysis build feeds the (single-pod-only)
                # roofline table; multi-pod cells prove sharding + memory.
                rep = run_cell(arch, shape, mp, analysis=not mp)
                with open(path, "w") as f:
                    json.dump(rep, f, indent=1)
                print(
                    f"  ok: compile={rep['compile_s']}s "
                    f"mem/dev={rep.get('per_device_bytes', -1)/2**30:.2f}GiB "
                    f"flops/dev={rep.get('hlo_flops_per_device', -1):.3g} "
                    f"coll/dev={rep['collectives_per_device_bytes']['total']/2**20:.1f}MiB",
                    flush=True,
                )
            except Exception as e:  # a failing cell is a framework bug
                failures.append((tag, repr(e)))
                with open(path + ".FAILED", "w") as f:
                    f.write(traceback.format_exc())
                print(f"  FAILED: {e}", flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nall cells compiled.")


if __name__ == "__main__":
    main()
