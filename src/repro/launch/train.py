"""Training launcher: geo-planned data ingest, fault-tolerant checkpointing,
elastic restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto

Production posture (what transfers to a real fleet):

* **--resume auto** restores the newest *committed* checkpoint (a crashed
  save can never be restored), and the data pipeline fast-forwards to the
  restored step — bitwise-identical batch order after recovery.
* checkpoints are written asynchronously off the training loop, with
  retention + milestones.
* **--mesh DxM / --multi-pod** lay the job out on (data, model[, pod]) and
  shard params/optimizer FSDP×TP via the same rules the dry-run proves at
  16×16 and 2×16×16.  A checkpoint taken on one mesh restores onto any
  other (elastic re-shard: arrays are stored unsharded).
* **--compression int8|bf16** enables error-feedback gradient compression
  for the cross-pod hop.
* **--geo-ingest** plans the corpus push with the paper's optimizer and
  logs the modeled ingest time vs a myopic plan.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, padded_for_tp
from repro.core.platform import tpu_pod_platform
from repro.data.pipeline import GeoDataPipeline
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.models.sharding import DEFAULT_RULES, axis_rules
from repro.train.checkpoint import CheckpointManager
from repro.train.optim import AdamWConfig, cosine_schedule
from repro.train.train_step import (
    TrainConfig, init_state, make_train_step, state_shardings,
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--mesh", default=None,
                    help="DxM, e.g. 2x2 (needs that many devices)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--geo-ingest", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--dtype", default="float32",
                    choices=["float32", "bfloat16"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    mesh = None
    if args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        mesh = make_mesh((d, m), ("data", "model"))
        cfg = padded_for_tp(cfg, m)

    dtype = jnp.bfloat16 if args.dtype == "bfloat16" else jnp.float32
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr),
        microbatches=args.microbatches,
        remat=args.remat,
        compute_dtype=dtype,
        compression=args.compression,
    )
    lr_fn = cosine_schedule(args.lr, args.warmup, args.steps)

    # --- geo-planned ingest -------------------------------------------------
    platform = tpu_pod_platform(n_pods=2, hosts_per_pod=4, compute_jitter=0.3,
                                seed=args.seed)
    pipe = GeoDataPipeline(
        platform, vocab=cfg.vocab, batch=args.batch, seq=args.seq,
        seed=args.seed, d_model=cfg.d_model, embeds=cfg.frontend == "embed",
        mode="e2e_push" if args.geo_ingest else "uniform",
    )
    if args.geo_ingest:
        from repro.core.optimize import optimize_plan

        myopic = optimize_plan(platform, "myopic_push", n_restarts=6, steps=200)
        print(f"[ingest] planned={pipe.modeled_ingest_time():.2f}s "
              f"myopic-push={myopic.breakdown['push']:.2f}s")

    # --- init / restore -------------------------------------------------------
    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start_step = 0

    def build_state():
        params = M.init(cfg, jax.random.PRNGKey(args.seed),
                        tp=mesh.shape["model"] if mesh else 1)
        return init_state(cfg, params, seed=args.seed,
                          compression=args.compression)

    with axis_rules(mesh, DEFAULT_RULES):
        state = build_state()
        if mgr and args.resume == "auto" and mgr.latest_step() is not None:
            like = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state
            )
            shard_tree = None
            if mesh is not None:
                shard_tree = state_shardings(cfg, like, mesh)
            state, extras, start_step = mgr.restore(None, like, shard_tree)
            print(f"[resume] restored committed step {start_step}")

        step_fn = make_train_step(cfg, tcfg, mesh=mesh, lr_fn=lr_fn)
        if mesh is not None:
            st_sh = state_shardings(
                cfg,
                jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                             state),
                mesh,
            )
            step_fn = jax.jit(step_fn, in_shardings=(st_sh, None),
                              out_shardings=(st_sh, None),
                              donate_argnums=(0,))
        else:
            step_fn = jax.jit(step_fn, donate_argnums=(0,))

        pipe.start(from_step=start_step)
        t_last = time.time()
        try:
            for s in range(start_step, args.steps):
                _, batch_np = next(pipe)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                state, metrics = step_fn(state, batch)
                if (s + 1) % args.log_every == 0 or s + 1 == args.steps:
                    dt = time.time() - t_last
                    t_last = time.time()
                    tok_s = args.batch * args.seq * args.log_every / max(dt, 1e-9)
                    print(
                        f"step {s+1:5d} loss={float(metrics['loss']):.4f} "
                        f"gnorm={float(metrics['grad_norm']):.2f} "
                        f"lr={float(metrics['lr']):.2e} tok/s={tok_s:.0f}",
                        flush=True,
                    )
                if mgr and (s + 1) % args.ckpt_every == 0:
                    mgr.save_async(s + 1, state, extras={"arch": cfg.name})
            if mgr:
                mgr.save(args.steps, state, extras={"arch": cfg.name},
                         milestone=True)
        finally:
            pipe.stop()
            if mgr:
                mgr.wait()
    print("[train] done")


if __name__ == "__main__":
    main()
