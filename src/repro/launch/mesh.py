"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — the dry-run sets its
fake-device XLA flag before any jax initialization.

Mesh layouts:

* single pod: ``(data=16, model=16)`` — 256 chips (one v5e pod).
  DP/FSDP over ``data``, TP/EP over ``model``.
* multi-pod: ``(pod=2, data=16, model=16)`` — 512 chips.  The ``pod`` axis
  is the DCN dimension: batch parallelism across pods, gradient reduction
  hierarchically scheduled (reduce-scatter on ICI, cross-pod on DCN,
  all-gather on ICI — see repro.train.collective_schedule).

Generalization to ``(P, D, T)`` is direct: the same axis names drive all
sharding rules, so a 16-pod 4096-chip job only changes the shape tuple.
"""
from __future__ import annotations

from typing import Tuple

import jax

try:  # jax >= 0.5 names explicit/auto axis types; older jax has no kwarg
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}
except ImportError:
    _AXIS_KW = lambda n: {}

__all__ = ["make_production_mesh", "make_mesh", "batch_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh with the framework's axis conventions."""
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(shape)))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
