"""Serving launcher: continuous-batching engine over a (reduced or full)
arch config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 16 --slots 4 --max-new 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import Request, ServeConfig, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend == "embed":
        raise SystemExit(f"{cfg.name} is a stub-frontend arch; serve a "
                         "token-in arch (e.g. qwen3-1.7b)")
    params = M.init(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params,
                      ServeConfig(slots=args.slots, max_len=args.max_len))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for i in range(args.requests):
        n = int(rng.integers(4, 24))
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
            max_new_tokens=args.max_new,
        ))
    done = eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, {eng.step_count} decode steps")
    for r in done[:3]:
        print(f"  rid={r.rid} ttft_steps={r.ttft_steps} out={r.output[:8]}...")


if __name__ == "__main__":
    main()
