"""Analytic FLOP accounting for the pieces HLO cost analysis cannot see.

The dry-run's analysis build unrolls the layer-group scan, so per-layer
matmuls/collectives are counted exactly — but the chunked-attention inner
scans (and the decode path's cache attention) stay rolled, and XLA counts
while bodies once.  Attention score/value contractions are plain matmuls
with exactly known shapes, so we add them analytically:

    fwd attention FLOPs / layer = 4 · B · Σ_t S_eff(t) · H · Dh

with ``S_eff(t)`` the causal (and windowed) visible context, and a 4×
multiplier for training (fwd + 2× bwd + 1× remat re-forward).
"""
from __future__ import annotations

from typing import Optional

from repro.models.config import ArchConfig

__all__ = ["attention_flops", "visible_context_sum"]


def visible_context_sum(T: int, q_offset: int, window: Optional[int]) -> float:
    """Σ over queries at absolute positions q_offset..q_offset+T-1 of the
    number of visible keys under causal (+ optional window) masking."""
    total = 0.0
    # closed forms per regime to stay O(1)
    lo, hi = q_offset, q_offset + T - 1
    if window is None:
        # Σ (t+1) for t in [lo, hi]
        return (hi + 1 + lo + 1) * T / 2.0
    w = window
    # below the window fill-up point, t+1 keys; after, exactly w
    fill_end = min(hi, w - 1)
    if lo <= fill_end:
        n = fill_end - lo + 1
        total += (fill_end + 1 + lo + 1) * n / 2.0
    rest = hi - max(lo, w - 1 + 1) + 1
    if rest > 0:
        total += rest * w
    return total


def attention_flops(cfg: ArchConfig, kind: str, B: int, T: int,
                    cache_len: int = 0) -> float:
    """Total attention matmul FLOPs for one step across all devices."""
    H, Dh = cfg.n_heads, cfg.head_dim_
    blocks = [(b, cfg.n_groups) for b in cfg.pattern] + [(b, 1) for b in cfg.tail]
    total = 0.0
    for blk, reps in blocks:
        if blk.mixer != "attn":
            continue
        if kind == "decode":
            # one query per row against the populated cache
            s_sum = min(cache_len, blk.window) if blk.window else cache_len
            s_sum = float(s_sum) * T
        else:
            s_sum = visible_context_sum(T, 0, blk.window)
        total += reps * 4.0 * B * s_sum * H * Dh
    mult = 4.0 if kind == "train" else 1.0
    return total * mult
