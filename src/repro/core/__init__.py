"""GeoPlan core — the paper's contribution as a composable JAX library.

* :mod:`repro.core.platform` — tripartite platform model (§2.1).
* :mod:`repro.core.plan` — valid execution plans (§2.2, Eqs 1–3).
* :mod:`repro.core.makespan` — differentiable makespan model (Eqs 4–14,
  G/L/P barrier semantics).
* :mod:`repro.core.optimize` — plan optimization (§2.3; MIP replaced by an
  annealed smooth-max multi-restart gradient solver, validated by brute
  force and by the paper's own linearization in :mod:`repro.core.milp`).
* :mod:`repro.core.simulate` — chunk-granular discrete-event executor with
  the paper's dynamic mechanisms (speculation, stealing) plus stragglers,
  failures and replication.
* :mod:`repro.core.fluid` — flow-level executor for the scale tier
  (``SimConfig(mode="fluid")``): continuous flows at shared service
  rates, same steering surface as the DES.
* :mod:`repro.core.topology` — 3-tier edge→region→backbone substrate and
  job-mix generators for the 10²–10³-node scale tiers.
* :mod:`repro.core.collective_plan` — the technique applied to multi-pod
  gradient aggregation.
* :mod:`repro.core.moe_plan` — the technique applied to MoE dispatch.
"""
from .fluid import FluidSim
from .makespan import (
    BARRIERS_ALL_GLOBAL,
    BARRIERS_ALL_PIPELINED,
    BARRIERS_GGL,
    CostModel,
    JobProgress,
    makespan,
    makespan_model,
    phase_breakdown,
    residual_volumes,
    shared_effective_volumes,
)
from .optimize import (
    MODES,
    SCHEDULE_OBJECTIVES,
    OnlineConfig,
    PipelinePlanResult,
    PlanResult,
    SchedulePlanResult,
    ScheduleReplanResult,
    SolveTimeEMA,
    SolverService,
    available_modes,
    available_online_policies,
    available_pipeline_modes,
    available_policies,
    brute_force_plan,
    get_online_config,
    get_online_policy,
    get_pipeline_planner,
    get_planner,
    get_schedule_planner,
    optimize_pipeline,
    optimize_plan,
    optimize_plan_batch,
    optimize_schedule,
    register_online_policy,
    register_pipeline_planner,
    register_planner,
    register_schedule_planner,
    replan,
    replan_batch,
    replan_schedule,
    reset_solver_cache_stats,
    score_residual_shared,
    solver_cache_occupancy,
    solver_cache_stats,
    swap_charge,
)
from .pipeline import PipelineSpec, StageSpec, chain_spec
from .plan import ExecutionPlan, local_push_plan, uniform_plan
from .platform import (
    CapacityTrace,
    FailureEvent,
    FailureTrace,
    Platform,
    Substrate,
    planetlab_platform,
    tpu_pod_platform,
    two_cluster_example,
)
from .simulate import (
    ProgressSnapshot,
    ResourceStats,
    ScheduleSimResult,
    SimConfig,
    SimResult,
    open_schedule,
    simulate,
    simulate_schedule,
)
from .topology import scale_job_mix, scale_tier_substrate

__all__ = [
    "BARRIERS_ALL_GLOBAL",
    "BARRIERS_ALL_PIPELINED",
    "BARRIERS_GGL",
    "CapacityTrace",
    "CostModel",
    "ExecutionPlan",
    "FailureEvent",
    "FailureTrace",
    "FluidSim",
    "JobProgress",
    "MODES",
    "OnlineConfig",
    "PipelinePlanResult",
    "PipelineSpec",
    "Platform",
    "PlanResult",
    "ProgressSnapshot",
    "StageSpec",
    "ResourceStats",
    "SCHEDULE_OBJECTIVES",
    "SchedulePlanResult",
    "ScheduleReplanResult",
    "ScheduleSimResult",
    "SimConfig",
    "SimResult",
    "SolveTimeEMA",
    "SolverService",
    "Substrate",
    "available_modes",
    "available_online_policies",
    "available_pipeline_modes",
    "available_policies",
    "brute_force_plan",
    "chain_spec",
    "get_online_config",
    "get_online_policy",
    "get_pipeline_planner",
    "get_planner",
    "get_schedule_planner",
    "local_push_plan",
    "open_schedule",
    "register_online_policy",
    "register_pipeline_planner",
    "register_planner",
    "register_schedule_planner",
    "makespan",
    "makespan_model",
    "optimize_pipeline",
    "optimize_plan",
    "optimize_plan_batch",
    "optimize_schedule",
    "phase_breakdown",
    "planetlab_platform",
    "replan",
    "replan_batch",
    "replan_schedule",
    "reset_solver_cache_stats",
    "residual_volumes",
    "scale_job_mix",
    "scale_tier_substrate",
    "score_residual_shared",
    "solver_cache_occupancy",
    "solver_cache_stats",
    "swap_charge",
    "shared_effective_volumes",
    "simulate",
    "simulate_schedule",
    "tpu_pod_platform",
    "two_cluster_example",
    "uniform_plan",
]
