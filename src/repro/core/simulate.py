"""Discrete-event execution of a plan on a modeled platform.

The paper validates its analytic model against a modified Hadoop running on
an emulated (``tc``-shaped) testbed.  This container offers a single CPU, so
we do the analogous thing in software: a **chunk-granular discrete-event
executor** that runs an execution plan over the platform model, serializing
chunks on links and compute nodes, honoring the barrier configuration, and —
unlike the analytic model — supporting the *dynamic* mechanisms the paper
compares against (§4.6.4) and the failure modes a production deployment must
survive:

* **speculative execution** — when a node goes idle, unstarted work queued at
  a node whose expected remaining time exceeds ``spec_threshold ×`` the fleet
  mean is *cloned* to the idle node (first copy to finish wins; an
  already-started clone is wasted work, as in Hadoop);
* **work stealing** — idle nodes *take* (rather than clone) unstarted chunks
  from the most backlogged peer, re-fetching inputs from the source;
* **stragglers** — per-node slowdown factors unknown to the planner;
* **node failure** — a mapper dies at a given time; its unfinished work is
  re-fetched from the data source (or nearest replica) and re-queued on the
  best surviving node;
* **replication** — push chunks are written ``replication×``, optionally
  across clusters (paper §4.6.5), consuming link capacity and speeding up
  recovery.

The executor is used by the Fig-4 validation benchmark (model-vs-execution
correlation), the Fig-10/11 dynamics study, and the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .makespan import BARRIERS_GGL, _check_barriers
from .plan import ExecutionPlan
from .platform import Platform

__all__ = ["SimConfig", "SimResult", "simulate"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    chunk_mb: float = 64.0
    barriers: Tuple[str, str, str] = BARRIERS_GGL
    speculation: bool = False
    stealing: bool = False
    spec_threshold: float = 1.5
    replication: int = 1
    cross_cluster_replication: bool = False
    #: per-node compute slowdown factors applied at runtime (unknown to the
    #: planner): {("m"| "r", node_index): factor >= 1}
    stragglers: Optional[Dict[Tuple[str, int], float]] = None
    #: (mapper_index, fail_time_s) — the mapper dies; work is recovered.
    fail_mapper: Optional[Tuple[int, float]] = None
    #: lognormal sigma on per-chunk service times (0 = deterministic).
    compute_noise: float = 0.0
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "barriers", _check_barriers(self.barriers))


@dataclasses.dataclass
class SimResult:
    makespan: float
    push_end: float
    map_end: float
    shuffle_end: float
    reduce_end: float
    wasted_mb: float  # duplicated / re-executed work
    recovered_chunks: int
    total_map_chunks: int

    def phases(self) -> Dict[str, float]:
        return {
            "push": self.push_end,
            "map": max(self.map_end - self.push_end, 0.0),
            "shuffle": max(self.shuffle_end - self.map_end, 0.0),
            "reduce": max(self.reduce_end - self.shuffle_end, 0.0),
            "makespan": self.makespan,
        }


class _Chunk:
    __slots__ = ("cid", "size", "src", "done", "started_copies", "owner", "cloned")

    def __init__(self, cid: int, size: float, src: int, owner: int = -1):
        self.cid = cid
        self.size = size
        self.src = src  # source index for map chunks; mapper index for reduce
        self.done = False
        self.started_copies = 0
        self.owner = owner  # mapper whose gate/progress counters hold it
        self.cloned = False


class _Sim:
    """Event-driven executor.  Events are (time, seq, fn_name, args)."""

    def __init__(self, platform: Platform, plan: ExecutionPlan, cfg: SimConfig):
        self.p = platform
        self.plan = plan
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.now = 0.0
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self._cid = itertools.count()

        nS, nM, nR = platform.nS, platform.nM, platform.nR
        self.push_link_free = np.zeros((nS, nM))
        self.shuf_link_free = np.zeros((nM, nR))
        self.map_free = np.zeros(nM)
        self.red_free = np.zeros(nR)
        self.map_alive = np.ones(nM, dtype=bool)

        self.map_queue: List[List[_Chunk]] = [[] for _ in range(nM)]
        self.red_queue: List[List[_Chunk]] = [[] for _ in range(nR)]
        self.map_busy = np.zeros(nM, dtype=bool)
        self.red_busy = np.zeros(nR, dtype=bool)

        # outstanding counters for gates
        self.push_inflight = np.zeros(nM, dtype=np.int64)
        self.map_unfinished = np.zeros(nM, dtype=np.int64)
        self.shuf_inflight = np.zeros(nR, dtype=np.int64)
        self.total_push_inflight = 0
        self.total_map_unfinished = 0
        self.total_shuf_inflight = 0

        self.push_end = 0.0
        self.map_end = 0.0
        self.shuffle_end = 0.0
        self.reduce_end = 0.0
        self.wasted_mb = 0.0
        self.recovered = 0
        self.total_map_chunks = 0

        # chunks delivered to mapper j but gated (push/map barrier)
        self.map_gated: List[List[_Chunk]] = [[] for _ in range(nM)]
        # shuffle emissions gated at mapper j (map/shuffle barrier)
        self.shuf_gated: List[List[Tuple[int, _Chunk]]] = [[] for _ in range(nM)]
        # reduce chunks gated at reducer k (shuffle/reduce barrier)
        self.red_gated: List[List[_Chunk]] = [[] for _ in range(nR)]

    # -- infrastructure ----------------------------------------------------
    def at(self, t: float, fn: str, *args):
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def run(self) -> SimResult:
        self._seed_push()
        if self.cfg.fail_mapper is not None:
            j, tf = self.cfg.fail_mapper
            self.at(tf, "fail_mapper", j)
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            getattr(self, "_ev_" + fn)(*args)
        return SimResult(
            makespan=self.reduce_end,
            push_end=self.push_end,
            map_end=self.map_end,
            shuffle_end=self.shuffle_end,
            reduce_end=self.reduce_end,
            wasted_mb=self.wasted_mb,
            recovered_chunks=self.recovered,
            total_map_chunks=self.total_map_chunks,
        )

    def _noise(self) -> float:
        if self.cfg.compute_noise <= 0:
            return 1.0
        return float(np.exp(self.rng.normal(0.0, self.cfg.compute_noise)))

    def _rate(self, tier: str, idx: int) -> float:
        base = self.p.C_m[idx] if tier == "m" else self.p.C_r[idx]
        slow = 1.0
        if self.cfg.stragglers:
            slow = self.cfg.stragglers.get((tier, idx), 1.0)
        return base / slow

    # -- push phase ----------------------------------------------------------
    def _seed_push(self):
        cfg, p = self.cfg, self.p
        for i in range(p.nS):
            remaining = p.D[i]
            for j in range(p.nM):
                amount = p.D[i] * self.plan.x[i, j]
                if amount <= 1e-9:
                    continue
                n_chunks = max(int(np.ceil(amount / cfg.chunk_mb)), 1)
                sizes = np.full(n_chunks, amount / n_chunks)
                for s in sizes:
                    c = _Chunk(next(self._cid), float(s), i, owner=j)
                    self.total_map_chunks += 1
                    self.push_inflight[j] += 1
                    self.total_push_inflight += 1
                    self.map_unfinished[j] += 1
                    self.total_map_unfinished += 1
                    self._send_push(i, j, c, replica=False)
                    self._replicate(i, j, s)
            del remaining

    def _replicate(self, i: int, j: int, size: float):
        """Write replication-1 extra copies of a push chunk (replica targets
        never run map work; they only consume link capacity)."""
        p, cfg = self.p, self.cfg
        for r in range(cfg.replication - 1):
            if cfg.cross_cluster_replication:
                candidates = [
                    m for m in range(p.nM) if p.cluster_m[m] != p.cluster_m[j]
                ]
            else:
                candidates = [
                    m
                    for m in range(p.nM)
                    if p.cluster_m[m] == p.cluster_m[j] and m != j
                ]
            if not candidates:
                candidates = [m for m in range(p.nM) if m != j]
            tgt = candidates[(j + r + 1) % len(candidates)]
            start = max(self.now, self.push_link_free[i, tgt])
            end = start + size / self.p.B_sm[i, tgt]
            self.push_link_free[i, tgt] = end
            self.wasted_mb += size
            # the write pipeline is not durable (and the push phase not
            # complete) until every replica is on disk: replica writes gate
            # the ORIGIN mapper's input like any other push chunk.
            self.push_inflight[j] += 1
            self.total_push_inflight += 1
            self.at(end, "replica_done", j)

    def _ev_replica_done(self, j: int):
        self.push_end = max(self.push_end, self.now)
        self.push_inflight[j] -= 1
        self.total_push_inflight -= 1
        b = self.cfg.barriers[0]
        if b == "L" and self.push_inflight[j] == 0:
            self._open_map_gate(j)
        elif b == "G" and self.total_push_inflight == 0:
            for m in range(self.p.nM):
                self._open_map_gate(m)

    def _send_push(self, i: int, j: int, c: _Chunk, replica: bool):
        start = max(self.now, self.push_link_free[i, j])
        end = start + c.size / self.p.B_sm[i, j]
        self.push_link_free[i, j] = end
        self.at(end, "push_arrive", i, j, c)

    def _ev_push_arrive(self, i: int, j: int, c: _Chunk):
        self.push_end = max(self.push_end, self.now)
        self.push_inflight[j] -= 1
        self.total_push_inflight -= 1
        if not self.map_alive[j]:
            self._recover_chunk(j, c)
            return
        b = self.cfg.barriers[0]
        if b == "P":
            self.map_queue[j].append(c)
            self._pump_map(j)
        else:
            self.map_gated[j].append(c)
            if b == "L" and self.push_inflight[j] == 0:
                self._open_map_gate(j)
            elif b == "G" and self.total_push_inflight == 0:
                for m in range(self.p.nM):
                    self._open_map_gate(m)

    def _open_map_gate(self, j: int):
        if self.map_gated[j]:
            self.map_queue[j].extend(self.map_gated[j])
            self.map_gated[j].clear()
        self._pump_map(j)

    # -- map phase -------------------------------------------------------------
    def _pump_map(self, j: int):
        if self.map_busy[j] or not self.map_alive[j] or not self.map_queue[j]:
            if (
                not self.map_busy[j]
                and not self.map_queue[j]
                and self.map_alive[j]
            ):
                self._idle_mapper(j)
            return
        c = self.map_queue[j].pop(0)
        if c.done:  # a speculative twin already finished this chunk
            self._pump_map(j)
            return
        c.started_copies += 1
        self.map_busy[j] = True
        dur = c.size / self._rate("m", j) * self._noise()
        self.at(self.now + dur, "map_done", j, c)

    def _ev_map_done(self, j: int, c: _Chunk):
        self.map_busy[j] = False
        if c.done:
            self.wasted_mb += c.size  # lost the speculation race
            self._pump_map(j)
            return
        c.done = True
        self.map_end = max(self.map_end, self.now)
        owner = c.owner if c.owner >= 0 else j
        self.map_unfinished[owner] -= 1
        self.total_map_unfinished -= 1
        self._emit_shuffle(j, c)
        if owner != j and self.cfg.barriers[1] == "L" and self.map_unfinished[owner] == 0:
            self._open_shuffle_gate(owner)
        self._pump_map(j)

    def _emit_shuffle(self, j: int, c: _Chunk):
        b = self.cfg.barriers[1]
        for k in range(self.p.nR):
            amount = self.p.alpha * c.size * self.plan.y[k]
            if amount <= 1e-9:
                continue
            sc = _Chunk(next(self._cid), float(amount), j)
            self.shuf_inflight[k] += 1
            self.total_shuf_inflight += 1
            if b == "P":
                self._send_shuffle(j, k, sc)
            else:
                self.shuf_gated[j].append((k, sc))
        if b == "L" and self.map_unfinished[j] == 0:
            self._open_shuffle_gate(j)
        elif b == "G" and self.total_map_unfinished == 0:
            for m in range(self.p.nM):
                self._open_shuffle_gate(m)

    def _open_shuffle_gate(self, j: int):
        for k, sc in self.shuf_gated[j]:
            self._send_shuffle(j, k, sc)
        self.shuf_gated[j].clear()

    def _send_shuffle(self, j: int, k: int, sc: _Chunk):
        start = max(self.now, self.shuf_link_free[j, k])
        end = start + sc.size / self.p.B_mr[j, k]
        self.shuf_link_free[j, k] = end
        self.at(end, "shuffle_arrive", j, k, sc)

    def _ev_shuffle_arrive(self, j: int, k: int, sc: _Chunk):
        self.shuffle_end = max(self.shuffle_end, self.now)
        self.shuf_inflight[k] -= 1
        self.total_shuf_inflight -= 1
        b = self.cfg.barriers[2]
        if b == "P":
            self.red_queue[k].append(sc)
            self._pump_reduce(k)
        else:
            self.red_gated[k].append(sc)
            if b == "L" and self.shuf_inflight[k] == 0 and self._shuffle_final():
                self._open_reduce_gate(k)
            elif b == "G" and self.total_shuf_inflight == 0 and self._shuffle_final():
                for r in range(self.p.nR):
                    self._open_reduce_gate(r)

    def _shuffle_final(self) -> bool:
        """No more shuffle chunks can appear (all map work finished)."""
        return self.total_map_unfinished == 0 and self.total_push_inflight == 0

    def _open_reduce_gate(self, k: int):
        if self.red_gated[k]:
            self.red_queue[k].extend(self.red_gated[k])
            self.red_gated[k].clear()
        self._pump_reduce(k)

    # -- reduce phase ------------------------------------------------------------
    def _pump_reduce(self, k: int):
        if self.red_busy[k] or not self.red_queue[k]:
            return
        sc = self.red_queue[k].pop(0)
        if sc.done:
            self._pump_reduce(k)
            return
        self.red_busy[k] = True
        dur = sc.size / self._rate("r", k) * self._noise()
        self.at(self.now + dur, "reduce_done", k, sc)

    def _ev_reduce_done(self, k: int, sc: _Chunk):
        self.red_busy[k] = False
        if not sc.done:
            sc.done = True
            self.reduce_end = max(self.reduce_end, self.now)
        else:
            self.wasted_mb += sc.size
        self._pump_reduce(k)

    # -- dynamics: stealing / speculation ----------------------------------------
    def _idle_mapper(self, j: int):
        cfg = self.cfg
        if not (cfg.stealing or cfg.speculation):
            return
        # expected remaining compute time per mapper
        rem = np.array(
            [
                sum(c.size for c in self.map_queue[m] if not c.done)
                / self._rate("m", m)
                for m in range(self.p.nM)
            ]
        )
        if rem.sum() <= 0:
            return
        # fleet-mean progress (zeros included): a node is a straggler when
        # it lags the whole fleet, not merely other still-busy nodes
        mean = rem.mean()
        victim = int(rem.argmax())
        if victim == j or rem[victim] < cfg.spec_threshold * max(mean, 1e-9):
            return
        pending = [c for c in self.map_queue[victim] if not c.done and not c.cloned]
        if not pending:
            return
        c = pending[-1]
        # progress-based sanity check (Hadoop estimates task progress before
        # speculating): only act when the thief can plausibly win the race.
        my_time = c.size / self.p.B_sm[c.src, j] + c.size / self._rate("m", j)
        if my_time >= rem[victim]:
            return
        if cfg.stealing:
            self.map_queue[victim].remove(c)
            # ownership (and its gate counters) moves with the chunk
            self.map_unfinished[victim] -= 1
            self.map_unfinished[j] += 1
            c.owner = j
            if self.cfg.barriers[1] == "L" and self.map_unfinished[victim] == 0 \
                    and not self.map_busy[victim]:
                self._open_shuffle_gate(victim)
            moved = c
        else:  # speculation: clone, twin-completion resolved via c.done
            c.cloned = True
            moved = c
        # re-fetch the input from the source over the push link
        i = moved.src
        start = max(self.now, self.push_link_free[i, j])
        end = start + moved.size / self.p.B_sm[i, j]
        self.push_link_free[i, j] = end
        if not cfg.stealing:
            self.wasted_mb += 0.0  # waste only counted if the race is lost
        self.at(end, "stolen_arrive", j, moved)

    def _ev_stolen_arrive(self, j: int, c: _Chunk):
        if c.done or not self.map_alive[j]:
            return
        self.map_queue[j].append(c)
        self._pump_map(j)

    # -- dynamics: failure recovery ----------------------------------------------
    def _ev_fail_mapper(self, j: int):
        self.map_alive[j] = False
        lost = [c for c in self.map_queue[j] if not c.done]
        lost += [c for c in self.map_gated[j] if not c.done]
        self.map_queue[j].clear()
        self.map_gated[j].clear()
        self.map_busy[j] = False
        for c in lost:
            self._recover_chunk(j, c)

    def _recover_chunk(self, dead: int, c: _Chunk):
        """Re-push a lost chunk from its source to the best surviving mapper."""
        self.recovered += 1
        alive = np.flatnonzero(self.map_alive)
        if alive.size == 0:
            raise RuntimeError("all mappers dead")
        i = c.src
        tgt = int(alive[np.argmax(self.p.B_sm[i, alive])])
        if c.owner >= 0 and c.owner != tgt:
            self.map_unfinished[c.owner] -= 1
            self.map_unfinished[tgt] += 1
            c.owner = tgt
        self.wasted_mb += c.size
        start = max(self.now, self.push_link_free[i, tgt])
        end = start + c.size / self.p.B_sm[i, tgt]
        self.push_link_free[i, tgt] = end
        self.push_inflight[tgt] += 1
        self.total_push_inflight += 1
        self.at(end, "push_arrive", i, tgt, c)


def simulate(
    platform: Platform, plan: ExecutionPlan, cfg: Optional[SimConfig] = None
) -> SimResult:
    """Execute ``plan`` on ``platform`` under ``cfg`` and return timings."""
    return _Sim(platform, plan, cfg or SimConfig()).run()
