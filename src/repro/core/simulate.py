"""Resource-centric discrete-event execution of plans on a shared substrate.

The paper validates its analytic model against a modified Hadoop running on
an emulated (``tc``-shaped) testbed.  This container offers a single CPU, so
we do the analogous thing in software: a **chunk-granular discrete-event
executor** that runs execution plans over the platform model, serializing
chunks on links and compute nodes, honoring the barrier configuration, and —
unlike the analytic model — supporting the *dynamic* mechanisms the paper
compares against (§4.6.4) and the failure modes a production deployment must
survive:

* **speculative execution** — when a node goes idle, unstarted work queued at
  a node whose expected remaining time exceeds ``spec_threshold ×`` the fleet
  mean is *cloned* to the idle node (first copy to finish wins; an
  already-started clone is wasted work, as in Hadoop);
* **work stealing** — idle nodes *take* (rather than clone) unstarted chunks
  from the most backlogged peer, re-fetching inputs from the source;
* **stragglers** — per-node slowdown factors unknown to the planner;
* **node failure** — a job's mapper worker dies at a given time; its
  unfinished work is re-fetched from the data source (or nearest replica)
  and re-queued on the best surviving node;
* **replication** — push chunks are written ``replication×``, optionally
  across clusters (paper §4.6.5), consuming link capacity and speeding up
  recovery.

Events flow through **shared resources**, not through one hard-coded plan:
every push/shuffle link is a :class:`LinkResource` and every mapper/reducer
a :class:`ComputeResource`, each serving booked chunks FIFO.  ``N`` plans
run *concurrently* on one :class:`repro.core.platform.Substrate`
(:func:`simulate_schedule`) with real contention — chunks of different jobs
interleave on the same links and nodes in booking order, which
approximates fair sharing because concurrent jobs seed and emit their
chunks round-robin.  The single-plan :func:`simulate` is the ``N=1``
special case with unchanged semantics.

The executor is used by the Fig-4 validation benchmark (model-vs-execution
correlation), the Fig-10/11 dynamics study, the multi-job contention
benchmark, and the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .makespan import BARRIERS_GGL, _check_barriers
from .plan import ExecutionPlan
from .platform import Platform, Substrate

__all__ = [
    "ComputeResource",
    "LinkResource",
    "ResourceStats",
    "ScheduleSimResult",
    "SimConfig",
    "SimResult",
    "simulate",
    "simulate_schedule",
]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    chunk_mb: float = 64.0
    barriers: Tuple[str, str, str] = BARRIERS_GGL
    speculation: bool = False
    stealing: bool = False
    spec_threshold: float = 1.5
    replication: int = 1
    cross_cluster_replication: bool = False
    #: per-node compute slowdown factors applied at runtime (unknown to the
    #: planner): {("m"| "r", node_index): factor >= 1}
    stragglers: Optional[Dict[Tuple[str, int], float]] = None
    #: (mapper_index, fail_time_s) — the job's worker on that mapper dies;
    #: its work is recovered onto surviving mappers.
    fail_mapper: Optional[Tuple[int, float]] = None
    #: lognormal sigma on per-chunk service times (0 = deterministic).
    compute_noise: float = 0.0
    seed: int = 0
    #: release time: the job's sources start pushing at this absolute time.
    start_time: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "barriers", _check_barriers(self.barriers))


@dataclasses.dataclass
class SimResult:
    makespan: float
    push_end: float
    map_end: float
    shuffle_end: float
    reduce_end: float
    wasted_mb: float  # duplicated / re-executed work
    recovered_chunks: int
    total_map_chunks: int

    def phases(self) -> Dict[str, float]:
        return {
            "push": self.push_end,
            "map": max(self.map_end - self.push_end, 0.0),
            "shuffle": max(self.shuffle_end - self.map_end, 0.0),
            "reduce": max(self.reduce_end - self.shuffle_end, 0.0),
            "makespan": self.makespan,
        }

    def as_dict(self) -> Dict[str, float]:
        """Stable flat form for benchmark emission / JSON dumps: every
        scalar field by name (seconds / MB / counts)."""
        return {
            "makespan": self.makespan,
            "push_end": self.push_end,
            "map_end": self.map_end,
            "shuffle_end": self.shuffle_end,
            "reduce_end": self.reduce_end,
            "wasted_mb": self.wasted_mb,
            "recovered_chunks": float(self.recovered_chunks),
            "total_map_chunks": float(self.total_map_chunks),
        }


# ---------------------------------------------------------------------------
# shared resources
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResourceStats:
    """Accumulated service accounting for one named substrate resource."""

    busy_s: float = 0.0  # seconds spent serving chunks
    waited_s: float = 0.0  # chunk-seconds spent queued behind earlier bookings
    volume_mb: float = 0.0
    n_chunks: int = 0
    jobs: set = dataclasses.field(default_factory=set)

    def utilization(self, horizon: float) -> float:
        """Fraction of ``horizon`` this resource spent serving."""
        return self.busy_s / horizon if horizon > 0 else 0.0

    @property
    def contended(self) -> bool:
        return len(self.jobs) > 1

    def as_dict(self) -> Dict[str, float]:
        return {
            "busy_s": self.busy_s,
            "waited_s": self.waited_s,
            "volume_mb": self.volume_mb,
            "n_chunks": float(self.n_chunks),
            "n_jobs": float(len(self.jobs)),
        }


class LinkResource:
    """A point-to-point link serving booked transfers FIFO.

    Bookings reserve the link eagerly: ``book`` returns the completion time
    of a transfer queued behind everything already booked — exactly the
    serialization the single-job executor applied, now shared by every job
    that routes chunks through this link.
    """

    __slots__ = ("name", "bw", "free", "stats")

    def __init__(self, name: str, bw: float):
        self.name = name
        self.bw = float(bw)
        self.free = 0.0
        self.stats = ResourceStats()

    def book(self, now: float, size: float, job: int) -> float:
        start = max(now, self.free)
        end = start + size / self.bw
        self.free = end
        s = self.stats
        s.busy_s += end - start
        s.waited_s += start - now
        s.volume_mb += size
        s.n_chunks += 1
        s.jobs.add(job)
        return end


class ComputeResource:
    """A map/reduce worker node serving queued chunks FIFO across jobs."""

    __slots__ = ("name", "rate", "busy", "current", "queue", "stats")

    def __init__(self, name: str, rate: float):
        self.name = name
        self.rate = float(rate)
        self.busy = False
        #: the job whose chunk is in service (None when idle) — barrier
        #: checks must distinguish "busy with MY chunk" from "busy at all"
        self.current: Optional["_JobRun"] = None
        #: FIFO of (job_state, chunk, enqueue_time)
        self.queue: List[Tuple["_JobRun", "_Chunk", float]] = []
        self.stats = ResourceStats()

    def enqueue(self, run: "_JobRun", chunk: "_Chunk", now: float) -> None:
        self.queue.append((run, chunk, now))

    def job_chunks(self, run: "_JobRun") -> List["_Chunk"]:
        return [c for g, c, _ in self.queue if g is run]

    def remove(self, run: "_JobRun", chunk: "_Chunk") -> None:
        for idx, (g, c, _) in enumerate(self.queue):
            if g is run and c is chunk:
                del self.queue[idx]
                return
        raise ValueError("chunk not queued at this resource")

    def record_service(self, start: float, enqueued: float, dur: float,
                       size: float, job: int) -> None:
        s = self.stats
        s.busy_s += dur
        s.waited_s += start - enqueued
        s.volume_mb += size
        s.n_chunks += 1
        s.jobs.add(job)


class _Chunk:
    __slots__ = ("cid", "size", "src", "done", "started_copies", "owner", "cloned")

    def __init__(self, cid: int, size: float, src: int, owner: int = -1):
        self.cid = cid
        self.size = size
        self.src = src  # source index for map chunks; mapper index for reduce
        self.done = False
        self.started_copies = 0
        self.owner = owner  # mapper whose gate/progress counters hold it
        self.cloned = False


class _JobRun:
    """Per-job executor state: the plan, barrier gates, progress counters and
    phase timestamps of one job sharing the substrate."""

    def __init__(self, idx: int, platform: Platform, plan: ExecutionPlan,
                 cfg: SimConfig, nM: int, nR: int):
        self.idx = idx
        self.p = platform
        self.plan = plan
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)

        self.map_alive = np.ones(nM, dtype=bool)

        # outstanding counters for gates
        self.push_inflight = np.zeros(nM, dtype=np.int64)
        self.map_unfinished = np.zeros(nM, dtype=np.int64)
        self.shuf_inflight = np.zeros(nR, dtype=np.int64)
        self.total_push_inflight = 0
        self.total_map_unfinished = 0
        self.total_shuf_inflight = 0

        self.push_end = 0.0
        self.map_end = 0.0
        self.shuffle_end = 0.0
        self.reduce_end = 0.0
        self.wasted_mb = 0.0
        self.recovered = 0
        self.total_map_chunks = 0

        # chunks delivered to mapper j but gated (push/map barrier)
        self.map_gated: List[List[_Chunk]] = [[] for _ in range(nM)]
        # shuffle emissions gated at mapper j (map/shuffle barrier)
        self.shuf_gated: List[List[Tuple[int, _Chunk]]] = [[] for _ in range(nM)]
        # reduce chunks gated at reducer k (shuffle/reduce barrier)
        self.red_gated: List[List[_Chunk]] = [[] for _ in range(nR)]

    def noise(self) -> float:
        if self.cfg.compute_noise <= 0:
            return 1.0
        return float(np.exp(self.rng.normal(0.0, self.cfg.compute_noise)))

    def slowdown(self, tier: str, idx: int) -> float:
        if self.cfg.stragglers:
            return self.cfg.stragglers.get((tier, idx), 1.0)
        return 1.0

    def result(self) -> SimResult:
        return SimResult(
            makespan=self.reduce_end,
            push_end=self.push_end,
            map_end=self.map_end,
            shuffle_end=self.shuffle_end,
            reduce_end=self.reduce_end,
            wasted_mb=self.wasted_mb,
            recovered_chunks=self.recovered,
            total_map_chunks=self.total_map_chunks,
        )


@dataclasses.dataclass
class ScheduleSimResult:
    """Concurrent execution of N jobs on one substrate: per-job timings plus
    per-resource service accounting."""

    jobs: List[SimResult]
    makespan: float  # absolute completion time of the last job
    resources: Dict[str, ResourceStats]

    def utilization(self) -> Dict[str, float]:
        """Busy fraction of the schedule horizon per named resource."""
        return {
            name: s.utilization(self.makespan)
            for name, s in self.resources.items()
        }

    def contended(self) -> Dict[str, ResourceStats]:
        """Resources that served chunks of more than one job."""
        return {n: s for n, s in self.resources.items() if s.contended}

    def summary(self) -> str:
        worst = sorted(
            self.resources.items(), key=lambda kv: -kv[1].busy_s
        )[:3]
        hot = " ".join(
            f"{n}={s.utilization(self.makespan):.0%}" for n, s in worst
        )
        return (
            f"schedule: {len(self.jobs)} jobs makespan={self.makespan:.1f}s "
            f"contended={len(self.contended())} hottest: {hot}"
        )


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class _MultiSim:
    """Resource-centric event engine running N jobs on one substrate.

    Events are ``(time, seq, fn_name, args)``; chunk events are routed
    through the shared :class:`LinkResource`/:class:`ComputeResource`
    objects, so concurrent jobs contend for the same capacity entries.
    """

    def __init__(self, substrate: Substrate, runs: List[_JobRun]):
        self.sub = substrate
        self.runs = runs
        self.now = 0.0
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self._cid = itertools.count()

        nS, nM, nR = substrate.nS, substrate.nM, substrate.nR
        self.push_links = [
            [LinkResource(f"push[s{i}->m{j}]", substrate.B_sm[i, j])
             for j in range(nM)]
            for i in range(nS)
        ]
        self.shuf_links = [
            [LinkResource(f"shuffle[m{j}->r{k}]", substrate.B_mr[j, k])
             for k in range(nR)]
            for j in range(nM)
        ]
        self.mappers = [
            ComputeResource(f"map[m{j}]", substrate.C_m[j]) for j in range(nM)
        ]
        self.reducers = [
            ComputeResource(f"reduce[r{k}]", substrate.C_r[k]) for k in range(nR)
        ]

    # -- infrastructure ----------------------------------------------------
    def at(self, t: float, fn: str, *args):
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def run(self) -> ScheduleSimResult:
        # jobs sharing a release time seed round-robin (chunk-interleaved
        # bookings approximate fair-share FIFO on contended links)
        for start in sorted({g.cfg.start_time for g in self.runs}):
            group = [g for g in self.runs if g.cfg.start_time == start]
            self.at(start, "seed_jobs", tuple(g.idx for g in group))
        for g in self.runs:
            if g.cfg.fail_mapper is not None:
                j, tf = g.cfg.fail_mapper
                self.at(tf, "fail_mapper", g, j)
        while self._heap:
            t, _, fn, args = heapq.heappop(self._heap)
            self.now = max(self.now, t)
            getattr(self, "_ev_" + fn)(*args)
        resources: Dict[str, ResourceStats] = {}
        for row in self.push_links:
            for link in row:
                resources[link.name] = link.stats
        for row in self.shuf_links:
            for link in row:
                resources[link.name] = link.stats
        for node in self.mappers + self.reducers:
            resources[node.name] = node.stats
        return ScheduleSimResult(
            jobs=[g.result() for g in self.runs],
            makespan=max((g.reduce_end for g in self.runs), default=0.0),
            resources=resources,
        )

    def _rate(self, g: _JobRun, tier: str, idx: int) -> float:
        node = self.mappers[idx] if tier == "m" else self.reducers[idx]
        return node.rate / g.slowdown(tier, idx)

    # -- push phase ----------------------------------------------------------
    def _ev_seed_jobs(self, idxs: Tuple[int, ...]):
        """Seed every push chunk of the released jobs, interleaving chunks
        across jobs so shared links serve them round-robin."""
        pending = [(self.runs[i], self._push_ops(self.runs[i])) for i in idxs]
        cursors = [0] * len(pending)
        live = True
        while live:
            live = False
            for slot, (g, ops) in enumerate(pending):
                if cursors[slot] >= len(ops):
                    continue
                live = True
                i, j, size = ops[cursors[slot]]
                cursors[slot] += 1
                c = _Chunk(next(self._cid), size, i, owner=j)
                g.total_map_chunks += 1
                g.push_inflight[j] += 1
                g.total_push_inflight += 1
                g.map_unfinished[j] += 1
                g.total_map_unfinished += 1
                self._send_push(g, i, j, c)
                self._replicate(g, i, j, size)

    def _push_ops(self, g: _JobRun) -> List[Tuple[int, int, float]]:
        """The job's push chunks as (source, mapper, MB) in seeding order."""
        cfg, p = g.cfg, g.p
        ops: List[Tuple[int, int, float]] = []
        for i in range(p.nS):
            for j in range(p.nM):
                amount = p.D[i] * g.plan.x[i, j]
                if amount <= 1e-9:
                    continue
                n_chunks = max(int(np.ceil(amount / cfg.chunk_mb)), 1)
                ops.extend((i, j, amount / n_chunks) for _ in range(n_chunks))
        return ops

    def _replicate(self, g: _JobRun, i: int, j: int, size: float):
        """Write replication-1 extra copies of a push chunk (replica targets
        never run map work; they only consume link capacity)."""
        sub, cfg = self.sub, g.cfg
        for r in range(cfg.replication - 1):
            if cfg.cross_cluster_replication:
                candidates = [
                    m for m in range(sub.nM)
                    if sub.cluster_m[m] != sub.cluster_m[j]
                ]
            else:
                candidates = [
                    m
                    for m in range(sub.nM)
                    if sub.cluster_m[m] == sub.cluster_m[j] and m != j
                ]
            if not candidates:
                candidates = [m for m in range(sub.nM) if m != j]
            tgt = candidates[(j + r + 1) % len(candidates)]
            end = self.push_links[i][tgt].book(self.now, size, g.idx)
            g.wasted_mb += size
            # the write pipeline is not durable (and the push phase not
            # complete) until every replica is on disk: replica writes gate
            # the ORIGIN mapper's input like any other push chunk.
            g.push_inflight[j] += 1
            g.total_push_inflight += 1
            self.at(end, "replica_done", g, j)

    def _ev_replica_done(self, g: _JobRun, j: int):
        g.push_end = max(g.push_end, self.now)
        g.push_inflight[j] -= 1
        g.total_push_inflight -= 1
        b = g.cfg.barriers[0]
        if b == "L" and g.push_inflight[j] == 0:
            self._open_map_gate(g, j)
        elif b == "G" and g.total_push_inflight == 0:
            for m in range(self.sub.nM):
                self._open_map_gate(g, m)

    def _send_push(self, g: _JobRun, i: int, j: int, c: _Chunk):
        end = self.push_links[i][j].book(self.now, c.size, g.idx)
        self.at(end, "push_arrive", g, i, j, c)

    def _ev_push_arrive(self, g: _JobRun, i: int, j: int, c: _Chunk):
        g.push_end = max(g.push_end, self.now)
        g.push_inflight[j] -= 1
        g.total_push_inflight -= 1
        if not g.map_alive[j]:
            self._recover_chunk(g, j, c)
            return
        b = g.cfg.barriers[0]
        if b == "P":
            self.mappers[j].enqueue(g, c, self.now)
            self._pump_map(j)
        else:
            g.map_gated[j].append(c)
            if b == "L" and g.push_inflight[j] == 0:
                self._open_map_gate(g, j)
            elif b == "G" and g.total_push_inflight == 0:
                for m in range(self.sub.nM):
                    self._open_map_gate(g, m)

    def _open_map_gate(self, g: _JobRun, j: int):
        if g.map_gated[j]:
            for c in g.map_gated[j]:
                self.mappers[j].enqueue(g, c, self.now)
            g.map_gated[j].clear()
        self._pump_map(j)

    # -- map phase -------------------------------------------------------------
    def _pump_map(self, j: int):
        node = self.mappers[j]
        if node.busy:
            return
        if not node.queue:
            self._idle_mapper(j)
            return
        g, c, t_enq = node.queue.pop(0)
        if c.done:  # a speculative twin already finished this chunk
            self._pump_map(j)
            return
        c.started_copies += 1
        node.busy = True
        node.current = g
        dur = c.size / self._rate(g, "m", j) * g.noise()
        node.record_service(self.now, t_enq, dur, c.size, g.idx)
        self.at(self.now + dur, "map_done", g, j, c)

    def _ev_map_done(self, g: _JobRun, j: int, c: _Chunk):
        self.mappers[j].busy = False
        self.mappers[j].current = None
        if c.done:
            g.wasted_mb += c.size  # lost the speculation race
            self._pump_map(j)
            return
        c.done = True
        g.map_end = max(g.map_end, self.now)
        owner = c.owner if c.owner >= 0 else j
        g.map_unfinished[owner] -= 1
        g.total_map_unfinished -= 1
        self._emit_shuffle(g, j, c)
        if owner != j and g.cfg.barriers[1] == "L" and g.map_unfinished[owner] == 0:
            self._open_shuffle_gate(g, owner)
        self._pump_map(j)

    def _emit_shuffle(self, g: _JobRun, j: int, c: _Chunk):
        b = g.cfg.barriers[1]
        for k in range(self.sub.nR):
            amount = g.p.alpha * c.size * g.plan.y[k]
            if amount <= 1e-9:
                continue
            sc = _Chunk(next(self._cid), float(amount), j)
            g.shuf_inflight[k] += 1
            g.total_shuf_inflight += 1
            if b == "P":
                self._send_shuffle(g, j, k, sc)
            else:
                g.shuf_gated[j].append((k, sc))
        if b == "L" and g.map_unfinished[j] == 0:
            self._open_shuffle_gate(g, j)
        elif b == "G" and g.total_map_unfinished == 0:
            for m in range(self.sub.nM):
                self._open_shuffle_gate(g, m)

    def _open_shuffle_gate(self, g: _JobRun, j: int):
        for k, sc in g.shuf_gated[j]:
            self._send_shuffle(g, j, k, sc)
        g.shuf_gated[j].clear()

    def _send_shuffle(self, g: _JobRun, j: int, k: int, sc: _Chunk):
        end = self.shuf_links[j][k].book(self.now, sc.size, g.idx)
        self.at(end, "shuffle_arrive", g, j, k, sc)

    def _ev_shuffle_arrive(self, g: _JobRun, j: int, k: int, sc: _Chunk):
        g.shuffle_end = max(g.shuffle_end, self.now)
        g.shuf_inflight[k] -= 1
        g.total_shuf_inflight -= 1
        b = g.cfg.barriers[2]
        if b == "P":
            self.reducers[k].enqueue(g, sc, self.now)
            self._pump_reduce(k)
        else:
            g.red_gated[k].append(sc)
            if b == "L" and g.shuf_inflight[k] == 0 and self._shuffle_final(g):
                self._open_reduce_gate(g, k)
            elif b == "G" and g.total_shuf_inflight == 0 and self._shuffle_final(g):
                for r in range(self.sub.nR):
                    self._open_reduce_gate(g, r)

    def _shuffle_final(self, g: _JobRun) -> bool:
        """No more shuffle chunks can appear (all the job's map work done)."""
        return g.total_map_unfinished == 0 and g.total_push_inflight == 0

    def _open_reduce_gate(self, g: _JobRun, k: int):
        if g.red_gated[k]:
            for sc in g.red_gated[k]:
                self.reducers[k].enqueue(g, sc, self.now)
            g.red_gated[k].clear()
        self._pump_reduce(k)

    # -- reduce phase ------------------------------------------------------------
    def _pump_reduce(self, k: int):
        node = self.reducers[k]
        if node.busy or not node.queue:
            return
        g, sc, t_enq = node.queue.pop(0)
        if sc.done:
            self._pump_reduce(k)
            return
        node.busy = True
        node.current = g
        dur = sc.size / self._rate(g, "r", k) * g.noise()
        node.record_service(self.now, t_enq, dur, sc.size, g.idx)
        self.at(self.now + dur, "reduce_done", g, k, sc)

    def _ev_reduce_done(self, g: _JobRun, k: int, sc: _Chunk):
        self.reducers[k].busy = False
        self.reducers[k].current = None
        if not sc.done:
            sc.done = True
            g.reduce_end = max(g.reduce_end, self.now)
        else:
            g.wasted_mb += sc.size
        self._pump_reduce(k)

    # -- dynamics: stealing / speculation ----------------------------------------
    def _idle_mapper(self, j: int):
        """The node ran out of queued work entirely; let each job with
        dynamics enabled (and a live worker here) try to relocate one of its
        own backlogged chunks.  At most one booking per idle trigger."""
        for g in self.runs:
            if not (g.cfg.stealing or g.cfg.speculation) or not g.map_alive[j]:
                continue
            if self._idle_mapper_for(g, j):
                return

    def _idle_mapper_for(self, g: _JobRun, j: int) -> bool:
        cfg = g.cfg
        # expected remaining compute time per mapper (this job's chunks)
        rem = np.array(
            [
                sum(c.size for c in self.mappers[m].job_chunks(g) if not c.done)
                / self._rate(g, "m", m)
                for m in range(self.sub.nM)
            ]
        )
        if rem.sum() <= 0:
            return False
        # fleet-mean progress (zeros included): a node is a straggler when
        # it lags the whole fleet, not merely other still-busy nodes
        mean = rem.mean()
        victim = int(rem.argmax())
        if victim == j or rem[victim] < cfg.spec_threshold * max(mean, 1e-9):
            return False
        pending = [
            c for c in self.mappers[victim].job_chunks(g)
            if not c.done and not c.cloned
        ]
        if not pending:
            return False
        c = pending[-1]
        # progress-based sanity check (Hadoop estimates task progress before
        # speculating): only act when the thief can plausibly win the race.
        my_time = c.size / self.sub.B_sm[c.src, j] + c.size / self._rate(g, "m", j)
        if my_time >= rem[victim]:
            return False
        if cfg.stealing:
            self.mappers[victim].remove(g, c)
            # ownership (and its gate counters) moves with the chunk
            g.map_unfinished[victim] -= 1
            g.map_unfinished[j] += 1
            c.owner = j
            # open now unless the victim is mid-service on one of THIS
            # job's chunks (that chunk's map_done reopens the gate);
            # another job's in-service chunk must not hold g's gate shut
            victim_node = self.mappers[victim]
            if cfg.barriers[1] == "L" and g.map_unfinished[victim] == 0 \
                    and not (victim_node.busy and victim_node.current is g):
                self._open_shuffle_gate(g, victim)
        else:  # speculation: clone, twin-completion resolved via c.done
            c.cloned = True
        # re-fetch the input from the source over the push link
        end = self.push_links[c.src][j].book(self.now, c.size, g.idx)
        self.at(end, "stolen_arrive", g, j, c)
        return True

    def _ev_stolen_arrive(self, g: _JobRun, j: int, c: _Chunk):
        if c.done:
            return
        if not g.map_alive[j]:
            # a STOLEN chunk (ownership moved to the thief) dies with the
            # thief unless recovered; a speculative clone still lives in
            # the victim's queue and can simply be dropped
            if c.owner == j:
                self._recover_chunk(g, j, c)
            return
        self.mappers[j].enqueue(g, c, self.now)
        self._pump_map(j)

    # -- dynamics: failure recovery ----------------------------------------------
    def _ev_fail_mapper(self, g: _JobRun, j: int):
        g.map_alive[j] = False
        node = self.mappers[j]
        lost = [c for c in node.job_chunks(g) if not c.done]
        lost += [c for c in g.map_gated[j] if not c.done]
        node.queue = [(h, c, t) for h, c, t in node.queue if h is not g]
        g.map_gated[j].clear()
        # an in-flight chunk (already popped) still completes — the node's
        # busy flag clears at its map_done, exactly as before the refactor
        for c in lost:
            self._recover_chunk(g, j, c)

    def _recover_chunk(self, g: _JobRun, dead: int, c: _Chunk):
        """Re-push a lost chunk from its source to the job's best surviving
        mapper."""
        g.recovered += 1
        alive = np.flatnonzero(g.map_alive)
        if alive.size == 0:
            raise RuntimeError("all mappers dead")
        i = c.src
        tgt = int(alive[np.argmax(self.sub.B_sm[i, alive])])
        if c.owner >= 0 and c.owner != tgt:
            g.map_unfinished[c.owner] -= 1
            g.map_unfinished[tgt] += 1
            c.owner = tgt
        g.wasted_mb += c.size
        end = self.push_links[i][tgt].book(self.now, c.size, g.idx)
        g.push_inflight[tgt] += 1
        g.total_push_inflight += 1
        self.at(end, "push_arrive", g, i, tgt, c)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

_JobEntry = Union[
    Tuple[Platform, ExecutionPlan],
    Tuple[Platform, ExecutionPlan, Optional[SimConfig]],
]


def simulate_schedule(
    jobs: Sequence[_JobEntry],
    substrate: Optional[Substrate] = None,
) -> ScheduleSimResult:
    """Execute N jobs concurrently on one shared substrate.

    ``jobs`` is a sequence of ``(platform, plan)`` or ``(platform, plan,
    cfg)`` entries whose platforms must all be views of the same substrate
    (checked via :meth:`Substrate.compatible`); ``substrate`` overrides the
    inferred one.  Each job keeps its own barriers, chunking, dynamics and
    release time (``SimConfig.start_time``) — only the link/compute
    resources are shared.
    """
    if not jobs:
        raise ValueError("simulate_schedule needs at least one job")
    entries = []
    for entry in jobs:
        platform, plan, cfg = entry if len(entry) == 3 else (*entry, None)
        entries.append((platform, plan, cfg or SimConfig()))
    sub = substrate if substrate is not None else Substrate.of(entries[0][0])
    for platform, _, _ in entries:
        if not sub.compatible(Substrate.of(platform)):
            raise ValueError(
                f"platform {platform.name!r} is not a view of substrate "
                f"{sub.name!r} — build job platforms with Substrate.view()"
            )
    runs = [
        _JobRun(idx, platform, plan, cfg, sub.nM, sub.nR)
        for idx, (platform, plan, cfg) in enumerate(entries)
    ]
    return _MultiSim(sub, runs).run()


def simulate(
    platform: Platform, plan: ExecutionPlan, cfg: Optional[SimConfig] = None
) -> SimResult:
    """Execute ``plan`` on ``platform`` under ``cfg`` and return timings —
    the N=1 case of :func:`simulate_schedule` (one job, sole tenant of its
    substrate)."""
    return simulate_schedule([(platform, plan, cfg or SimConfig())]).jobs[0]
