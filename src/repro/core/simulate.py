"""Resource-centric discrete-event execution of plans on a shared substrate.

The paper validates its analytic model against a modified Hadoop running on
an emulated (``tc``-shaped) testbed.  This container offers a single CPU, so
we do the analogous thing in software: a **chunk-granular discrete-event
executor** that runs execution plans over the platform model, serializing
chunks on links and compute nodes, honoring the barrier configuration, and —
unlike the analytic model — supporting the *dynamic* mechanisms the paper
compares against (§4.6.4) and the failure modes a production deployment must
survive:

* **speculative execution** — when a node goes idle, unstarted work queued at
  a node whose expected remaining time exceeds ``spec_threshold ×`` the fleet
  mean is *cloned* to the idle node (first copy to finish wins; an
  already-started clone is wasted work, as in Hadoop);
* **work stealing** — idle nodes *take* (rather than clone) unstarted chunks
  from the most backlogged peer, re-fetching inputs from the source;
* **stragglers** — per-node slowdown factors unknown to the planner;
* **failures** — typed :class:`repro.core.platform.FailureEvent`\\ s
  (``mapper_kill`` / ``reducer_kill`` per job or fabric-wide, plus
  substrate-level ``cluster_partition`` with repair): in-flight chunks on
  dead paths are dropped, undelivered map/reduce output is un-delivered,
  and lost work is re-executed from surviving replicas (or re-fetched from
  the source) on the best surviving node;
* **replication** — push chunks are written ``replication×``, optionally
  across clusters (paper §4.6.5), consuming link capacity and speeding up
  recovery.

Events flow through **shared resources**, not through one hard-coded plan:
every push/shuffle link is a :class:`LinkResource` and every mapper/reducer
a :class:`ComputeResource`, each serving booked chunks FIFO.  ``N`` plans
run *concurrently* on one :class:`repro.core.platform.Substrate`
(:func:`simulate_schedule`) with real contention — chunks of different jobs
interleave on the same links and nodes in booking order, which
approximates fair sharing because concurrent jobs seed and emit their
chunks round-robin.  The single-plan :func:`simulate` is the ``N=1``
special case with unchanged semantics.

Links serve their queue one transfer at a time (a *pump*, like the compute
nodes) rather than pre-booking completion times, so the engine is
**observable and steerable** — the substance of the online control plane:

* every transfer sitting in a queue is uncommitted and can be re-routed,
  which is what :meth:`_MultiSim.swap_plan` does when an online policy
  replaces a job's plan mid-flight;
* a link's service rate is read *at service start* from its
  :class:`repro.core.platform.CapacityTrace`, so WAN capacities may drift
  while chunks are queued (an in-service transfer keeps the rate it
  started with);
* :meth:`_MultiSim.snapshot` captures a :class:`ProgressSnapshot` at any
  event time — per-job residual volumes bucketed by what a re-planner can
  still control (:class:`repro.core.makespan.JobProgress`), plus
  per-resource backlog;
* :meth:`_MultiSim.inject` admits new jobs after t=0, so arrivals stream
  in rather than being known upfront;
* :meth:`_MultiSim.run_until` pauses the event loop at a decision instant
  (:func:`open_schedule` hands out a paused engine;
  :func:`simulate_schedule` is the run-to-completion wrapper).

The executor is used by the Fig-4 validation benchmark (model-vs-execution
correlation), the Fig-10/11 dynamics study, the multi-job contention and
online re-planning benchmarks, and the fault-tolerance tests.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import warnings
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .makespan import BARRIERS_GGL, JobProgress, _check_barriers
from .plan import ExecutionPlan
from .platform import FailureEvent, Platform, Substrate

__all__ = [
    "ComputeResource",
    "FailureEvent",
    "LinkResource",
    "ProgressSnapshot",
    "ResourceStats",
    "ScheduleSimResult",
    "SimConfig",
    "SimResult",
    "open_schedule",
    "simulate",
    "simulate_schedule",
]


#: executor modes: chunk-granular discrete events ("event"), the
#: array-native drain of the same events ("event_vec") or continuous
#: flow-level simulation ("fluid", see :mod:`repro.core.fluid`).
SIM_MODES = ("event", "event_vec", "fluid")

_NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    chunk_mb: float = 64.0
    barriers: Tuple[str, str, str] = BARRIERS_GGL
    speculation: bool = False
    stealing: bool = False
    spec_threshold: float = 1.5
    replication: int = 1
    cross_cluster_replication: bool = False
    #: per-node compute slowdown factors applied at runtime (unknown to the
    #: planner): {("m"| "r", node_index): factor >= 1}
    stragglers: Optional[Dict[Tuple[str, int], float]] = None
    #: DEPRECATED spelling of ``failures=[FailureEvent.mapper_kill(j, t)]``
    #: — converted (with a DeprecationWarning) at construction; the engine
    #: only ever reads :attr:`failures`.
    fail_mapper: Optional[Tuple[int, float]] = None
    #: this job's fault script: typed :class:`FailureEvent`\\ s
    #: (``mapper_kill`` / ``reducer_kill`` — the *job's* worker on that
    #: node dies).  Fabric-wide faults, including ``cluster_partition``,
    #: attach to the substrate instead (:meth:`Substrate.with_failures`).
    failures: Tuple[FailureEvent, ...] = ()
    #: lognormal sigma on per-chunk service times (0 = deterministic).
    compute_noise: float = 0.0
    seed: int = 0
    #: release time: the job's sources start pushing at this absolute time.
    start_time: float = 0.0
    #: runtime sanitizer: check gate-counter sanity after every event and
    #: byte conservation at completion; violations land on
    #: :attr:`ScheduleSimResult.violations` (see :mod:`repro.analysis.audit`).
    audit: bool = False
    #: executor mode — every job of one schedule must agree on it:
    #:
    #: * ``"event"``     — chunk-granular DES (the default);
    #: * ``"event_vec"`` — the same events drained with batched
    #:   per-resource service scans (bit-identical results on scenarios
    #:   the determinism auditor certifies race-free).  Dynamics
    #:   (speculation, stealing, failures, noise, replication) are
    #:   rejected; steered engines (``run_until`` / ``snapshot`` /
    #:   ``swap_plan`` / ``inject``) drain each segment between decision
    #:   points through the same scans, falling back to the scalar event
    #:   loop only when a job's dynamics leave the vectorized vocabulary;
    #: * ``"fluid"``     — continuous flows at shared service rates (the
    #:   scale-tier fast path, see :mod:`repro.core.fluid`).
    mode: str = "event"
    #: DEPRECATED spelling of ``mode="event_vec"`` — converted (with a
    #: DeprecationWarning) at construction; the engine only ever reads
    #: :attr:`mode`.
    vectorized: bool = False

    def __post_init__(self):
        object.__setattr__(self, "barriers", _check_barriers(self.barriers))
        if self.start_time < 0:
            raise ValueError(
                f"start_time must be >= 0, got {self.start_time}"
            )
        if self.replication < 1:
            raise ValueError(
                f"replication must be >= 1, got {self.replication}"
            )
        if self.vectorized:
            warnings.warn(
                "SimConfig(vectorized=True) is deprecated — spell it "
                'SimConfig(mode="event_vec")',
                DeprecationWarning, stacklevel=3,
            )
            if self.mode == "fluid":
                raise ValueError(
                    'vectorized=True conflicts with mode="fluid" — pick one '
                    f"executor mode from {SIM_MODES}"
                )
            object.__setattr__(self, "mode", "event_vec")
            object.__setattr__(self, "vectorized", False)
        if self.mode not in SIM_MODES:
            raise ValueError(
                f"mode must be one of {SIM_MODES}, got {self.mode!r}"
            )
        failures = tuple(self.failures)
        if self.fail_mapper is not None:
            warnings.warn(
                "SimConfig(fail_mapper=(j, t)) is deprecated — spell it "
                "SimConfig(failures=[FailureEvent.mapper_kill(j, t)])",
                DeprecationWarning, stacklevel=3,
            )
            j, tf = self.fail_mapper
            failures = failures + (
                FailureEvent.mapper_kill(int(j), float(tf)),
            )
            object.__setattr__(self, "fail_mapper", None)
        for ev in failures:
            if not isinstance(ev, FailureEvent):
                raise TypeError(f"failures entries must be FailureEvent, "
                                f"got {ev!r}")
            if ev.kind == "cluster_partition":
                raise ValueError(
                    "cluster_partition is a fabric fact, not a per-job "
                    "fault — attach it to the substrate: "
                    "Substrate.with_failures([FailureEvent."
                    "cluster_partition(...)])"
                )
        object.__setattr__(self, "failures", failures)


@dataclasses.dataclass
class SimResult:
    makespan: float
    push_end: float
    map_end: float
    shuffle_end: float
    reduce_end: float
    wasted_mb: float  # duplicated / re-executed work
    recovered_chunks: int
    total_map_chunks: int
    #: payload MB lost to failures (dead workers, dropped in-flight
    #: transfers) and the MB re-dispatched to make it up — conservation
    #: requires the two to match at completion (audited).
    lost_mb: float = 0.0
    reexec_mb: float = 0.0

    def phases(self) -> Dict[str, float]:
        return {
            "push": self.push_end,
            "map": max(self.map_end - self.push_end, 0.0),
            "shuffle": max(self.shuffle_end - self.map_end, 0.0),
            "reduce": max(self.reduce_end - self.shuffle_end, 0.0),
            "makespan": self.makespan,
        }

    def as_dict(self) -> Dict[str, float]:
        """Stable flat form for benchmark emission / JSON dumps: every
        scalar field by name (seconds / MB / counts)."""
        return {
            "makespan": self.makespan,
            "push_end": self.push_end,
            "map_end": self.map_end,
            "shuffle_end": self.shuffle_end,
            "reduce_end": self.reduce_end,
            "wasted_mb": self.wasted_mb,
            "recovered_chunks": float(self.recovered_chunks),
            "total_map_chunks": float(self.total_map_chunks),
            "lost_mb": self.lost_mb,
            "reexec_mb": self.reexec_mb,
        }


# ---------------------------------------------------------------------------
# shared resources
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResourceStats:
    """Accumulated service accounting for one named substrate resource."""

    busy_s: float = 0.0  # seconds spent serving chunks
    waited_s: float = 0.0  # chunk-seconds spent queued behind earlier bookings
    volume_mb: float = 0.0
    n_chunks: int = 0
    jobs: set = dataclasses.field(default_factory=set)
    #: absolute time of the first/last service — a job released at t>0 must
    #: leave ``first_busy_s >= t`` on every resource it alone touches.
    first_busy_s: float = float("inf")
    last_busy_s: float = 0.0

    def record(self, start: float, enqueued: float, dur: float,
               size: float, job: int) -> None:
        self.busy_s += dur
        self.waited_s += start - enqueued
        self.volume_mb += size
        self.n_chunks += 1
        self.jobs.add(job)
        self.first_busy_s = min(self.first_busy_s, start)
        self.last_busy_s = max(self.last_busy_s, start + dur)

    #: default load-warning thresholds (the queueing-delay warning idiom:
    #: flag a resource before it becomes the bottleneck, not after): a
    #: resource is a *hotspot* when its busy fraction of the horizon
    #: exceeds ``UTILIZATION_WARN`` or the mean time a chunk spent queued
    #: behind earlier bookings exceeds ``BACKLOG_AGE_WARN_S``.
    UTILIZATION_WARN = 0.85
    BACKLOG_AGE_WARN_S = 60.0

    def utilization(self, horizon: float) -> float:
        """Fraction of ``horizon`` this resource spent serving."""
        return self.busy_s / horizon if horizon > 0 else 0.0

    @property
    def contended(self) -> bool:
        return len(self.jobs) > 1

    @property
    def mean_wait_s(self) -> float:
        """Mean per-chunk queue delay (fluid mode: the backlog-age
        integral per completed flow) — the backlog-age signal behind
        :meth:`load_warnings`."""
        return self.waited_s / self.n_chunks if self.n_chunks else 0.0

    def load_warnings(
        self,
        horizon: float,
        utilization_above: Optional[float] = None,
        backlog_age_above_s: Optional[float] = None,
    ) -> List[str]:
        """Threshold violations for this resource over ``horizon`` —
        empty when healthy.  ``None`` thresholds fall back to the class
        defaults."""
        u_th = self.UTILIZATION_WARN if utilization_above is None \
            else utilization_above
        b_th = self.BACKLOG_AGE_WARN_S if backlog_age_above_s is None \
            else backlog_age_above_s
        warns = []
        util = self.utilization(horizon)
        if util > u_th:
            warns.append(f"utilization {util:.0%} > {u_th:.0%}")
        if self.mean_wait_s > b_th:
            warns.append(
                f"mean queue delay {self.mean_wait_s:.1f}s > {b_th:.0f}s"
            )
        return warns

    def as_dict(self) -> Dict[str, float]:
        return {
            "busy_s": self.busy_s,
            "waited_s": self.waited_s,
            "mean_wait_s": float(self.mean_wait_s),
            "volume_mb": self.volume_mb,
            "n_chunks": float(self.n_chunks),
            "n_jobs": float(len(self.jobs)),
        }


class _Transfer:
    """One queued/in-service link transfer: the chunk-sized payload plus the
    event to fire when it completes."""

    __slots__ = ("run", "size", "fn", "args", "enqueued")

    def __init__(self, run: "_JobRun", size: float, fn: str, args: tuple,
                 enqueued: float):
        self.run = run
        self.size = float(size)
        self.fn = fn
        self.args = args
        self.enqueued = enqueued


class LinkResource:
    """A point-to-point link serving queued transfers FIFO, one at a time.

    Transfers wait in :attr:`queue` until the link is free — exactly the
    serialization the old eager-booking link applied, but *revocable*: a
    queued transfer has committed nothing and can be pulled back and
    re-routed (plan swap), and each service reads the link's capacity trace
    at its own start time (drift).  Only :attr:`current` is committed.
    """

    __slots__ = ("name", "bw", "trace", "busy", "current", "queue", "stats",
                 "down", "serial")

    def __init__(self, name: str, bw: float, trace=None):
        self.name = name
        self.bw = float(bw)
        self.trace = trace
        self.busy = False
        self.current: Optional[_Transfer] = None
        self.queue: List[_Transfer] = []
        self.stats = ResourceStats()
        #: partition depth: >0 means the link is severed (overlapping
        #: partitions nest, each repair decrements) — the pump refuses to
        #: start service and queued transfers park until repair
        self.down = 0
        #: service generation: bumped on each service start so a completion
        #: event voided by a partition (service revoked mid-flight) can be
        #: recognized as stale and dropped
        self.serial = 0

    def rate_at(self, t: float) -> float:
        """MB/s in force at time ``t`` (nominal unless a trace overrides)."""
        return self.trace.at(t) if self.trace is not None else self.bw


class ComputeResource:
    """A map/reduce worker node serving queued chunks FIFO across jobs."""

    __slots__ = ("name", "rate", "trace", "busy", "current", "current_chunk",
                 "queue", "stats")

    def __init__(self, name: str, rate: float, trace=None):
        self.name = name
        self.rate = float(rate)
        self.trace = trace
        self.busy = False
        #: the job whose chunk is in service (None when idle) — barrier
        #: checks must distinguish "busy with MY chunk" from "busy at all"
        self.current: Optional["_JobRun"] = None
        self.current_chunk: Optional["_Chunk"] = None
        #: FIFO of (job_state, chunk, enqueue_time)
        self.queue: List[Tuple["_JobRun", "_Chunk", float]] = []
        self.stats = ResourceStats()

    def rate_at(self, t: float) -> float:
        return self.trace.at(t) if self.trace is not None else self.rate

    def enqueue(self, run: "_JobRun", chunk: "_Chunk", now: float) -> None:
        self.queue.append((run, chunk, now))

    def job_chunks(self, run: "_JobRun") -> List["_Chunk"]:
        return [c for g, c, _ in self.queue if g is run]

    def remove(self, run: "_JobRun", chunk: "_Chunk") -> None:
        for idx, (g, c, _) in enumerate(self.queue):
            if g is run and c is chunk:
                del self.queue[idx]
                return
        raise ValueError("chunk not queued at this resource")


class _Chunk:
    __slots__ = ("cid", "size", "src", "done", "started_copies", "owner",
                 "cloned", "landed", "replicas")

    def __init__(self, cid: int, size: float, src: int, owner: int = -1):
        self.cid = cid
        self.size = size
        self.src = src  # source index for map chunks; mapper index for reduce
        self.done = False
        self.started_copies = 0
        self.owner = owner  # mapper whose gate/progress counters hold it
        self.cloned = False
        self.landed = False  # push chunk delivered to a live mapper once
        self.replicas = None  # mappers holding a landed replica copy


class _JobRun:
    """Per-job executor state: the plan, barrier gates, progress counters and
    phase timestamps of one job sharing the substrate."""

    def __init__(self, idx: int, platform: Platform, plan: ExecutionPlan,
                 cfg: SimConfig, nM: int, nR: int):
        self.idx = idx
        self.p = platform
        self.plan = plan
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.seeded = False

        # -- pipeline stage linkage (set via _MultiSim.link_stages) --------
        #: upstream run indices whose reduce output feeds this run
        self.stage_deps: Tuple[int, ...] = ()
        #: upstream run idx -> reduce-output MB per reduce-input MB
        self.stage_scale: Dict[int, float] = {}
        #: source idx -> set of upstream run idxs whose output has not yet
        #: landed there (a source releases when its set empties)
        self.dep_pending: Dict[int, set] = {}
        #: per-source MB landed from finalized upstream reducers
        self.dep_landed = np.zeros(platform.nS)
        #: reduce-input MB this run has *completed* per reducer (what a
        #: downstream stage's source receives, times out_scale)
        self.delivered_out = np.zeros(nR)
        #: shuffle chunks destined to each reducer, created but not yet
        #: reduced — zero (with shuffle final) marks the reducer's output
        #: as landed for downstream stages
        self.reduce_outstanding = np.zeros(nR, dtype=np.int64)
        self.reducer_final = np.zeros(nR, dtype=bool)

        self.map_alive = np.ones(nM, dtype=bool)
        self.red_alive = np.ones(nR, dtype=bool)
        #: reduce-output provenance: MB reduced at reducer k that came from
        #: mapper j — what a reducer_kill must claw back to the right
        #: mapper pools (zeroed per column on claw-back)
        self.reduced_by = np.zeros((nM, nR))

        # outstanding counters for gates
        self.push_inflight = np.zeros(nM, dtype=np.int64)
        self.map_unfinished = np.zeros(nM, dtype=np.int64)
        self.shuf_inflight = np.zeros(nR, dtype=np.int64)
        self.total_push_inflight = 0
        self.total_map_unfinished = 0
        self.total_shuf_inflight = 0

        self.push_end = 0.0
        self.map_end = 0.0
        self.shuffle_end = 0.0
        self.reduce_end = 0.0
        self.wasted_mb = 0.0
        self.recovered = 0
        self.total_map_chunks = 0
        # failure loss ledger: payload MB voided by failures and the MB
        # re-dispatched (replica fetch / source re-push / shuffle re-emit /
        # link retransmit) to make it up — conservation demands equality
        self.lost_mb = 0.0
        self.reexec_mb = 0.0

        # byte-conservation ledger (original payload only — replica and
        # speculative traffic is wasted-work accounting, not job volume):
        # seeded pushes must land and map exactly once; shuffle emissions
        # must land and reduce exactly once.  Checked when cfg.audit is on.
        self.pushed_mb = 0.0
        self.landed_mb = 0.0
        self.mapped_mb = 0.0
        self.shuf_created_mb = 0.0
        self.shuf_landed_mb = 0.0
        self.reduced_mb = 0.0

        # chunks delivered to mapper j but gated (push/map barrier)
        self.map_gated: List[List[_Chunk]] = [[] for _ in range(nM)]
        # shuffle emissions gated at mapper j (map/shuffle barrier)
        self.shuf_gated: List[List[Tuple[int, _Chunk]]] = [[] for _ in range(nM)]
        # reduce chunks gated at reducer k (shuffle/reduce barrier)
        self.red_gated: List[List[_Chunk]] = [[] for _ in range(nR)]

    def noise(self) -> float:
        if self.cfg.compute_noise <= 0:
            return 1.0
        return float(np.exp(self.rng.normal(0.0, self.cfg.compute_noise)))

    def slowdown(self, tier: str, idx: int) -> float:
        if self.cfg.stragglers:
            return self.cfg.stragglers.get((tier, idx), 1.0)
        return 1.0

    def result(self) -> SimResult:
        return SimResult(
            makespan=self.reduce_end,
            push_end=self.push_end,
            map_end=self.map_end,
            shuffle_end=self.shuffle_end,
            reduce_end=self.reduce_end,
            wasted_mb=self.wasted_mb,
            recovered_chunks=self.recovered,
            total_map_chunks=self.total_map_chunks,
            lost_mb=self.lost_mb,
            reexec_mb=self.reexec_mb,
        )


@dataclasses.dataclass
class ScheduleSimResult:
    """Concurrent execution of N jobs on one substrate: per-job timings plus
    per-resource service accounting."""

    jobs: List[SimResult]
    makespan: float  # absolute completion time of the last job
    resources: Dict[str, ResourceStats]
    #: runtime-audit findings (``SimConfig(audit=True)`` jobs only) —
    #: empty on a conserving, sane execution.  Deliberately excluded from
    #: :meth:`as_dict` to keep the benchmark JSON schema stable.
    violations: List[str] = dataclasses.field(default_factory=list)

    def utilization(self) -> Dict[str, float]:
        """Busy fraction of the schedule horizon per named resource."""
        return {
            name: s.utilization(self.makespan)
            for name, s in self.resources.items()
        }

    def contended(self) -> Dict[str, ResourceStats]:
        """Resources that served chunks of more than one job."""
        return {n: s for n, s in self.resources.items() if s.contended}

    def hotspots(
        self,
        utilization_above: Optional[float] = None,
        backlog_age_above_s: Optional[float] = None,
    ) -> Dict[str, List[str]]:
        """Resources whose load crossed a warning threshold, mapped to the
        human-readable threshold violations — the schedule-level view of
        :meth:`ResourceStats.load_warnings`.  ``None`` thresholds use the
        :class:`ResourceStats` class defaults (utilization > 85%, mean
        queue delay > 60 s); empty dict = no hotspots."""
        out: Dict[str, List[str]] = {}
        for name, stats in self.resources.items():
            warns = stats.load_warnings(
                self.makespan, utilization_above, backlog_age_above_s
            )
            if warns:
                out[name] = warns
        return out

    def as_dict(self) -> Dict[str, object]:
        """Stable nested form mirroring :meth:`SimResult.as_dict` one level
        up: aggregate makespan, per-job phase timings, per-resource
        utilization and service accounting — what the schedule benchmarks
        and ``--json`` emission feed to figures."""
        return {
            "makespan": self.makespan,
            "jobs": [job.as_dict() for job in self.jobs],
            "utilization": self.utilization(),
            "resources": {n: s.as_dict() for n, s in self.resources.items()},
        }

    def summary(self) -> str:
        worst = sorted(
            self.resources.items(), key=lambda kv: -kv[1].busy_s
        )[:3]
        hot = " ".join(
            f"{n}={s.utilization(self.makespan):.0%}" for n, s in worst
        )
        return (
            f"schedule: {len(self.jobs)} jobs makespan={self.makespan:.1f}s "
            f"contended={len(self.contended())} hottest: {hot}"
        )


@dataclasses.dataclass(frozen=True)
class ProgressSnapshot:
    """The executor's observable state at one event time: every job's
    remaining work bucketed for the re-planner
    (:class:`repro.core.makespan.JobProgress`) plus the MB queued at each
    named resource."""

    time: float
    jobs: Tuple[JobProgress, ...]
    backlog: Dict[str, float]

    def active_jobs(self) -> Tuple[JobProgress, ...]:
        """Jobs with remaining work (released or not)."""
        return tuple(j for j in self.jobs if not j.done)

    def residual_view(self) -> Tuple[Tuple[int, JobProgress], ...]:
        """The multi-job residual view a schedule-aware re-planner
        consumes: ``(job_index, progress)`` for every job with remaining
        work, in job order.  These are the residuals
        :func:`repro.core.optimize.replan_schedule` co-optimizes jointly
        (the indices key :meth:`_MultiSim.swap_plan`)."""
        return tuple((j.job, j) for j in self.jobs if not j.done)

    def backlog_mb(self) -> float:
        """Total MB queued across every substrate resource — one scalar a
        policy can threshold on."""
        return float(sum(self.backlog.values()))


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class _MultiSim:
    """Resource-centric event engine running N jobs on one substrate.

    Events are ``(time, seq, fn_name, args)``; chunk events are routed
    through the shared :class:`LinkResource`/:class:`ComputeResource`
    objects, so concurrent jobs contend for the same capacity entries.

    The engine doubles as the **online control plane's plant**: a driver
    may interleave :meth:`run_until` (advance to a decision instant),
    :meth:`snapshot` (observe), :meth:`swap_plan`/:meth:`inject` (steer)
    and finally :meth:`run` (drain to completion).  :meth:`run` with no
    intervening steering is byte-for-byte the offline
    :func:`simulate_schedule`.
    """

    def __init__(self, substrate: Substrate, runs: List[_JobRun]):
        self.sub = substrate
        self.runs = runs
        self.now = 0.0
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._seq = itertools.count()
        self._cid = itertools.count()
        self._started = False
        #: pipeline linkage: parent run idx -> downstream run idxs whose
        #: sources consume the parent's reduce output
        self.stage_children: Dict[int, List[int]] = {}
        #: runtime-audit findings (see :meth:`_audit_step`); bounded so a
        #: broken invariant cannot balloon memory on a long run
        self.violations: List[str] = []
        self._audit = any(g.cfg.audit for g in runs)
        #: substrate-wide dead workers (from the substrate FailureTrace) —
        #: jobs injected after the kill inherit the dead state
        self._dead_m: set = set()
        self._dead_r: set = set()
        #: cached per-job slowdown tables for steered vectorized drains;
        #: rebuilt whenever the job count changes (inject)
        self._vec_slow = None

        nS, nM, nR = substrate.nS, substrate.nM, substrate.nR
        trace = substrate.trace_for
        self.push_links = [
            [LinkResource(f"push[s{i}->m{j}]", substrate.B_sm[i, j],
                          trace(f"push[s{i}->m{j}]"))
             for j in range(nM)]
            for i in range(nS)
        ]
        self.shuf_links = [
            [LinkResource(f"shuffle[m{j}->r{k}]", substrate.B_mr[j, k],
                          trace(f"shuffle[m{j}->r{k}]"))
             for k in range(nR)]
            for j in range(nM)
        ]
        self.mappers = [
            ComputeResource(f"map[m{j}]", substrate.C_m[j], trace(f"map[m{j}]"))
            for j in range(nM)
        ]
        self.reducers = [
            ComputeResource(f"reduce[r{k}]", substrate.C_r[k],
                            trace(f"reduce[r{k}]"))
            for k in range(nR)
        ]

    # -- infrastructure ----------------------------------------------------
    def at(self, t: float, fn: str, *args):
        heapq.heappush(self._heap, (t, next(self._seq), fn, args))

    def _start(self):
        """Schedule the initial seeds and failures (idempotent) — jobs
        sharing a release time seed round-robin (chunk-interleaved bookings
        approximate fair-share FIFO on contended links).  Stage-linked runs
        (:meth:`link_stages`) are not seeded here: their sources release as
        upstream reduce output lands."""
        if self._started:
            return
        self._started = True
        roots = [g for g in self.runs if not g.stage_deps]
        for start in sorted({g.cfg.start_time for g in roots}):
            group = [g for g in roots if g.cfg.start_time == start]
            self.at(start, "seed_jobs", tuple(g.idx for g in group))
        for g in self.runs:
            self._schedule_job_failures(g)
        if self.sub.failures:
            for ev in self.sub.failures:
                if ev.kind == "mapper_kill":
                    self.at(ev.time, "fail_mapper_all", ev.node)
                elif ev.kind == "reducer_kill":
                    self.at(ev.time, "fail_reducer_all", ev.node)
                else:  # cluster_partition
                    self.at(ev.time, "partition", ev.cluster, ev.t_repair)

    def _schedule_job_failures(self, g: _JobRun) -> None:
        """Book job ``g``'s per-job fault script (kills only — fabric
        faults live on the substrate)."""
        for ev in g.cfg.failures:
            fn = "fail_mapper" if ev.kind == "mapper_kill" else "fail_reducer"
            self.at(ev.time, fn, g, ev.node)

    # -- pipeline stage linkage --------------------------------------------
    def link_stages(
        self, child: int, parents: Sequence[Tuple[int, float]]
    ) -> None:
        """Make run ``child`` a downstream pipeline stage of ``parents``
        (``(parent_run_idx, out_scale)`` pairs): its push chunks at source
        node ``s`` release only when every parent's reduce output destined
        for ``s`` (reducer ``s``, scaled by that parent's ``out_scale``)
        has landed.  Must be called before the engine starts."""
        if self._started:
            raise RuntimeError("link_stages must precede the first event")
        if self.sub.nS != self.sub.nR:
            raise ValueError(
                f"stage linking needs nS == nR (reducer r feeds source r), "
                f"substrate has nS={self.sub.nS} nR={self.sub.nR}"
            )
        g = self.runs[child]
        if g.stage_deps:
            raise ValueError(f"run {child} is already stage-linked")
        parent_idxs = [int(p) for p, _ in parents]
        if len(set(parent_idxs)) != len(parent_idxs):
            raise ValueError(f"duplicate parents {parent_idxs}")
        for p in parent_idxs:
            if not 0 <= p < len(self.runs) or p == child:
                raise ValueError(f"bad parent run index {p} for run {child}")
            # reject cycles: child must not already be upstream of p
            stack, seen = [p], set()
            while stack:
                u = stack.pop()
                if u == child:
                    raise ValueError(
                        f"stage link {p}->{child} would close a cycle"
                    )
                if u in seen:
                    continue
                seen.add(u)
                stack.extend(self.runs[u].stage_deps)
        g.stage_deps = tuple(parent_idxs)
        g.stage_scale = {int(p): float(s) for p, s in parents}
        g.dep_pending = {
            i: set(parent_idxs) for i in range(self.sub.nS)
        }
        for p in parent_idxs:
            self.stage_children.setdefault(p, []).append(child)

    def _maybe_finalize_stage(self, g: _JobRun) -> None:
        """Mark the reducers of ``g`` whose output can no longer grow as
        final and hand their landed volume to downstream stage sources.
        No-op unless ``g`` has stage children."""
        children = self.stage_children.get(g.idx)
        if not children or not self._shuffle_final(g):
            return
        for k in range(self.sub.nR):
            if (g.reducer_final[k] or g.shuf_inflight[k] != 0
                    or g.reduce_outstanding[k] != 0):
                continue
            g.reducer_final[k] = True
            for c in children:
                child = self.runs[c]
                waiting = child.dep_pending.get(k)
                if waiting is None or g.idx not in waiting:
                    continue
                child.dep_landed[k] += (
                    child.stage_scale[g.idx] * g.delivered_out[k]
                )
                waiting.discard(g.idx)
                if not waiting:
                    del child.dep_pending[k]
                    self._release_source(child, k)

    def _release_source(self, g: _JobRun, i: int) -> None:
        """Seed source ``i``'s push chunks of a stage-linked run: the
        *measured* upstream output that landed there, routed per the run's
        (possibly swapped-in) plan.  When this was the last pending source,
        re-check every barrier gate — phases that were held back solely by
        the pending sources may now proceed."""
        g.seeded = True
        amount = float(g.dep_landed[i])
        if amount > 1e-9:
            cfg = g.cfg
            for j in range(self.sub.nM):
                share = amount * g.plan.x[i, j]
                if share <= 1e-9:
                    continue
                n_chunks = max(int(np.ceil(share / cfg.chunk_mb)), 1)
                for _ in range(n_chunks):
                    self._seed_push_chunk(g, i, j, share / n_chunks)
        if not g.dep_pending:
            self._recheck_gates(g)

    def _recheck_gates(self, g: _JobRun) -> None:
        """Open every barrier gate whose condition holds now — called once
        a stage-linked run becomes fully fed, since the pending-source
        guards may have held gates shut past their trigger events."""
        b0, b1, b2 = g.cfg.barriers
        nM, nR = self.sub.nM, self.sub.nR
        if b0 == "L":
            for j in range(nM):
                if g.push_inflight[j] == 0:
                    self._open_map_gate(g, j)
        elif b0 == "G" and g.total_push_inflight == 0:
            for j in range(nM):
                self._open_map_gate(g, j)
        if b1 == "L":
            for j in range(nM):
                node = self.mappers[j]
                if g.map_unfinished[j] == 0 \
                        and not (node.busy and node.current is g):
                    self._open_shuffle_gate(g, j)
        elif b1 == "G" and g.total_map_unfinished == 0 \
                and g.total_push_inflight == 0:
            for j in range(nM):
                self._open_shuffle_gate(g, j)
        if b2 == "L":
            for k in range(nR):
                if g.shuf_inflight[k] == 0 and self._shuffle_final(g):
                    self._open_reduce_gate(g, k)
        elif b2 == "G" and g.total_shuf_inflight == 0 \
                and self._shuffle_final(g):
            for k in range(nR):
                self._open_reduce_gate(g, k)
        self._maybe_finalize_stage(g)

    def _dispatch(self):
        t, _, fn, args = heapq.heappop(self._heap)
        self.now = max(self.now, t)
        getattr(self, "_ev_" + fn)(*args)
        if self._audit:
            self._audit_step(fn)

    # -- runtime audit -----------------------------------------------------
    _MAX_VIOLATIONS = 200

    def _violate(self, msg: str) -> None:
        if len(self.violations) < self._MAX_VIOLATIONS:
            self.violations.append(f"t={self.now:.6f}: {msg}")
        elif len(self.violations) == self._MAX_VIOLATIONS:
            self.violations.append("... further violations suppressed")

    def _audit_step(self, fn: str) -> None:
        """Post-event sanity: gate counters must stay non-negative and the
        scalar totals must equal their per-node sums — a drift here means a
        gate can deadlock shut or open early."""
        for g in self.runs:
            if not g.cfg.audit:
                continue
            for name, arr in (
                ("push_inflight", g.push_inflight),
                ("map_unfinished", g.map_unfinished),
                ("shuf_inflight", g.shuf_inflight),
                ("reduce_outstanding", g.reduce_outstanding),
            ):
                if np.any(arr < 0):
                    self._violate(
                        f"job {g.idx}: after {fn}: {name} negative at "
                        f"nodes {np.flatnonzero(arr < 0).tolist()}"
                    )
            for name, total, arr in (
                ("push_inflight", g.total_push_inflight, g.push_inflight),
                ("map_unfinished", g.total_map_unfinished, g.map_unfinished),
                ("shuf_inflight", g.total_shuf_inflight, g.shuf_inflight),
            ):
                if total != int(arr.sum()):
                    self._violate(
                        f"job {g.idx}: after {fn}: total_{name}={total} "
                        f"!= sum({name})={int(arr.sum())}"
                    )

    def _audit_final(self) -> None:
        """Byte conservation at completion: every seeded MB lands, maps,
        shuffles (scaled by alpha) and reduces exactly once."""

        def close(a: float, b: float) -> bool:
            # rel 1e-6 plus a small absolute floor: shuffle emission skips
            # sub-1e-9 slivers, so alpha-scaled totals are near- but not
            # bit-exact
            return abs(a - b) <= 1e-6 * max(abs(a), abs(b)) + 1e-3

        for g in self.runs:
            if not g.cfg.audit or not g.seeded:
                continue
            checks = (
                ("landed_mb", g.landed_mb, "pushed_mb", g.pushed_mb),
                ("mapped_mb", g.mapped_mb, "landed_mb", g.landed_mb),
                ("shuf_created_mb", g.shuf_created_mb,
                 "alpha*mapped_mb", g.p.alpha * g.mapped_mb),
                ("shuf_landed_mb", g.shuf_landed_mb,
                 "shuf_created_mb", g.shuf_created_mb),
                ("reduced_mb", g.reduced_mb,
                 "shuf_landed_mb", g.shuf_landed_mb),
                # bytes voided by failures must be exactly re-dispatched:
                # no silent byte creation or loss around a fault
                ("reexec_mb", g.reexec_mb, "lost_mb", g.lost_mb),
            )
            for name_a, a, name_b, b in checks:
                if not close(a, b):
                    self._violate(
                        f"job {g.idx}: conservation: {name_a}={a:.6f} != "
                        f"{name_b}={b:.6f}"
                    )

    @property
    def finished(self) -> bool:
        return self._started and not self._heap

    def run_until(self, t: float, inclusive: bool = False) -> None:
        """Advance the clock to ``t``, processing every event strictly
        before it.  Events *at* ``t`` stay pending, so a decision taken at
        ``t`` (inject, swap) acts before them — matching the offline event
        order, where release seeds carry the earliest sequence numbers.
        ``inclusive`` additionally drains the events *at* ``t`` — the right
        framing when the decision must observe what happens at that instant
        (e.g. re-planning *after* a worker failure fires)."""
        self._start()
        if self._heap and self._vec_steer_eligible():
            self._vec_drain(t, inclusive)
        else:
            while self._heap and (
                self._heap[0][0] < t
                or (inclusive and self._heap[0][0] == t)
            ):
                self._dispatch()
        self.now = max(self.now, t)

    def run(self) -> ScheduleSimResult:
        if (not self._started and self.runs
                and all(g.cfg.mode == "event_vec" for g in self.runs)):
            return self._run_vectorized()
        self._start()
        if self._heap and self._vec_steer_eligible():
            # started (steered) engine: drain everything through the
            # batched scans; the scalar loop below mops up anything a
            # drained segment re-scheduled (it never does today, but the
            # fallback keeps the contract obvious)
            self._vec_drain(None, True)
        while self._heap:
            self._dispatch()
        if self._audit:
            self._audit_final()
        return self.result()

    def result(self) -> ScheduleSimResult:
        resources: Dict[str, ResourceStats] = {}
        for row in self.push_links:
            for link in row:
                resources[link.name] = link.stats
        for row in self.shuf_links:
            for link in row:
                resources[link.name] = link.stats
        for node in self.mappers + self.reducers:
            resources[node.name] = node.stats
        return ScheduleSimResult(
            jobs=[g.result() for g in self.runs],
            makespan=max((g.reduce_end for g in self.runs), default=0.0),
            resources=resources,
            violations=list(self.violations),
        )

    def _rate(self, g: _JobRun, tier: str, idx: int) -> float:
        node = self.mappers[idx] if tier == "m" else self.reducers[idx]
        return node.rate_at(self.now) / g.slowdown(tier, idx)

    # -- link pump ---------------------------------------------------------
    def _link_send(self, link: LinkResource, g: _JobRun, size: float,
                   fn: str, args: tuple) -> None:
        link.queue.append(_Transfer(g, size, fn, args, self.now))
        self._pump_link(link)

    def _pump_link(self, link: LinkResource):
        if link.busy or link.down or not link.queue:
            return
        tr = link.queue.pop(0)
        link.busy = True
        link.current = tr
        link.serial += 1
        dur = tr.size / link.rate_at(self.now)
        link.stats.record(self.now, tr.enqueued, dur, tr.size, tr.run.idx)
        self.at(self.now + dur, "link_done", link, tr, link.serial)

    def _ev_link_done(self, link: LinkResource, tr: _Transfer, serial=None):
        if serial is not None and serial != link.serial:
            # a partition revoked this service mid-flight; the completion is
            # void and the payload was already re-queued at partition time
            return
        link.busy = False
        link.current = None
        getattr(self, "_ev_" + tr.fn)(*tr.args)
        self._pump_link(link)

    # -- push phase ----------------------------------------------------------
    def _ev_seed_jobs(self, idxs: Tuple[int, ...]):
        """Seed every push chunk of the released jobs, interleaving chunks
        across jobs so shared links serve them round-robin."""
        pending = [(self.runs[i], self._push_ops(self.runs[i])) for i in idxs]
        for i in idxs:
            self.runs[i].seeded = True
        cursors = [0] * len(pending)
        live = True
        while live:
            live = False
            for slot, (g, ops) in enumerate(pending):
                if cursors[slot] >= len(ops):
                    continue
                live = True
                i, j, size = ops[cursors[slot]]
                cursors[slot] += 1
                self._seed_push_chunk(g, i, j, size)

    def _seed_push_chunk(self, g: _JobRun, i: int, j: int, size: float):
        """Create one push chunk (plus its replicas) with its gate
        counters — the unit of both t=0 seeding and per-source stage
        release."""
        c = _Chunk(next(self._cid), size, i, owner=j)
        g.total_map_chunks += 1
        g.pushed_mb += size
        g.push_inflight[j] += 1
        g.total_push_inflight += 1
        g.map_unfinished[j] += 1
        g.total_map_unfinished += 1
        self._send_push(g, i, j, c)
        self._replicate(g, i, j, c)

    def _push_ops(self, g: _JobRun) -> List[Tuple[int, int, float]]:
        """The job's push chunks as (source, mapper, MB) in seeding order."""
        cfg, p = g.cfg, g.p
        ops: List[Tuple[int, int, float]] = []
        for i in range(p.nS):
            for j in range(p.nM):
                amount = p.D[i] * g.plan.x[i, j]
                if amount <= 1e-9:
                    continue
                n_chunks = max(int(np.ceil(amount / cfg.chunk_mb)), 1)
                ops.extend((i, j, amount / n_chunks) for _ in range(n_chunks))
        return ops

    def _replicate(self, g: _JobRun, i: int, j: int, c: _Chunk):
        """Write replication-1 extra copies of a push chunk (replica targets
        never run map work; they only consume link capacity — until the
        origin mapper dies, when a landed replica becomes the cheapest
        recovery source)."""
        sub, cfg = self.sub, g.cfg
        size = c.size
        for r in range(cfg.replication - 1):
            if cfg.cross_cluster_replication:
                candidates = [
                    m for m in range(sub.nM)
                    if sub.cluster_m[m] != sub.cluster_m[j]
                ]
            else:
                candidates = [
                    m
                    for m in range(sub.nM)
                    if sub.cluster_m[m] == sub.cluster_m[j] and m != j
                ]
            if not candidates:
                candidates = [m for m in range(sub.nM) if m != j]
            tgt = candidates[(j + r + 1) % len(candidates)]
            g.wasted_mb += size
            # the write pipeline is not durable (and the push phase not
            # complete) until every replica is on disk: replica writes gate
            # the ORIGIN mapper's input like any other push chunk.
            g.push_inflight[j] += 1
            g.total_push_inflight += 1
            self._link_send(self.push_links[i][tgt], g, size,
                            "replica_done", (g, c, j, tgt))

    def _ev_replica_done(self, g: _JobRun, c: _Chunk, j: int, tgt: int):
        g.push_end = max(g.push_end, self.now)
        g.push_inflight[j] -= 1
        g.total_push_inflight -= 1
        if g.map_alive[tgt]:
            if c.replicas is None:
                c.replicas = []
            c.replicas.append(tgt)
        b = g.cfg.barriers[0]
        if g.dep_pending:
            # a pending stage source may still route data anywhere: every
            # map gate stays shut until the run is fully fed
            return
        if b == "L" and g.push_inflight[j] == 0:
            self._open_map_gate(g, j)
        elif b == "G" and g.total_push_inflight == 0:
            for m in range(self.sub.nM):
                self._open_map_gate(g, m)

    def _send_push(self, g: _JobRun, i: int, j: int, c: _Chunk):
        self._link_send(self.push_links[i][j], g, c.size,
                        "push_arrive", (g, i, j, c))

    def _ev_push_arrive(self, g: _JobRun, i: int, j: int, c: _Chunk):
        g.push_end = max(g.push_end, self.now)
        g.push_inflight[j] -= 1
        g.total_push_inflight -= 1
        if not g.map_alive[j]:
            self._recover_chunk(g, j, c)
            return
        self._deliver_push(g, j, c)

    def _deliver_push(self, g: _JobRun, j: int, c: _Chunk):
        """Land chunk ``c`` at live mapper ``j``: ledger, then queue or
        gate per the push/map barrier.  Shared by arrival over the push
        link and zero-cost local delivery from an on-node replica."""
        if not c.landed:
            c.landed = True
            g.landed_mb += c.size
        b = g.cfg.barriers[0]
        if b == "P":
            self.mappers[j].enqueue(g, c, self.now)
            self._pump_map(j)
        else:
            g.map_gated[j].append(c)
            if g.dep_pending:
                return  # fully-fed gate checks happen at the last release
            if b == "L" and g.push_inflight[j] == 0:
                self._open_map_gate(g, j)
            elif b == "G" and g.total_push_inflight == 0:
                for m in range(self.sub.nM):
                    self._open_map_gate(g, m)

    def _open_map_gate(self, g: _JobRun, j: int):
        if g.map_gated[j]:
            for c in g.map_gated[j]:
                self.mappers[j].enqueue(g, c, self.now)
            g.map_gated[j].clear()
        self._pump_map(j)

    # -- map phase -------------------------------------------------------------
    def _pump_map(self, j: int):
        node = self.mappers[j]
        if node.busy:
            return
        if not node.queue:
            self._idle_mapper(j)
            return
        g, c, t_enq = node.queue.pop(0)
        if c.done:  # a speculative twin already finished this chunk
            self._pump_map(j)
            return
        c.started_copies += 1
        node.busy = True
        node.current = g
        node.current_chunk = c
        dur = c.size / self._rate(g, "m", j) * g.noise()
        node.stats.record(self.now, t_enq, dur, c.size, g.idx)
        self.at(self.now + dur, "map_done", g, j, c)

    def _ev_map_done(self, g: _JobRun, j: int, c: _Chunk):
        self.mappers[j].busy = False
        self.mappers[j].current = None
        self.mappers[j].current_chunk = None
        if c.done:
            g.wasted_mb += c.size  # lost the speculation race
            self._pump_map(j)
            return
        c.done = True
        g.map_end = max(g.map_end, self.now)
        g.mapped_mb += c.size
        owner = c.owner if c.owner >= 0 else j
        g.map_unfinished[owner] -= 1
        g.total_map_unfinished -= 1
        self._emit_shuffle(g, j, c)
        if owner != j and g.cfg.barriers[1] == "L" \
                and g.map_unfinished[owner] == 0 and not g.dep_pending:
            self._open_shuffle_gate(g, owner)
        self._pump_map(j)
        self._maybe_finalize_stage(g)

    def _emit_shuffle(self, g: _JobRun, j: int, c: _Chunk):
        b = g.cfg.barriers[1]
        y = g.plan.y
        if not g.red_alive.all():
            # mask dead reducers and renormalize — new emissions must not
            # target a dead node (the guard keeps no-failure runs on the
            # exact float path of the original expression)
            live = np.where(g.red_alive, y, 0.0)
            if live.sum() <= 1e-12:
                live = np.where(g.red_alive, 1.0, 0.0)
                if live.sum() == 0:
                    raise RuntimeError("all reducers dead")
            y = live / live.sum()
        for k in range(self.sub.nR):
            amount = g.p.alpha * c.size * y[k]
            if amount <= 1e-9:
                continue
            sc = _Chunk(next(self._cid), float(amount), j)
            g.shuf_created_mb += sc.size
            g.shuf_inflight[k] += 1
            g.total_shuf_inflight += 1
            g.reduce_outstanding[k] += 1
            if b == "P":
                self._send_shuffle(g, j, k, sc)
            else:
                g.shuf_gated[j].append((k, sc))
        if g.dep_pending:
            return  # pending stage sources will add map work: gates held
        if b == "L" and g.map_unfinished[j] == 0:
            self._open_shuffle_gate(g, j)
        elif b == "G" and g.total_map_unfinished == 0:
            for m in range(self.sub.nM):
                self._open_shuffle_gate(g, m)

    def _open_shuffle_gate(self, g: _JobRun, j: int):
        for k, sc in g.shuf_gated[j]:
            self._send_shuffle(g, j, k, sc)
        g.shuf_gated[j].clear()

    def _send_shuffle(self, g: _JobRun, j: int, k: int, sc: _Chunk):
        self._link_send(self.shuf_links[j][k], g, sc.size,
                        "shuffle_arrive", (g, j, k, sc))

    def _ev_shuffle_arrive(self, g: _JobRun, j: int, k: int, sc: _Chunk):
        g.shuf_inflight[k] -= 1
        g.total_shuf_inflight -= 1
        if not g.red_alive[k]:
            # the reducer died while this emission was in flight: the
            # payload bounces — void it and re-emit to surviving reducers
            g.reduce_outstanding[k] -= 1
            g.shuf_created_mb -= sc.size
            g.lost_mb += sc.size
            g.wasted_mb += sc.size
            self._reemit_shuffle(g, j, sc.size)
            return
        g.shuffle_end = max(g.shuffle_end, self.now)
        g.shuf_landed_mb += sc.size
        b = g.cfg.barriers[2]
        if b == "P":
            self.reducers[k].enqueue(g, sc, self.now)
            self._pump_reduce(k)
        else:
            g.red_gated[k].append(sc)
            if b == "L" and g.shuf_inflight[k] == 0 and self._shuffle_final(g):
                self._open_reduce_gate(g, k)
            elif b == "G" and g.total_shuf_inflight == 0 and self._shuffle_final(g):
                for r in range(self.sub.nR):
                    self._open_reduce_gate(g, r)

    def _shuffle_final(self, g: _JobRun) -> bool:
        """No more shuffle chunks can appear (all the job's map work done
        and, for a stage-linked run, every source fully fed)."""
        return (g.total_map_unfinished == 0 and g.total_push_inflight == 0
                and not g.dep_pending)

    def _open_reduce_gate(self, g: _JobRun, k: int):
        if g.red_gated[k]:
            for sc in g.red_gated[k]:
                self.reducers[k].enqueue(g, sc, self.now)
            g.red_gated[k].clear()
        self._pump_reduce(k)

    # -- reduce phase ------------------------------------------------------------
    def _pump_reduce(self, k: int):
        node = self.reducers[k]
        if node.busy or not node.queue:
            return
        g, sc, t_enq = node.queue.pop(0)
        if sc.done:
            self._pump_reduce(k)
            return
        node.busy = True
        node.current = g
        node.current_chunk = sc
        dur = sc.size / self._rate(g, "r", k) * g.noise()
        node.stats.record(self.now, t_enq, dur, sc.size, g.idx)
        self.at(self.now + dur, "reduce_done", g, k, sc)

    def _ev_reduce_done(self, g: _JobRun, k: int, sc: _Chunk):
        self.reducers[k].busy = False
        self.reducers[k].current = None
        self.reducers[k].current_chunk = None
        if not sc.done:
            sc.done = True
            g.reduce_end = max(g.reduce_end, self.now)
            g.reduced_mb += sc.size
            g.delivered_out[k] += sc.size
            g.reduce_outstanding[k] -= 1
            g.reduced_by[sc.src, k] += sc.size
        else:
            g.wasted_mb += sc.size
        self._pump_reduce(k)
        self._maybe_finalize_stage(g)

    # -- dynamics: stealing / speculation ----------------------------------------
    def _idle_mapper(self, j: int):
        """The node ran out of queued work entirely; let each job with
        dynamics enabled (and a live worker here) try to relocate one of its
        own backlogged chunks.  At most one booking per idle trigger."""
        for g in self.runs:
            if not (g.cfg.stealing or g.cfg.speculation) or not g.map_alive[j]:
                continue
            if self._idle_mapper_for(g, j):
                return

    def _idle_mapper_for(self, g: _JobRun, j: int) -> bool:
        cfg = g.cfg
        # expected remaining compute time per mapper (this job's chunks)
        rem = np.array(
            [
                sum(c.size for c in self.mappers[m].job_chunks(g) if not c.done)
                / self._rate(g, "m", m)
                for m in range(self.sub.nM)
            ]
        )
        if rem.sum() <= 0:
            return False
        # fleet-mean progress (zeros included): a node is a straggler when
        # it lags the whole fleet, not merely other still-busy nodes
        mean = rem.mean()
        victim = int(rem.argmax())
        if victim == j or rem[victim] < cfg.spec_threshold * max(mean, 1e-9):
            return False
        pending = [
            c for c in self.mappers[victim].job_chunks(g)
            if not c.done and not c.cloned
        ]
        if not pending:
            return False
        c = pending[-1]
        # progress-based sanity check (Hadoop estimates task progress before
        # speculating): only act when the thief can plausibly win the race.
        my_time = c.size / self.sub.B_sm[c.src, j] + c.size / self._rate(g, "m", j)
        if my_time >= rem[victim]:
            return False
        if cfg.stealing:
            self.mappers[victim].remove(g, c)
            # ownership (and its gate counters) moves with the chunk
            g.map_unfinished[victim] -= 1
            g.map_unfinished[j] += 1
            c.owner = j
            # open now unless the victim is mid-service on one of THIS
            # job's chunks (that chunk's map_done reopens the gate);
            # another job's in-service chunk must not hold g's gate shut
            victim_node = self.mappers[victim]
            if cfg.barriers[1] == "L" and g.map_unfinished[victim] == 0 \
                    and not g.dep_pending \
                    and not (victim_node.busy and victim_node.current is g):
                self._open_shuffle_gate(g, victim)
        else:  # speculation: clone, twin-completion resolved via c.done
            c.cloned = True
        # re-fetch the input from the source over the push link
        self._link_send(self.push_links[c.src][j], g, c.size,
                        "stolen_arrive", (g, j, c))
        return True

    def _ev_stolen_arrive(self, g: _JobRun, j: int, c: _Chunk):
        if c.done:
            return
        if not g.map_alive[j]:
            # a STOLEN chunk (ownership moved to the thief) dies with the
            # thief unless recovered; a speculative clone still lives in
            # the victim's queue and can simply be dropped
            if c.owner == j:
                self._recover_chunk(g, j, c)
            return
        self.mappers[j].enqueue(g, c, self.now)
        self._pump_map(j)

    # -- dynamics: failure recovery ----------------------------------------------
    def _ev_fail_mapper(self, g: _JobRun, j: int):
        if not g.map_alive[j]:
            return  # already dead (per-job script + substrate trace overlap)
        g.map_alive[j] = False
        node = self.mappers[j]
        lost = [c for c in node.job_chunks(g) if not c.done]
        lost += [c for c in g.map_gated[j] if not c.done]
        node.queue = [(h, c, t) for h, c, t in node.queue if h is not g]
        g.map_gated[j].clear()
        # an in-flight chunk (already popped) still completes — the node's
        # busy flag clears at its map_done, exactly as before the refactor
        for c in lost:
            self._recover_chunk(g, j, c)

    def _recover_chunk(self, g: _JobRun, dead: int, c: _Chunk):
        """Re-execute a lost chunk: promote a landed replica on a surviving
        mapper (zero-cost local delivery — the copy is already on disk
        there), else re-push from the source to the job's best surviving
        mapper."""
        g.recovered += 1
        g.lost_mb += c.size
        alive = np.flatnonzero(g.map_alive)
        if alive.size == 0:
            raise RuntimeError("all mappers dead")
        holders = [int(t) for t in (c.replicas or ()) if g.map_alive[t]]
        if holders:
            tgt = holders[int(np.argmax(self.sub.C_m[holders]))]
            if c.owner >= 0 and c.owner != tgt:
                g.map_unfinished[c.owner] -= 1
                g.map_unfinished[tgt] += 1
                c.owner = tgt
            g.reexec_mb += c.size
            self._deliver_push(g, tgt, c)
            return
        i = c.src
        tgt = int(alive[np.argmax(self.sub.B_sm[i, alive])])
        if c.owner >= 0 and c.owner != tgt:
            g.map_unfinished[c.owner] -= 1
            g.map_unfinished[tgt] += 1
            c.owner = tgt
        g.wasted_mb += c.size
        g.reexec_mb += c.size
        g.push_inflight[tgt] += 1
        g.total_push_inflight += 1
        self._link_send(self.push_links[i][tgt], g, c.size,
                        "push_arrive", (g, i, tgt, c))

    def _ev_fail_reducer(self, g: _JobRun, k: int):
        """Reducer ``k`` dies for job ``g``: every byte it held —
        queued, barrier-gated, mid-service, even already reduced — is
        void.  The claw-back nets the conservation ledger and pools the
        volume back at its origin mappers for re-emission toward the
        surviving reducers."""
        if not g.red_alive[k]:
            return  # already dead (per-job script + substrate trace overlap)
        if (self._shuffle_final(g) and g.total_shuf_inflight == 0
                and int(g.reduce_outstanding.sum()) == 0):
            # the job already committed its output — a later node death
            # cannot un-deliver it (completion is the durability point)
            return
        g.red_alive[k] = False
        node = self.reducers[k]
        pool = np.zeros(self.sub.nM)
        # landed-but-unreduced chunks queued at the node or barrier-gated
        clawed = [sc for h, sc, _ in node.queue if h is g and not sc.done]
        clawed += [sc for sc in g.red_gated[k] if not sc.done]
        node.queue = [(h, sc, t) for h, sc, t in node.queue if h is not g]
        g.red_gated[k].clear()
        for sc in clawed:
            pool[sc.src] += sc.size
            g.shuf_landed_mb -= sc.size
            g.shuf_created_mb -= sc.size
            g.reduce_outstanding[k] -= 1
            g.lost_mb += sc.size
            g.wasted_mb += sc.size
        # un-started emissions queued on the shuffle links toward k are
        # simply pulled back (nothing spent yet); a transfer mid-service
        # is committed and bounces on arrival (_ev_shuffle_arrive)
        for j in range(self.sub.nM):
            link = self.shuf_links[j][k]
            kept = []
            for tr in link.queue:
                if tr.run is g and tr.fn == "shuffle_arrive":
                    sc = tr.args[3]
                    pool[sc.src] += sc.size
                    g.shuf_inflight[k] -= 1
                    g.total_shuf_inflight -= 1
                    g.reduce_outstanding[k] -= 1
                    g.shuf_created_mb -= sc.size
                    g.lost_mb += sc.size
                else:
                    kept.append(tr)
            link.queue = kept
        # the chunk mid-service on the dead node dies with it: marking it
        # done sends its pending reduce_done into the wasted branch
        if node.current is g and node.current_chunk is not None \
                and not node.current_chunk.done:
            sc = node.current_chunk
            pool[sc.src] += sc.size
            g.shuf_landed_mb -= sc.size
            g.shuf_created_mb -= sc.size
            g.reduce_outstanding[k] -= 1
            g.lost_mb += sc.size
            sc.done = True
        # output already reduced at k is void too — un-deliver it by
        # provenance (a finalized reducer's output has been handed to
        # downstream stages and cannot be clawed back)
        if not g.reducer_final[k]:
            for j in range(self.sub.nM):
                lost_red = float(g.reduced_by[j, k])
                if lost_red <= 1e-9:
                    continue
                pool[j] += lost_red
                g.reduced_mb -= lost_red
                g.shuf_landed_mb -= lost_red
                g.shuf_created_mb -= lost_red
                g.delivered_out[k] -= lost_red
                g.wasted_mb += lost_red
                g.lost_mb += lost_red
            g.reduced_by[:, k] = 0.0
        for j in range(self.sub.nM):
            if pool[j] > 1e-9:
                self._reemit_shuffle(g, j, float(pool[j]))

    def _reemit_shuffle(self, g: _JobRun, j: int, amount: float) -> None:
        """Re-emit ``amount`` MB of mapper ``j``'s shuffle output toward
        the surviving open reducers — the plan's ``y`` renormalized over
        ``red_alive & ~reducer_final`` (uniform fallback when the plan
        routed everything to dead nodes), chunked at ``cfg.chunk_mb``."""
        y = np.asarray(g.plan.y)
        open_r = g.red_alive & ~g.reducer_final
        if not open_r.any():
            raise RuntimeError("all reducers dead")
        shares = np.where((y > 1e-9) & open_r, y, 0.0)
        if shares.sum() <= 0:
            shares = np.where(open_r, 1.0, 0.0)
        shares *= amount / shares.sum()
        b1 = g.cfg.barriers[1]
        for k in range(self.sub.nR):
            if shares[k] <= 1e-9:
                continue
            n = max(int(np.ceil(shares[k] / g.cfg.chunk_mb)), 1)
            for _ in range(n):
                sc = _Chunk(next(self._cid), shares[k] / n, j)
                g.shuf_created_mb += sc.size
                g.reexec_mb += sc.size
                g.shuf_inflight[k] += 1
                g.total_shuf_inflight += 1
                g.reduce_outstanding[k] += 1
                if b1 == "P":
                    self._send_shuffle(g, j, k, sc)
                else:
                    g.shuf_gated[j].append((k, sc))
        if b1 == "P" or g.dep_pending:
            return
        node = self.mappers[j]
        if b1 == "L" and g.map_unfinished[j] == 0 \
                and not (node.busy and node.current is g):
            self._open_shuffle_gate(g, j)
        elif b1 == "G" and g.total_map_unfinished == 0 \
                and g.total_push_inflight == 0:
            self._open_shuffle_gate(g, j)

    # -- substrate-wide failures (the FailureTrace) -------------------------------
    def _ev_fail_mapper_all(self, j: int):
        self._dead_m.add(int(j))
        for g in self.runs:
            if g.map_alive[j]:
                self._ev_fail_mapper(g, j)

    def _ev_fail_reducer_all(self, k: int):
        self._dead_r.add(int(k))
        for g in self.runs:
            if g.red_alive[k]:
                self._ev_fail_reducer(g, k)

    def _partition_links(self, cluster: int) -> List[LinkResource]:
        """Every link severed by partitioning ``cluster`` away (one
        endpoint inside, one outside)."""
        push_cut, shuf_cut = self.sub.partition_cut(cluster)
        links: List[LinkResource] = []
        for i, row in enumerate(self.push_links):
            for j, link in enumerate(row):
                if push_cut[i, j]:
                    links.append(link)
        for j, row in enumerate(self.shuf_links):
            for k, link in enumerate(row):
                if shuf_cut[j, k]:
                    links.append(link)
        return links

    def _ev_partition(self, cluster: int, t_repair):
        """Sever every link crossing the cluster boundary: the in-service
        transfer fails immediately (its payload is lost and re-queued at the
        FRONT of the link, where a plan swap can still pull it back and
        re-route it), queued transfers park — also revocable by a swap."""
        for link in self._partition_links(cluster):
            link.down += 1
            if link.current is not None:
                tr = link.current
                g = tr.run
                g.lost_mb += tr.size
                g.reexec_mb += tr.size
                g.wasted_mb += tr.size
                link.queue.insert(0, tr)
                link.busy = False
                link.current = None
                link.serial += 1
        if t_repair is not None:
            self.at(float(t_repair), "partition_repair", cluster)

    def _ev_partition_repair(self, cluster: int):
        for link in self._partition_links(cluster):
            link.down -= 1
            if not link.down:
                self._pump_link(link)

    # -- online control plane: observe ------------------------------------------
    def snapshot(self) -> ProgressSnapshot:
        """Capture every job's remaining work at the current event time,
        bucketed by what a re-planner can still control (see
        :class:`repro.core.makespan.JobProgress`), plus per-resource queued
        MB.  Speculative/replica overhead traffic is excluded — it is
        wasted-work accounting, not residual job volume.

        A single pass over every queue buckets in-flight traffic by owning
        run, so the cost is O(queued transfers + jobs) rather than
        O(queued transfers x jobs) — at the scale tier (100+ jobs, deep
        link queues) the per-job rescan used to dominate steered runs."""
        nS, nM, nR = self.sub.nS, self.sub.nM, self.sub.nR
        # per-run accumulators: resid_push, committed_push, at_mapper,
        # shuffle_pool, committed_shuffle, at_reducer
        acc: Dict[int, list] = {
            id(g): [np.zeros(g.p.nS), np.zeros((g.p.nS, nM)), np.zeros(nM),
                    np.zeros(nM), np.zeros((nM, nR)), np.zeros(nR)]
            for g in self.runs if g.seeded
        }

        def add_push(tr, current: bool):
            a = acc.get(id(tr.run))
            if a is None:
                return
            if tr.fn == "push_arrive":
                c = tr.args[3]
                if not c.done:
                    if current and tr.run.map_alive[tr.args[2]]:
                        a[1][tr.args[1], tr.args[2]] += c.size
                    else:
                        # queued, or in flight to a dead mapper (it will
                        # bounce into recovery): the planner may still
                        # re-route it
                        a[0][tr.args[1]] += c.size
            elif tr.fn == "stolen_arrive":
                # stolen chunks (ownership moved to the thief) are real
                # residual work in flight to a fixed destination;
                # speculative clones are overhead (their originals still
                # sit, counted, in the victim's queue)
                j, c = tr.args[1], tr.args[2]
                if c.owner == j and not c.done:
                    a[1][c.src, j] += c.size

        for row in self.push_links:
            for link in row:
                for tr in link.queue:
                    add_push(tr, current=False)
                if link.current is not None:
                    add_push(link.current, current=True)
        for row in self.shuf_links:
            for link in row:
                for tr in link.queue:
                    if tr.fn == "shuffle_arrive" \
                            and (a := acc.get(id(tr.run))) is not None:
                        sc = tr.args[3]
                        if not sc.done:
                            a[3][tr.args[1]] += sc.size
                cur = link.current
                if cur is not None and cur.fn == "shuffle_arrive" \
                        and (a := acc.get(id(cur.run))) is not None:
                    sc = cur.args[3]
                    if not sc.done:
                        if not cur.run.red_alive[cur.args[2]]:
                            # destined to a dead reducer: it bounces
                            # back into the pool on arrival
                            a[3][cur.args[1]] += sc.size
                        else:
                            a[4][cur.args[1], cur.args[2]] += sc.size
        for j, node in enumerate(self.mappers):
            for h, c, _ in node.queue:
                if not c.done and (a := acc.get(id(h))) is not None:
                    a[2][j] += c.size
            if node.current is not None and node.current_chunk is not None \
                    and not node.current_chunk.done \
                    and (a := acc.get(id(node.current))) is not None:
                a[2][j] += node.current_chunk.size
        for k, node in enumerate(self.reducers):
            for h, sc, _ in node.queue:
                if not sc.done and (a := acc.get(id(h))) is not None:
                    a[5][k] += sc.size
            if node.current is not None and node.current_chunk is not None \
                    and not node.current_chunk.done \
                    and (a := acc.get(id(node.current))) is not None:
                a[5][k] += node.current_chunk.size

        jobs = []
        for g in self.runs:
            if not g.seeded:
                prog = dataclasses.replace(
                    JobProgress.fresh(g.p, job=g.idx), released=False,
                    map_alive=g.map_alive.copy(),
                    red_alive=g.red_alive.copy(),
                )
                jobs.append(prog)
                continue
            resid_push, committed_push, at_mapper, pool, \
                committed_shuffle, at_reducer = acc[id(g)]
            for j in range(nM):
                at_mapper[j] += sum(c.size for c in g.map_gated[j] if not c.done)
                pool[j] += sum(sc.size for _, sc in g.shuf_gated[j] if not sc.done)
            for k in range(nR):
                at_reducer[k] += sum(sc.size for sc in g.red_gated[k] if not sc.done)
            # a stage-linked run's unreleased sources: the upstream output
            # has not landed yet, so the re-planner sees the *modeled*
            # volume (the stage platform's derived D) as re-routable —
            # steering a not-yet-started stage is exactly a push re-route
            for i in g.dep_pending:
                resid_push[i] += max(float(g.p.D[i]), float(g.dep_landed[i]))
            prog = JobProgress(
                job=g.idx, released=True, done=False,
                resid_push=resid_push, committed_push=committed_push,
                at_mapper=at_mapper, shuffle_pool=pool,
                committed_shuffle=committed_shuffle, at_reducer=at_reducer,
                alpha=float(g.p.alpha), total_push_mb=float(g.p.D.sum()),
                map_alive=g.map_alive.copy(),
                red_alive=g.red_alive.copy(),
            )
            if prog.remaining_mb()["reduce"] <= 1e-9:
                prog = dataclasses.replace(prog, done=True)
            jobs.append(prog)
        backlog: Dict[str, float] = {}
        for row in self.push_links + self.shuf_links:
            for link in row:
                backlog[link.name] = sum(tr.size for tr in link.queue)
        for node in self.mappers + self.reducers:
            backlog[node.name] = sum(
                c.size for _, c, _ in node.queue if not c.done
            )
        return ProgressSnapshot(
            time=self.now, jobs=tuple(jobs), backlog=backlog
        )

    # -- online control plane: steer ---------------------------------------------
    def inject(self, jobs: Sequence["_JobEntry"]) -> List[int]:
        """Admit new jobs mid-flight (streaming arrival).  Jobs released at
        or before the current time seed immediately — *ahead* of any event
        already pending at this instant, matching the offline order where
        release seeds carry the earliest sequence numbers; future releases
        schedule normally.  Returns the new job indices."""
        self._start()
        entries = _normalize_entries(jobs)
        idxs: List[int] = []
        for platform, plan, cfg in entries:
            if not self.sub.compatible(Substrate.of(platform)):
                raise ValueError(
                    f"platform {platform.name!r} is not a view of substrate "
                    f"{self.sub.name!r} — build job platforms with "
                    "Substrate.view()"
                )
            g = _JobRun(len(self.runs), platform, plan, cfg,
                        self.sub.nM, self.sub.nR)
            self.runs.append(g)
            self._audit = self._audit or cfg.audit
            idxs.append(g.idx)
            # raw fail times, exactly as _start() schedules them offline —
            # a past time simply fires on the next dispatch (a worker that
            # died before this job arrived is already dead)
            self._schedule_job_failures(g)
            # substrate-wide kills that already fired apply immediately
            for j in self._dead_m:
                g.map_alive[j] = False
            for k in self._dead_r:
                g.red_alive[k] = False
        for start in sorted({self.runs[i].cfg.start_time for i in idxs}):
            group = tuple(
                i for i in idxs if self.runs[i].cfg.start_time == start
            )
            if start <= self.now:
                # merge with a pending release group at this exact instant:
                # offline, equal start times seed as ONE round-robin group
                # (earlier jobs first), and the equivalence must survive an
                # arrival landing on another job's release time
                pending: List[int] = []
                rest = []
                for ev in self._heap:
                    if ev[0] == start and ev[2] == "seed_jobs":
                        pending.extend(ev[3][0])
                    else:
                        rest.append(ev)
                if pending:
                    self._heap = rest
                    heapq.heapify(self._heap)
                self._ev_seed_jobs(tuple(pending) + group)
            else:
                self.at(start, "seed_jobs", group)
        return idxs

    def swap_plan(self, idx: int, plan: ExecutionPlan) -> None:
        """Replace job ``idx``'s plan for every chunk not yet committed.

        Un-started push transfers are pulled back and redistributed across
        mappers per the new ``x`` (largest-deficit-first, so discrete chunks
        track the continuous split); un-started shuffle transfers and gated
        emissions are pooled per mapper and re-split per the new ``y``.
        In-service transfers, delivered data and finished work are
        untouched — the swap only redirects the future.  Barrier gate
        counters move with the chunks, and gates that the moves leave
        satisfiable open immediately.  Future shuffle emissions (of not yet
        mapped chunks) follow the new ``y`` automatically.
        """
        g = self.runs[idx]
        if plan.x.shape != g.plan.x.shape or plan.y.shape != g.plan.y.shape:
            raise ValueError(
                f"plan shapes {plan.x.shape}/{plan.y.shape} do not match "
                f"job {idx}'s {g.plan.x.shape}/{g.plan.y.shape}"
            )
        self._start()
        if not g.seeded:
            g.plan = plan  # released later: seeding reads the new plan
            return
        nM, nR = self.sub.nM, self.sub.nR
        b0, b1, b2 = g.cfg.barriers
        x = np.asarray(plan.x)
        y = np.asarray(plan.y)

        # --- pull back un-started push transfers, re-split per the new x
        pulled: Dict[int, List[_Chunk]] = {}
        for i, row in enumerate(self.push_links):
            for link in row:
                kept = []
                for tr in link.queue:
                    if tr.run is g and tr.fn == "push_arrive":
                        pulled.setdefault(tr.args[1], []).append(tr.args[3])
                    else:
                        kept.append(tr)
                link.queue = kept
        drained_j = set()
        for i, chunks in pulled.items():
            total = sum(c.size for c in chunks)
            # a severed link parks everything queued on it until repair —
            # routing re-split mass there would pin the job to the repair
            # time, so only reachable mappers receive it
            up = np.array([not self.push_links[i][j].down
                           for j in range(nM)])
            desired = np.where(
                (x[i] > 1e-9) & g.map_alive & up, total * x[i], 0.0
            )
            if desired.sum() <= 0:  # new row dead/unreachable: spread alive
                desired = np.where(g.map_alive & up, total / max(nM, 1), 0.0)
            if desired.sum() <= 0:  # every path severed: park per plan
                desired = np.where(g.map_alive, total / max(nM, 1), 0.0)
            # assign inside the eligible set only — an excluded mapper's
            # zero deficit must never beat an over-assigned eligible one
            eligible = np.flatnonzero(desired > 0)
            if eligible.size == 0:  # every mapper dead: recovery will raise
                eligible = np.arange(nM)
            assigned = np.zeros(nM)
            for c in chunks:
                j_new = int(eligible[
                    np.argmax(desired[eligible] - assigned[eligible])
                ])
                assigned[j_new] += c.size
                j_old = c.owner
                if j_new != j_old:
                    g.push_inflight[j_old] -= 1
                    g.push_inflight[j_new] += 1
                    g.map_unfinished[j_old] -= 1
                    g.map_unfinished[j_new] += 1
                    c.owner = j_new
                    drained_j.add(j_old)
                self._link_send(self.push_links[i][j_new], g, c.size,
                                "push_arrive", (g, i, j_new, c))

        # --- pull back un-started / gated shuffle, re-split per the new y
        pool_sent = np.zeros(nM)
        pool_gated = np.zeros(nM)
        drained_k = set()
        for j, row in enumerate(self.shuf_links):
            for k, link in enumerate(row):
                kept = []
                for tr in link.queue:
                    if tr.run is g and tr.fn == "shuffle_arrive":
                        pool_sent[tr.args[1]] += tr.args[3].size
                        g.shuf_inflight[k] -= 1
                        g.total_shuf_inflight -= 1
                        g.reduce_outstanding[k] -= 1
                        drained_k.add(k)
                    else:
                        kept.append(tr)
                link.queue = kept
        for j in range(nM):
            if g.shuf_gated[j]:
                for k, sc in g.shuf_gated[j]:
                    pool_gated[j] += sc.size
                    g.shuf_inflight[k] -= 1
                    g.total_shuf_inflight -= 1
                    g.reduce_outstanding[k] -= 1
                    drained_k.add(k)
                g.shuf_gated[j].clear()

        g.plan = plan  # future emissions (un-mapped chunks) use the new y
        # the pulled-back pool is re-created below under the new y: net it
        # out of the conservation ledger so created == landed still holds
        g.shuf_created_mb -= float(pool_sent.sum() + pool_gated.sum())

        # a finalized reducer's output has already been handed to the
        # downstream stage sources — routing new volume there would be
        # silently dropped, so the re-split only spreads over open *live*
        # reducers (all of them, for failure-free runs without stage
        # children)
        open_r = (~g.reducer_final) & g.red_alive
        for j in range(nM):
            # mask reducers behind a severed link: queued mass routed there
            # would park until repair and pin the makespan to it (the plan
            # may carry harmless dust on degraded paths — the executor must
            # not turn that dust into a repair-time wait)
            up = np.array([not self.shuf_links[j][k].down
                           for k in range(nR)])
            reach = open_r & up if (open_r & up).any() else open_r
            for amount, gated in ((pool_sent[j], False), (pool_gated[j], True)):
                if amount <= 1e-9:
                    continue
                shares = np.where((y > 1e-9) & reach, amount * y, 0.0)
                if shares.sum() <= 0:
                    # all-final is impossible while shuffle volume is still
                    # pooled (finality requires zero outstanding chunks)
                    shares = np.where(reach, amount / max(reach.sum(), 1),
                                      0.0)
                shares *= amount / max(shares.sum(), 1e-12)
                for k in range(nR):
                    if shares[k] <= 1e-9:
                        continue
                    n = max(int(np.ceil(shares[k] / g.cfg.chunk_mb)), 1)
                    for _ in range(n):
                        sc = _Chunk(next(self._cid), shares[k] / n, j)
                        g.shuf_created_mb += sc.size
                        g.shuf_inflight[k] += 1
                        g.total_shuf_inflight += 1
                        g.reduce_outstanding[k] += 1
                        if gated:
                            g.shuf_gated[j].append((k, sc))
                        else:
                            self._send_shuffle(g, j, k, sc)

        # --- gates the moves left satisfiable open now (mirrors the
        # arrival/steal paths; totals are unchanged, so 'G' gates only need
        # re-checking where a bucket drained to zero).  A stage-linked run
        # with pending sources keeps its gates shut — the final release
        # re-checks them all.
        for j in (drained_j if not g.dep_pending else ()):
            if b0 == "L" and g.push_inflight[j] == 0:
                self._open_map_gate(g, j)
            node = self.mappers[j]
            if b1 == "L" and g.map_unfinished[j] == 0 \
                    and not (node.busy and node.current is g):
                self._open_shuffle_gate(g, j)
        if b2 == "L":
            for k in drained_k:
                if g.shuf_inflight[k] == 0 and self._shuffle_final(g):
                    self._open_reduce_gate(g, k)
        elif b2 == "G" and g.total_shuf_inflight == 0 \
                and self._shuffle_final(g) and drained_k:
            for k in range(nR):
                self._open_reduce_gate(g, k)

    def set_speculation(self, idx: int, on: bool,
                        threshold: Optional[float] = None) -> None:
        """Toggle speculative execution for job ``idx`` mid-flight — the
        fault-reaction knob an online policy can flip per decision (e.g.
        duplicate straggling map work once a failure has been observed).
        ``threshold`` optionally retunes ``spec_threshold`` at the same
        time.  Takes effect at the next idle-worker trigger; clones
        already racing are unaffected."""
        g = self.runs[idx]
        kw: Dict[str, object] = {"speculation": bool(on)}
        if threshold is not None:
            kw["spec_threshold"] = float(threshold)
        g.cfg = dataclasses.replace(g.cfg, **kw)

    # -- vectorized frozen-plan fast path ----------------------------------
    #
    # ``run()`` on an engine whose jobs all set ``SimConfig(vectorized=
    # True)`` bypasses the per-chunk heap entirely: every resource serves
    # FIFO, so its service times follow the Lindley recursion ``start =
    # max(prev_end, enqueue)`` and a whole queue replays in one tight scan
    # evaluating the *same* float expressions as the scalar pump (same
    # operand order, hence bit-identical results).  The freedom to commute
    # events is exactly what the determinism audit certifies: on race-free
    # scenarios any same-timestamp event reordering yields the same
    # trajectory, and the scan only ever commutes same-timestamp events —
    # orderings that carry semantics (seed round-robin, gated-release
    # order, per-resource FIFO, ledger accumulation order) are replicated
    # exactly.  Barrier gates are not counters here but closed-form times:
    # each gate opens at the last completion/arrival that could satisfy it
    # (the scalar engine's trigger event), max-ed with a stage-linked run's
    # final source release (the scalar ``_recheck_gates`` sweep).  Stage
    # DAGs process in topological strata; a geometry where a later stage
    # would enqueue *behind* already-served work on some resource raises
    # rather than silently mis-ordering.  ``run_online``-style steering
    # takes the same scans segment-by-segment via ``_vec_drain`` below,
    # which swaps the closed-form gates for post-segment counter checks
    # and materializes still-pending work back into scalar state at each
    # decision point.

    def _vec_serve(self, res, enq, tie, size, jobv, state, slow=None,
                   cut=None, inclusive=False):
        """Exact FIFO replay of one resource's whole queue.  ``enq`` /
        ``tie`` / ``size`` / ``jobv`` (plus per-entry ``slow`` for
        compute nodes) are parallel arrays already sorted by
        ``(enq, tie)``.  Completion times come from the Lindley
        recursion ``end = max(prev_end, enq) + size/rate`` evaluated as
        numpy left folds over busy segments — ``np.add.accumulate`` is a
        strict sequential fold, so every float lands bit-identical to
        the scalar pump.  ``state`` carries ``(avail, last_enq)`` across
        calls; an entry enqueued before already-served work means the
        single-scan FIFO assumption broke (cross-stage interleaving) and
        is a hard error.

        ``cut`` bounds a *steered* segment: only services that start
        strictly before ``cut`` (at-or-before with ``inclusive``) commit
        — left folds over a prefix equal the full fold's prefix, so the
        committed floats are exactly the unbounded replay's.  Returns
        ``(ends, n_committed)``; stats/state are updated over the
        committed prefix only, and ``ends`` is only meaningful there
        (the computation may stop early once starts pass the horizon).
        """
        avail, last_enq = state.get(res, (0.0, _NEG_INF))
        n = enq.shape[0]
        if enq[0] < last_enq:
            raise RuntimeError(
                f"vectorized executor: out-of-order enqueue on {res.name} "
                "(cross-stage interleaving); rerun with "
                'SimConfig(mode="event")'
            )
        trace = res.trace
        starts = np.empty(n)
        ends = np.empty(n)
        filled = n
        if trace is None:
            if slow is not None:
                durs = size / (res.rate / slow)
            else:
                durs = size / res.bw
            a = avail
            i = 0
            while i < n:
                e0 = enq[i]
                s0 = a if a > e0 else e0
                if cut is not None and s0 > cut:
                    # starts are non-decreasing: nothing from here on can
                    # commit, so the replay may stop
                    filled = i
                    break
                # fold the busy run from s0; the first later entry that
                # enqueues at-or-after the running end starts a fresh
                # (idle-gap) segment.  Blocked so a pathological
                # all-gaps queue stays O(n).
                hi = i + 8192
                if hi > n:
                    hi = n
                seg = np.add.accumulate(
                    np.concatenate(([s0], durs[i:hi])))[1:]
                brk = np.flatnonzero(enq[i + 1:hi] >= seg[:-1])
                k = i + 1 + int(brk[0]) if brk.size else hi
                m = k - i
                starts[i] = s0
                if m > 1:
                    starts[i + 1:k] = seg[:m - 1]
                ends[i:k] = seg[:m]
                a = float(seg[m - 1])
                i = k
        else:
            # trace-modulated rate depends on each service's start time
            # -> exact sequential replay (trace scenarios are small)
            durs = np.empty(n)
            a = avail
            if slow is not None:
                for i in range(n):
                    e0 = enq[i]
                    s = a if a > e0 else e0
                    if cut is not None and s > cut:
                        filled = i
                        break
                    d = size[i] / (trace.at(s) / slow[i])
                    a = s + d
                    durs[i] = d
                    starts[i] = s
                    ends[i] = a
            else:
                for i in range(n):
                    e0 = enq[i]
                    s = a if a > e0 else e0
                    if cut is not None and s > cut:
                        filled = i
                        break
                    d = size[i] / trace.at(s)
                    a = s + d
                    durs[i] = d
                    starts[i] = s
                    ends[i] = a
            a = float(a)
        if cut is None:
            n_c = n
        else:
            side = "right" if inclusive else "left"
            n_c = int(np.searchsorted(starts[:filled], cut, side=side))
            if n_c == 0:
                return ends, 0
        st = res.stats
        st.busy_s = float(np.add.accumulate(
            np.concatenate(([st.busy_s], durs[:n_c])))[-1])
        st.waited_s = float(np.add.accumulate(
            np.concatenate(([st.waited_s], starts[:n_c] - enq[:n_c])))[-1])
        st.volume_mb = float(np.add.accumulate(
            np.concatenate(([st.volume_mb], size[:n_c])))[-1])
        st.n_chunks += n_c
        st.jobs.update(int(v) for v in np.unique(jobv[:n_c]))
        s0f = float(starts[0])
        if s0f < st.first_busy_s:
            st.first_busy_s = s0f
        ef = float(ends[n_c - 1])
        if ef > st.last_busy_s:
            st.last_busy_s = ef
        state[res] = (float(ends[n_c - 1]), float(enq[n_c - 1]))
        return ends, n_c

    def _vec_check_support(self):
        if self.sub.failures:
            raise ValueError(
                "vectorized executor: the substrate carries a "
                "FailureTrace — failure recovery needs the scalar event "
                'loop (SimConfig(mode="event"))'
            )
        for g in self.runs:
            c = g.cfg
            bad = [name for name, flag in (
                ("speculation", c.speculation),
                ("stealing", c.stealing),
                ("failures", bool(c.failures)),
                ("compute_noise", c.compute_noise > 0),
                ("replication>1", c.replication != 1),
            ) if flag]
            if bad:
                raise ValueError(
                    f"vectorized executor: job {g.idx} uses "
                    f"{'/'.join(bad)} — dynamics need the scalar event "
                    'loop (SimConfig(mode="event"))'
                )

    @staticmethod
    def _vec_by_job(jobarr, nJ):
        """Group an already time-sorted event column by job: returns
        ``(jsort, off)`` where ``jsort[off[g]:off[g+1]]`` indexes job
        ``g``'s events in time order (stable sort preserves it)."""
        jsort = np.argsort(jobarr, kind="stable")
        counts = np.bincount(jobarr, minlength=nJ)
        off = np.concatenate(([0], np.cumsum(counts)))
        return jsort, off

    @staticmethod
    def _vec_fold(base, arr):
        """Exact sequential left fold ``base + arr[0] + arr[1] + ...``
        — the order the scalar ledgers accumulate in."""
        return float(np.add.accumulate(np.concatenate(([base], arr)))[-1])

    def _run_vectorized(self) -> ScheduleSimResult:
        self._vec_check_support()
        runs = self.runs
        self._started = True
        nM, nR = self.sub.nM, self.sub.nR
        nJ = len(runs)
        NEG = _NEG_INF

        # topological strata of the stage DAG (roots = stratum 0)
        depth: Dict[int, int] = {}

        def _depth(i: int) -> int:
            d = depth.get(i)
            if d is None:
                d = 1 + max(
                    (_depth(p) for p in runs[i].stage_deps), default=-1
                )
                depth[i] = d
            return d

        for i in range(nJ):
            _depth(i)
        waves: List[List[_JobRun]] = [
            [] for _ in range(max(depth.values()) + 1)
        ]
        for i in range(nJ):
            waves[depth[i]].append(runs[i])

        root_ops = {
            g.idx: self._push_ops(g) for g in runs if not g.stage_deps
        }
        for g in runs:
            if self.stage_children.get(g.idx) and not g.stage_deps \
                    and not root_ops[g.idx]:
                raise ValueError(
                    f"vectorized executor: root job {g.idx} feeds "
                    "downstream stages but seeds no push chunks — its "
                    "reducers never finalize and the pipeline starves; "
                    'run with SimConfig(mode="event")'
                )

        # static per-job tables for the hot gathers
        alpha_j = np.array([g.p.alpha for g in runs], dtype=np.float64)
        slow_m = np.array(
            [[g.slowdown("m", j) for j in range(nM)] for g in runs])
        slow_r = np.array(
            [[g.slowdown("r", k) for k in range(nR)] for g in runs])
        ynz = [
            [(k, g.plan.y[k]) for k in range(nR) if g.plan.y[k] > 0.0]
            for g in runs
        ]
        fan = np.array([len(z) for z in ynz], dtype=np.int64)
        maxf = max(int(fan.max()), 1) if nJ else 1
        ynz_k = np.zeros((nJ, maxf), dtype=np.int64)
        ynz_y = np.zeros((nJ, maxf))
        for gi, z in enumerate(ynz):
            for s, (k, yk) in enumerate(z):
                ynz_k[gi, s] = k
                ynz_y[gi, s] = yk

        # closed-form gate trackers: last arrival / completion per
        # (job, location) and per job — each barrier gate opens at the
        # scalar engine's trigger event, which is exactly such a max
        arrj = np.full((nJ, nM), NEG)
        arr_any = np.full(nJ, NEG)
        compj = np.full((nJ, nM), NEG)
        comp_any = np.full(nJ, NEG)
        sarrk = np.full((nJ, nR), NEG)
        sarr_any = np.full(nJ, NEG)
        redk = np.full((nJ, nR), NEG)
        rel = np.full(nJ, NEG)

        state: Dict[object, Tuple[float, float]] = {}
        #: child idx -> [(t_finalize, parent idx, reducer k, landed MB)]
        child_contrib: Dict[int, List[Tuple[float, int, int, float]]] = {}
        t_max = 0.0
        gen = 0

        for wave in waves:
            # ---- push streams: root seeds + stage-source releases --------
            link_ents: Dict[Tuple[int, int], list] = {}
            roots = [g for g in wave if not g.stage_deps]
            for start in sorted({g.cfg.start_time for g in roots}):
                group = [(g, root_ops[g.idx])
                         for g in roots if g.cfg.start_time == start]
                for g, _ in group:
                    g.seeded = True
                r = 0
                live = True
                while live:  # round-robin, exactly like _ev_seed_jobs
                    live = False
                    for g, ops in group:
                        if r < len(ops):
                            live = True
                            i, j, size = ops[r]
                            g.total_map_chunks += 1
                            g.pushed_mb += size
                            link_ents.setdefault((i, j), []).append(
                                (start, gen, float(size), g.idx))
                            gen += 1
                    r += 1

            rels: List[Tuple[float, int, int]] = []
            for g in wave:
                if not g.stage_deps:
                    continue
                for t_fin, p, k, mb in sorted(
                        child_contrib.pop(g.idx, [])):
                    g.dep_landed[k] += mb
                    waiting = g.dep_pending.get(k)
                    if waiting is None or p not in waiting:
                        continue
                    waiting.discard(p)
                    if not waiting:
                        del g.dep_pending[k]
                        rels.append((t_fin, g.idx, k))
                if g.dep_pending:
                    raise RuntimeError(
                        f"vectorized executor: stage job {g.idx} never "
                        "fully releases (an upstream reducer deadlocked); "
                        'rerun with SimConfig(mode="event")'
                    )
            rels.sort()
            for rel_t, gi, k in rels:
                g = runs[gi]
                g.seeded = True
                if rel_t > rel[gi]:
                    rel[gi] = rel_t
                amount = float(g.dep_landed[k])
                if amount <= 1e-9:
                    continue
                cfg = g.cfg
                xrow = g.plan.x[k]
                for j in range(nM):
                    share = amount * xrow[j]
                    if share <= 1e-9:
                        continue
                    n_chunks = max(int(np.ceil(share / cfg.chunk_mb)), 1)
                    sz = share / n_chunks
                    fsz = float(sz)
                    for _ in range(n_chunks):
                        g.total_map_chunks += 1
                        g.pushed_mb += sz
                        link_ents.setdefault((k, j), []).append(
                            (rel_t, gen, fsz, gi))
                        gen += 1

            # ---- serve push links; arrivals in global event order --------
            cols = ([], [], [], [], [])  # end, tie, size, job, dest
            push_links = self.push_links
            for (i, j), ents in sorted(link_ents.items()):
                raw = list(zip(*ents))
                enq = np.asarray(raw[0], dtype=np.float64)
                tie = np.asarray(raw[1], dtype=np.int64)
                sz = np.asarray(raw[2], dtype=np.float64)
                jb = np.asarray(raw[3], dtype=np.int64)
                o = np.lexsort((tie, enq))
                enq, tie, sz, jb = enq[o], tie[o], sz[o], jb[o]
                ends, _ = self._vec_serve(
                    push_links[i][j], enq, tie, sz, jb, state)
                cols[0].append(ends)
                cols[1].append(tie)
                cols[2].append(sz)
                cols[3].append(jb)
                cols[4].append(np.full(ends.shape[0], j, dtype=np.int64))

            n_arr = 0
            if cols[0]:
                at, atie, asz, ajob, adst = map(np.concatenate, cols)
                o = np.lexsort((atie, at))
                at, atie, asz = at[o], atie[o], asz[o]
                ajob, adst = ajob[o], adst[o]
                n_arr = at.shape[0]
            if n_arr:
                t_max = max(t_max, float(at[-1]))
                # last write wins on duplicate indices and the arrays are
                # time-sorted, so plain fancy assignment IS the running
                # "latest arrival" ledger
                arrj[ajob, adst] = at
                arr_any[ajob] = at
                jsort, off = self._vec_by_job(ajob, nJ)
                aready = at.copy()
                for g in wave:
                    gi = g.idx
                    sel = jsort[off[gi]:off[gi + 1]]
                    if not sel.shape[0]:
                        continue
                    m = float(at[sel[-1]])
                    if m > g.push_end:
                        g.push_end = m
                    g.landed_mb = self._vec_fold(g.landed_mb, asz[sel])
                    b0 = g.cfg.barriers[0]
                    if b0 == "P":
                        continue
                    rv = arrj[gi, adst[sel]] if b0 == "L" else arr_any[gi]
                    aready[sel] = np.maximum(rv, rel[gi])

                # gated chunks flush to the node queue in *arrival*
                # order, so the tie key is the position in the
                # time-sorted arrival stream
                seqv = np.arange(n_arr, dtype=np.int64)
                morder = np.lexsort((seqv, aready, adst))
                noff = np.concatenate(
                    ([0], np.cumsum(np.bincount(adst, minlength=nM))))
                cols = ([], [], [], [], [])
                mappers = self.mappers
                for j in range(nM):
                    sel = morder[noff[j]:noff[j + 1]]
                    if not sel.shape[0]:
                        continue
                    jb = ajob[sel]
                    ends, _ = self._vec_serve(
                        mappers[j], aready[sel], seqv[sel], asz[sel], jb,
                        state, slow=slow_m[jb, j])
                    cols[0].append(ends)
                    cols[1].append(seqv[sel])
                    cols[2].append(asz[sel])
                    cols[3].append(jb)
                    cols[4].append(
                        np.full(ends.shape[0], j, dtype=np.int64))
                ct, ctie, csz, cjob, cdst = map(np.concatenate, cols)
                o = np.lexsort((ctie, ct))
                ct, ctie, csz = ct[o], ctie[o], csz[o]
                cjob, cdst = cjob[o], cdst[o]
                n_comp = ct.shape[0]

                t_max = max(t_max, float(ct[-1]))
                compj[cjob, cdst] = ct
                comp_any[cjob] = ct
                jsort, off = self._vec_by_job(cjob, nJ)
                cready = ct.copy()
                for g in wave:
                    gi = g.idx
                    sel = jsort[off[gi]:off[gi + 1]]
                    if not sel.shape[0]:
                        continue
                    m = float(ct[sel[-1]])
                    if m > g.map_end:
                        g.map_end = m
                    g.mapped_mb = self._vec_fold(g.mapped_mb, csz[sel])
                    b1 = g.cfg.barriers[1]
                    if b1 == "P":
                        continue
                    rv = compj[gi, cdst[sel]] if b1 == "L" \
                        else comp_any[gi]
                    cready[sel] = np.maximum(rv, rel[gi])
            else:
                n_comp = 0

            # ---- shuffle emissions: completion-major, reducer-minor,
            # exactly _emit_shuffle's creation order ----------------------
            n_em = 0
            if n_comp:
                counts = fan[cjob]
                tot = int(counts.sum())
                if tot:
                    off_e = np.concatenate(([0], np.cumsum(counts)))
                    repi = np.repeat(np.arange(n_comp), counts)
                    slot = np.arange(tot, dtype=np.int64) - off_e[repi]
                    ejob = cjob[repi]
                    ek = ynz_k[ejob, slot]
                    a_s = alpha_j[ejob] * csz[repi]
                    amt = a_s * ynz_y[ejob, slot]
                    keep = amt > 1e-9
                    eenq = cready[repi][keep]
                    ejob, ek, amt = ejob[keep], ek[keep], amt[keep]
                    ejv = cdst[repi][keep]
                    n_em = amt.shape[0]
            if n_em:
                etie = gen + np.arange(n_em, dtype=np.int64)
                gen += n_em
                jsort, off = self._vec_by_job(ejob, nJ)
                for g in wave:
                    sel = jsort[off[g.idx]:off[g.idx + 1]]
                    if sel.shape[0]:
                        g.shuf_created_mb = self._vec_fold(
                            g.shuf_created_mb, amt[sel])

                # ---- serve shuffle links ---------------------------------
                lkey = ejv * nR + ek
                lorder = np.lexsort((etie, eenq, lkey))
                lcounts = np.bincount(lkey, minlength=nM * nR)
                loff = np.concatenate(([0], np.cumsum(lcounts)))
                cols = ([], [], [], [], [])
                shuf_links = self.shuf_links
                for key in np.flatnonzero(lcounts):
                    j, k = divmod(int(key), nR)
                    sel = lorder[loff[key]:loff[key + 1]]
                    ends, _ = self._vec_serve(
                        shuf_links[j][k], eenq[sel], etie[sel], amt[sel],
                        ejob[sel], state)
                    cols[0].append(ends)
                    cols[1].append(etie[sel])
                    cols[2].append(amt[sel])
                    cols[3].append(ejob[sel])
                    cols[4].append(
                        np.full(ends.shape[0], k, dtype=np.int64))
                st_, stie, samt, sjob, sk = map(np.concatenate, cols)
                o = np.lexsort((stie, st_))
                st_, stie, samt = st_[o], stie[o], samt[o]
                sjob, sk = sjob[o], sk[o]
                n_sarr = st_.shape[0]

                t_max = max(t_max, float(st_[-1]))
                sarrk[sjob, sk] = st_
                sarr_any[sjob] = st_
                jsort, off = self._vec_by_job(sjob, nJ)
                sready = st_.copy()
                drop = np.zeros(n_sarr, dtype=bool)
                for g in wave:
                    gi = g.idx
                    sel = jsort[off[gi]:off[gi + 1]]
                    if not sel.shape[0]:
                        continue
                    m = float(st_[sel[-1]])
                    if m > g.shuffle_end:
                        g.shuffle_end = m
                    g.shuf_landed_mb = self._vec_fold(
                        g.shuf_landed_mb, samt[sel])
                    b2 = g.cfg.barriers[2]
                    if b2 == "P":
                        continue
                    rv = sarrk[gi, sk[sel]] if b2 == "L" \
                        else sarr_any[gi]
                    rv = np.maximum(rv, rel[gi])
                    sready[sel] = rv
                    # the gate's trigger arrival fired while map work was
                    # still outstanding and nothing re-checks it: the
                    # scalar engine leaves these chunks gated forever, so
                    # we drop them identically
                    drop[sel] = rv < comp_any[gi]

                keep = ~drop
                seqr = np.arange(n_sarr, dtype=np.int64)[keep]
                sready, samt = sready[keep], samt[keep]
                sjob, sk = sjob[keep], sk[keep]

                cols = ([], [], [], [], [])
                if sready.shape[0]:
                    korder = np.lexsort((seqr, sready, sk))
                    koff = np.concatenate(
                        ([0], np.cumsum(np.bincount(sk, minlength=nR))))
                    reducers = self.reducers
                    for k in range(nR):
                        sel = korder[koff[k]:koff[k + 1]]
                        if not sel.shape[0]:
                            continue
                        jb = sjob[sel]
                        ends, _ = self._vec_serve(
                            reducers[k], sready[sel], seqr[sel],
                            samt[sel], jb, state, slow=slow_r[jb, k])
                        cols[0].append(ends)
                        cols[1].append(seqr[sel])
                        cols[2].append(samt[sel])
                        cols[3].append(jb)
                        cols[4].append(
                            np.full(ends.shape[0], k, dtype=np.int64))
                if cols[0]:
                    rt, rtie, ramt, rjob, rk = map(np.concatenate, cols)
                    o = np.lexsort((rtie, rt))
                    rt, ramt = rt[o], ramt[o]
                    rjob, rk = rjob[o], rk[o]

                    t_max = max(t_max, float(rt[-1]))
                    redk[rjob, rk] = rt
                    jsort, off = self._vec_by_job(rjob, nJ)
                    for g in wave:
                        gi = g.idx
                        sel = jsort[off[gi]:off[gi + 1]]
                        if not sel.shape[0]:
                            continue
                        m = float(rt[sel[-1]])
                        if m > g.reduce_end:
                            g.reduce_end = m
                        g.reduced_mb = self._vec_fold(
                            g.reduced_mb, ramt[sel])
                        kv = rk[sel]
                        for k in np.unique(kv):
                            ks = sel[kv == k]
                            g.delivered_out[k] = self._vec_fold(
                                float(g.delivered_out[k]), ramt[ks])

            # ---- finalize stage parents: reducer k's output is complete
            # at max(last global map completion, last reduce at k) — the
            # first event where _maybe_finalize_stage sees it closed ------
            for g in wave:
                children = self.stage_children.get(g.idx)
                if not children:
                    continue
                gi = g.idx
                anchor = comp_any[gi]
                if anchor == NEG:
                    anchor = rel[gi]
                if anchor == NEG:
                    raise RuntimeError(
                        f"vectorized executor: stage parent {gi} produced "
                        "no anchor event; rerun with "
                        'SimConfig(mode="event")'
                    )
                anchor = float(anchor)
                for k in range(nR):
                    t_fin = anchor
                    lr = float(redk[gi, k])
                    if lr > t_fin:
                        t_fin = lr
                    g.reducer_final[k] = True
                    mb = float(g.delivered_out[k])
                    for c in children:
                        child = runs[c]
                        child_contrib.setdefault(c, []).append(
                            (t_fin, gi, k, child.stage_scale[gi] * mb))
                    t_max = max(t_max, t_fin)

        self.now = max(self.now, t_max)
        if self._audit:
            self._audit_final()
        return self.result()

    # -- vectorized steered drains -----------------------------------------
    #
    # ``run_until``/``run`` on a *started* engine drain each segment
    # between decision points through the same batched per-resource scans
    # as ``_run_vectorized`` whenever the pending events and every job's
    # dynamics stay inside the vectorized vocabulary.  Services that start
    # before the horizon commit (prefix of the same Lindley fold — same
    # floats as the unbounded replay); everything else materializes back
    # into scalar state, so ``snapshot``/``swap_plan``/``inject`` and
    # scalar fallback segments see exactly what the scalar loop would
    # have built.

    _VEC_STEER_EVENTS = frozenset(
        {"seed_jobs", "link_done", "map_done", "reduce_done"})

    def _vec_steer_eligible(self) -> bool:
        """True when a steered segment can take the batched scans —
        otherwise the caller silently falls back to the scalar loop (both
        paths are byte-identical on race-free scenarios, so segments may
        mix freely as dynamics toggle mid-run)."""
        if not self.runs or self.sub.failures or self.stage_children:
            return False
        if self._dead_m or self._dead_r:
            return False
        for g in self.runs:
            c = g.cfg
            if c.mode != "event_vec" or g.stage_deps:
                return False
            if (c.speculation or c.stealing or c.failures
                    or c.compute_noise > 0 or c.replication != 1):
                return False
        return all(ev[2] in self._VEC_STEER_EVENTS for ev in self._heap)

    def _vec_drain(self, cut, inclusive=False):
        """Drain one steered segment (events before ``cut``; everything
        when ``cut`` is None) through the vectorized per-resource scans.

        The segment replays exactly like :meth:`_run_vectorized` — same
        entry ordering, same Lindley folds, same ledger fold order — with
        three twists that make it safe between decision points:

        * pending heap events fold in: completions that *happen* join
          their tier's stream (their resource resumes from them), while
          completions at-or-past the horizon pin their resource busy for
          the whole segment;
        * barrier gates resolve by *post-segment counters* (the scalar
          trigger condition) instead of closed-form final times — a gate
          whose counter has not drained keeps its chunks gated, to be
          revisited next segment;
        * work still pending at the horizon materializes back into
          scalar state: queues, gated lists, in-service transfer/chunk
          objects and their heap completion events.
        """
        runs = self.runs
        nM, nR = self.sub.nM, self.sub.nR
        nJ = len(runs)
        NEG = _NEG_INF

        def happens(t):
            return cut is None or t < cut or (inclusive and t == cut)

        fire = [ev for ev in self._heap if happens(ev[0])]
        if not fire:
            return
        fire.sort()
        keep = [ev for ev in self._heap if not happens(ev[0])]
        heapq.heapify(keep)
        self._heap = keep

        BTIE = -(1 << 60)  # boundary completions: first among stream ties
        CTIE = -(1 << 40)  # carried queue entries: first in FIFO order
        GTIE = -(1 << 20)  # carried gated flushes: after queues, pre fresh
        gen = 0
        gctr = 0
        t_max = self.now
        state: Dict[object, Tuple[float, float]] = {}
        freed: Dict[object, float] = {}
        if self._vec_slow is None or self._vec_slow[0].shape[0] != nJ:
            self._vec_slow = (
                np.array([[g.slowdown("m", j) for j in range(nM)]
                          for g in runs]),
                np.array([[g.slowdown("r", k) for k in range(nR)]
                          for g in runs]),
            )
        slow_m, slow_r = self._vec_slow

        def _cat(lst, dtype=np.float64):
            if not lst:
                return np.empty(0, dtype=dtype)
            return np.concatenate(lst)

        def _mat_tr(enq, sz_, jb_, obj, fn, src, loc):
            if obj is not None:
                return obj
            g = runs[int(jb_)]
            if fn == "push_arrive":
                c = _Chunk(next(self._cid), float(sz_), src, owner=loc)
            else:
                c = _Chunk(next(self._cid), float(sz_), src)
            return _Transfer(g, float(sz_), fn, (g, src, loc, c),
                             float(enq))

        def _mat_chunk(sz_, jb_, obj, src_, tier, loc):
            if obj is not None:
                return obj
            if tier == "m":
                c = _Chunk(next(self._cid), float(sz_), int(src_),
                           owner=loc)
                c.landed = True
            else:
                c = _Chunk(next(self._cid), float(sz_), int(src_))
            return c

        def serve_link(link, ready, tie, sz, jb, objs, fn, src, loc, out):
            """Serve one link's segment queue; committed arrivals append
            to the tier stream ``out``, the rest materializes."""
            n = ready.shape[0]
            if n:
                o = np.lexsort((tie, ready))
                ready, tie, sz, jb = ready[o], tie[o], sz[o], jb[o]
                objs = objs[o]
            if link.busy:
                # in flight past the horizon: nothing can start here
                link.queue.extend(
                    _mat_tr(ready[i], sz[i], jb[i], objs[i], fn, src, loc)
                    for i in range(n))
                return
            nq = len(link.queue)
            if nq:
                qr = np.array([tr.enqueued for tr in link.queue])
                qt = CTIE + np.arange(nq, dtype=np.int64)
                qs = np.array([tr.size for tr in link.queue])
                qj = np.array([tr.run.idx for tr in link.queue],
                              dtype=np.int64)
                qo = np.empty(nq, dtype=object)
                qo[:] = link.queue
                ready = np.concatenate((qr, ready))
                tie = np.concatenate((qt, tie))
                sz = np.concatenate((qs, sz))
                jb = np.concatenate((qj, jb))
                objs = np.concatenate((qo, objs))
                o = np.lexsort((tie, ready))
                ready, tie, sz, jb = ready[o], tie[o], sz[o], jb[o]
                objs = objs[o]
                n += nq
            if not n:
                return
            state[link] = (freed.get(link, 0.0), NEG)
            ends, n_c = self._vec_serve(link, ready, tie, sz, jb, state,
                                        cut=cut, inclusive=inclusive)
            n_fin = n_c
            if n_c:
                link.serial += n_c
                last = float(ends[n_c - 1])
                if not happens(last):
                    n_fin = n_c - 1
                    i = n_c - 1
                    tr = _mat_tr(ready[i], sz[i], jb[i], objs[i], fn,
                                 src, loc)
                    link.busy = True
                    link.current = tr
                    self.at(last, "link_done", link, tr, link.serial)
            if n_fin:
                chunks = np.empty(n_fin, dtype=object)
                chunks[:] = [o_.args[3] if o_ is not None else None
                             for o_ in objs[:n_fin]]
                out[0].append(ends[:n_fin].copy())
                out[1].append(tie[:n_fin])
                out[2].append(sz[:n_fin])
                out[3].append(jb[:n_fin])
                out[4].append(chunks)
                out[5].append(np.full(n_fin, src, dtype=np.int64))
                out[6].append(np.full(n_fin, loc, dtype=np.int64))
            link.queue = [
                _mat_tr(ready[i], sz[i], jb[i], objs[i], fn, src, loc)
                for i in range(n_c, n)]

        def serve_node(node, ready, tie, sz, jb, objs, srcs, slow_tab,
                       tier, loc, fn, out):
            """Serve one compute node's segment queue; committed
            completions append to ``out``, the rest materializes."""
            n = ready.shape[0]
            if n:
                o = np.lexsort((tie, ready))
                ready, tie, sz, jb = ready[o], tie[o], sz[o], jb[o]
                objs, srcs = objs[o], srcs[o]
            if node.busy:
                node.queue.extend(
                    (runs[int(jb[i])],
                     _mat_chunk(sz[i], jb[i], objs[i], srcs[i], tier, loc),
                     float(ready[i]))
                    for i in range(n))
                return
            nq = len(node.queue)
            if nq:
                qr = np.array([t for (_g, _c, t) in node.queue])
                qt = CTIE + np.arange(nq, dtype=np.int64)
                qs = np.array([c.size for (_g, c, _t) in node.queue])
                qj = np.array([g_.idx for (g_, _c, _t) in node.queue],
                              dtype=np.int64)
                qo = np.empty(nq, dtype=object)
                qo[:] = [c for (_g, c, _t) in node.queue]
                qsrc = np.array([c.src for (_g, c, _t) in node.queue],
                                dtype=np.int64)
                ready = np.concatenate((qr, ready))
                tie = np.concatenate((qt, tie))
                sz = np.concatenate((qs, sz))
                jb = np.concatenate((qj, jb))
                objs = np.concatenate((qo, objs))
                srcs = np.concatenate((qsrc, srcs))
                o = np.lexsort((tie, ready))
                ready, tie, sz, jb = ready[o], tie[o], sz[o], jb[o]
                objs, srcs = objs[o], srcs[o]
                n += nq
            if not n:
                return
            state[node] = (freed.get(node, 0.0), NEG)
            ends, n_c = self._vec_serve(
                node, ready, tie, sz, jb, state, slow=slow_tab[jb, loc],
                cut=cut, inclusive=inclusive)
            n_fin = n_c
            if n_c:
                last = float(ends[n_c - 1])
                if not happens(last):
                    n_fin = n_c - 1
                    i = n_c - 1
                    c = _mat_chunk(sz[i], jb[i], objs[i], srcs[i], tier,
                                   loc)
                    c.started_copies += 1
                    node.busy = True
                    node.current = runs[int(jb[i])]
                    node.current_chunk = c
                    self.at(last, fn, runs[int(jb[i])], loc, c)
            if n_fin:
                for o_ in objs[:n_fin]:
                    if o_ is not None:
                        o_.done = True
                out[0].append(ends[:n_fin].copy())
                out[1].append(tie[:n_fin])
                out[2].append(sz[:n_fin])
                out[3].append(jb[:n_fin])
                out[4].append(srcs[:n_fin])
                out[5].append(np.full(n_fin, loc, dtype=np.int64))
            node.queue = [
                (runs[int(jb[i])],
                 _mat_chunk(sz[i], jb[i], objs[i], srcs[i], tier, loc),
                 float(ready[i]))
                for i in range(n_c, n)]

        # ---- pass 1: boundary events + seeds -----------------------------
        arr_b: list = []   # (t, tie, size, jobi, chunk, src i, dest j)
        comp_b: list = []  # (t, tie, size, jobi, src i, mapper j)
        sarr_b: list = []  # (t, tie, size, jobi, chunk, src j, reducer k)
        red_b: list = []   # (t, tie, size, jobi, src j, reducer k)
        link_fresh: Dict[Tuple[int, int], list] = {}
        sh_keys: set = set()
        freed_mj: list = []
        freed_rk: list = []
        seed_evs: list = []
        for pos, (t, _s, fn, args) in enumerate(fire):
            tie = BTIE + pos
            if fn == "seed_jobs":
                seed_evs.append((t, args[0]))
                continue
            if t > t_max:
                t_max = t
            if fn == "link_done":
                link, tr = args[0], args[1]
                freed[link] = t
                link.busy = False
                link.current = None
                g, src, loc, c = tr.args
                if tr.fn == "push_arrive":
                    arr_b.append((t, tie, tr.size, g.idx, c, src, loc))
                    link_fresh.setdefault((src, loc), [])
                else:
                    sarr_b.append((t, tie, tr.size, g.idx, c, src, loc))
                    sh_keys.add((src, loc))
            elif fn == "map_done":
                g, j, c = args
                node = self.mappers[j]
                freed[node] = t
                node.busy = False
                node.current = None
                node.current_chunk = None
                c.done = True
                comp_b.append((t, tie, c.size, g.idx, c.src, j))
                freed_mj.append(j)
            else:  # reduce_done
                g, k, sc = args
                node = self.reducers[k]
                freed[node] = t
                node.busy = False
                node.current = None
                node.current_chunk = None
                sc.done = True
                red_b.append((t, tie, sc.size, g.idx, sc.src, k))
                freed_rk.append(k)

        # seeds: round-robin interleave exactly like _ev_seed_jobs
        for t_seed, idxs in seed_evs:
            if t_seed > t_max:
                t_max = t_seed
            pending = [(runs[i], self._push_ops(runs[i])) for i in idxs]
            for i in idxs:
                runs[i].seeded = True
            sizes: Dict[int, list] = {i: [] for i in idxs}
            cursors = [0] * len(pending)
            live = True
            while live:
                live = False
                for slot, (g, ops) in enumerate(pending):
                    if cursors[slot] >= len(ops):
                        continue
                    live = True
                    i, j, size = ops[cursors[slot]]
                    cursors[slot] += 1
                    link_fresh.setdefault((i, j), []).append(
                        (t_seed, gen, float(size), g.idx))
                    gen += 1
                    sizes[g.idx].append(size)
                    g.push_inflight[j] += 1
                    g.map_unfinished[j] += 1
            for i in idxs:
                g = runs[i]
                ss = sizes[i]
                if ss:
                    g.pushed_mb = self._vec_fold(
                        g.pushed_mb, np.asarray(ss, dtype=np.float64))
                g.total_map_chunks += len(ss)
                g.total_push_inflight += len(ss)
                g.total_map_unfinished += len(ss)

        # ---- pass 2: push links → arrival stream -------------------------
        arr_p: Tuple[list, ...] = ([], [], [], [], [], [], [])
        if arr_b:
            cols = list(zip(*arr_b))
            arr_p[0].append(np.asarray(cols[0], dtype=np.float64))
            arr_p[1].append(np.asarray(cols[1], dtype=np.int64))
            arr_p[2].append(np.asarray(cols[2], dtype=np.float64))
            arr_p[3].append(np.asarray(cols[3], dtype=np.int64))
            bo = np.empty(len(arr_b), dtype=object)
            bo[:] = cols[4]
            arr_p[4].append(bo)
            arr_p[5].append(np.asarray(cols[5], dtype=np.int64))
            arr_p[6].append(np.asarray(cols[6], dtype=np.int64))
        for (i, j), fresh in sorted(link_fresh.items()):
            if fresh:
                fc = list(zip(*fresh))
                f_enq = np.asarray(fc[0], dtype=np.float64)
                f_tie = np.asarray(fc[1], dtype=np.int64)
                f_sz = np.asarray(fc[2], dtype=np.float64)
                f_jb = np.asarray(fc[3], dtype=np.int64)
                f_obj = np.empty(len(fresh), dtype=object)
            else:
                f_enq = np.empty(0)
                f_tie = np.empty(0, dtype=np.int64)
                f_sz = np.empty(0)
                f_jb = np.empty(0, dtype=np.int64)
                f_obj = np.empty(0, dtype=object)
            serve_link(self.push_links[i][j], f_enq, f_tie, f_sz, f_jb,
                       f_obj, "push_arrive", i, j, arr_p)

        # ---- pass 3: arrivals → push/map barrier gates -------------------
        EMPTYF = np.empty(0)
        EMPTYI = np.empty(0, dtype=np.int64)
        EMPTYO = np.empty(0, dtype=object)
        n_arr = sum(a.shape[0] for a in arr_p[0])
        flushm: Dict[int, list] = {}
        if n_arr:
            at = _cat(arr_p[0])
            atie = _cat(arr_p[1], np.int64)
            asz = _cat(arr_p[2])
            ajob = _cat(arr_p[3], np.int64)
            aobj = _cat(arr_p[4], object)
            asrc = _cat(arr_p[5], np.int64)
            adst = _cat(arr_p[6], np.int64)
            o = np.lexsort((atie, at))
            at, atie, asz, ajob = at[o], atie[o], asz[o], ajob[o]
            aobj, asrc, adst = aobj[o], asrc[o], adst[o]
            if float(at[-1]) > t_max:
                t_max = float(at[-1])
            for ob in aobj:
                if ob is not None:
                    ob.landed = True
            arrj = np.full((nJ, nM), NEG)
            arr_any = np.full(nJ, NEG)
            arrj[ajob, adst] = at
            arr_any[ajob] = at
            seqv = np.arange(n_arr, dtype=np.int64)
            aready = at.copy()
            agated = np.zeros(n_arr, dtype=bool)
            jsort, off = self._vec_by_job(ajob, nJ)
            for g in runs:
                gi = g.idx
                sel = jsort[off[gi]:off[gi + 1]]
                if not sel.shape[0]:
                    continue
                m = float(at[sel[-1]])
                if m > g.push_end:
                    g.push_end = m
                g.landed_mb = self._vec_fold(g.landed_mb, asz[sel])
                dsel = adst[sel]
                np.subtract.at(g.push_inflight, dsel, 1)
                g.total_push_inflight -= int(sel.shape[0])
                b0 = g.cfg.barriers[0]
                if b0 == "P":
                    continue
                if b0 == "L":
                    openm = g.push_inflight[dsel] == 0
                    aready[sel] = arrj[gi, dsel]
                    agated[sel] = ~openm
                    for j in np.unique(dsel[openm]):
                        j = int(j)
                        if g.map_gated[j]:
                            trig = float(arrj[gi, j])
                            for c in g.map_gated[j]:
                                flushm.setdefault(j, []).append(
                                    (trig, GTIE + gctr, c.size, gi, c,
                                     c.src))
                                gctr += 1
                            g.map_gated[j].clear()
                elif g.total_push_inflight == 0:  # G, fully arrived
                    trig = float(arr_any[gi])
                    aready[sel] = trig
                    for j in range(nM):
                        if g.map_gated[j]:
                            for c in g.map_gated[j]:
                                flushm.setdefault(j, []).append(
                                    (trig, GTIE + gctr, c.size, gi, c,
                                     c.src))
                                gctr += 1
                            g.map_gated[j].clear()
                else:  # G, still draining: everything parks at the gate
                    agated[sel] = True

            # gated arrivals park at the barrier in arrival order
            for idx in np.flatnonzero(agated):
                g = runs[int(ajob[idx])]
                c = aobj[idx]
                if c is None:
                    c = _Chunk(next(self._cid), float(asz[idx]),
                               int(asrc[idx]), owner=int(adst[idx]))
                    c.landed = True
                g.map_gated[int(adst[idx])].append(c)

        # ---- pass 4: mapper serves → completion stream -------------------
        comp_p: Tuple[list, ...] = ([], [], [], [], [], [])
        if comp_b:
            cols = list(zip(*comp_b))
            comp_p[0].append(np.asarray(cols[0], dtype=np.float64))
            comp_p[1].append(np.asarray(cols[1], dtype=np.int64))
            comp_p[2].append(np.asarray(cols[2], dtype=np.float64))
            comp_p[3].append(np.asarray(cols[3], dtype=np.int64))
            comp_p[4].append(np.asarray(cols[4], dtype=np.int64))
            comp_p[5].append(np.asarray(cols[5], dtype=np.int64))
        mvisit = set(flushm)
        mvisit.update(j for j in freed_mj if self.mappers[j].queue)
        if n_arr:
            mvisit.update(int(j) for j in np.unique(adst[~agated]))
        for j in sorted(mvisit):
            if n_arr:
                sel = np.flatnonzero(~agated & (adst == j))
                e_ready, e_tie, e_sz = aready[sel], seqv[sel], asz[sel]
                e_jb, e_obj, e_src = ajob[sel], aobj[sel], asrc[sel]
            else:
                e_ready, e_tie, e_sz = EMPTYF, EMPTYI, EMPTYF
                e_jb, e_obj, e_src = EMPTYI, EMPTYO, EMPTYI
            fl = flushm.get(j)
            if fl:
                fc = list(zip(*fl))
                fo = np.empty(len(fl), dtype=object)
                fo[:] = fc[4]
                e_ready = np.concatenate(
                    (np.asarray(fc[0], dtype=np.float64), e_ready))
                e_tie = np.concatenate(
                    (np.asarray(fc[1], dtype=np.int64), e_tie))
                e_sz = np.concatenate(
                    (np.asarray(fc[2], dtype=np.float64), e_sz))
                e_jb = np.concatenate(
                    (np.asarray(fc[3], dtype=np.int64), e_jb))
                e_obj = np.concatenate((fo, e_obj))
                e_src = np.concatenate(
                    (np.asarray(fc[5], dtype=np.int64), e_src))
            serve_node(self.mappers[j], e_ready, e_tie, e_sz, e_jb,
                       e_obj, e_src, slow_m, "m", j, "map_done", comp_p)

        # ---- pass 5: completions → shuffle barrier → emissions -----------
        sarr_p: Tuple[list, ...] = ([], [], [], [], [], [], [])
        if sarr_b:
            cols = list(zip(*sarr_b))
            sarr_p[0].append(np.asarray(cols[0], dtype=np.float64))
            sarr_p[1].append(np.asarray(cols[1], dtype=np.int64))
            sarr_p[2].append(np.asarray(cols[2], dtype=np.float64))
            sarr_p[3].append(np.asarray(cols[3], dtype=np.int64))
            bo = np.empty(len(sarr_b), dtype=object)
            bo[:] = cols[4]
            sarr_p[4].append(bo)
            sarr_p[5].append(np.asarray(cols[5], dtype=np.int64))
            sarr_p[6].append(np.asarray(cols[6], dtype=np.int64))
        shflush: Dict[Tuple[int, int], list] = {}
        n_comp = sum(a.shape[0] for a in comp_p[0])
        n_em = 0
        if n_comp:
            ct = _cat(comp_p[0])
            ctie = _cat(comp_p[1], np.int64)
            csz = _cat(comp_p[2])
            cjob = _cat(comp_p[3], np.int64)
            cdst = _cat(comp_p[5], np.int64)
            o = np.lexsort((ctie, ct))
            ct, csz, cjob, cdst = ct[o], csz[o], cjob[o], cdst[o]
            if float(ct[-1]) > t_max:
                t_max = float(ct[-1])
            compj = np.full((nJ, nM), NEG)
            comp_any = np.full(nJ, NEG)
            compj[cjob, cdst] = ct
            comp_any[cjob] = ct
            cready = ct.copy()
            cgated = np.zeros(n_comp, dtype=bool)
            jsort, off = self._vec_by_job(cjob, nJ)
            for g in runs:
                gi = g.idx
                sel = jsort[off[gi]:off[gi + 1]]
                if not sel.shape[0]:
                    continue
                m = float(ct[sel[-1]])
                if m > g.map_end:
                    g.map_end = m
                g.mapped_mb = self._vec_fold(g.mapped_mb, csz[sel])
                dsel = cdst[sel]
                np.subtract.at(g.map_unfinished, dsel, 1)
                g.total_map_unfinished -= int(sel.shape[0])
                b1 = g.cfg.barriers[1]
                if b1 == "P":
                    continue
                if b1 == "L":
                    openm = g.map_unfinished[dsel] == 0
                    cready[sel] = compj[gi, dsel]
                    cgated[sel] = ~openm
                    flushj = [int(j) for j in np.unique(dsel[openm])]
                elif g.total_map_unfinished == 0:  # G, all map work done
                    cready[sel] = float(comp_any[gi])
                    flushj = list(range(nM))
                else:  # G, maps still outstanding
                    cgated[sel] = True
                    continue
                for j in flushj:
                    if not g.shuf_gated[j]:
                        continue
                    trig = float(compj[gi, j]) if b1 == "L" \
                        else float(comp_any[gi])
                    for k, sc in g.shuf_gated[j]:
                        tr = _Transfer(g, sc.size, "shuffle_arrive",
                                       (g, j, k, sc), trig)
                        shflush.setdefault((j, k), []).append(
                            (trig, GTIE + gctr, sc.size, gi, tr))
                        gctr += 1
                    g.shuf_gated[j].clear()

            # emissions: completion-major, reducer-minor — exactly
            # _emit_shuffle's creation order, gated or not
            alpha_j = np.array([g.p.alpha for g in runs],
                               dtype=np.float64)
            ynz = [
                [(k, g.plan.y[k]) for k in range(nR)
                 if g.plan.y[k] > 0.0]
                for g in runs
            ]
            fan = np.array([len(z) for z in ynz], dtype=np.int64)
            maxf = max(int(fan.max()), 1)
            ynz_k = np.zeros((nJ, maxf), dtype=np.int64)
            ynz_y = np.zeros((nJ, maxf))
            for gi, z in enumerate(ynz):
                for s, (k, yk) in enumerate(z):
                    ynz_k[gi, s] = k
                    ynz_y[gi, s] = yk
            counts = fan[cjob]
            tot = int(counts.sum())
            if tot:
                off_e = np.concatenate(([0], np.cumsum(counts)))
                repi = np.repeat(np.arange(n_comp), counts)
                slot = np.arange(tot, dtype=np.int64) - off_e[repi]
                ejob = cjob[repi]
                ek = ynz_k[ejob, slot]
                a_s = alpha_j[ejob] * csz[repi]
                amt = a_s * ynz_y[ejob, slot]
                keep = amt > 1e-9
                eenq = cready[repi][keep]
                egated = cgated[repi][keep]
                ejob, ek, amt = ejob[keep], ek[keep], amt[keep]
                ejv = cdst[repi][keep]
                n_em = amt.shape[0]
        if n_em:
            etie = gen + np.arange(n_em, dtype=np.int64)
            gen += n_em
            jsort, off = self._vec_by_job(ejob, nJ)
            for g in runs:
                gi = g.idx
                sel = jsort[off[gi]:off[gi + 1]]
                if not sel.shape[0]:
                    continue
                g.shuf_created_mb = self._vec_fold(
                    g.shuf_created_mb, amt[sel])
                ksel = ek[sel]
                np.add.at(g.shuf_inflight, ksel, 1)
                g.total_shuf_inflight += int(sel.shape[0])
                np.add.at(g.reduce_outstanding, ksel, 1)
            # emissions born behind a shut gate park on it (creation
            # order), to be flushed by a later segment's trigger
            for idx in np.flatnonzero(egated):
                g = runs[int(ejob[idx])]
                sc = _Chunk(next(self._cid), float(amt[idx]),
                            int(ejv[idx]))
                g.shuf_gated[int(ejv[idx])].append((int(ek[idx]), sc))

        # ---- pass 6: shuffle-link serves → shuffle-arrival stream --------
        skeys = set(shflush)
        skeys.update((j, k) for (j, k) in sh_keys
                     if self.shuf_links[j][k].queue)
        eopen = None
        if n_em:
            eopen = np.flatnonzero(~egated)
            lkey = ejv[eopen] * nR + ek[eopen]
            skeys.update(
                (int(kk) // nR, int(kk) % nR) for kk in np.unique(lkey))
        for (j, k) in sorted(skeys):
            if eopen is not None:
                sel = eopen[lkey == j * nR + k]
                e_ready, e_tie, e_sz = eenq[sel], etie[sel], amt[sel]
                e_jb = ejob[sel]
                e_obj = np.full(sel.shape[0], None, dtype=object)
            else:
                e_ready, e_tie, e_sz = EMPTYF, EMPTYI, EMPTYF
                e_jb, e_obj = EMPTYI, EMPTYO
            fl = shflush.get((j, k))
            if fl:
                fc = list(zip(*fl))
                fo = np.empty(len(fl), dtype=object)
                fo[:] = fc[4]
                e_ready = np.concatenate(
                    (np.asarray(fc[0], dtype=np.float64), e_ready))
                e_tie = np.concatenate(
                    (np.asarray(fc[1], dtype=np.int64), e_tie))
                e_sz = np.concatenate(
                    (np.asarray(fc[2], dtype=np.float64), e_sz))
                e_jb = np.concatenate(
                    (np.asarray(fc[3], dtype=np.int64), e_jb))
                e_obj = np.concatenate((fo, e_obj))
            serve_link(self.shuf_links[j][k], e_ready, e_tie, e_sz,
                       e_jb, e_obj, "shuffle_arrive", j, k, sarr_p)

        # ---- pass 7: shuffle arrivals → reduce barrier gates -------------
        n_sarr = sum(a.shape[0] for a in sarr_p[0])
        flushr: Dict[int, list] = {}
        if n_sarr:
            st_ = _cat(sarr_p[0])
            stie = _cat(sarr_p[1], np.int64)
            samt = _cat(sarr_p[2])
            sjob = _cat(sarr_p[3], np.int64)
            sobj = _cat(sarr_p[4], object)
            ssrc = _cat(sarr_p[5], np.int64)
            skv = _cat(sarr_p[6], np.int64)
            o = np.lexsort((stie, st_))
            st_, stie, samt, sjob = st_[o], stie[o], samt[o], sjob[o]
            sobj, ssrc, skv = sobj[o], ssrc[o], skv[o]
            if float(st_[-1]) > t_max:
                t_max = float(st_[-1])
            sarrk = np.full((nJ, nR), NEG)
            sarr_any = np.full(nJ, NEG)
            sarrk[sjob, skv] = st_
            sarr_any[sjob] = st_
            seqr = np.arange(n_sarr, dtype=np.int64)
            sready = st_.copy()
            sgated = np.zeros(n_sarr, dtype=bool)
            jsort, off = self._vec_by_job(sjob, nJ)
            for g in runs:
                gi = g.idx
                sel = jsort[off[gi]:off[gi + 1]]
                if not sel.shape[0]:
                    continue
                m = float(st_[sel[-1]])
                if m > g.shuffle_end:
                    g.shuffle_end = m
                g.shuf_landed_mb = self._vec_fold(
                    g.shuf_landed_mb, samt[sel])
                ksel = skv[sel]
                np.subtract.at(g.shuf_inflight, ksel, 1)
                g.total_shuf_inflight -= int(sel.shape[0])
                b2 = g.cfg.barriers[2]
                if b2 == "P":
                    continue
                # _shuffle_final at the trigger: all map work drained in
                # this segment's past — the trigger must not precede the
                # last map completion (or push arrival), else the scalar
                # check failed at its final chance and the gate stays
                # shut until new work re-triggers it
                final = (g.total_map_unfinished == 0
                         and g.total_push_inflight == 0
                         and not g.dep_pending)
                if b2 == "L":
                    openk = (final
                             & (g.shuf_inflight[ksel] == 0)
                             & (sarrk[gi, ksel] >= g.map_end)
                             & (sarrk[gi, ksel] >= g.push_end))
                    sready[sel] = sarrk[gi, ksel]
                    sgated[sel] = ~openk
                    flushk = [int(k) for k in np.unique(ksel[openk])]
                    trigk = {k: float(sarrk[gi, k]) for k in flushk}
                elif (final and g.total_shuf_inflight == 0
                        and float(sarr_any[gi]) >= g.map_end
                        and float(sarr_any[gi]) >= g.push_end):  # G
                    trig = float(sarr_any[gi])
                    sready[sel] = trig
                    flushk = list(range(nR))
                    trigk = {k: trig for k in flushk}
                else:  # G, not final yet
                    sgated[sel] = True
                    continue
                for k in flushk:
                    if not g.red_gated[k]:
                        continue
                    trig = trigk[k]
                    for sc in g.red_gated[k]:
                        flushr.setdefault(k, []).append(
                            (trig, GTIE + gctr, sc.size, gi, sc, sc.src))
                        gctr += 1
                    g.red_gated[k].clear()

            # gated shuffle arrivals park at the barrier in stream order
            for idx in np.flatnonzero(sgated):
                g = runs[int(sjob[idx])]
                sc = sobj[idx]
                if sc is None:
                    sc = _Chunk(next(self._cid), float(samt[idx]),
                                int(ssrc[idx]))
                g.red_gated[int(skv[idx])].append(sc)

        # ---- pass 8: reducer serves → reduce completion stream -----------
        red_p: Tuple[list, ...] = ([], [], [], [], [], [])
        if red_b:
            cols = list(zip(*red_b))
            red_p[0].append(np.asarray(cols[0], dtype=np.float64))
            red_p[1].append(np.asarray(cols[1], dtype=np.int64))
            red_p[2].append(np.asarray(cols[2], dtype=np.float64))
            red_p[3].append(np.asarray(cols[3], dtype=np.int64))
            red_p[4].append(np.asarray(cols[4], dtype=np.int64))
            red_p[5].append(np.asarray(cols[5], dtype=np.int64))
        rvisit = set(flushr)
        rvisit.update(k for k in freed_rk if self.reducers[k].queue)
        if n_sarr:
            rvisit.update(int(k) for k in np.unique(skv[~sgated]))
        for k in sorted(rvisit):
            if n_sarr:
                sel = np.flatnonzero(~sgated & (skv == k))
                e_ready, e_tie, e_sz = sready[sel], seqr[sel], samt[sel]
                e_jb, e_obj, e_src = sjob[sel], sobj[sel], ssrc[sel]
            else:
                e_ready, e_tie, e_sz = EMPTYF, EMPTYI, EMPTYF
                e_jb, e_obj, e_src = EMPTYI, EMPTYO, EMPTYI
            fl = flushr.get(k)
            if fl:
                fc = list(zip(*fl))
                fo = np.empty(len(fl), dtype=object)
                fo[:] = fc[4]
                e_ready = np.concatenate(
                    (np.asarray(fc[0], dtype=np.float64), e_ready))
                e_tie = np.concatenate(
                    (np.asarray(fc[1], dtype=np.int64), e_tie))
                e_sz = np.concatenate(
                    (np.asarray(fc[2], dtype=np.float64), e_sz))
                e_jb = np.concatenate(
                    (np.asarray(fc[3], dtype=np.int64), e_jb))
                e_obj = np.concatenate((fo, e_obj))
                e_src = np.concatenate(
                    (np.asarray(fc[5], dtype=np.int64), e_src))
            serve_node(self.reducers[k], e_ready, e_tie, e_sz, e_jb,
                       e_obj, e_src, slow_r, "r", k, "reduce_done",
                       red_p)

        # ---- pass 9: reduce ledger ---------------------------------------
        n_red = sum(a.shape[0] for a in red_p[0])
        if n_red:
            rt = _cat(red_p[0])
            rtie = _cat(red_p[1], np.int64)
            ramt = _cat(red_p[2])
            rjob = _cat(red_p[3], np.int64)
            rsrc = _cat(red_p[4], np.int64)
            rkv = _cat(red_p[5], np.int64)
            o = np.lexsort((rtie, rt))
            rt, ramt, rjob = rt[o], ramt[o], rjob[o]
            rsrc, rkv = rsrc[o], rkv[o]
            if float(rt[-1]) > t_max:
                t_max = float(rt[-1])
            jsort, off = self._vec_by_job(rjob, nJ)
            for g in runs:
                gi = g.idx
                sel = jsort[off[gi]:off[gi + 1]]
                if not sel.shape[0]:
                    continue
                m = float(rt[sel[-1]])
                if m > g.reduce_end:
                    g.reduce_end = m
                g.reduced_mb = self._vec_fold(g.reduced_mb, ramt[sel])
                kv = rkv[sel]
                np.subtract.at(g.reduce_outstanding, kv, 1)
                for k in np.unique(kv):
                    ks = sel[kv == k]
                    g.delivered_out[k] = self._vec_fold(
                        float(g.delivered_out[k]), ramt[ks])
                bykey = rsrc[sel] * nR + kv
                for key in np.unique(bykey):
                    ks = sel[bykey == key]
                    src_, k_ = divmod(int(key), nR)
                    g.reduced_by[src_, k_] = self._vec_fold(
                        float(g.reduced_by[src_, k_]), ramt[ks])

        self.now = max(self.now, t_max)
        if self._audit:
            self._audit_step("vec_drain")


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
_JobEntry = Union[
    Tuple[Platform, ExecutionPlan],
    Tuple[Platform, ExecutionPlan, Optional[SimConfig]],
]


def _normalize_entries(jobs: Sequence[_JobEntry]):
    entries = []
    for entry in jobs:
        platform, plan, cfg = entry if len(entry) == 3 else (*entry, None)
        entries.append((platform, plan, cfg or SimConfig()))
    return entries


def open_schedule(
    jobs: Sequence[_JobEntry],
    substrate: Optional[Substrate] = None,
    stage_links: Optional[Dict[int, Sequence[Tuple[int, float]]]] = None,
) -> _MultiSim:
    """Build (but do not run) the multi-job engine — the entry point of the
    online control plane.  The returned engine supports ``run_until(t)`` /
    ``snapshot()`` / ``swap_plan(idx, plan)`` / ``inject(jobs)`` / ``run()``;
    draining it without steering is exactly :func:`simulate_schedule`.

    ``jobs`` is a sequence of ``(platform, plan)`` or ``(platform, plan,
    cfg)`` entries whose platforms must all be views of the same substrate
    (checked via :meth:`Substrate.compatible`); ``substrate`` overrides the
    inferred one.  ``stage_links`` turns entries into pipeline stages:
    ``{child_idx: [(parent_idx, out_scale), ...]}`` — the child's source
    ``s`` releases only when every parent's reduce output destined for
    node ``s`` lands (see :meth:`_MultiSim.link_stages`).
    """
    if not jobs:
        raise ValueError("open_schedule needs at least one job")
    entries = _normalize_entries(jobs)
    sub = substrate if substrate is not None else Substrate.of(entries[0][0])
    for platform, _, _ in entries:
        if not sub.compatible(Substrate.of(platform)):
            raise ValueError(
                f"platform {platform.name!r} is not a view of substrate "
                f"{sub.name!r} — build job platforms with Substrate.view()"
            )
    modes = {cfg.mode for _, _, cfg in entries}
    if "fluid" in modes:
        if modes != {"fluid"}:
            raise ValueError(
                "every job of one schedule must agree on SimConfig.mode — "
                f"got {sorted(modes)}"
            )
        if stage_links:
            raise ValueError(
                "fluid mode does not support pipeline stage links — use "
                'SimConfig(mode="event")'
            )
        from .fluid import FluidSim
        return FluidSim(sub, entries)
    runs = [
        _JobRun(idx, platform, plan, cfg, sub.nM, sub.nR)
        for idx, (platform, plan, cfg) in enumerate(entries)
    ]
    eng = _MultiSim(sub, runs)
    for child, parents in (stage_links or {}).items():
        eng.link_stages(int(child), list(parents))
    return eng


def simulate_schedule(
    jobs: Sequence[_JobEntry],
    substrate: Optional[Substrate] = None,
    stage_links: Optional[Dict[int, Sequence[Tuple[int, float]]]] = None,
) -> ScheduleSimResult:
    """Execute N jobs concurrently on one shared substrate.

    Each job keeps its own barriers, chunking, dynamics and release time
    (``SimConfig.start_time``) — only the link/compute resources are
    shared.  This is :func:`open_schedule` drained to completion with no
    online steering (the frozen-plan baseline of the control plane).
    ``stage_links`` runs a pipeline: see :func:`open_schedule`.
    """
    if not jobs:
        raise ValueError("simulate_schedule needs at least one job")
    return open_schedule(jobs, substrate, stage_links).run()


def simulate(
    platform: Platform, plan: ExecutionPlan, cfg: Optional[SimConfig] = None
) -> SimResult:
    """Execute ``plan`` on ``platform`` under ``cfg`` and return timings —
    the N=1 case of :func:`simulate_schedule` (one job, sole tenant of its
    substrate)."""
    return simulate_schedule([(platform, plan, cfg or SimConfig())]).jobs[0]
