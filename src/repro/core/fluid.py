"""Flow-level (fluid) executor: the scale-tier fast path.

The chunk-granular DES in :mod:`repro.core.simulate` prices every chunk
service as an event — faithful, but at 10^2–10^3 nodes with ~10^2
concurrent jobs the event count itself becomes the wall.  This module
trades chunk granularity for *flows*: each job's remaining volume moves
as a continuous fluid served at shared rates (every resource splits its
capacity equally across the jobs with backlog on it — the fluid limit of
the DES's round-robin FIFO), and the engine only steps at *rate-change
events*: a flow empties, a barrier gate opens, a job releases.  Makespan
error against the per-chunk DES is bounded by chunk granularity (the
cross-validation suite holds it ≤ 2% on the 27 barrier triples).

The model keeps the same three-layer pipeline and per-job barrier
semantics one level up from chunks:

* **push** — per-(source, mapper) flows drain at the link's fair share;
  arrivals accumulate at the mapper (gated by the push/map barrier:
  ``P`` serves as it lands, ``L`` opens per mapper when that mapper's
  inbound flows empty, ``G`` when all of the job's push empties).
* **map** — mapper capacity is fair-shared per job (divided by any
  straggler slowdown); output (``alpha`` × mapped volume) is emitted
  into per-(mapper, reducer) shuffle flows split by ``y`` — immediately
  (``P``) or when the map/shuffle gate opens (``L``/``G``).
* **shuffle / reduce** — same discipline one layer down.

:class:`FluidSim` exposes the *same* control surface as the event
engine — ``run_until`` / ``snapshot`` / ``swap_plan`` / ``inject`` /
``run`` returning the same :class:`ScheduleSimResult` shape — so
``run_online`` / ``replan_schedule`` drive it unchanged.  Because flows
are continuous, plan swaps are exact re-splits (no chunk re-assignment
residue).  :class:`~repro.core.platform.CapacityTrace` drift is
supported natively: a rate step is just another piecewise-linear event,
so the engine folds ``Substrate.drift_times()`` into its event horizon
and re-reads capacities at each step.  Event-mode dynamics that are
inherently chunk-granular (speculation, stealing, worker failure,
compute noise, replication) and pipeline stage links are rejected at
construction with a pointer back to ``mode="event"``.

Only resources a job's plan touches are materialized (no per-pair
objects), so construction is O(flows), not O(nodes²) — the property
that makes the 1000-node tier tractable.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from typing import Dict, List, Optional, Sequence, Tuple

from .makespan import JobProgress, _live_plan_arrays
from .plan import ExecutionPlan
from .platform import Platform, Substrate
from .simulate import (
    ProgressSnapshot,
    ResourceStats,
    ScheduleSimResult,
    SimConfig,
    SimResult,
)

__all__ = ["FluidSim", "fluid_score_residual"]

#: volume below which a flow/buffer counts as drained (MB)
_EPS = 1e-6
#: hard cap on rate-change events — a correct run needs O(flows)
_MAX_EVENTS = 2_000_000


class _FluidJob:
    """Per-job fluid state: static plan tables plus phase timestamps.
    Flow volumes live in the engine's flat arrays (see
    :meth:`FluidSim._rebuild`)."""

    def __init__(self, idx: int, platform: Platform, plan: ExecutionPlan,
                 cfg: SimConfig, nM: int, nR: int):
        self.idx = idx
        self.p = platform
        self.plan = plan
        self.cfg = cfg
        self.seeded = False
        self.done = False
        # static per-job flow specs, rebuilt into the flat arrays:
        # push [(src, dst, remaining_mb)], shuffle [(j, k, y_share, rem)]
        self.push_spec: List[List[float]] = []
        self.shuf_spec: List[List[float]] = []
        self.push_end = 0.0
        self.map_end = 0.0
        self.shuffle_end = 0.0
        self.reduce_end = 0.0
        self._push_done = False
        self._map_done = False
        self._shuffle_done = False

    def result(self) -> SimResult:
        return SimResult(
            makespan=self.reduce_end,
            push_end=self.push_end,
            map_end=self.map_end,
            shuffle_end=self.shuffle_end,
            reduce_end=self.reduce_end,
            wasted_mb=0.0,
            recovered_chunks=0,
            total_map_chunks=0,
        )


class _TierStats:
    """Flat per-resource accounting for one tier (push links, mappers,
    shuffle links, reducers) — materialized into named
    :class:`ResourceStats` only for resources that served volume."""

    def __init__(self, n: int, cap: np.ndarray):
        self.cap = np.asarray(cap, dtype=np.float64).reshape(-1)
        self.busy = np.zeros(n)
        self.wait = np.zeros(n)
        self.vol = np.zeros(n)
        self.n_done = np.zeros(n, dtype=np.int64)
        self.first = np.full(n, np.inf)
        self.last = np.zeros(n)
        self.jobs: Dict[int, set] = {}

    def advance(self, served_rate: np.ndarray, backlog: np.ndarray,
                now: float, dt: float) -> None:
        """Integrate one constant-rate interval: ``busy`` is the served
        fraction of capacity, ``wait`` the backlog drain-age integral
        (``∫ backlog/capacity dt`` — the fluid analogue of the DES's
        queued chunk-seconds)."""
        on = served_rate > 0.0
        if not on.any():
            return
        self.busy[on] += served_rate[on] / self.cap[on] * dt
        self.vol[on] += served_rate[on] * dt
        self.wait[on] += backlog[on] / self.cap[on] * dt
        np.minimum(self.first, np.where(on, now, np.inf), out=self.first)
        self.last[on] = now + dt

    def touch(self, rid: int, job: int) -> None:
        self.jobs.setdefault(rid, set()).add(job)

    def emit(self, out: Dict[str, ResourceStats], name) -> None:
        for rid in np.flatnonzero((self.vol > 0) | (self.busy > 0)):
            rid = int(rid)
            out[name(rid)] = ResourceStats(
                busy_s=float(self.busy[rid]),
                waited_s=float(self.wait[rid]),
                volume_mb=float(self.vol[rid]),
                n_chunks=int(self.n_done[rid]),
                jobs=set(self.jobs.get(rid, ())),
                first_busy_s=float(self.first[rid]),
                last_busy_s=float(self.last[rid]),
            )


class FluidSim:
    """Flow-level multi-job engine over one substrate — drop-in for
    :class:`repro.core.simulate._MultiSim` on frozen or online-steered
    schedules (``SimConfig(mode="fluid")``)."""

    def __init__(self, substrate: Substrate,
                 entries: Sequence[Tuple[Platform, ExecutionPlan,
                                         SimConfig]]):
        self.sub = substrate
        self.now = 0.0
        self._started = False
        self.violations: List[str] = []
        self.runs: List[_FluidJob] = []
        nS, nM, nR = substrate.nS, substrate.nM, substrate.nR
        self.nS, self.nM, self.nR = nS, nM, nR
        if getattr(substrate, "failures", None):
            raise ValueError(
                "fluid mode does not support a substrate FailureTrace — "
                "failure recovery is chunk-event-granular; use SimConfig("
                'mode="event")'
            )
        # CapacityTrace drift folds into the event horizon: rates are
        # piecewise-constant between drift steps, so every step is just
        # one more rate-change event (_refresh_caps re-reads the folded
        # capacities, _next_dt never integrates across a step)
        self._drift = tuple(substrate.drift_times())
        self._drift_i = 0
        sub0 = substrate.at(0.0)
        self._B_sm = np.asarray(sub0.B_sm, dtype=np.float64)
        self._B_mr = np.asarray(sub0.B_mr, dtype=np.float64)
        self._C_m = np.asarray(sub0.C_m, dtype=np.float64)
        self._C_r = np.asarray(sub0.C_r, dtype=np.float64)
        self._st_push = _TierStats(nS * nM, self._B_sm)
        self._st_map = _TierStats(nM, self._C_m)
        self._st_shuf = _TierStats(nM * nR, self._B_mr)
        self._st_red = _TierStats(nR, self._C_r)

        # flat flow arrays (rebuilt on structural change)
        self._pf_job = np.zeros(0, dtype=np.int64)
        self._pf_src = np.zeros(0, dtype=np.int64)
        self._pf_dst = np.zeros(0, dtype=np.int64)
        self._pf_rem = np.zeros(0)
        self._sf_job = np.zeros(0, dtype=np.int64)
        self._sf_j = np.zeros(0, dtype=np.int64)
        self._sf_k = np.zeros(0, dtype=np.int64)
        self._sf_y = np.zeros(0)
        self._sf_rem = np.zeros(0)

        # per-job buffers / gates (rows grow on inject)
        self._at_map = np.zeros((0, nM))
        self._gated_map = np.zeros((0, nM))
        self._pool = np.zeros((0, nM))
        self._at_red = np.zeros((0, nR))
        self._gated_red = np.zeros((0, nR))
        self._open_map = np.zeros((0, nM), dtype=bool)
        self._open_em = np.zeros((0, nM), dtype=bool)
        self._open_red = np.zeros((0, nR), dtype=bool)
        self._released = np.zeros(0, dtype=bool)
        # push-service priority = seeding order (FIFO release order)
        self._prio = np.zeros(0, dtype=np.int64)
        self._seed_seq = 0
        self._alpha = np.zeros(0)
        self._slow_m = np.zeros((0, nM))
        self._slow_r = np.zeros((0, nR))
        self._audit = False
        for platform, plan, cfg in entries:
            self._admit(platform, plan, cfg)

    # -- construction ------------------------------------------------------
    def _admit(self, platform: Platform, plan: ExecutionPlan,
               cfg: SimConfig) -> int:
        if cfg.mode != "fluid":
            raise ValueError(
                "every job of a fluid schedule must set SimConfig("
                f'mode="fluid"), got mode={cfg.mode!r}'
            )
        bad = [name for name, flag in (
            ("speculation", cfg.speculation),
            ("stealing", cfg.stealing),
            ("failures", bool(cfg.failures)),
            ("compute_noise", cfg.compute_noise > 0),
            ("replication>1", cfg.replication != 1),
        ) if flag]
        if bad:
            raise ValueError(
                f"fluid mode: {'/'.join(bad)} is chunk-granular — use "
                'SimConfig(mode="event")'
            )
        g = _FluidJob(len(self.runs), platform, plan, cfg,
                      self.nM, self.nR)
        self.runs.append(g)
        self._audit = self._audit or cfg.audit
        nM, nR = self.nM, self.nR
        self._at_map = np.vstack([self._at_map, np.zeros((1, nM))])
        self._gated_map = np.vstack([self._gated_map, np.zeros((1, nM))])
        self._pool = np.vstack([self._pool, np.zeros((1, nM))])
        self._at_red = np.vstack([self._at_red, np.zeros((1, nR))])
        self._gated_red = np.vstack([self._gated_red, np.zeros((1, nR))])
        self._open_map = np.vstack(
            [self._open_map, np.zeros((1, nM), dtype=bool)])
        self._open_em = np.vstack(
            [self._open_em, np.zeros((1, nM), dtype=bool)])
        self._open_red = np.vstack(
            [self._open_red, np.zeros((1, nR), dtype=bool)])
        self._released = np.append(self._released, False)
        self._prio = np.append(self._prio, np.iinfo(np.int64).max)
        self._alpha = np.append(self._alpha, float(platform.alpha))
        self._slow_m = np.vstack([self._slow_m, [[
            cfg.stragglers.get(("m", j), 1.0) if cfg.stragglers else 1.0
            for j in range(nM)]]])
        self._slow_r = np.vstack([self._slow_r, [[
            cfg.stragglers.get(("r", k), 1.0) if cfg.stragglers else 1.0
            for k in range(nR)]]])
        return g.idx

    def _seed(self, g: _FluidJob) -> None:
        """Materialize the job's flows from its (current) plan."""
        self._writeback()  # preserve in-flight volumes across the rebuild
        gi = g.idx
        D = np.asarray(g.p.D, dtype=np.float64)
        x = np.asarray(g.plan.x, dtype=np.float64)
        y = np.asarray(g.plan.y, dtype=np.float64)
        g.push_spec = []
        for i in np.flatnonzero(D > _EPS):
            for j in np.flatnonzero(x[i] > 1e-9):
                vol = float(D[i] * x[i, j])
                if vol > _EPS:
                    g.push_spec.append([int(i), int(j), vol])
                    self._st_push.touch(int(i) * self.nM + int(j), gi)
                    self._st_map.touch(int(j), gi)
        dests = sorted({int(j) for _, j, _ in g.push_spec})
        ky = np.flatnonzero(y > 1e-9)
        ysum = float(y[ky].sum()) or 1.0
        g.shuf_spec = []
        for j in dests:
            for k in ky:
                g.shuf_spec.append([int(j), int(k), float(y[k] / ysum),
                                    0.0])
                self._st_shuf.touch(int(j) * self.nR + int(k), gi)
                self._st_red.touch(int(k), gi)
        g.seeded = True
        self._released[gi] = True
        self._prio[gi] = self._seed_seq
        self._seed_seq += 1
        b0, b1, b2 = g.cfg.barriers
        self._open_map[gi] = b0 == "P"
        self._open_em[gi] = b1 == "P"
        self._open_red[gi] = b2 == "P"
        if not g.push_spec:  # degenerate zero-volume job
            g.push_end = g.map_end = g.shuffle_end = g.reduce_end = self.now
            g._push_done = g._map_done = g._shuffle_done = True
            g.done = True
        self._rebuild()

    def _rebuild(self) -> None:
        """Flatten every seeded job's flow specs into the global arrays
        (called on seed / inject / swap — rare, O(flows))."""
        pj, ps, pd, pr = [], [], [], []
        sj, sjj, sk, sy, sr = [], [], [], [], []
        for g in self.runs:
            if not g.seeded or g.done:
                continue
            for i, j, rem in g.push_spec:
                pj.append(g.idx)
                ps.append(i)
                pd.append(j)
                pr.append(rem)
            for j, k, yk, rem in g.shuf_spec:
                sj.append(g.idx)
                sjj.append(j)
                sk.append(k)
                sy.append(yk)
                sr.append(rem)
        self._pf_job = np.asarray(pj, dtype=np.int64)
        self._pf_src = np.asarray(ps, dtype=np.int64)
        self._pf_dst = np.asarray(pd, dtype=np.int64)
        self._pf_rem = np.asarray(pr, dtype=np.float64)
        self._sf_job = np.asarray(sj, dtype=np.int64)
        self._sf_j = np.asarray(sjj, dtype=np.int64)
        self._sf_k = np.asarray(sk, dtype=np.int64)
        self._sf_y = np.asarray(sy, dtype=np.float64)
        self._sf_rem = np.asarray(sr, dtype=np.float64)

    def _writeback(self) -> None:
        """Mirror the flat remaining volumes back into the per-job specs
        (before a structural rebuild)."""
        cursor_p: Dict[int, int] = {}
        for n, gi in enumerate(self._pf_job):
            g = self.runs[gi]
            c = cursor_p.get(gi, 0)
            g.push_spec[c][2] = float(self._pf_rem[n])
            cursor_p[gi] = c + 1
        cursor_s: Dict[int, int] = {}
        for n, gi in enumerate(self._sf_job):
            g = self.runs[gi]
            c = cursor_s.get(gi, 0)
            g.shuf_spec[c][3] = float(self._sf_rem[n])
            cursor_s[gi] = c + 1

    # -- the fluid step ----------------------------------------------------
    def _rates(self):
        """Piecewise-constant service rates for the current state, in
        pipeline order (downstream inflow = upstream service)."""
        nJ, nM, nR = len(self.runs), self.nM, self.nR
        rel = self._released

        # push links: the DES seeds a job's entire push backlog at its
        # release instant, so a shared link drains jobs in strict FIFO
        # release order — model that as priority service (the earliest-
        # seeded job with backlog owns the link), not processor sharing
        pact = self._pf_rem > _EPS
        prate = np.zeros(self._pf_rem.shape[0])
        lid = self._pf_src * nM + self._pf_dst
        if pact.any():
            fprio = self._prio[self._pf_job]
            best = np.full(self.nS * nM, np.iinfo(np.int64).max)
            np.minimum.at(best, lid[pact], fprio[pact])
            serve = pact & (fprio == best[lid])
            prate[serve] = self._B_sm.reshape(-1)[lid[serve]]
        ar = np.zeros((nJ, nM))
        if pact.any():
            np.add.at(ar, (self._pf_job[pact], self._pf_dst[pact]),
                      prate[pact])

        inflow_m = np.where(self._open_map, ar, 0.0)
        elig = ((self._at_map > _EPS) | (inflow_m > 0.0)) & rel[:, None]
        m_rate = np.zeros((nJ, nM))
        if elig.any():
            cnt = elig.sum(axis=0)
            share = np.where(cnt > 0, self._C_m / np.maximum(cnt, 1), 0.0)
            m_rate = np.where(elig, share[None, :] / self._slow_m, 0.0)
            # an empty buffer serves no faster than it fills
            m_rate = np.where(self._at_map > _EPS, m_rate,
                              np.minimum(m_rate, inflow_m))

        emit = self._alpha[:, None] * m_rate
        e_open = np.where(self._open_em, emit, 0.0)
        pool_rate = emit - e_open
        inflow_sf = e_open[self._sf_job, self._sf_j] * self._sf_y

        sact = (self._sf_rem > _EPS) | (inflow_sf > 0.0)
        srate = np.zeros(self._sf_rem.shape[0])
        lid2 = self._sf_j * nR + self._sf_k
        if sact.any():
            cnt = np.bincount(lid2[sact], minlength=nM * nR)
            srate[sact] = self._B_mr.reshape(-1)[lid2[sact]] \
                / cnt[lid2[sact]]
            srate = np.where(self._sf_rem > _EPS, srate,
                             np.minimum(srate, inflow_sf))

        sr = np.zeros((nJ, nR))
        if sact.any():
            np.add.at(sr, (self._sf_job[sact], self._sf_k[sact]),
                      srate[sact])
        inflow_r = np.where(self._open_red, sr, 0.0)
        elig_r = ((self._at_red > _EPS) | (inflow_r > 0.0)) & rel[:, None]
        r_rate = np.zeros((nJ, nR))
        if elig_r.any():
            cnt = elig_r.sum(axis=0)
            share = np.where(cnt > 0, self._C_r / np.maximum(cnt, 1), 0.0)
            r_rate = np.where(elig_r, share[None, :] / self._slow_r, 0.0)
            r_rate = np.where(self._at_red > _EPS, r_rate,
                              np.minimum(r_rate, inflow_r))
        return prate, ar, inflow_m, m_rate, pool_rate, inflow_sf, srate, \
            sr, inflow_r, r_rate

    def _next_dt(self, prate, inflow_m, m_rate, inflow_sf, srate,
                 inflow_r, r_rate, t_cap: Optional[float]) -> float:
        """Time to the next rate-change event: some flow or buffer hits
        empty, a job releases, or the caller's horizon lands."""
        dt = np.inf
        on = prate > 0.0
        if on.any():
            dt = min(dt, float((self._pf_rem[on] / prate[on]).min()))
        net = m_rate - inflow_m
        zc = (net > 0.0) & (self._at_map > _EPS)
        if zc.any():
            dt = min(dt, float((self._at_map[zc] / net[zc]).min()))
        net = srate - inflow_sf
        zc = (net > 0.0) & (self._sf_rem > _EPS)
        if zc.any():
            dt = min(dt, float((self._sf_rem[zc] / net[zc]).min()))
        net = r_rate - inflow_r
        zc = (net > 0.0) & (self._at_red > _EPS)
        if zc.any():
            dt = min(dt, float((self._at_red[zc] / net[zc]).min()))
        pending = [g.cfg.start_time for g in self.runs
                   if not g.seeded and g.cfg.start_time > self.now]
        if pending:
            dt = min(dt, min(pending) - self.now)
        if self._drift_i < len(self._drift):
            # never integrate across a capacity drift step — rates are
            # only piecewise-constant between them
            dt = min(dt, self._drift[self._drift_i] - self.now)
        if t_cap is not None:
            dt = min(dt, t_cap - self.now)
        return max(dt, 0.0)

    def _advance(self, dt: float, prate, ar, inflow_m, m_rate, pool_rate,
                 inflow_sf, srate, sr, inflow_r, r_rate) -> None:
        nM, nR = self.nM, self.nR
        now = self.now
        if dt > 0.0:
            self._pf_rem -= prate * dt
            self._at_map += (inflow_m - m_rate) * dt
            self._gated_map += (ar - inflow_m) * dt
            self._pool += pool_rate * dt
            self._sf_rem += (inflow_sf - srate) * dt
            self._at_red += (inflow_r - r_rate) * dt
            self._gated_red += (sr - inflow_r) * dt
            for buf in (self._pf_rem, self._at_map, self._gated_map,
                        self._pool, self._sf_rem, self._at_red,
                        self._gated_red):
                np.clip(buf, 0.0, None, out=buf)

            # backlogs are linear within a constant-rate interval, so the
            # midpoint value makes the ``∫ backlog dt`` age integral exact
            # — and therefore invariant to how a steered run_until splits
            # the interval (a right-endpoint sample is not additive)
            lid = self._pf_src * nM + self._pf_dst
            served = np.zeros(self.nS * nM)
            np.add.at(served, lid, prate)
            backlog = np.zeros(self.nS * nM)
            np.add.at(backlog, lid, self._pf_rem + prate * (0.5 * dt))
            self._st_push.advance(served, backlog, now, dt)
            done_p = (self._pf_rem <= _EPS) & (prate > 0.0)
            if done_p.any():
                np.add.at(self._st_push.n_done, lid[done_p], 1)

            self._st_map.advance(
                m_rate.sum(axis=0),
                (self._at_map - (inflow_m - m_rate) * (0.5 * dt))
                .sum(axis=0),
                now, dt)
            lid2 = self._sf_j * nR + self._sf_k
            served = np.zeros(nM * nR)
            np.add.at(served, lid2, srate)
            backlog = np.zeros(nM * nR)
            np.add.at(backlog, lid2,
                      self._sf_rem - (inflow_sf - srate) * (0.5 * dt))
            self._st_shuf.advance(served, backlog, now, dt)
            done_s = (self._sf_rem <= _EPS) & (srate > 0.0)
            if done_s.any():
                np.add.at(self._st_shuf.n_done, lid2[done_s], 1)
            self._st_red.advance(
                r_rate.sum(axis=0),
                (self._at_red - (inflow_r - r_rate) * (0.5 * dt))
                .sum(axis=0),
                now, dt)
        self.now = now + dt

    def _settle(self) -> None:
        """Open every gate whose condition now holds and stamp phase
        completions — evaluated after each advance, in pipeline order so
        one settling cascades downstream within the same instant."""
        nJ, nM, nR = len(self.runs), self.nM, self.nR
        pending_push = np.zeros((nJ, nM), dtype=np.int64)
        act = self._pf_rem > _EPS
        if act.any():
            np.add.at(pending_push, (self._pf_job[act], self._pf_dst[act]),
                      1)
        now = self.now
        for g in self.runs:
            if not g.seeded or g.done:
                continue
            gi = g.idx
            b0, b1, b2 = g.cfg.barriers
            pp = pending_push[gi]
            push_done = not pp.any()
            if push_done and not g._push_done:
                g._push_done = True
                g.push_end = now
            # push/map gate
            if b0 == "L":
                newly = ~self._open_map[gi] & (pp == 0)
            elif b0 == "G":
                newly = np.full(nM, push_done) & ~self._open_map[gi]
            else:
                newly = np.zeros(nM, dtype=bool)
            if newly.any():
                self._open_map[gi, newly] = True
                self._at_map[gi, newly] += self._gated_map[gi, newly]
                self._gated_map[gi, newly] = 0.0
            # map completion per mapper: nothing buffered, gated or
            # still arriving
            map_done_j = (pp == 0) & (self._at_map[gi] <= _EPS) \
                & (self._gated_map[gi] <= _EPS)
            all_map = push_done and bool(map_done_j.all())
            if all_map and not g._map_done:
                g._map_done = True
                g.map_end = now
            # map/shuffle gate: release the held emission pool into the
            # job's shuffle flows (split by y)
            if b1 == "L":
                newly = ~self._open_em[gi] & map_done_j
            elif b1 == "G":
                newly = np.full(nM, all_map) & ~self._open_em[gi]
            else:
                newly = np.zeros(nM, dtype=bool)
            if newly.any():
                self._open_em[gi, newly] = True
                mine = self._sf_job == gi
                for j in np.flatnonzero(newly):
                    held = self._pool[gi, j]
                    if held > _EPS:
                        fsel = mine & (self._sf_j == j)
                        self._sf_rem[fsel] += held * self._sf_y[fsel]
                    self._pool[gi, j] = 0.0
            # shuffle completion per reducer: emission finished and the
            # inbound flows drained
            emission_done = all_map and not (self._pool[gi] > _EPS).any() \
                and bool(self._open_em[gi].all())
            mine = self._sf_job == gi
            pend_k = np.zeros(nR, dtype=np.int64)
            msel = mine & (self._sf_rem > _EPS)
            if msel.any():
                np.add.at(pend_k, self._sf_k[msel], 1)
            shuf_done_k = (pend_k == 0) & np.full(nR, emission_done)
            if emission_done and bool(shuf_done_k.all()) \
                    and not g._shuffle_done:
                g._shuffle_done = True
                g.shuffle_end = now
            # shuffle/reduce gate
            if b2 == "L":
                newly = ~self._open_red[gi] & shuf_done_k
            elif b2 == "G":
                newly = np.full(nR, g._shuffle_done) & ~self._open_red[gi]
            else:
                newly = np.zeros(nR, dtype=bool)
            if newly.any():
                self._open_red[gi, newly] = True
                self._at_red[gi, newly] += self._gated_red[gi, newly]
                self._gated_red[gi, newly] = 0.0
            if g._shuffle_done and (self._at_red[gi] <= _EPS).all() \
                    and (self._gated_red[gi] <= _EPS).all():
                g.reduce_end = now
                g.done = True
                self._released[gi] = True

    def _release_due(self) -> bool:
        due = [g for g in self.runs
               if not g.seeded and g.cfg.start_time <= self.now + 1e-12]
        for g in due:
            self._seed(g)
        return bool(due)

    def _refresh_caps(self) -> None:
        """Fold every capacity-trace step at or before ``now`` into the
        service-rate arrays (and the per-tier stats denominators, so
        utilization keeps integrating against the *current* capacity)."""
        if self._drift_i >= len(self._drift) \
                or self._drift[self._drift_i] > self.now + 1e-9:
            return
        while self._drift_i < len(self._drift) \
                and self._drift[self._drift_i] <= self.now + 1e-9:
            self._drift_i += 1
        sub_t = self.sub.at(self.now)
        self._B_sm = np.asarray(sub_t.B_sm, dtype=np.float64)
        self._B_mr = np.asarray(sub_t.B_mr, dtype=np.float64)
        self._C_m = np.asarray(sub_t.C_m, dtype=np.float64)
        self._C_r = np.asarray(sub_t.C_r, dtype=np.float64)
        self._st_push.cap = self._B_sm.reshape(-1)
        self._st_shuf.cap = self._B_mr.reshape(-1)
        self._st_map.cap = self._C_m.reshape(-1)
        self._st_red.cap = self._C_r.reshape(-1)

    def _step(self, t_cap: Optional[float]) -> bool:
        """One rate-change event.  Returns False when nothing remains to
        do (before ``t_cap``)."""
        self._release_due()
        self._refresh_caps()
        rates = self._rates()
        dt = self._next_dt(rates[0], rates[2], rates[3], rates[5],
                           rates[6], rates[8], rates[9], t_cap)
        if not np.isfinite(dt):
            return False
        if t_cap is not None and self.now + dt > t_cap:
            dt = max(t_cap - self.now, 0.0)
        self._advance(dt, *rates)
        self._settle()
        if self._release_due():
            return True
        if t_cap is not None and self.now >= t_cap:
            return False
        return True

    def _drain(self, t_cap: Optional[float]) -> None:
        self._started = True
        for _ in range(_MAX_EVENTS):
            if all(g.done for g in self.runs if g.seeded) \
                    and not any(
                        not g.seeded and (t_cap is None
                                          or g.cfg.start_time <= t_cap)
                        for g in self.runs):
                if t_cap is not None:
                    self.now = max(self.now, t_cap)
                return
            if not self._step(t_cap):
                return
        raise RuntimeError(
            f"fluid executor exceeded {_MAX_EVENTS} rate events — "
            "a flow is not draining (file a bug with the scenario)"
        )

    # -- control surface (mirrors _MultiSim) -------------------------------
    @property
    def finished(self) -> bool:
        return self._started and all(g.done or not g.seeded
                                     for g in self.runs) \
            and all(g.seeded for g in self.runs)

    def run_until(self, t: float, inclusive: bool = False) -> None:
        self._drain(t)
        self.now = max(self.now, t)

    def run(self) -> ScheduleSimResult:
        self._drain(None)
        if self._audit:
            self._audit_final()
        return self.result()

    def result(self) -> ScheduleSimResult:
        resources: Dict[str, ResourceStats] = {}
        nM, nR = self.nM, self.nR
        self._st_push.emit(
            resources, lambda r: f"push[s{r // nM}->m{r % nM}]")
        self._st_shuf.emit(
            resources, lambda r: f"shuffle[m{r // nR}->r{r % nR}]")
        self._st_map.emit(resources, lambda r: f"map[m{r}]")
        self._st_red.emit(resources, lambda r: f"reduce[r{r}]")
        return ScheduleSimResult(
            jobs=[g.result() for g in self.runs],
            makespan=max((g.reduce_end for g in self.runs), default=0.0),
            resources=resources,
            violations=list(self.violations),
        )

    def _audit_final(self) -> None:
        """Post-run conservation check (``SimConfig(audit=True)``): a
        finished job must have drained every flow and buffer — left-over
        volume means a gate never opened or a rate never reached it."""
        self._writeback()
        for g in self.runs:
            if not g.cfg.audit or not g.seeded or not g.done:
                continue
            gi = g.idx
            total = float(np.asarray(g.p.D).sum())
            tol = max(1e-6 * max(total, 1.0), 1e-2)
            left = {
                "push flows": sum(s[2] for s in g.push_spec),
                "shuffle flows": sum(s[3] for s in g.shuf_spec),
                "mapper buffers": float(
                    self._at_map[gi].sum() + self._gated_map[gi].sum()
                    + self._pool[gi].sum()),
                "reducer buffers": float(
                    self._at_red[gi].sum() + self._gated_red[gi].sum()),
            }
            for where, rem in left.items():
                if rem > tol:
                    self.violations.append(
                        f"job {gi}: fluid conservation: {rem:.6f} MB "
                        f"left in {where} on a finished job"
                    )

    def link_stages(self, child: int,
                    parents: Sequence[Tuple[int, float]]) -> None:
        raise ValueError(
            "fluid mode does not support pipeline stage links — use "
            'SimConfig(mode="event")'
        )

    def snapshot(self) -> ProgressSnapshot:
        """Remaining work bucketed for the re-planner.  Fluid volumes
        are continuously divisible, so *everything* still in flight is
        re-routable: remaining push reports as residual (not committed)
        and in-transit shuffle pools with its mapper."""
        self._release_due()
        nS, nM, nR = self.nS, self.nM, self.nR
        jobs = []
        for g in self.runs:
            if not g.seeded:
                prog = JobProgress.fresh(g.p, job=g.idx)
                prog = dataclasses.replace(prog, released=False)
                jobs.append(prog)
                continue
            gi = g.idx
            resid_push = np.zeros(nS)
            sel = self._pf_job == gi
            if sel.any():
                np.add.at(resid_push, self._pf_src[sel],
                          self._pf_rem[sel])
            at_mapper = self._at_map[gi] + self._gated_map[gi]
            pool = self._pool[gi].copy()
            ssel = self._sf_job == gi
            if ssel.any():
                np.add.at(pool, self._sf_j[ssel], self._sf_rem[ssel])
            at_reducer = self._at_red[gi] + self._gated_red[gi]
            prog = JobProgress(
                job=gi, released=True, done=g.done,
                resid_push=resid_push,
                committed_push=np.zeros((nS, nM)),
                at_mapper=at_mapper.copy(), shuffle_pool=pool,
                committed_shuffle=np.zeros((nM, nR)),
                at_reducer=at_reducer.copy(),
                alpha=float(g.p.alpha),
                total_push_mb=float(np.asarray(g.p.D).sum()),
                map_alive=np.ones(nM, dtype=bool),
            )
            if not g.done and prog.remaining_mb()["reduce"] <= 1e-9:
                prog = dataclasses.replace(prog, done=True)
            jobs.append(prog)
        backlog: Dict[str, float] = {}
        act = self._pf_rem > _EPS
        for n in np.flatnonzero(act):
            name = f"push[s{self._pf_src[n]}->m{self._pf_dst[n]}]"
            backlog[name] = backlog.get(name, 0.0) + float(self._pf_rem[n])
        act = self._sf_rem > _EPS
        for n in np.flatnonzero(act):
            name = f"shuffle[m{self._sf_j[n]}->r{self._sf_k[n]}]"
            backlog[name] = backlog.get(name, 0.0) + float(self._sf_rem[n])
        for j in range(nM):
            v = float(self._at_map[:, j].sum())
            if v > _EPS:
                backlog[f"map[m{j}]"] = v
        for k in range(nR):
            v = float(self._at_red[:, k].sum())
            if v > _EPS:
                backlog[f"reduce[r{k}]"] = v
        return ProgressSnapshot(time=self.now, jobs=tuple(jobs),
                                backlog=backlog)

    def inject(self, jobs) -> List[int]:
        from .simulate import _normalize_entries
        self._started = True
        idxs = []
        for platform, plan, cfg in _normalize_entries(jobs):
            if not self.sub.compatible(Substrate.of(platform)):
                raise ValueError(
                    f"platform {platform.name!r} is not a view of "
                    f"substrate {self.sub.name!r} — build job platforms "
                    "with Substrate.view()"
                )
            idxs.append(self._admit(platform, plan, cfg))
        self._release_due()
        return idxs

    def swap_plan(self, idx: int, plan: ExecutionPlan) -> None:
        """Re-split job ``idx``'s remaining fluid per the new plan: each
        source's remaining push volume follows the new ``x`` row, the
        per-mapper shuffle volume (in transit + held pool) the new
        ``y``.  Landed buffers are location-bound and stay."""
        g = self.runs[idx]
        if plan.x.shape != g.plan.x.shape or plan.y.shape != g.plan.y.shape:
            raise ValueError(
                f"plan shapes {plan.x.shape}/{plan.y.shape} do not match "
                f"job {idx}'s {g.plan.x.shape}/{g.plan.y.shape}"
            )
        self._started = True
        if not g.seeded:
            g.plan = plan
            return
        self._writeback()
        gi = g.idx
        x = np.asarray(plan.x, dtype=np.float64)
        y = np.asarray(plan.y, dtype=np.float64)
        resid = np.zeros(self.nS)
        for i, _, rem in g.push_spec:
            resid[i] += rem
        new_push: List[List[float]] = []
        for i in np.flatnonzero(resid > _EPS):
            row = x[i] if x[i].sum() > 1e-9 else np.full(self.nM,
                                                         1.0 / self.nM)
            for j in np.flatnonzero(row > 1e-9):
                vol = float(resid[i] * row[j] / row.sum())
                if vol > _EPS:
                    new_push.append([int(i), int(j), vol])
                    self._st_push.touch(int(i) * self.nM + int(j), gi)
                    self._st_map.touch(int(j), gi)
        pool_j = np.zeros(self.nM)
        for j, _, _, rem in g.shuf_spec:
            pool_j[j] += rem
        dests = sorted(
            {int(j) for _, j, _ in new_push}
            | {int(j) for j in np.flatnonzero(
                pool_j + self._at_map[gi] + self._gated_map[gi]
                + self._pool[gi] > _EPS)}
        )
        ky = np.flatnonzero(y > 1e-9)
        ysum = float(y[ky].sum())
        new_shuf: List[List[float]] = []
        for j in dests:
            for k in ky:
                new_shuf.append([int(j), int(k), float(y[k] / ysum),
                                 float(pool_j[j] * y[k] / ysum)])
                self._st_shuf.touch(int(j) * self.nR + int(k), gi)
                self._st_red.touch(int(k), gi)
        g.push_spec = new_push
        g.shuf_spec = new_shuf
        g.plan = plan
        # a swap can only *relax* what a gate waits on; recompute at the
        # next settle (gates never re-close: opened state persists)
        self._rebuild()
        self._settle()

    # -- residual pricing --------------------------------------------------
    def _seed_residual(self, g: _FluidJob, prog: JobProgress) -> None:
        """Seed job ``g`` from a :class:`JobProgress` residual instead of
        its full ``D``: the re-routable buckets follow the job's (current)
        plan exactly like :func:`repro.core.makespan.residual_volumes`
        routes them, committed transfers enter on the lanes they are
        already on, and delivered buffers preload the tier buffers —
        gated when the barrier in force would still hold them, so
        ``_settle`` releases them the instant the gate condition holds."""
        gi = g.idx
        nM, nR = self.nM, self.nR
        if prog.done or prog.remaining_mb()["reduce"] <= 1e-9:
            g.seeded = True
            g.done = True
            g._push_done = g._map_done = g._shuffle_done = True
            g.push_end = g.map_end = g.shuffle_end = self.now
            g.reduce_end = self.now
            self._released[gi] = True
            self._rebuild()
            return
        x, y = _live_plan_arrays(prog, g.plan)
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        resid = np.asarray(prog.resid_push, dtype=np.float64)
        comm_p = np.asarray(prog.committed_push, dtype=np.float64)
        push: Dict[Tuple[int, int], float] = {}
        for i in np.flatnonzero(resid > _EPS):
            row = x[i] if float(x[i].sum()) > 1e-9 \
                else np.full(nM, 1.0 / nM)
            for j in np.flatnonzero(row > 1e-9):
                vol = float(resid[i] * row[j] / row.sum())
                if vol > _EPS:
                    key = (int(i), int(j))
                    push[key] = push.get(key, 0.0) + vol
        for i, j in zip(*np.nonzero(comm_p > _EPS)):
            key = (int(i), int(j))
            push[key] = push.get(key, 0.0) + float(comm_p[i, j])
        g.push_spec = [[i, j, vol] for (i, j), vol in sorted(push.items())]
        for i, j, _ in g.push_spec:
            self._st_push.touch(i * nM + j, gi)
            self._st_map.touch(j, gi)
        at_m = np.asarray(prog.at_mapper, dtype=np.float64)
        pool = np.asarray(prog.shuffle_pool, dtype=np.float64)
        comm_s = np.asarray(prog.committed_shuffle, dtype=np.float64)
        at_r = np.asarray(prog.at_reducer, dtype=np.float64)
        dests = sorted(
            {j for _, j, _ in g.push_spec}
            | set(np.flatnonzero(at_m > _EPS).tolist())
            | set(np.flatnonzero(pool > _EPS).tolist())
        )
        ky = np.flatnonzero(y > 1e-9)
        ysum = float(y[ky].sum()) or 1.0
        flows: Dict[Tuple[int, int], List[float]] = {}
        for j in dests:
            for k in ky:
                flows[(int(j), int(k))] = [float(y[k] / ysum), 0.0]
        for j, k in zip(*np.nonzero(comm_s > _EPS)):
            f = flows.setdefault((int(j), int(k)), [0.0, 0.0])
            f[1] += float(comm_s[j, k])
        b0, b1, b2 = g.cfg.barriers
        if b1 == "P":
            # no emission gate: pooled map output is queued sends, not
            # held volume — route it into the flows by the (live) y now
            for j in np.flatnonzero(pool > _EPS):
                for k in ky:
                    flows[(int(j), int(k))][1] += \
                        float(pool[j] * y[k] / ysum)
        else:
            self._pool[gi] = pool
        g.shuf_spec = [[j, k, share, rem]
                       for (j, k), (share, rem) in sorted(flows.items())]
        for j, k, _, _ in g.shuf_spec:
            self._st_shuf.touch(j * nR + k, gi)
            self._st_red.touch(k, gi)
        if b0 == "P":
            self._at_map[gi] = at_m
        else:
            self._gated_map[gi] = at_m
        if b2 == "P":
            self._at_red[gi] = at_r
        else:
            self._gated_red[gi] = at_r
        g.seeded = True
        self._released[gi] = True
        self._prio[gi] = self._seed_seq
        self._seed_seq += 1
        self._open_map[gi] = b0 == "P"
        self._open_em[gi] = b1 == "P"
        self._open_red[gi] = b2 == "P"
        self._rebuild()


def fluid_score_residual(
    substrate: Substrate,
    entries: Sequence[Tuple[Platform, ExecutionPlan, SimConfig,
                            JobProgress]],
    now: float = 0.0,
) -> List[float]:
    """Fluid-rollout residual pricing: per-job modeled remaining seconds
    of ``entries`` (``(platform, plan, cfg, progress)`` per live job)
    under a shared-capacity **fluid** execution from ``now`` — the
    ``OnlineConfig(candidate_pricing="fluid")`` counterpart of
    :func:`repro.core.optimize.score_residual_shared`.

    The rollout seeds one :class:`FluidSim` from the residual buckets
    (re-routable volume split by each job's plan, committed transfers on
    their lanes, landed buffers behind the barriers still holding them)
    and drains it to completion in float64, folding any remaining
    :class:`~repro.core.platform.CapacityTrace` drift of ``substrate``
    into the horizon — so unlike the closed-form model it prices a
    candidate against the capacities it will *actually* see.  Both the
    incumbent and the candidate stack are priced by the same rollout, so
    a gate that adopts only on a strict fluid improvement keeps the
    never-priced-worse guarantee.

    Chunk-granular dynamics (speculation, stealing, failures, compute
    noise, replication) are stripped from the pricing configs — the
    rollout is a flow relaxation; per-job dead reducers are still routed
    around via the live-``y`` mask, like the closed-form path."""
    sim = FluidSim(substrate, [])
    sim.now = float(now)
    sim._started = True
    # consume drift steps already behind the observation instant and
    # fold the capacities in force at `now`
    while sim._drift_i < len(sim._drift) \
            and sim._drift[sim._drift_i] <= sim.now + 1e-9:
        sim._drift_i += 1
    sub_t = substrate.at(sim.now)
    sim._B_sm = np.asarray(sub_t.B_sm, dtype=np.float64)
    sim._B_mr = np.asarray(sub_t.B_mr, dtype=np.float64)
    sim._C_m = np.asarray(sub_t.C_m, dtype=np.float64)
    sim._C_r = np.asarray(sub_t.C_r, dtype=np.float64)
    sim._st_push.cap = sim._B_sm.reshape(-1)
    sim._st_shuf.cap = sim._B_mr.reshape(-1)
    sim._st_map.cap = sim._C_m.reshape(-1)
    sim._st_red.cap = sim._C_r.reshape(-1)
    for platform, plan, cfg, prog in entries:
        pricing_cfg = dataclasses.replace(
            cfg, mode="fluid", speculation=False, stealing=False,
            failures=(), compute_noise=0.0, replication=1, audit=False,
            start_time=float(now),
        )
        gi = sim._admit(platform, plan, pricing_cfg)
        sim._seed_residual(sim.runs[gi], prog)
    # open every gate whose condition already holds before the first
    # rate computation (e.g. push long done behind an L/G barrier)
    sim._settle()
    sim._drain(None)
    return [max(g.reduce_end - float(now), 0.0) for g in sim.runs]
