"""Scale-tier substrate generation: 3-tier edge -> region -> backbone
topologies at 10^2-10^3 nodes, plus a seeded job-mix generator.

Every scenario the repo inherited from the paper is paper-sized (~8
nodes).  The geo-distributed MapReduce surveys motivate a different
shape for production claims: *many* weak edge sites feeding *regional*
datacenters over heterogeneous uplinks, with a small *backbone* tier
holding the reduce capacity.  This module generates such substrates
deterministically from a seed:

* **edge tier** — the sources.  Each edge node lives in a region and
  owns a log-uniform uplink; pushing inside its own region rides the
  region LAN, pushing across regions is capped by the thinner of the
  two regions' WAN uplinks.
* **region tier** — the mappers.  Each region holds a pool of map
  workers with heterogeneous compute rates and one WAN uplink toward
  the backbone.
* **backbone tier** — the reducers.  A few well-provisioned sites; the
  mapper->reducer capacity is the min of the region uplink and the
  backbone site's ingress, with per-pair jitter so no two paths tie.

All capacities are drawn log-uniformly (heterogeneity is the point:
uniform capacities produce the simultaneous-completion event storms a
scale-tier benchmark must *not* accidentally dodge, and exact float
ties that would race the executor's tie-break).

:func:`scale_job_mix` generates the matching workload: jobs with
region-local data footprints, sparse heuristic plans (each source
pushes over its best few links, shuffle lands on the best few
reducers), staggered release times and per-job alpha — directly
consumable by :func:`repro.core.simulate.simulate_schedule` or the
fluid engine.  Both generators are pure functions of their seed.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from .plan import ExecutionPlan
from .platform import Platform, Substrate
from .simulate import SimConfig

__all__ = ["scale_job_mix", "scale_tier_substrate"]


def _log_uniform(rng: np.random.Generator, lo_hi: Tuple[float, float],
                 size) -> np.ndarray:
    lo, hi = float(lo_hi[0]), float(lo_hi[1])
    if not (0 < lo <= hi):
        raise ValueError(f"need 0 < lo <= hi, got ({lo}, {hi})")
    return np.exp(rng.uniform(np.log(lo), np.log(hi), size=size))


def scale_tier_substrate(
    n_regions: int = 4,
    edges_per_region: int = 12,
    mappers_per_region: int = 8,
    n_backbone: int = 2,
    reducers_per_backbone: int = 6,
    seed: int = 0,
    edge_up_mbps: Tuple[float, float] = (2.0, 20.0),
    lan_mbps: Tuple[float, float] = (60.0, 200.0),
    region_wan_mbps: Tuple[float, float] = (8.0, 48.0),
    backbone_mbps: Tuple[float, float] = (60.0, 240.0),
    map_rate: Tuple[float, float] = (20.0, 90.0),
    reduce_rate: Tuple[float, float] = (30.0, 120.0),
    name: Optional[str] = None,
) -> Substrate:
    """Generate a 3-tier substrate: ``n_regions * edges_per_region``
    sources, ``n_regions * mappers_per_region`` mappers and
    ``n_backbone * reducers_per_backbone`` reducers.

    Path capacities compose hierarchically: an edge->mapper path is
    ``min(edge uplink, region LAN)`` inside one region and
    ``min(edge uplink, both regions' WAN uplinks)`` across regions; a
    mapper->reducer path is ``min(region WAN uplink, backbone ingress)``
    with per-pair log-uniform jitter.  Deterministic in ``seed``.
    """
    if min(n_regions, edges_per_region, mappers_per_region,
           n_backbone, reducers_per_backbone) < 1:
        raise ValueError("every tier needs at least one node")
    rng = np.random.default_rng(seed)
    nS = n_regions * edges_per_region
    nM = n_regions * mappers_per_region
    nR = n_backbone * reducers_per_backbone

    region_s = np.repeat(np.arange(n_regions), edges_per_region)
    region_m = np.repeat(np.arange(n_regions), mappers_per_region)
    site_r = np.repeat(np.arange(n_backbone), reducers_per_backbone)

    edge_up = _log_uniform(rng, edge_up_mbps, nS)
    lan = _log_uniform(rng, lan_mbps, n_regions)
    region_wan = _log_uniform(rng, region_wan_mbps, n_regions)
    backbone_in = _log_uniform(rng, backbone_mbps, n_backbone)

    # edge -> mapper: LAN inside the region, min of both WAN uplinks across
    same = region_s[:, None] == region_m[None, :]
    inter = np.minimum(region_wan[region_s][:, None],
                       region_wan[region_m][None, :])
    path = np.where(same, lan[region_m][None, :], inter)
    B_sm = np.minimum(edge_up[:, None], path)
    B_sm = B_sm * _log_uniform(rng, (0.85, 1.18), (nS, nM))

    # mapper -> reducer: region WAN uplink capped by backbone ingress
    B_mr = np.minimum(region_wan[region_m][:, None],
                      backbone_in[site_r][None, :])
    B_mr = B_mr * _log_uniform(rng, (0.85, 1.18), (nM, nR))

    C_m = _log_uniform(rng, map_rate, nM)
    C_r = _log_uniform(rng, reduce_rate, nR)

    return Substrate(
        B_sm=B_sm, B_mr=B_mr, C_m=C_m, C_r=C_r,
        cluster_s=region_s, cluster_m=region_m,
        # backbone cluster ids offset past the regions so a reducer is
        # never mistaken for region-local by cluster-id comparisons
        cluster_r=site_r + n_regions,
        name=name or (
            f"scale[{n_regions}x{edges_per_region}e"
            f"+{n_regions}x{mappers_per_region}m"
            f"+{n_backbone}x{reducers_per_backbone}r seed={seed}]"
        ),
    )


def scale_job_mix(
    substrate: Substrate,
    n_jobs: int = 100,
    seed: int = 0,
    mb_per_job: Tuple[float, float] = (1500.0, 12000.0),
    sources_per_job: int = 3,
    push_fan: int = 2,
    reduce_fan: int = 3,
    alpha_range: Tuple[float, float] = (0.6, 1.4),
    arrival_spread_s: float = 0.0,
    base_cfg: Optional[SimConfig] = None,
) -> List[Tuple[Platform, ExecutionPlan, SimConfig]]:
    """Generate ``n_jobs`` jobs on ``substrate``: each picks a home
    region, places a log-uniform data footprint on a few of that
    region's edge nodes, and gets a *sparse* heuristic plan (every
    active source spreads over its ``push_fan`` best links,
    bandwidth-weighted; shuffle lands on the ``reduce_fan`` best
    reducers as seen from the chosen mappers, capacity-weighted).

    Returns ``(platform_view, plan, cfg)`` entries ready for
    :func:`repro.core.simulate.simulate_schedule`.  ``base_cfg`` seeds
    each job's :class:`SimConfig` (barriers, chunking, mode flags);
    release times are staggered uniformly over ``arrival_spread_s``.
    Deterministic in ``seed``.
    """
    if n_jobs < 1:
        raise ValueError(f"n_jobs must be >= 1, got {n_jobs}")
    rng = np.random.default_rng(seed)
    cfg0 = base_cfg if base_cfg is not None else SimConfig()
    nS, nM, nR = substrate.nS, substrate.nM, substrate.nR
    B_sm = np.asarray(substrate.B_sm, dtype=np.float64)
    B_mr = np.asarray(substrate.B_mr, dtype=np.float64)
    C_r = np.asarray(substrate.C_r, dtype=np.float64)
    regions = np.asarray(substrate.cluster_s)
    region_ids = np.unique(regions)

    entries: List[Tuple[Platform, ExecutionPlan, SimConfig]] = []
    for n in range(n_jobs):
        home = int(rng.choice(region_ids))
        local = np.flatnonzero(regions == home)
        k_src = min(sources_per_job, local.size)
        srcs = np.sort(rng.choice(local, size=k_src, replace=False))

        total = float(_log_uniform(rng, mb_per_job, ()))
        split = rng.dirichlet(np.full(k_src, 3.0))
        D = np.zeros(nS)
        D[srcs] = total * split

        # push: each source spreads over its best few links, weighted by
        # bandwidth; inactive sources get a one-hot row (zero volume, but
        # Eq. 2 requires every row on the simplex)
        x = np.zeros((nS, nM))
        best = np.argmax(B_sm, axis=1)
        x[np.arange(nS), best] = 1.0
        used_mappers: set = set()
        for i in srcs:
            fan = min(push_fan, nM)
            top = np.argsort(B_sm[i])[::-1][:fan]
            w = B_sm[i, top]
            x[i] = 0.0
            x[i, top] = w / w.sum()
            used_mappers.update(int(j) for j in top)

        # shuffle: the best few reducers as seen from the mappers this job
        # actually uses, weighted by reduce capacity
        fan_r = min(reduce_fan, nR)
        mlist = sorted(used_mappers)
        reach = B_mr[mlist].mean(axis=0)
        top_r = np.argsort(reach * C_r)[::-1][:fan_r]
        y = np.zeros(nR)
        y[top_r] = C_r[top_r] / C_r[top_r].sum()

        alpha = float(rng.uniform(*alpha_range))
        start = float(rng.uniform(0.0, arrival_spread_s)) \
            if arrival_spread_s > 0 else 0.0
        cfg = dataclasses.replace(cfg0, start_time=start, seed=seed + n)
        platform = substrate.view(D, alpha, name=f"scale-job{n}")
        plan = ExecutionPlan(x=x, y=y, meta=f"scale_mix[{n}]")
        entries.append((platform, plan, cfg))
    return entries
