"""Execution plans (paper §2.2): push fractions ``x_ij`` and shuffle
fractions ``y_k``.

A valid plan satisfies Equations 1–3 of the paper:

  (1) 0 ≤ x_ij ≤ 1
  (2) each node's outgoing fractions sum to 1
  (3) one-reducer-per-key: every mapper uses the same shuffle row,
      ``x_jk = y_k`` — so the shuffle side of a plan is a single simplex
      vector ``y`` of length nR.

Plans here are *dense* (every source may talk to every mapper); heuristic
constructors give the paper's baselines (uniform, local push).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..analysis.validate import validate_plan_arrays
from .platform import Platform

__all__ = ["ExecutionPlan", "uniform_plan", "local_push_plan", "validate_plan"]

_ATOL = 1e-6


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """A valid execution plan: ``x[i, j]`` push fractions, ``y[k]`` shuffle
    fractions (shared across mappers per the one-reducer-per-key constraint).
    """

    x: np.ndarray  # (nS, nM)
    y: np.ndarray  # (nR,)
    meta: str = ""

    def __post_init__(self):
        object.__setattr__(self, "x", np.asarray(self.x, dtype=np.float64))
        object.__setattr__(self, "y", np.asarray(self.y, dtype=np.float64))
        validate_plan(self.x, self.y)

    @property
    def nS(self) -> int:
        return self.x.shape[0]

    @property
    def nM(self) -> int:
        return self.x.shape[1]

    @property
    def nR(self) -> int:
        return self.y.shape[0]

    @classmethod
    def renormalized(cls, x, y, meta: str = "") -> "ExecutionPlan":
        """Build a plan from near-simplex candidates (e.g. float32 softmax
        output of the annealed solvers): clip negatives and renormalize the
        rows of ``x`` and ``y`` in float64 so the plan validates exactly."""
        x = np.clip(np.asarray(x, dtype=np.float64), 0.0, None)
        x = x / x.sum(axis=1, keepdims=True)
        y = np.clip(np.asarray(y, dtype=np.float64), 0.0, None)
        y = y / y.sum()
        return cls(x=x, y=y, meta=meta)

    def x_mr(self) -> np.ndarray:
        """The full (nM, nR) shuffle matrix implied by Equation 3."""
        return np.broadcast_to(self.y[None, :], (self.nM, self.nR)).copy()

    def map_load(self, platform: Platform) -> np.ndarray:
        """MB of input data arriving at each mapper."""
        return self.x.T @ platform.D

    def reduce_load(self, platform: Platform) -> np.ndarray:
        """MB of intermediate data arriving at each reducer."""
        return platform.alpha * float(self.map_load(platform).sum()) * self.y


def validate_plan(x: np.ndarray, y: np.ndarray, atol: float = _ATOL) -> None:
    """Equations 1–3 plus finiteness — the shared structural checker in
    :mod:`repro.analysis.validate`, which names the offending entries."""
    validate_plan_arrays(x, y, atol=atol)


def uniform_plan(platform: Platform) -> ExecutionPlan:
    """Uniform data placement (paper Equations 15/16)."""
    x = np.full((platform.nS, platform.nM), 1.0 / platform.nM)
    y = np.full(platform.nR, 1.0 / platform.nR)
    return ExecutionPlan(x=x, y=y, meta="uniform")


def local_push_plan(
    platform: Platform, y: Optional[np.ndarray] = None
) -> ExecutionPlan:
    """Each source pushes all data to mappers in its own cluster (uniformly
    across them); shuffle defaults to uniform.  This is Hadoop's
    data-locality baseline generalized to the wide area (paper §4.6.1).
    """
    x = np.zeros((platform.nS, platform.nM))
    for i in range(platform.nS):
        local = np.flatnonzero(platform.cluster_m == platform.cluster_s[i])
        if local.size == 0:  # no local mapper: fall back to best link
            local = np.array([int(np.argmax(platform.B_sm[i]))])
        x[i, local] = 1.0 / local.size
    if y is None:
        y = np.full(platform.nR, 1.0 / platform.nR)
    return ExecutionPlan(x=x, y=np.asarray(y), meta="local_push")
