"""Model-driven execution-plan optimization (paper §2.3 and §4).

The paper linearizes the makespan model into a Mixed Integer Program and
solves it with Gurobi.  An MIP solver is neither available here nor
JAX-idiomatic, so we keep the paper's *model* exactly (Equations 1–14) and
replace the *solver*:

* validity (Equations 1–3) holds **by construction** — plans are parametrized
  by row-softmax logits for ``x`` and softmax logits for ``y``;
* every ``max`` is annealed through ``tau·logsumexp(·/tau)`` with the
  temperature ``tau`` geometrically decayed inside a single compiled
  ``lax.scan`` loop (so gradients reach every branch early and the objective
  converges to the exact piecewise model late);
* we run many Adam restarts in parallel with ``vmap`` (random inits plus the
  paper's heuristic plans as warm starts), then re-evaluate every candidate
  under the **exact hard-max** model and keep the best.

On small instances this is validated against brute-force grid search
(``brute_force_plan``) and against the separable-programming linearization of
the paper (:mod:`repro.core.milp`); on the paper's scenarios it reproduces
the §1.3 worked example exactly and the headline §4.2/§4.3 reductions.

Planner modes (mirroring the paper's §4 comparisons):

* ``uniform``        — Equations 15/16, no optimization.
* ``local_push``     — Hadoop-like locality push + uniform shuffle.
* ``myopic_push``    — minimize *push duration* only (locally optimal).
* ``myopic_multi``   — myopic push, then myopic shuffle given that push.
* ``e2e_push``       — minimize end-to-end makespan controlling ``x`` only.
* ``e2e_shuffle``    — minimize makespan controlling ``y`` only.
* ``e2e_multi``      — the paper's proposed optimization: makespan over both.

New strategies plug in through the **planner registry** without editing the
solver: ``register_planner(name)`` decorates a function
``(platform, barriers, *, n_restarts, steps, seed, fixed_x) -> (plan, objective)``
and :func:`optimize_plan` (and the :class:`repro.api.GeoJob` facade) will
dispatch to it by name.
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .makespan import (
    BARRIERS_ALL_GLOBAL,
    CostModel,
    JobProgress,
    _live_plan_arrays,
    _np_hard_ops,
    analytic_volumes,
    attribute_phases,
    hard_ops,
    makespan,
    phase_breakdown,
    residual_volumes,
    shared_effective_volumes,
    smooth_ops,
    phase_model,
    volume_model,
)
from .pipeline import PipelineSpec
from .plan import ExecutionPlan, local_push_plan, uniform_plan
from .platform import Platform, Substrate

__all__ = [
    "MODES",
    "SCHEDULE_OBJECTIVES",
    "OnlineConfig",
    "PipelinePlanResult",
    "PlanResult",
    "SchedulePlanResult",
    "ScheduleReplanResult",
    "available_modes",
    "available_online_policies",
    "available_pipeline_modes",
    "available_policies",
    "brute_force_plan",
    "get_online_config",
    "get_online_policy",
    "get_pipeline_planner",
    "get_planner",
    "get_schedule_planner",
    "optimize_pipeline",
    "optimize_plan",
    "optimize_plan_batch",
    "optimize_schedule",
    "register_online_policy",
    "register_pipeline_planner",
    "register_planner",
    "register_schedule_planner",
    "replan",
    "replan_batch",
    "replan_schedule",
    "reset_solver_cache_stats",
    "score_residual_shared",
    "solver_cache_stats",
    "swap_charge",
    "SolveTimeEMA",
    "SolverService",
]

#: The paper's built-in planner modes (kept as a tuple for backwards
#: compatibility; the live set is :func:`available_modes`).
MODES = (
    "uniform",
    "local_push",
    "myopic_push",
    "myopic_multi",
    "e2e_push",
    "e2e_shuffle",
    "e2e_multi",
)

# ---------------------------------------------------------------------------
# planner registry
# ---------------------------------------------------------------------------

#: name -> fn(platform, barriers, *, n_restarts, steps, seed, fixed_x)
#:         -> (ExecutionPlan, objective)
_PLANNERS: Dict[str, Callable] = {}


def register_planner(name: str, fn: Optional[Callable] = None):
    """Register a planning strategy under ``name``.

    Usable as a decorator (``@register_planner("my_mode")``) or a direct
    call.  A registered planner takes ``(platform, barriers, *, n_restarts,
    steps, seed, fixed_x)`` and returns ``(plan, objective)`` where
    ``objective`` is the value of the strategy's own loss (== the makespan
    for end-to-end strategies).  Registered names are immediately usable in
    :func:`optimize_plan` and :meth:`repro.api.GeoJob.plan`.
    """
    if fn is None:
        return lambda f: register_planner(name, f)
    if name in _PLANNERS:
        raise ValueError(f"planner {name!r} is already registered")
    _PLANNERS[name] = fn
    return fn


def get_planner(name: str) -> Callable:
    """Look up a registered planner; raises ``ValueError`` for unknown names."""
    try:
        return _PLANNERS[name]
    except KeyError:
        raise ValueError(
            f"mode must be one of {available_modes()}, got {name!r}"
        ) from None


def available_modes() -> Tuple[str, ...]:
    """Names of every registered planner, built-in and user-added."""
    return tuple(_PLANNERS)


@dataclasses.dataclass(frozen=True)
class PlanResult:
    plan: ExecutionPlan
    makespan: float
    breakdown: Dict[str, float]
    mode: str
    barriers: Tuple[str, str, str]
    objective: float  # value of the mode's own objective (== makespan for e2e)

    def __repr__(self):
        return (
            f"PlanResult(mode={self.mode}, barriers={''.join(self.barriers)}, "
            f"makespan={self.makespan:.1f}s)"
        )


# ---------------------------------------------------------------------------
# objectives
# ---------------------------------------------------------------------------

def _push_duration(D, B_sm, x, mx):
    return mx((D[:, None] * x) / B_sm)


def _shuffle_duration(D, B_mr, alpha, x, y, mx):
    map_in = x.T @ D
    return mx(alpha * (map_in[:, None] * y[None, :]) / B_mr)


def _objective_fn(mode: str, barriers) -> Callable:
    """Return loss(arrays, x, y, mx, pmax) -> scalar for the given mode."""

    def e2e(arrs, x, y, mx, pmax):
        D, B_sm, B_mr, C_m, C_r, alpha = arrs
        out = phase_model(D, B_sm, B_mr, C_m, C_r, alpha, x, y, barriers, mx, pmax)
        return out["makespan"]

    def push(arrs, x, y, mx, pmax):
        D, B_sm, _, _, _, _ = arrs
        return _push_duration(D, B_sm, x, mx)

    def shuffle(arrs, x, y, mx, pmax):
        D, _, B_mr, _, _, alpha = arrs
        return _shuffle_duration(D, B_mr, alpha, x, y, mx)

    return {"e2e": e2e, "push": push, "shuffle": shuffle}[mode]


# ---------------------------------------------------------------------------
# the annealed multi-restart solver
# ---------------------------------------------------------------------------

def _adam_anneal(loss, params0, steps: int, scale, lr, tau0_frac, tau1_frac):
    """The one annealed-Adam loop every solver here shares: minimize
    ``loss(params, tau)`` for ``steps`` iterations with the smoothing
    temperature ``tau`` geometrically decayed from ``scale*tau0_frac`` to
    ``scale*tau1_frac`` inside a single ``lax.scan``.  Pure JAX — callers
    invoke it inside their own jitted bodies, so each solver keeps its own
    compilation cache entry."""
    m0 = jax.tree.map(jnp.zeros_like, params0)
    v0 = jax.tree.map(jnp.zeros_like, params0)

    def step(carry, t):
        params, m, v = carry
        frac = t / max(steps - 1, 1)
        tau = scale * tau0_frac * (tau1_frac / tau0_frac) ** frac
        g = jax.grad(loss)(params, tau)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t1 = t + 1.0
        mhat = jax.tree.map(lambda a: a / (1 - b1**t1), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2**t1), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
            params, mhat, vhat,
        )
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(
        step, (params0, m0, v0), jnp.arange(steps, dtype=jnp.float32)
    )
    return params


# ---------------------------------------------------------------------------
# solver service plumbing: shape-keyed executable cache with hit/miss/compile
# counters
# ---------------------------------------------------------------------------

#: cumulative counters over every compiled-solver call in this process;
#: read with :func:`solver_cache_stats`, zero with
#: :func:`reset_solver_cache_stats`.  The executable cache itself is
#: jit's own (module-level, so it survives across GeoSchedule /
#: SolverService instances); these counters make it observable.
_SOLVER_STATS = {"calls": 0, "hits": 0, "misses": 0, "compiles": 0}
_SOLVER_KEYS: set = set()


def _abstract_leaf(leaf):
    """A leaf's contribution to the executable cache key: arrays key by
    shape+dtype only; bare Python scalars are weak-typed traced values
    under jit, so their *type* keys the executable and their value does
    not (this is what lets the incremental mode reuse the full-anneal
    executable when only ``lr``/``tau`` change)."""
    if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
        return ("arr", tuple(leaf.shape), str(leaf.dtype))
    return ("weak", type(leaf).__name__)


def _counted_solver(static_argnames: Tuple[str, ...] = ()):
    """``jax.jit`` plus cache accounting: wraps a solver kernel so every
    call is classified as a hit (an executable keyed by the same
    shapes/dtypes + static values was requested before) or a miss, and
    true XLA compiles are counted via the jitted function's own cache
    size.  The counters feed the cache-semantics tests, the
    ``bench_planner`` provenance, and the warm/cold split of the measured
    solver-cost EMA (:class:`SolveTimeEMA`)."""
    statics = tuple(static_argnames)

    def deco(fn):
        jitted = jax.jit(fn, static_argnames=statics)
        sig = inspect.signature(fn)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            bound = sig.bind(*args, **kwargs)
            bound.apply_defaults()
            parts: list = [fn.__name__]
            for pname, val in bound.arguments.items():
                if pname in statics:
                    parts.append((pname, "static", val))
                else:
                    parts.append((pname, tuple(
                        _abstract_leaf(leaf) for leaf in jax.tree.leaves(val)
                    )))
            key = tuple(parts)
            _SOLVER_STATS["calls"] += 1
            if key in _SOLVER_KEYS:
                _SOLVER_STATS["hits"] += 1
            else:
                _SOLVER_STATS["misses"] += 1
                _SOLVER_KEYS.add(key)
            size_fn = getattr(jitted, "_cache_size", None)
            before = size_fn() if callable(size_fn) else None
            out = jitted(*args, **kwargs)
            if before is not None:
                compiled = size_fn() > before
            else:  # pragma: no cover — jax without _cache_size()
                compiled = key not in _SOLVER_KEYS
            if compiled:
                _SOLVER_STATS["compiles"] += 1
            return out

        wrapper._jitted = jitted
        return wrapper

    return deco


#: entry count past which :func:`solver_cache_occupancy` raises its
#: growth warning.  The executable cache is jit's own and has NO
#: eviction: every distinct (kernel, shapes, statics) key compiled stays
#: resident for the life of the process.  Paper-sized runs sit in the
#: low tens; a scale-tier shape population past this bound usually means
#: a caller is leaking shapes (e.g. ragged batch sizes) rather than
#: reusing them.
CACHE_GROWTH_WARN_ENTRIES = 256


def _occupancy_label(key) -> str:
    """Human-readable shape group of one cache key: the kernel name plus
    its array-leaf shapes (statics and weak scalars don't change the
    memory profile, so they are folded out of the label)."""
    dims = []
    for part in key[1:]:
        if len(part) == 2:  # (pname, leaf_abstracts)
            for leaf in part[1]:
                if leaf[0] == "arr":
                    dims.append("x".join(map(str, leaf[1])) or "()")
    return f"{key[0]}[{';'.join(dims)}]" if dims else str(key[0])


def solver_cache_occupancy() -> Dict[str, object]:
    """Per-shape occupancy of the eviction-free executable cache:
    ``entries`` (total keys), ``by_shape`` (entry count per kernel+shape
    group — the scale tier's larger shape population made this worth
    watching), and ``growth_warning`` (a message once ``entries``
    crosses :data:`CACHE_GROWTH_WARN_ENTRIES`, else ``None``)."""
    by_shape: Dict[str, int] = {}
    for key in _SOLVER_KEYS:
        label = _occupancy_label(key)
        by_shape[label] = by_shape.get(label, 0) + 1
    entries = len(_SOLVER_KEYS)
    warning = None
    if entries >= CACHE_GROWTH_WARN_ENTRIES:
        warning = (
            f"solver executable cache holds {entries} entries across "
            f"{len(by_shape)} shape groups and never evicts — check for "
            "shape churn (ragged batch sizes, per-call static values)"
        )
    return {"entries": entries, "by_shape": by_shape,
            "growth_warning": warning}


def solver_cache_stats() -> Dict[str, int]:
    """Cumulative solver-executable cache counters for this process:
    ``calls`` (compiled-solver invocations), ``hits``/``misses`` (against
    the shape+static key), ``compiles`` (true XLA compilations —
    a re-trace of a known key, e.g. after a donated-buffer change, counts
    here but not as a miss), plus the cache population: ``entries``
    (distinct keys alive) and ``shapes`` (distinct kernel+shape groups —
    see :func:`solver_cache_occupancy` for the full breakdown)."""
    labels = {_occupancy_label(key) for key in _SOLVER_KEYS}
    return dict(_SOLVER_STATS, entries=len(_SOLVER_KEYS),
                shapes=len(labels))


def reset_solver_cache_stats() -> None:
    """Zero the counters (the compiled executables themselves stay
    cached — this resets accounting, not the cache)."""
    for k in _SOLVER_STATS:
        _SOLVER_STATS[k] = 0
    _SOLVER_KEYS.clear()


@_counted_solver(
    static_argnames=("loss_kind", "barriers", "opt_x", "opt_y", "steps")
)
def _solve_batch_many(
    arrs,  # 6-tuple of (B, ...) arrays: D, B_sm, B_mr, C_m, C_r, alpha
    logits_x0,  # (B, R, nS, nM)
    logits_y0,  # (B, R, nR)
    x_fixed,  # (B, nS, nM) used when opt_x=False
    y_fixed,  # (B, nR)     used when opt_y=False
    scale,  # (B,) — typical makespan per request, sets the tau units
    loss_kind: str,
    barriers: Tuple[str, str, str],
    opt_x: bool,
    opt_y: bool,
    steps: int,
    lr: float = 0.08,
    tau0_frac: float = 0.3,
    tau1_frac: float = 1e-3,
):
    """Run ``B`` independent solve requests × ``R`` Adam restarts of
    ``steps`` annealed iterations in **one** compiled dispatch (requests
    vmapped over restarts vmapped over the anneal); return per-request,
    per-restart final (x, y) plus their exact hard-model objectives."""
    loss_core = _objective_fn(loss_kind, barriers)

    def one_request(arrs_b, lx_b, ly_b, xf, yf, sc):
        def build(params):
            x = jax.nn.softmax(params["x"], axis=-1) if opt_x else xf
            y = jax.nn.softmax(params["y"], axis=-1) if opt_y else yf
            return x, y

        def loss(params, tau):
            mx, pmax = smooth_ops(tau)
            x, y = build(params)
            return loss_core(arrs_b, x, y, mx, pmax) / sc

        def one_restart(lx0, ly0):
            params = _adam_anneal(
                loss, {"x": lx0, "y": ly0}, steps, sc, lr, tau0_frac,
                tau1_frac,
            )
            x, y = build(params)
            mx, pmax = hard_ops()
            exact = loss_core(arrs_b, x, y, mx, pmax)
            return x, y, exact

        return jax.vmap(one_restart)(lx_b, ly_b)

    return jax.vmap(one_request)(
        arrs, logits_x0, logits_y0, x_fixed, y_fixed, scale
    )


def _initial_logits(platform: Platform, n_restarts: int, seed: int):
    """Random inits plus deterministic warm starts (uniform, local push,
    bandwidth-greedy)."""
    rng = np.random.default_rng(seed)
    nS, nM, nR = platform.nS, platform.nM, platform.nR
    eps = 1e-9

    warm_x = [
        np.zeros((nS, nM)),  # uniform
        np.log(local_push_plan(platform).x + eps),  # locality
        np.log(platform.B_sm / platform.B_sm.max() + eps),  # bandwidth-greedy
    ]
    warm_y = [
        np.zeros(nR),  # uniform
        np.log(platform.C_r / platform.C_r.max() + eps),  # compute-greedy
        np.log(np.mean(platform.B_mr, axis=0) / platform.B_mr.max() + eps),
    ]
    lx = list(warm_x)
    ly = list(warm_y)
    while len(lx) < n_restarts:
        sigma = rng.uniform(0.3, 3.0)
        lx.append(rng.normal(0.0, sigma, size=(nS, nM)))
        ly.append(rng.normal(0.0, sigma, size=(nR,)))
    lx = np.stack(lx[:n_restarts]).astype(np.float32)
    ly = np.stack(ly[:n_restarts]).astype(np.float32)
    return jnp.asarray(lx), jnp.asarray(ly)


def _run_solver_many(
    platforms: Sequence[Platform],
    loss_kind: str,
    barriers,
    opt_x: bool,
    opt_y: bool,
    x_fixed_list: Optional[Sequence[Optional[np.ndarray]]],
    y_fixed_list: Optional[Sequence[Optional[np.ndarray]]],
    n_restarts: int,
    steps: int,
    seeds: Sequence[int],
) -> "list[Tuple[np.ndarray, np.ndarray, float]]":
    """Solve ``B`` same-shape requests in one vmapped device dispatch.

    Every platform must share ``(nS, nM, nR)`` (callers group by shape —
    see :func:`optimize_plan_batch`); per-request ``D``/``alpha``/
    capacities/seeds are free.  Returns one ``(x, y, exact)`` per request,
    the best restart under the exact hard-max model, float64-renormalized.
    """
    B = len(platforms)
    raw = [p.as_arrays() for p in platforms]
    arrs = tuple(
        jnp.asarray(np.stack([np.asarray(r[i], dtype=np.float64)
                              for r in raw]), jnp.float32)
        for i in range(6)
    )
    if x_fixed_list is None:
        x_fixed_list = [None] * B
    if y_fixed_list is None:
        y_fixed_list = [None] * B
    xf = np.stack([
        uniform_plan(p).x if x is None else np.asarray(x)
        for p, x in zip(platforms, x_fixed_list)
    ])
    yf = np.stack([
        uniform_plan(p).y if y is None else np.asarray(y)
        for p, y in zip(platforms, y_fixed_list)
    ])
    scales = np.array([
        max(makespan(p, uniform_plan(p), barriers=barriers), 1e-6)
        for p in platforms
    ])
    inits = [_initial_logits(p, n_restarts, s)
             for p, s in zip(platforms, seeds)]
    xs, ys, exact = _solve_batch_many(
        arrs,
        jnp.stack([lx for lx, _ in inits]),
        jnp.stack([ly for _, ly in inits]),
        jnp.asarray(xf, jnp.float32),
        jnp.asarray(yf, jnp.float32),
        jnp.asarray(scales, jnp.float32),
        loss_kind,
        tuple(barriers),
        opt_x,
        opt_y,
        steps,
    )
    exact = np.asarray(exact)
    out = []
    for b in range(B):
        best = int(np.argmin(exact[b]))
        # renormalize against float32 round-off so the plan validates
        plan = ExecutionPlan.renormalized(np.asarray(xs[b, best]),
                                          np.asarray(ys[b, best]))
        out.append((plan.x, plan.y, float(exact[b, best])))
    return out


def _run_solver(
    platform: Platform,
    loss_kind: str,
    barriers,
    opt_x: bool,
    opt_y: bool,
    x_fixed: Optional[np.ndarray],
    y_fixed: Optional[np.ndarray],
    n_restarts: int,
    steps: int,
    seed: int,
) -> Tuple[np.ndarray, np.ndarray, float]:
    """One solve request — a batch of one through the vmapped service
    path, so single plans and batched plans share one executable cache."""
    return _run_solver_many(
        [platform], loss_kind, barriers, opt_x, opt_y,
        [x_fixed], [y_fixed], n_restarts, steps, [seed],
    )[0]


# ---------------------------------------------------------------------------
# built-in planners
# ---------------------------------------------------------------------------

@register_planner("uniform")
def _uniform_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    plan = uniform_plan(platform)
    return plan, makespan(platform, plan, barriers)


@register_planner("local_push")
def _local_push_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    plan = local_push_plan(platform)
    return plan, makespan(platform, plan, barriers)


@register_planner("myopic_push")
def _myopic_push_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    x, _, obj = _run_solver(
        platform, "push", barriers, True, False, None, None,
        n_restarts, steps, seed,
    )
    return ExecutionPlan(x=x, y=uniform_plan(platform).y, meta="myopic_push"), obj


@register_planner("myopic_multi")
def _myopic_multi_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    # locally-optimal push, then locally-optimal shuffle given that push
    x, _, _ = _run_solver(
        platform, "push", barriers, True, False, None, None,
        n_restarts, steps, seed,
    )
    _, y, obj = _run_solver(
        platform, "shuffle", barriers, False, True, x, None,
        n_restarts, steps, seed + 1,
    )
    return ExecutionPlan(x=x, y=y, meta="myopic_multi"), obj


@register_planner("e2e_push")
def _e2e_push_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    x, _, obj = _run_solver(
        platform, "e2e", barriers, True, False, None, None,
        n_restarts, steps, seed,
    )
    return ExecutionPlan(x=x, y=uniform_plan(platform).y, meta="e2e_push"), obj


@register_planner("e2e_shuffle")
def _e2e_shuffle_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    _, y, obj = _run_solver(
        platform, "e2e", barriers, False, True, fixed_x, None,
        n_restarts, steps, seed,
    )
    x = uniform_plan(platform).x if fixed_x is None else np.asarray(fixed_x)
    return ExecutionPlan(x=x, y=y, meta="e2e_shuffle"), obj


@register_planner("e2e_multi")
def _e2e_multi_planner(platform, barriers, *, n_restarts, steps, seed, fixed_x):
    x, y, obj = _run_solver(
        platform, "e2e", barriers, True, True, None, None,
        n_restarts, steps, seed,
    )
    return ExecutionPlan(x=x, y=y, meta="e2e_multi"), obj


# ---------------------------------------------------------------------------
# public entry point
# ---------------------------------------------------------------------------

def optimize_plan(
    platform: Platform,
    mode: str = "e2e_multi",
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 24,
    steps: int = 500,
    seed: int = 0,
    fixed_x: Optional[np.ndarray] = None,
) -> PlanResult:
    """Produce an execution plan for ``platform`` with the given planner
    ``mode`` (any name in :func:`available_modes`), evaluated under
    ``barriers``.

    ``fixed_x`` pins the push matrix for the shuffle-only modes
    (``e2e_shuffle``); defaults to the uniform push of Equation 15.  This is
    how the collective/MoE planners express "the push side is dictated by
    the system" (identity routing).
    """
    planner = get_planner(mode)
    barriers = tuple(barriers)
    plan, obj = planner(
        platform, barriers,
        n_restarts=n_restarts, steps=steps, seed=seed, fixed_x=fixed_x,
    )
    return PlanResult(
        plan=plan,
        makespan=makespan(platform, plan, barriers),
        breakdown=phase_breakdown(platform, plan, barriers),
        mode=mode,
        barriers=barriers,
        objective=float(obj),
    )


# ---------------------------------------------------------------------------
# planner-as-a-service: batched concurrent solve requests
# ---------------------------------------------------------------------------

#: built-in modes whose planner is exactly one `_run_solver` call —
#: batchable as (loss_kind, opt_x, opt_y).  ``myopic_multi`` (two chained
#: solves) is batched as two rounds; anything else falls back to a
#: per-request planner loop.
_BATCHED_SOLVES = {
    "myopic_push": ("push", True, False),
    "e2e_push": ("e2e", True, False),
    "e2e_shuffle": ("e2e", False, True),
    "e2e_multi": ("e2e", True, True),
}


def _plan_group(platforms, mode, barriers, n_restarts, steps, seeds,
                fixed_xs) -> "list[Tuple[ExecutionPlan, float]]":
    """Plan one same-shape group of requests, batching the solver
    dispatches where the mode allows; mirrors the built-in planners'
    construction exactly (same warm starts, seeds, and plan assembly)."""
    if mode == "myopic_multi":
        # locally-optimal push, then locally-optimal shuffle given that
        # push — two batched rounds, round 2 reseeded at seed+1 like the
        # sequential planner
        r1 = _run_solver_many(platforms, "push", barriers, True, False,
                              None, None, n_restarts, steps, seeds)
        xs = [x for x, _, _ in r1]
        r2 = _run_solver_many(platforms, "shuffle", barriers, False, True,
                              xs, None, n_restarts, steps,
                              [s + 1 for s in seeds])
        return [
            (ExecutionPlan(x=x, y=y, meta="myopic_multi"), obj)
            for x, (_, y, obj) in zip(xs, r2)
        ]
    if mode in _BATCHED_SOLVES:
        loss_kind, opt_x, opt_y = _BATCHED_SOLVES[mode]
        xf = fixed_xs if not opt_x else [None] * len(platforms)
        solved = _run_solver_many(platforms, loss_kind, barriers, opt_x,
                                  opt_y, xf, None, n_restarts, steps, seeds)
        plans = []
        for p, fx, (x, y, obj) in zip(platforms, fixed_xs, solved):
            if not opt_y:
                y = uniform_plan(p).y
            if not opt_x:
                x = uniform_plan(p).x if fx is None else np.asarray(fx)
            plans.append((ExecutionPlan(x=x, y=y, meta=mode), obj))
        return plans
    # heuristic or externally-registered mode: per-request dispatch
    planner = get_planner(mode)
    return [
        planner(p, barriers, n_restarts=n_restarts, steps=steps, seed=s,
                fixed_x=fx)
        for p, s, fx in zip(platforms, seeds, fixed_xs)
    ]


def optimize_plan_batch(
    platforms: Sequence[Platform],
    mode: str = "e2e_multi",
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 24,
    steps: int = 500,
    seed: int = 0,
    seeds: Optional[Sequence[int]] = None,
    fixed_x: Optional[Sequence[Optional[np.ndarray]]] = None,
) -> "list[PlanResult]":
    """Plan ``N`` independent jobs in as few compiled dispatches as their
    shapes allow — the batched front door of the solver service.

    Requests are grouped by ``(nS, nM, nR)``; each same-shape group of a
    solver-backed mode runs as **one** vmapped dispatch
    (:func:`_solve_batch_many`), sharing a single cached executable with
    every other same-shape solve in the process.  ``seeds`` gives one seed
    per request (default ``seed + 17*g``, matching what the
    ``independent`` schedule policy always used); ``fixed_x`` one pinned
    push matrix per request for the shuffle-only modes.  Results are
    per-request :class:`PlanResult`\\ s, identical (to float32 vmap
    round-off) to calling :func:`optimize_plan` per request.
    """
    platforms = list(platforms)
    barriers = tuple(barriers)
    if seeds is None:
        seeds = [seed + 17 * g for g in range(len(platforms))]
    seeds = list(seeds)
    if len(seeds) != len(platforms):
        raise ValueError(
            f"one seed per platform, got {len(seeds)} seeds for "
            f"{len(platforms)} platforms"
        )
    if fixed_x is None:
        fixed_x = [None] * len(platforms)
    fixed_x = list(fixed_x)
    if len(fixed_x) != len(platforms):
        raise ValueError(
            f"one fixed_x per platform, got {len(fixed_x)} for "
            f"{len(platforms)} platforms"
        )
    get_planner(mode)  # validate the mode before any solve
    groups: Dict[Tuple[int, int, int], list] = {}
    for g, p in enumerate(platforms):
        groups.setdefault((p.nS, p.nM, p.nR), []).append(g)
    results: "list[Optional[PlanResult]]" = [None] * len(platforms)
    for idxs in groups.values():
        planned = _plan_group(
            [platforms[g] for g in idxs], mode, barriers, n_restarts,
            steps, [seeds[g] for g in idxs], [fixed_x[g] for g in idxs],
        )
        for g, (plan, obj) in zip(idxs, planned):
            results[g] = PlanResult(
                plan=plan,
                makespan=makespan(platforms[g], plan, barriers),
                breakdown=phase_breakdown(platforms[g], plan, barriers),
                mode=mode,
                barriers=barriers,
                objective=float(obj),
            )
    return results  # type: ignore[return-value]


class SolverService:
    """Planner-as-a-service facade: batched same-shape solves, the
    process-wide shape-keyed executable cache, and its counters.

    The cache itself is module state (jit executables keyed by solver +
    array shapes/dtypes + static config), so it survives across
    :class:`SolverService` *and* ``GeoSchedule`` instances — a service
    object only carries request defaults.  ``plan``/``plan_many`` route
    through :func:`optimize_plan_batch` (same-shape requests share one
    vmapped dispatch); ``replan_many`` through :func:`replan_batch`
    (optionally warm-started incremental re-solves)."""

    def __init__(
        self,
        mode: str = "e2e_multi",
        barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
        n_restarts: int = 24,
        steps: int = 500,
    ):
        self.mode = mode
        self.barriers = tuple(barriers)
        self.n_restarts = int(n_restarts)
        self.steps = int(steps)

    def _defaults(self, overrides: dict) -> dict:
        kw = dict(mode=self.mode, barriers=self.barriers,
                  n_restarts=self.n_restarts, steps=self.steps)
        kw.update(overrides)
        return kw

    def plan(self, platform: Platform, seed: int = 0,
             **overrides) -> PlanResult:
        """One request (a batch of one — still served from the shared
        executable cache)."""
        return self.plan_many([platform], seeds=[seed], **overrides)[0]

    def plan_many(self, platforms: Sequence[Platform],
                  seeds: Optional[Sequence[int]] = None,
                  **overrides) -> "list[PlanResult]":
        """N concurrent plan requests, batched per shape group."""
        return optimize_plan_batch(
            platforms, seeds=seeds, **self._defaults(overrides)
        )

    def replan_many(
        self,
        platforms: Sequence[Platform],
        incumbents: Sequence[ExecutionPlan],
        progresses=None,
        seeds: Optional[Sequence[int]] = None,
        incremental: bool = False,
        **overrides,
    ) -> "list[PlanResult]":
        """N concurrent residual re-plan requests, batched per shape
        group (see :func:`replan_batch`)."""
        kw = self._defaults(overrides)
        kw.pop("mode", None)
        return replan_batch(
            platforms, incumbents, progresses, seeds=seeds,
            incremental=incremental, **kw,
        )

    @staticmethod
    def stats() -> Dict[str, int]:
        """The process-wide cache counters (:func:`solver_cache_stats`)."""
        return solver_cache_stats()

    @staticmethod
    def reset_stats() -> None:
        reset_solver_cache_stats()


# ---------------------------------------------------------------------------
# multi-job scheduling: policies over a shared substrate
# ---------------------------------------------------------------------------

#: name -> fn(substrate, platforms, barriers, *, mode, n_restarts, steps, seed)
#:         -> [ExecutionPlan, ...] (one per job)
_SCHEDULE_PLANNERS: Dict[str, Callable] = {}


def register_schedule_planner(name: str, fn: Optional[Callable] = None):
    """Register a multi-job scheduling policy under ``name`` (decorator or
    direct call, mirroring :func:`register_planner`).  A policy takes
    ``(substrate, platforms, barriers, *, mode, n_restarts, steps, seed)``
    — ``platforms`` being per-job views of ``substrate`` — and returns one
    :class:`ExecutionPlan` per job.  Registered names are immediately
    usable in :func:`optimize_schedule` and
    :meth:`repro.api.GeoSchedule.plan`."""
    if fn is None:
        return lambda f: register_schedule_planner(name, f)
    if name in _SCHEDULE_PLANNERS:
        raise ValueError(f"schedule policy {name!r} is already registered")
    _SCHEDULE_PLANNERS[name] = fn
    return fn


def get_schedule_planner(name: str) -> Callable:
    try:
        return _SCHEDULE_PLANNERS[name]
    except KeyError:
        raise ValueError(
            f"policy must be one of {available_policies()}, got {name!r}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    """Names of every registered multi-job scheduling policy."""
    return tuple(_SCHEDULE_PLANNERS)


@dataclasses.dataclass(frozen=True)
class SchedulePlanResult:
    """N per-job plans priced together on their shared substrate.  Each
    per-job :class:`PlanResult` carries the job's *contended* makespan
    (shared-capacity pricing — the other jobs' demand inflates every
    resource the job touches); ``makespan`` is the modeled aggregate.
    ``objective`` records what the policy optimized (see
    :data:`SCHEDULE_OBJECTIVES`)."""

    results: Tuple[PlanResult, ...]
    makespan: float
    policy: str
    mode: str
    barriers: Tuple[str, str, str]
    objective: str = "makespan"

    @property
    def plans(self) -> Tuple[ExecutionPlan, ...]:
        return tuple(r.plan for r in self.results)

    def __repr__(self):
        per_job = " ".join(f"{r.makespan:.1f}s" for r in self.results)
        return (
            f"SchedulePlanResult(policy={self.policy}, mode={self.mode}, "
            f"jobs={len(self.results)}, makespan={self.makespan:.1f}s "
            f"[{per_job}])"
        )


def _job_volumes(platforms, plans):
    """Per-job analytic volumes (numpy float64) for shared pricing."""
    return [
        analytic_volumes(p.D, np.asarray(plan.x), np.asarray(plan.y),
                         p.alpha, xp=np)
        for p, plan in zip(platforms, plans)
    ]


def _shared_schedule_result(
    platforms, plans, barriers, policy: str, mode: str,
    objective: str = "makespan",
) -> SchedulePlanResult:
    """Price per-job plans under shared-capacity float64 equations and wrap
    them in per-job PlanResults + the aggregate."""
    cm = CostModel(platforms[0], barriers)
    priced = cm.price_shared(_job_volumes(platforms, plans), barriers)
    results = []
    for plan, out in zip(plans, priced):
        breakdown = attribute_phases(out)
        results.append(
            PlanResult(
                plan=plan,
                makespan=breakdown["makespan"],
                breakdown=breakdown,
                mode=f"{policy}:{mode}",
                barriers=tuple(barriers),
                objective=breakdown["makespan"],
            )
        )
    return SchedulePlanResult(
        results=tuple(results),
        makespan=max(r.makespan for r in results),
        policy=policy,
        mode=mode,
        barriers=tuple(barriers),
        objective=objective,
    )


def optimize_schedule(
    platforms: "list[Platform]",
    policy: str = "joint",
    mode: str = "e2e_multi",
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 24,
    steps: int = 500,
    seed: int = 0,
    objective: str = "makespan",
) -> SchedulePlanResult:
    """Plan N concurrent jobs sharing one substrate.

    ``platforms`` are the jobs' substrate views (same capacities, per-job
    ``D``/``alpha``); ``policy`` is any name in
    :func:`available_policies` — built in:

    * ``independent`` — every job planned as the sole tenant (``mode``
      planner on the full-capacity view); the myopic baseline.
    * ``sequential``  — greedy: jobs planned largest-first, each on the
      capacity left over after earlier jobs' committed utilization.
    * ``joint``       — one optimization over all jobs' stacked ``x``/``y``
      against the shared-capacity pricing (never worse than
      ``independent`` under the model, because the independent plans are a
      candidate).

    ``objective`` selects what the policy minimizes
    (:data:`SCHEDULE_OBJECTIVES`): the aggregate ``makespan``, or
    ``min_max_slowdown`` — the fairness objective bounding how much any one
    job is stretched relative to running alone.  It is forwarded to
    policies that accept an ``objective`` keyword (the built-in ``joint``
    does); requesting a non-default objective from a policy that does not
    is an error rather than a silent ignore.

    The result prices every job with shared-capacity float64 equations, so
    policies are compared on exactly the surface the executor measures.
    """
    if not platforms:
        raise ValueError("optimize_schedule needs at least one job")
    if objective not in SCHEDULE_OBJECTIVES:
        raise ValueError(
            f"objective must be one of {SCHEDULE_OBJECTIVES}, got {objective!r}"
        )
    sub = Substrate.of(platforms[0])
    for p in platforms[1:]:
        if not sub.compatible(Substrate.of(p)):
            raise ValueError(
                f"platform {p.name!r} does not share the substrate — build "
                "job platforms with Substrate.view()"
            )
    planner = get_schedule_planner(policy)
    barriers = tuple(barriers)
    kwargs = dict(mode=mode, n_restarts=n_restarts, steps=steps, seed=seed)
    if "objective" in inspect.signature(planner).parameters:
        kwargs["objective"] = objective
    elif objective != "makespan":
        raise ValueError(
            f"policy {policy!r} does not take an objective — register it "
            "with an `objective` keyword to opt in"
        )
    plans = planner(sub, list(platforms), barriers, **kwargs)
    return _shared_schedule_result(
        platforms, plans, barriers, policy, mode, objective
    )


@register_schedule_planner("independent")
def _independent_policy(substrate, platforms, barriers, *, mode, n_restarts,
                        steps, seed):
    """Each job planned as if it owned the whole substrate (the per-job
    myopic baseline the paper's end-to-end argument extends across jobs).
    All jobs share one batched solver dispatch per shape group
    (:func:`optimize_plan_batch`, default per-job seeds ``seed + 17*g``
    — the seeds this policy always used)."""
    return [
        res.plan
        for res in optimize_plan_batch(
            platforms, mode=mode, barriers=barriers,
            n_restarts=n_restarts, steps=steps, seed=seed,
        )
    ]


@register_schedule_planner("sequential")
def _sequential_policy(substrate, platforms, barriers, *, mode, n_restarts,
                       steps, seed):
    """Greedy multi-job planning: jobs are planned largest-data-first, and
    after each job commits, its planned per-resource utilization (busy
    seconds over its own makespan) is deducted from the substrate the
    remaining jobs see (:meth:`Substrate.residual`)."""
    planner = get_planner(mode)
    order = sorted(
        range(len(platforms)), key=lambda g: -float(platforms[g].D.sum())
    )
    plans: List[Optional[ExecutionPlan]] = [None] * len(platforms)
    frac_push = np.zeros_like(substrate.B_sm)
    frac_shuf = np.zeros_like(substrate.B_mr)
    frac_map = np.zeros_like(substrate.C_m)
    frac_red = np.zeros_like(substrate.C_r)
    for step_idx, g in enumerate(order):
        residual = substrate.residual(frac_push, frac_shuf, frac_map, frac_red)
        view = residual.view(platforms[g].D, platforms[g].alpha,
                             name=f"{platforms[g].name}/residual")
        plan, _ = planner(view, barriers, n_restarts=n_restarts, steps=steps,
                          seed=seed + 17 * step_idx, fixed_x=None)
        plans[g] = plan
        # commit the job's utilization at FULL capacity (the fraction of
        # wall-clock each resource spends on it while the job runs)
        V_push, V_map, V_shuf, V_red = _job_volumes([platforms[g]], [plan])[0]
        T = max(makespan(platforms[g], plan, barriers), 1e-9)
        frac_push += (V_push / substrate.B_sm) / T
        frac_shuf += (V_shuf / substrate.B_mr) / T
        frac_map += (V_map / substrate.C_m) / T
        frac_red += (V_red / substrate.C_r) / T
    return plans


#: Selectable aggregation objectives for multi-job scheduling:
#: ``makespan`` minimizes the schedule's aggregate (max-over-jobs) makespan;
#: ``min_max_slowdown`` minimizes the worst per-job *slowdown* — the job's
#: contended makespan divided by its independent-plan (sole-tenant)
#: makespan — so no job is sacrificed to shorten the schedule.
SCHEDULE_OBJECTIVES = ("makespan", "min_max_slowdown")


@_counted_solver(
    static_argnames=("barriers", "steps", "kappa", "objective")
)
def _solve_joint_batch(
    D_stack,  # (J, nS)
    alpha_stack,  # (J,)
    B_sm,
    B_mr,
    C_m,
    C_r,
    logits_x0,  # (R, J, nS, nM)
    logits_y0,  # (R, J, nR)
    scale,  # scalar — typical makespan, sets the tau schedule units
    refs,  # (J,) per-job reference makespans (1s for the makespan objective)
    kappa: float,  # static — smooth-usage-gate width, MB
    barriers: Tuple[str, str, str],
    steps: int,
    objective: str = "makespan",
    lr: float = 0.08,
    tau0_frac: float = 0.3,
    tau1_frac: float = 1e-3,
):
    """Anneal all jobs' stacked plans jointly against shared-capacity
    pricing; return per-restart (x, y) stacks plus their exact hard-gate
    aggregate objective values."""

    def aggregate(x, y, mx, pmax, kap):
        # one vmapped instance of the volume/pricing graph regardless of J
        # (a per-job python loop here makes XLA compile time linear in the
        # job count — see _stacked_effective_volumes)
        vols = jax.vmap(
            lambda D, xg, yg, a: analytic_volumes(D, xg, yg, a, xp=jnp)
        )(D_stack, x, y, alpha_stack)
        eff = _stacked_effective_volumes(vols, kap)
        spans = jax.vmap(
            lambda v: volume_model(*v, B_sm, B_mr, C_m, C_r, barriers, mx,
                                   pmax, xp=jnp)["makespan"]
        )(eff)
        if objective == "min_max_slowdown":
            spans = spans / refs * scale  # keep the tau schedule's units
        return mx(spans)

    def loss(params, tau):
        mx, pmax = smooth_ops(tau)
        x = jax.nn.softmax(params["x"], axis=-1)
        y = jax.nn.softmax(params["y"], axis=-1)
        return aggregate(x, y, mx, pmax, kappa) / scale

    def one_restart(lx0, ly0):
        params = _adam_anneal(
            loss, {"x": lx0, "y": ly0}, steps, scale, lr, tau0_frac, tau1_frac
        )
        x = jax.nn.softmax(params["x"], axis=-1)
        y = jax.nn.softmax(params["y"], axis=-1)
        mx, pmax = hard_ops()
        # hard max, but the smooth usage gate (a hard gate kills the
        # gradient-free comparison too): final selection re-prices in f64
        exact = aggregate(x, y, mx, pmax, kappa)
        return x, y, exact

    return jax.vmap(one_restart)(logits_x0, logits_y0)


def _stacked_effective_volumes(vols, kappa: float, xp=jnp, bg=None):
    """Batched :func:`shared_effective_volumes` over job-stacked volumes.

    ``vols`` is a 4-tuple of (J, ...) arrays (one entry per resource
    class, leading axis = job).  The list-of-tuples original builds J
    copies of every op into the caller's jit graph — at the 1000-node
    tier that made XLA compile time scale linearly with live jobs
    (minutes at J≈90); here the contention inflation is one batched
    expression regardless of J.

    ``bg`` optionally adds fixed per-resource background demand (a
    4-tuple of unbatched arrays) to every total: the residual volumes of
    live jobs *outside* the annealed stack, held at their incumbent
    routing (see the stack cap in :func:`replan_schedule`)."""
    out = []
    for c, V in enumerate(vols):
        total = V.sum(axis=0, keepdims=True)
        if bg is not None:
            total = total + bg[c][None]
        if kappa > 0:
            gate = V / (V + kappa)
        else:
            gate = xp.where(V > 1e-9, 1.0, 0.0)
        out.append(V + gate * (total - V))
    return tuple(out)


def _normalized_plans(xs, ys, meta: str) -> "list[ExecutionPlan]":
    """float64-renormalize a stacked (J, nS, nM)/(J, nR) candidate so every
    per-job plan validates exactly.

    Softmax-epsilon entries are zeroed below 1e-6 of their row max before
    renormalizing: warm-start logits put ~e^-20 mass on routes the
    incumbent never used, and at multi-GB job sizes those epsilon routes
    would otherwise materialize thousands of microscopic flows/chunks in
    the executors while carrying <1e-6 of the volume."""
    xs = np.clip(np.asarray(xs, dtype=np.float64), 0.0, None)
    ys = np.clip(np.asarray(ys, dtype=np.float64), 0.0, None)
    xs = np.where(xs >= 1e-6 * xs.max(axis=-1, keepdims=True), xs, 0.0)
    ys = np.where(ys >= 1e-6 * ys.max(axis=-1, keepdims=True), ys, 0.0)
    return [
        ExecutionPlan.renormalized(xs[g], ys[g], meta)
        for g in range(xs.shape[0])
    ]


@register_schedule_planner("joint")
def _joint_policy(substrate, platforms, barriers, *, mode, n_restarts, steps,
                  seed, objective: str = "makespan"):
    """The paper's end-to-end argument lifted across jobs: one annealed
    optimization over every job's stacked ``x``/``y`` against
    shared-capacity pricing.  Warm starts include the independent per-job
    plans (so the joint result is never worse than ``independent`` under
    the model) and node-rotated anti-affinity variants that bias different
    jobs toward different substrate entries.  ``objective`` selects the
    aggregate being annealed *and* the float64 selection criterion:
    ``makespan`` or ``min_max_slowdown`` (per-job contended makespan over
    its independent-plan sole-tenant makespan)."""
    J, nS, nM, nR = len(platforms), substrate.nS, substrate.nM, substrate.nR
    indep = _independent_policy(
        substrate, platforms, barriers,
        mode=mode, n_restarts=n_restarts, steps=steps, seed=seed,
    )
    rng = np.random.default_rng(seed)
    eps = 1e-9

    indep_x = np.stack([np.log(plan.x + eps) for plan in indep])
    indep_y = np.stack([np.log(plan.y + eps) for plan in indep])
    greedy_x = np.log(substrate.B_sm / substrate.B_sm.max() + eps)
    greedy_y = np.log(substrate.C_r / substrate.C_r.max() + eps)
    lx = [
        indep_x,  # the myopic candidate itself
        np.zeros((J, nS, nM)),  # uniform
        # anti-affinity: rotate each job's bandwidth-greedy bias so jobs
        # prefer different mappers/reducers
        np.stack([np.roll(greedy_x, g, axis=1) for g in range(J)]),
    ]
    ly = [
        indep_y,
        np.zeros((J, nR)),
        np.stack([np.roll(greedy_y, g) for g in range(J)]),
    ]
    while len(lx) < n_restarts:
        sigma = rng.uniform(0.3, 3.0)
        lx.append(rng.normal(0.0, sigma, size=(J, nS, nM)))
        ly.append(rng.normal(0.0, sigma, size=(J, nR)))
    logits_x = jnp.asarray(np.stack(lx[:n_restarts]), jnp.float32)
    logits_y = jnp.asarray(np.stack(ly[:n_restarts]), jnp.float32)

    D_stack = np.stack([p.D for p in platforms])
    alpha_stack = np.array([p.alpha for p in platforms])
    scale = max(
        makespan(platforms[0], uniform_plan(platforms[0]), barriers=barriers),
        1e-6,
    )
    # per-job fairness references: what each job would take as sole tenant
    # under its own independent plan (slowdown = contended / this)
    refs = np.maximum(
        np.array([
            makespan(p, plan, barriers=barriers)
            for p, plan in zip(platforms, indep)
        ]),
        1e-9,
    )
    # smooth usage-gate width: small against a typical per-link volume
    kappa = max(1e-3 * float(D_stack.sum()) / max(nM, 1), 1e-9)
    xs, ys, _ = _solve_joint_batch(
        jnp.asarray(D_stack, jnp.float32),
        jnp.asarray(alpha_stack, jnp.float32),
        *(jnp.asarray(a, jnp.float32)
          for a in (substrate.B_sm, substrate.B_mr, substrate.C_m,
                    substrate.C_r)),
        logits_x,
        logits_y,
        jnp.float32(scale),
        jnp.asarray(refs, jnp.float32),
        kappa=float(kappa),
        barriers=tuple(barriers),
        steps=steps,
        objective=objective,
    )

    # exact float64 shared pricing picks the winner; the independent stack
    # competes as candidate -1
    cm = CostModel(platforms[0], barriers)
    candidates = [
        _normalized_plans(np.asarray(xs[r]), np.asarray(ys[r]), "joint")
        for r in range(int(xs.shape[0]))
    ]
    candidates.append([
        dataclasses.replace(plan, meta="joint") for plan in indep
    ])

    def score(plans):
        priced = cm.price_shared(_job_volumes(platforms, plans), barriers)
        spans = np.array([float(out["makespan"]) for out in priced])
        if objective == "min_max_slowdown":
            return float(np.max(spans / refs))
        return float(np.max(spans))

    scores = [score(plans) for plans in candidates]
    return candidates[int(np.argmin(scores))]


# ---------------------------------------------------------------------------
# online re-planning: warm-started residual optimization + policy registry
# ---------------------------------------------------------------------------

@_counted_solver(static_argnames=("barriers", "steps"))
def _solve_residual_batch_many(
    resid,  # 6-tuple of (B, ...) arrays: resid_push, committed_push,
            # at_mapper, shuffle_pool, committed_shuffle, at_reducer
    caps,  # 4-tuple of (B, ...) arrays: B_sm, B_mr, C_m, C_r
    alpha,  # (B,)
    logits_x0,  # (B, R, nS, nM)
    logits_y0,  # (B, R, nR)
    scale,  # (B,)
    barriers: Tuple[str, str, str],
    steps: int,
    lr: float = 0.08,
    tau0_frac: float = 0.3,
    tau1_frac: float = 1e-3,
):
    """Anneal ``B`` independent jobs' *residual* makespans × ``R``
    restarts in one compiled dispatch — the remaining work of each
    observed job (re-routable buckets through candidate x/y, committed
    buckets fixed) priced by the same phase equations.  Per-request
    capacities carry each job's own dead-mapper degradation."""

    def one_request(resid_b, caps_b, alpha_b, lx_b, ly_b, sc):
        def residual_span(x, y, mx, pmax):
            V = residual_volumes(*resid_b, alpha_b, x, y, xp=jnp)
            return volume_model(*V, *caps_b, barriers, mx, pmax,
                                xp=jnp)["makespan"]

        def loss(params, tau):
            mx, pmax = smooth_ops(tau)
            x = jax.nn.softmax(params["x"], axis=-1)
            y = jax.nn.softmax(params["y"], axis=-1)
            return residual_span(x, y, mx, pmax) / sc

        def one_restart(lx0, ly0):
            params = _adam_anneal(
                loss, {"x": lx0, "y": ly0}, steps, sc, lr, tau0_frac,
                tau1_frac,
            )
            x = jax.nn.softmax(params["x"], axis=-1)
            y = jax.nn.softmax(params["y"], axis=-1)
            mx, pmax = hard_ops()
            return x, y, residual_span(x, y, mx, pmax)

        return jax.vmap(one_restart)(lx_b, ly_b)

    return jax.vmap(one_request)(
        resid, caps, alpha, logits_x0, logits_y0, scale
    )


def _incremental_budget(n_restarts: int, steps: int) -> Tuple[int, int]:
    """The warm-start incremental re-solve budget: at most 4 restarts
    (the incumbent plus jittered copies — heuristic restarts add nothing
    when the answer is already near the incumbent) and an eighth of the
    anneal, floored at 25 steps so Adam can still move mass."""
    return max(min(n_restarts, 4), 1), max(steps // 8, 25)


def _shared_incremental_budget(
    n_restarts: int, steps: int, n_jobs: int
) -> Tuple[int, int]:
    """One warm-start anneal budget for the whole *stack*:
    :func:`replan_schedule` solves every live job in a single batched
    anneal whose per-step cost already scales with the live-job count, so
    the incremental polish divides the per-job step budget by the stack
    size instead of paying :func:`_incremental_budget` once per job.  The
    divisor is quantized to powers of two because ``steps`` is a static
    jit argument — as the live set grows and shrinks across decision
    points the budget lands on a handful of values (25 / 12 / 8) and the
    warm solver cache keeps hitting (counter-verify via
    :func:`solver_cache_stats`).  Floored at 8 steps: the polish starts
    at the incumbent logits and the float64 selection keeps the
    never-modeled-worse guarantee regardless of how short it is."""
    n_eff, steps_eff = _incremental_budget(n_restarts, steps)
    if n_jobs > 1:
        div = 1 << int(np.ceil(np.log2(n_jobs)))
        steps_eff = max(steps_eff // div, 8)
    return n_eff, steps_eff


def _replan_logits(platform, incumbent, n_restarts, seed, incremental):
    """Warm-start logits for one residual re-solve: the incumbent first
    (it must compete), then — full mode — the standard heuristic+random
    restarts, or — incremental mode — small jitters of the incumbent
    itself (stay in its basin, polish at low temperature)."""
    eps = 1e-9
    lx_inc = np.log(np.asarray(incumbent.x) + eps)
    ly_inc = np.log(np.asarray(incumbent.y) + eps)
    if incremental:
        rng = np.random.default_rng(seed)
        lx, ly = [lx_inc], [ly_inc]
        while len(lx) < n_restarts:
            lx.append(lx_inc + rng.normal(0.0, 0.25, size=lx_inc.shape))
            ly.append(ly_inc + rng.normal(0.0, 0.25, size=ly_inc.shape))
        return (np.stack(lx[:n_restarts]).astype(np.float32),
                np.stack(ly[:n_restarts]).astype(np.float32))
    lx0, ly0 = _initial_logits(platform, max(n_restarts - 1, 1), seed)
    lx = np.concatenate([lx_inc[None], np.asarray(lx0)])[:n_restarts]
    ly = np.concatenate([ly_inc[None], np.asarray(ly0)])[:n_restarts]
    return lx.astype(np.float32), ly.astype(np.float32)

#: low-temperature anneal for incremental re-solves: the tau schedule
#: starts already almost hard (the incumbent is assumed near-optimal) and
#: the learning rate is dropped so the polish cannot jump basins.
_INCREMENTAL_ANNEAL = dict(lr=0.05, tau0_frac=0.02, tau1_frac=1e-3)

#: incremental co-replans anneal at most this many live jobs at once (the
#: most-behind ones); the rest keep their incumbent routing and enter the
#: solve as fixed background contention.  Keeps a decision point's anneal
#: tensors — and its wall-clock — flat as jobs accumulate at the scale
#: tier; the float64 selection still re-prices the full live stack, so
#: never-modeled-worse is unaffected.
_INCREMENTAL_STACK_CAP = 16


def _degraded_platform(platform: Platform, progress: JobProgress):
    """``platform`` with this job's dead mappers collapsed 1000x.  A dead
    worker is a capacity fact the drift traces cannot express: collapse
    its compute and ingest links so the solver (and the float64
    selection) routes the residual around it.  Not zero — softmax plans
    keep epsilon mass everywhere and the phase equations have no usage
    gate on push links."""
    changes = {}
    if progress.map_alive is not None and not progress.map_alive.all():
        alive = progress.map_alive.astype(bool)
        changes.update(
            C_m=np.where(alive, platform.C_m, platform.C_m * 1e-3),
            B_sm=np.where(alive[None, :], platform.B_sm,
                          platform.B_sm * 1e-3),
        )
    if progress.red_alive is not None and not progress.red_alive.all():
        alive_r = progress.red_alive.astype(bool)
        changes.update(
            C_r=np.where(alive_r, platform.C_r, platform.C_r * 1e-3),
            B_mr=np.where(alive_r[None, :], platform.B_mr,
                          platform.B_mr * 1e-3),
        )
    if not changes:
        return platform
    return dataclasses.replace(platform, **changes)


def replan_batch(
    platforms: Sequence[Platform],
    incumbents: Sequence[ExecutionPlan],
    progresses=None,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 8,
    steps: int = 200,
    seed: int = 0,
    seeds: Optional[Sequence[int]] = None,
    incremental: bool = False,
) -> "list[PlanResult]":
    """Re-optimize ``N`` running jobs' plans against their *remaining*
    work, solo residual pricing per job, batched into one vmapped solver
    dispatch per shape group — the residual counterpart of
    :func:`optimize_plan_batch` (and exactly N :func:`replan` calls,
    minus N-1 dispatches).  ``progresses`` is one
    :class:`~repro.core.makespan.JobProgress` (or ``None`` = fresh) per
    job; ``seeds`` one seed per job (default: ``seed`` for all).

    ``incremental=True`` swaps the full anneal for a warm-started polish:
    at most 4 restarts (incumbent + jitters), an eighth of the steps, and
    a low-temperature schedule (:data:`_INCREMENTAL_ANNEAL`) — the
    cheap mode whose measured wall-clock :class:`SolveTimeEMA` feeds into
    :func:`swap_charge`.  Every candidate is still re-priced in float64
    and the incumbent still competes, so "never modeled-worse" holds in
    both modes.
    """
    barriers = tuple(barriers)
    platforms = list(platforms)
    incumbents = list(incumbents)
    if progresses is None:
        progresses = [None] * len(platforms)
    progresses = [
        JobProgress.fresh(p) if pr is None else pr
        for p, pr in zip(platforms, progresses)
    ]
    if not (len(platforms) == len(incumbents) == len(progresses)):
        raise ValueError(
            f"one incumbent+progress per platform, got {len(platforms)} "
            f"platforms, {len(incumbents)} incumbents, "
            f"{len(progresses)} progresses"
        )
    if seeds is None:
        seeds = [seed] * len(platforms)
    seeds = list(seeds)
    n_eff, steps_eff = (
        _incremental_budget(n_restarts, steps) if incremental
        else (n_restarts, steps)
    )
    anneal = _INCREMENTAL_ANNEAL if incremental else {}

    degraded = [
        _degraded_platform(p, pr) for p, pr in zip(platforms, progresses)
    ]
    cms = [CostModel(p, barriers) for p in degraded]
    inc_outs = [
        cm.price_residual(pr, inc)
        for cm, pr, inc in zip(cms, progresses, incumbents)
    ]
    inc_spans = [float(out["makespan"]) for out in inc_outs]

    groups: Dict[Tuple[int, int, int], list] = {}
    for g, p in enumerate(platforms):
        groups.setdefault((p.nS, p.nM, p.nR), []).append(g)
    results: "list[Optional[PlanResult]]" = [None] * len(platforms)
    for idxs in groups.values():
        logits = [
            _replan_logits(degraded[g], incumbents[g], n_eff, seeds[g],
                           incremental)
            for g in idxs
        ]
        resid = tuple(
            jnp.asarray(a, jnp.float32)
            for a in JobProgress.stack([progresses[g] for g in idxs])
        )
        caps = tuple(
            jnp.asarray(np.stack([
                np.asarray(getattr(degraded[g], f), dtype=np.float64)
                for g in idxs
            ]), jnp.float32)
            for f in ("B_sm", "B_mr", "C_m", "C_r")
        )
        xs, ys, _ = _solve_residual_batch_many(
            resid,
            caps,
            jnp.asarray(np.array([progresses[g].alpha for g in idxs]),
                        jnp.float32),
            jnp.asarray(np.stack([lx for lx, _ in logits])),
            jnp.asarray(np.stack([ly for _, ly in logits])),
            jnp.asarray(np.array([max(inc_spans[g], 1e-6) for g in idxs]),
                        jnp.float32),
            barriers=barriers,
            steps=steps_eff,
            **anneal,
        )
        xs, ys = np.asarray(xs), np.asarray(ys)
        for b, g in enumerate(idxs):
            best_plan, best_span, best_out = (
                incumbents[g], inc_spans[g], inc_outs[g]
            )
            for r in range(xs.shape[1]):
                plan = ExecutionPlan.renormalized(xs[b, r], ys[b, r],
                                                  "replan")
                out = cms[g].price_residual(progresses[g], plan)
                if float(out["makespan"]) < best_span:
                    best_plan, best_span, best_out = (
                        plan, float(out["makespan"]), out
                    )
            results[g] = PlanResult(
                plan=best_plan,
                makespan=best_span,
                breakdown=attribute_phases(best_out),
                mode="replan",
                barriers=barriers,
                objective=best_span,
            )
    return results  # type: ignore[return-value]


def replan(
    platform: Platform,
    incumbent: ExecutionPlan,
    progress: Optional[JobProgress] = None,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 8,
    steps: int = 200,
    seed: int = 0,
    incremental: bool = False,
) -> PlanResult:
    """Re-optimize a running job's plan against its *remaining* work.

    ``platform`` should be the **current view** of the fabric
    (:meth:`repro.core.platform.Substrate.at` folds capacity drift in);
    ``progress`` is the executor's observed residual
    (:class:`repro.core.makespan.JobProgress`; ``None`` means the job has
    not started — a fresh zero-progress snapshot, i.e. ordinary planning).
    The annealed solver **warm-starts from the incumbent plan's logits**
    (plus the standard heuristic and random restarts), every candidate is
    re-priced in float64 through :meth:`CostModel.price_residual`, and the
    incumbent itself competes — so the returned plan is never modeled
    worse than keeping it, and is the *same object* when keeping it wins.

    ``incremental=True`` is the cheap warm-started mode (few low-
    temperature polish steps from the incumbent instead of a full anneal
    — see :func:`replan_batch`); the never-modeled-worse guarantee is
    unchanged because the float64 selection is.

    The returned :class:`PlanResult`'s ``makespan``/``breakdown`` are the
    modeled **remaining** seconds from the observation instant, not a
    from-scratch makespan.  This is a batch of one through
    :func:`replan_batch` — concurrent re-plans share one dispatch there.
    """
    return replan_batch(
        [platform], [incumbent], [progress], barriers=barriers,
        n_restarts=n_restarts, steps=steps, seed=seed,
        incremental=incremental,
    )[0]


# ---------------------------------------------------------------------------
# schedule-aware online re-planning: joint residual optimization
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ScheduleReplanResult:
    """The outcome of one joint residual co-replan over all live jobs.

    ``plans`` holds one plan per input job (the incumbent object itself for
    done jobs, and for every job when keeping the whole incumbent stack
    won); ``before``/``after`` are the per-job modeled remaining seconds
    under shared-capacity residual pricing for the incumbent stack and the
    returned stack respectively.  The incumbent stack competes as a
    candidate, so ``makespan`` (the aggregate ``max(after)``) is never
    modeled worse than ``max(before)``."""

    plans: Tuple[ExecutionPlan, ...]
    before: Tuple[float, ...]
    after: Tuple[float, ...]
    makespan: float
    barriers: Tuple[str, str, str]

    @property
    def improvement(self) -> float:
        """Aggregate modeled seconds the co-replan removed (>= 0)."""
        return max(self.before, default=0.0) - self.makespan


@_counted_solver(static_argnames=("barriers", "steps", "kappa"))
def _solve_residual_shared_batch(
    resid_stack,  # 6-tuple stacked over jobs: (J,nS) (J,nS,nM) (J,nM)
                  #                            (J,nM) (J,nM,nR) (J,nR)
    caps_stack,  # 4-tuple stacked over jobs (dead mappers degraded per job)
    alpha_stack,  # (J,)
    bg_stack,  # 4-tuple unbatched: residual demand of live jobs OUTSIDE
               # the annealed stack, held at their incumbent routing
    logits_x0,  # (R, J, nS, nM)
    logits_y0,  # (R, J, nR)
    scale,
    kappa: float,  # static — smooth-usage-gate width, MB
    barriers: Tuple[str, str, str],
    steps: int,
    lr: float = 0.08,
    tau0_frac: float = 0.3,
    tau1_frac: float = 1e-3,
):
    """Anneal ``R`` restarts of the *joint* residual objective: every live
    job's remaining work under its candidate plan, contention-inflated by
    the other jobs' residual demand (:func:`shared_effective_volumes`) and
    priced through the shared phase equations — the schedule analogue of
    :func:`_solve_residual_batch`."""

    def aggregate(x, y, mx, pmax, kap):
        # one vmapped instance of the volume/pricing graph regardless of J
        # (a per-job python loop here makes XLA compile time linear in the
        # live-job count — minutes at the 1000-node/100-job tier)
        vols = jax.vmap(
            lambda r, a, xg, yg: residual_volumes(*r, a, xg, yg, xp=jnp)
        )(resid_stack, alpha_stack, x, y)
        eff = _stacked_effective_volumes(vols, kap, bg=bg_stack)
        spans = jax.vmap(
            lambda v, c: volume_model(*v, *c, barriers, mx, pmax,
                                      xp=jnp)["makespan"]
        )(eff, caps_stack)
        return mx(spans)

    def loss(params, tau):
        mx, pmax = smooth_ops(tau)
        x = jax.nn.softmax(params["x"], axis=-1)
        y = jax.nn.softmax(params["y"], axis=-1)
        return aggregate(x, y, mx, pmax, kappa) / scale

    def one_restart(lx0, ly0):
        params = _adam_anneal(
            loss, {"x": lx0, "y": ly0}, steps, scale, lr, tau0_frac, tau1_frac
        )
        x = jax.nn.softmax(params["x"], axis=-1)
        y = jax.nn.softmax(params["y"], axis=-1)
        mx, pmax = hard_ops()
        # hard max, smooth usage gate; final selection re-prices in f64
        exact = aggregate(x, y, mx, pmax, kappa)
        return x, y, exact

    return jax.vmap(one_restart)(logits_x0, logits_y0)


def _degraded_caps(substrate, progress: JobProgress):
    """Per-job capacity arrays with this job's dead mappers *and
    reducers* collapsed 1000x (same rationale as :func:`replan`: liveness
    is a capacity fact traces cannot express; not zero because softmax
    plans keep epsilon mass)."""
    B_sm, B_mr = substrate.B_sm, substrate.B_mr
    C_m, C_r = substrate.C_m, substrate.C_r
    if progress.map_alive is not None and not progress.map_alive.all():
        alive = progress.map_alive.astype(bool)
        C_m = np.where(alive, C_m, C_m * 1e-3)
        B_sm = np.where(alive[None, :], B_sm, B_sm * 1e-3)
    if progress.red_alive is not None and not progress.red_alive.all():
        alive_r = progress.red_alive.astype(bool)
        C_r = np.where(alive_r, C_r, C_r * 1e-3)
        B_mr = np.where(alive_r[None, :], B_mr, B_mr * 1e-3)
    return B_sm, B_mr, C_m, C_r


def _score_residual_stack(caps_list, progresses, plans, barriers):
    """float64 shared-residual pricing of one candidate stack: per-job
    residual volumes, hard-gate contention inflation, exact phase equations
    with each job's (possibly liveness-degraded) capacities."""
    vols = [
        residual_volumes(
            pr.resid_push, pr.committed_push, pr.at_mapper, pr.shuffle_pool,
            pr.committed_shuffle, pr.at_reducer, pr.alpha,
            *_live_plan_arrays(pr, plan), xp=np,
        )
        for pr, plan in zip(progresses, plans)
    ]
    eff = shared_effective_volumes(vols, kappa=0.0, xp=np)
    mx, pmax = _np_hard_ops()
    return [
        float(volume_model(
            np.asarray(v[0], dtype=np.float64),
            np.asarray(v[1], dtype=np.float64),
            np.asarray(v[2], dtype=np.float64),
            np.asarray(v[3], dtype=np.float64),
            *caps, barriers, mx, pmax, xp=np,
        )["makespan"])
        for v, caps in zip(eff, caps_list)
    ]


def score_residual_shared(
    substrate, progresses, plans,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
) -> "list[float]":
    """Per-job modeled remaining seconds of ``plans`` under shared-capacity
    residual pricing (float64, hard gate, per-job dead mappers degraded) —
    the exact selection metric :func:`replan_schedule` uses.  Exposed so a
    caller that adopts only *part* of a co-replanned stack (hysteresis may
    reject individual swaps) can re-price the mix it actually executes."""
    caps_list = [_degraded_caps(substrate, pr) for pr in progresses]
    return _score_residual_stack(caps_list, progresses, plans,
                                 tuple(barriers))


def replan_schedule(
    substrate,
    incumbents: Sequence[ExecutionPlan],
    progresses,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 8,
    steps: int = 200,
    seed: int = 0,
    incremental: bool = False,
) -> ScheduleReplanResult:
    """Co-replan **all** live jobs' residuals jointly on their shared
    substrate — the schedule-aware counterpart of :func:`replan`.

    PR 3's :func:`replan` re-optimizes each job's residual *solo* against
    the current capacities, re-introducing at the schedule level exactly
    the myopia the paper's end-to-end argument is about: every job grabs
    the same fast links because none of them models the others.  Here one
    annealed optimization steers every live job's stacked ``x``/``y``
    against :meth:`CostModel.price_residual_shared` — each job's remaining
    work inflated by the other jobs' residual demand on every resource it
    touches — warm-started from the stacked incumbent logits.

    ``substrate`` should be the current view of the fabric
    (:meth:`repro.core.platform.Substrate.at` folds drift in);
    ``progresses`` is a sequence of :class:`JobProgress` (or a
    :class:`repro.core.simulate.ProgressSnapshot`, whose ``jobs`` are
    used), parallel to ``incumbents``.  Done jobs pass through untouched
    with zero residual spans; every candidate stack is re-priced in
    float64 and the incumbent stack competes, so the returned aggregate is
    never modeled worse than keeping every plan (and the plan *objects*
    are the incumbents when keeping wins).

    ``incremental=True`` is the warm-started cheap mode (mirroring
    :func:`replan_batch`): at most 4 restarts — the incumbent stack plus
    jittered copies of it — and **one shared anneal budget for the whole
    stack** (:func:`_shared_incremental_budget`: the per-job step budget
    divided by the power-of-two-quantized live-job count, so the cost of
    a decision point stays flat as jobs accumulate instead of paying the
    per-job budget J times over) at a low-temperature schedule.  Past
    :data:`_INCREMENTAL_STACK_CAP` live jobs only the most-behind ones
    enter the anneal; the rest keep their incumbent routing and enter the
    solve as fixed background contention, so the anneal tensors stay
    bounded at the 1000-node/100-job tier.  The
    float64 selection (and with it the never-modeled-worse guarantee) is
    identical in both modes.
    """
    barriers = tuple(barriers)
    if hasattr(progresses, "jobs"):  # a ProgressSnapshot
        progresses = list(progresses.jobs)
    progresses = list(progresses)
    incumbents = list(incumbents)
    if len(progresses) != len(incumbents):
        raise ValueError(
            f"one incumbent per progress, got {len(incumbents)} incumbents "
            f"and {len(progresses)} progresses"
        )
    live = [g for g, pr in enumerate(progresses) if not pr.done]
    n = len(progresses)
    plans_out: List[ExecutionPlan] = list(incumbents)
    before_out = [0.0] * n
    after_out = [0.0] * n
    if not live:
        return ScheduleReplanResult(
            plans=tuple(plans_out), before=tuple(before_out),
            after=tuple(after_out), makespan=0.0, barriers=barriers,
        )

    live_prog = [progresses[g] for g in live]
    live_inc = [incumbents[g] for g in live]
    caps_list = [_degraded_caps(substrate, pr) for pr in live_prog]
    before = _score_residual_stack(caps_list, live_prog, live_inc, barriers)
    scale = max(max(before), 1e-6)

    J, nS, nM, nR = len(live), substrate.nS, substrate.nM, substrate.nR
    eps = 1e-9
    rng = np.random.default_rng(seed)
    n_eff, steps_eff = (
        _shared_incremental_budget(n_restarts, steps, J) if incremental
        else (n_restarts, steps)
    )
    anneal = _INCREMENTAL_ANNEAL if incremental else {}
    # incremental stack cap: anneal only the K most-behind live jobs and
    # hold everyone else at their incumbent routing, folded into the
    # solver's contention totals as fixed background demand.  Without the
    # cap the anneal tensors (and the decision's wall-clock) grow linearly
    # with live jobs — at the 1000-node/100-job tier a single decision
    # point cost ~45 s.  The f64 selection below still re-prices the FULL
    # live stack (hot candidates spliced over incumbent plans), so the
    # never-modeled-worse guarantee is unchanged.
    if incremental and J > _INCREMENTAL_STACK_CAP:
        worst = np.argsort(np.asarray(before))[::-1]
        hot = sorted(int(s) for s in worst[:_INCREMENTAL_STACK_CAP])
    else:
        hot = list(range(J))
    cold = sorted(set(range(J)) - set(hot))
    hot_prog = [live_prog[s] for s in hot]
    hot_inc = [live_inc[s] for s in hot]
    K = len(hot)
    inc_x = np.stack([np.log(np.asarray(p.x) + eps) for p in hot_inc])
    inc_y = np.stack([np.log(np.asarray(p.y) + eps) for p in hot_inc])
    lx = [inc_x]
    ly = [inc_y]
    if incremental:
        # stay in the incumbent stack's basin: jittered copies only
        while len(lx) < n_eff:
            lx.append(inc_x + rng.normal(0.0, 0.25, size=inc_x.shape))
            ly.append(inc_y + rng.normal(0.0, 0.25, size=inc_y.shape))
    else:
        lx.append(np.zeros((K, nS, nM)))
        ly.append(np.zeros((K, nR)))
        # anti-affinity rotations, as in the offline joint policy: bias
        # different jobs toward different substrate entries
        greedy_x = np.log(substrate.B_sm / substrate.B_sm.max() + eps)
        greedy_y = np.log(substrate.C_r / substrate.C_r.max() + eps)
        lx.append(np.stack([np.roll(greedy_x, g, axis=1) for g in range(K)]))
        ly.append(np.stack([np.roll(greedy_y, g) for g in range(K)]))
        while len(lx) < n_eff:
            sigma = rng.uniform(0.3, 3.0)
            lx.append(rng.normal(0.0, sigma, size=(K, nS, nM)))
            ly.append(rng.normal(0.0, sigma, size=(K, nR)))
    logits_x = jnp.asarray(np.stack(lx[:n_eff]), jnp.float32)
    logits_y = jnp.asarray(np.stack(ly[:n_eff]), jnp.float32)

    resid_stack = tuple(
        jnp.asarray(a, jnp.float32) for a in JobProgress.stack(hot_prog)
    )
    caps_stack = tuple(
        jnp.asarray(np.stack([caps_list[s][c] for s in hot]), jnp.float32)
        for c in range(4)
    )
    alpha_stack = jnp.asarray(
        np.array([pr.alpha for pr in hot_prog]), jnp.float32
    )
    bg = [np.zeros((nS, nM)), np.zeros(nM), np.zeros((nM, nR)), np.zeros(nR)]
    for s in cold:
        pr, plan = live_prog[s], live_inc[s]
        v = residual_volumes(
            pr.resid_push, pr.committed_push, pr.at_mapper, pr.shuffle_pool,
            pr.committed_shuffle, pr.at_reducer, pr.alpha,
            *_live_plan_arrays(pr, plan), xp=np,
        )
        for c in range(4):
            bg[c] += v[c]
    bg_stack = tuple(jnp.asarray(a, jnp.float32) for a in bg)
    total_resid = float(sum(
        pr.remaining_mb()["reduce"] for pr in live_prog
    ))
    kappa = max(1e-3 * total_resid / max(nM, 1), 1e-9)
    # kappa is a static jit arg (shared_effective_volumes branches on it):
    # quantize to half-decade buckets so successive decision points with
    # shrinking residuals reuse the compiled solver instead of re-tracing
    kappa = float(10.0 ** (round(np.log10(kappa) * 2.0) / 2.0))
    xs, ys, _ = _solve_residual_shared_batch(
        resid_stack, caps_stack, alpha_stack, bg_stack, logits_x, logits_y,
        jnp.float32(scale), kappa=float(kappa), barriers=barriers,
        steps=steps_eff, **anneal,
    )

    best_live, best_after, best_score = live_inc, before, max(before)
    for r in range(int(xs.shape[0])):
        cand_hot = _normalized_plans(np.asarray(xs[r]), np.asarray(ys[r]),
                                     "replan_shared")
        cand = list(live_inc)
        for slot, s in enumerate(hot):
            cand[s] = cand_hot[slot]
        spans = _score_residual_stack(caps_list, live_prog, cand, barriers)
        if max(spans) < best_score:
            best_live, best_after, best_score = cand, spans, max(spans)

    for slot, g in enumerate(live):
        plans_out[g] = best_live[slot]
        before_out[g] = before[slot]
        after_out[g] = best_after[slot]
    return ScheduleReplanResult(
        plans=tuple(plans_out), before=tuple(before_out),
        after=tuple(after_out), makespan=best_score, barriers=barriers,
    )


# ---------------------------------------------------------------------------
# replan-cost hysteresis: pricing the swap itself
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OnlineConfig:
    """How an online policy re-plans when it fires.

    ``shared=True`` co-replans all live jobs jointly through
    :func:`replan_schedule` (shared-capacity residual pricing) instead of
    each job solo through :func:`replan`.

    ``hysteresis`` is the replan-cost damping factor: a candidate swap is
    charged :func:`swap_charge` (solver wall-clock plus the modeled data
    movement of re-routing its queued bytes) and fires only when its
    modeled savings exceed ``hysteresis ×`` that charge.  ``0`` swaps on
    any modeled improvement (PR 3's behavior, independent of the solver
    cost); ``inf`` never swaps, reproducing the ``static`` policy
    byte-for-byte (no solve is even attempted).

    ``solver_cost_s`` is the solver wall-clock the charge uses.  ``None``
    (the default) charges the **measured** cost: a
    :class:`SolveTimeEMA` of this run's observed solve times — cold
    compiles excluded, quantized to half-decade buckets for stability —
    so a cheap incremental re-solve is charged what it actually costs
    instead of the old hardcoded 1-second guess.  A float pins the charge
    to that estimate (deterministic and host-independent).

    ``incremental=True`` re-plans in the warm-started incremental mode
    (few low-temperature steps from the incumbent — see
    :func:`replan_batch` / :func:`replan_schedule`) instead of a full
    anneal; paired with measured costs, the hysteresis gate then charges
    the *small* solve the policy actually runs.

    ``speculation`` steers the executor's speculative-execution knob on
    failure decisions: ``True`` turns speculation *on* for every live job
    once a failure has been observed (duplicate straggling work — a dead
    worker's recovery traffic creates exactly the stragglers speculation
    hedges), ``False`` forces it off, ``None`` (default) leaves each
    job's :class:`~repro.core.simulate.SimConfig` untouched.

    ``candidate_pricing`` selects how the replan gate scores the
    incumbent stack against the co-replanned candidate stack.
    ``"model"`` (default) keeps the closed-form float64 residual model
    (:func:`score_residual_shared`).  ``"fluid"`` prices **both** stacks
    with a shared-capacity fluid rollout
    (:func:`repro.core.fluid.fluid_score_residual`) from the decision
    instant — folding any remaining capacity drift into the horizon —
    and adopts the candidate only on a strict fluid improvement, so the
    incumbent still competes in float64 and the never-priced-worse
    guarantee carries over to the pricing in force.  Fluid pricing
    scores the *whole* stack at once and therefore requires
    ``shared=True``."""

    shared: bool = False
    hysteresis: float = 0.0
    solver_cost_s: Optional[float] = None
    incremental: bool = False
    speculation: Optional[bool] = None
    candidate_pricing: str = "model"

    def __post_init__(self):
        if not (self.hysteresis >= 0.0):  # rejects negatives and NaN
            raise ValueError(
                f"hysteresis must be >= 0 (inf allowed), got "
                f"{self.hysteresis}"
            )
        if self.solver_cost_s is not None \
                and not (self.solver_cost_s >= 0.0):
            raise ValueError(
                f"solver_cost_s must be >= 0 (or None = measured), got "
                f"{self.solver_cost_s}"
            )
        if self.candidate_pricing not in ("model", "fluid"):
            raise ValueError(
                'candidate_pricing must be "model" or "fluid", got '
                f"{self.candidate_pricing!r}"
            )
        if self.candidate_pricing == "fluid" and not self.shared:
            raise ValueError(
                'candidate_pricing="fluid" prices the whole co-replanned '
                "stack with one rollout — it requires shared=True"
            )


class SolveTimeEMA:
    """Running estimate of one re-planning solve's wall-clock seconds —
    what :func:`swap_charge` charges as ``solver_cost_s``.

    ``fixed`` pins the charge to a constant (deterministic,
    host-independent — the pre-measurement behavior); ``None`` tracks an
    exponential moving average of *observed* solve times.  Samples that
    triggered a fresh XLA compile are excluded — compile cost is paid
    once per shape, not per decision, so charging it to one unlucky swap
    would be wrong in both directions.  The reported charge is quantized
    to half-decade buckets (1.0, 0.32, 0.1, ...) so the hysteresis gate
    keys off the solve's order of magnitude, not scheduler noise; before
    the first warm sample it falls back to ``fallback`` (the historical
    1-second estimate)."""

    def __init__(self, fixed: Optional[float] = None, beta: float = 0.3,
                 fallback: float = 1.0):
        if fixed is not None and not (fixed >= 0.0):
            raise ValueError(f"fixed must be >= 0 or None, got {fixed}")
        if not (0.0 < beta <= 1.0):
            raise ValueError(f"beta must be in (0, 1], got {beta}")
        self.fixed = fixed
        self.beta = float(beta)
        self.fallback = float(fallback)
        self.ema: Optional[float] = None
        self.samples = 0
        self.excluded = 0

    def observe(self, seconds: float, compiled: bool = False) -> None:
        """Fold one measured solve in; ``compiled=True`` marks a cold
        sample (excluded from the average)."""
        if compiled or not np.isfinite(seconds) or seconds <= 0.0:
            self.excluded += 1
            return
        self.samples += 1
        self.ema = (
            float(seconds) if self.ema is None
            else (1.0 - self.beta) * self.ema + self.beta * float(seconds)
        )

    def charge_s(self) -> float:
        """The solver cost a swap is charged right now (seconds)."""
        if self.fixed is not None:
            return float(self.fixed)
        if self.ema is None:
            return self.fallback
        return float(10.0 ** (round(np.log10(max(self.ema, 1e-9)) * 2.0)
                              / 2.0))

    def __repr__(self):
        mode = (f"fixed={self.fixed}" if self.fixed is not None
                else f"ema={self.ema}")
        return (f"SolveTimeEMA({mode}, charge_s={self.charge_s():.3g}, "
                f"samples={self.samples}, excluded={self.excluded})")


def swap_charge(
    platform,
    progress: JobProgress,
    incumbent: ExecutionPlan,
    candidate: ExecutionPlan,
    solver_cost_s: float = 1.0,
) -> float:
    """Modeled cost (seconds) of swapping ``incumbent`` for ``candidate``
    on a running job — what replan-cost hysteresis charges a swap before
    it may fire.

    The charge is the solver wall-clock estimate plus the data-movement
    cost of re-routing the job's committed-but-queued bytes: push MB still
    queued at the sources move ``0.5·Σᵢ resid_push[i]·‖x'ᵢ − xᵢ‖₁`` (the MB
    whose destination actually changes) and pooled shuffle MB move
    ``0.5·Σⱼ pool[j]·‖y' − y‖₁``, each priced at the fabric's mean link
    bandwidth.  The executor itself re-queues pulled-back chunks for free —
    this is a *modeled* control charge (connection churn, re-registration,
    coordination) that damps thrash, per the communication-pattern modeling
    argument that re-planning overhead must be priced rather than assumed
    free."""
    x0, x1 = np.asarray(incumbent.x), np.asarray(candidate.x)
    y0, y1 = np.asarray(incumbent.y), np.asarray(candidate.y)
    moved_push = 0.5 * float(
        (progress.resid_push * np.abs(x1 - x0).sum(axis=1)).sum()
    )
    moved_shuf = 0.5 * float(
        (progress.shuffle_pool * np.abs(y1 - y0).sum()).sum()
    )
    return (
        float(solver_cost_s)
        + moved_push / max(float(np.mean(platform.B_sm)), 1e-9)
        + moved_shuf / max(float(np.mean(platform.B_mr)), 1e-9)
    )


#: name -> fn(kind, snapshot) -> bool (replan now?)
_ONLINE_POLICIES: Dict[str, Callable] = {}

#: name -> the OnlineConfig the policy registered with (default when absent)
_ONLINE_CONFIGS: Dict[str, OnlineConfig] = {}


def register_online_policy(
    name: str, fn: Optional[Callable] = None, *,
    config: Optional[OnlineConfig] = None,
):
    """Register an online re-planning policy under ``name`` (decorator or
    direct call, mirroring :func:`register_planner`).  A policy is called
    at every candidate decision point of
    :meth:`repro.api.GeoSchedule.run_online` with ``(kind, snapshot)`` —
    ``kind`` one of ``"arrival"`` / ``"drift"`` / ``"failure"`` /
    ``"tick"``, ``snapshot`` the executor's
    :class:`repro.core.simulate.ProgressSnapshot` at that instant — and
    returns whether to re-plan the active jobs now.

    ``config`` attaches an :class:`OnlineConfig` describing *how* the
    policy re-plans when it fires (solo vs shared co-replanning, the
    replan-cost hysteresis factor); it defaults to PR 3's behavior (solo,
    no hysteresis) and callers of ``run_online`` may override it per run."""
    if fn is None:
        return lambda f: register_online_policy(name, f, config=config)
    if name in _ONLINE_POLICIES:
        raise ValueError(f"online policy {name!r} is already registered")
    _ONLINE_POLICIES[name] = fn
    if config is not None:
        _ONLINE_CONFIGS[name] = config
    return fn


def get_online_policy(name: str) -> Callable:
    try:
        return _ONLINE_POLICIES[name]
    except KeyError:
        raise ValueError(
            f"online policy must be one of {available_online_policies()}, "
            f"got {name!r}"
        ) from None


def available_online_policies() -> Tuple[str, ...]:
    """Names of every registered online re-planning policy."""
    return tuple(_ONLINE_POLICIES)


def get_online_config(name: str) -> OnlineConfig:
    """The :class:`OnlineConfig` policy ``name`` registered with (the
    default — solo re-planning, no hysteresis — when it registered none)."""
    get_online_policy(name)  # validate the name
    return _ONLINE_CONFIGS.get(name, OnlineConfig())


@register_online_policy("static")
def _static_online_policy(kind, snapshot):
    """Never re-plan: the frozen offline pipeline, reproduced exactly —
    the baseline every online policy is measured against."""
    return False


@register_online_policy("reactive")
def _reactive_online_policy(kind, snapshot):
    """Re-plan whenever the world changes: a job arrives, a worker fails,
    or a traced capacity steps."""
    return kind in ("arrival", "failure", "drift")


@register_online_policy("horizon")
def _horizon_online_policy(kind, snapshot):
    """Re-plan on a fixed cadence (every ``replan_dt`` tick), ignoring
    event triggers — the rolling-horizon control baseline."""
    return kind == "tick"


@register_online_policy(
    "reactive_shared",
    config=OnlineConfig(shared=True, hysteresis=1.0),
)
def _reactive_shared_policy(kind, snapshot):
    """``reactive``'s triggers, but schedule-aware and cost-aware: every
    firing co-replans all live jobs' residuals jointly against
    shared-capacity pricing (:func:`replan_schedule`), and each per-job
    swap must beat its :func:`swap_charge` under hysteresis 1.0."""
    return kind in ("arrival", "failure", "drift")


@register_online_policy(
    "horizon_shared",
    config=OnlineConfig(shared=True, hysteresis=1.0),
)
def _horizon_shared_policy(kind, snapshot):
    """``horizon``'s fixed cadence with shared co-replanning and
    replan-cost hysteresis (see :data:`OnlineConfig`)."""
    return kind == "tick"


@register_online_policy(
    "reactive_incremental",
    config=OnlineConfig(shared=True, hysteresis=1.0, incremental=True),
)
def _reactive_incremental_policy(kind, snapshot):
    """``reactive_shared``'s triggers and shared co-replanning, but each
    firing runs the warm-started *incremental* solve (few low-temperature
    anneal steps from the incumbent logits) and the hysteresis gate
    charges the measured incremental solve time — the cheap-and-frequent
    corner of the replan-cost trade-off."""
    return kind in ("arrival", "failure", "drift")


@register_online_policy(
    "reactive_fluid",
    config=OnlineConfig(shared=True, hysteresis=1.0, incremental=True,
                        candidate_pricing="fluid"),
)
def _reactive_fluid_policy(kind, snapshot):
    """``reactive_incremental``'s triggers and warm-started shared
    solves, with the replan gate scored by a **fluid rollout**
    (``candidate_pricing="fluid"``): incumbent and candidate stacks are
    both drained through :func:`repro.core.fluid.fluid_score_residual`
    from the decision instant — drift-aware, float64 — and the swap
    fires only on a strict fluid improvement that clears the hysteresis
    charge.  The scale-tier corner of the trade-off: pricing cost grows
    with flows, not chunks."""
    return kind in ("arrival", "failure", "drift")


@register_online_policy(
    "reactive_failover",
    config=OnlineConfig(shared=True, hysteresis=1.0, speculation=True),
)
def _reactive_failover_policy(kind, snapshot):
    """``reactive_shared``'s triggers and shared co-replanning, plus the
    fault-reaction knob: the first failure decision also switches every
    live job's speculative execution *on*
    (:meth:`_MultiSim.set_speculation`), so recovery-induced stragglers
    get hedged while the co-replan routes the residual around the dead
    resources (capacity collapsed until repair via
    :meth:`Substrate.at`)."""
    return kind in ("arrival", "failure", "drift")


# ---------------------------------------------------------------------------
# multi-stage pipelines: stagewise vs end-to-end cross-stage planning
# ---------------------------------------------------------------------------

#: name -> fn(spec, barriers, *, stage_mode, n_restarts, steps, seed)
#:         -> [ExecutionPlan, ...] (one per stage)
_PIPELINE_PLANNERS: Dict[str, Callable] = {}


def register_pipeline_planner(name: str, fn: Optional[Callable] = None):
    """Register a pipeline planning strategy under ``name`` (decorator or
    direct call, mirroring :func:`register_planner`).  A pipeline planner
    takes ``(spec, barriers, *, stage_mode, n_restarts, steps, seed)`` —
    ``spec`` a :class:`repro.core.pipeline.PipelineSpec` — and returns one
    :class:`ExecutionPlan` per stage.  Registered names are immediately
    usable in :func:`optimize_pipeline` and
    :meth:`repro.api.GeoPipeline.plan`."""
    if fn is None:
        return lambda f: register_pipeline_planner(name, f)
    if name in _PIPELINE_PLANNERS:
        raise ValueError(f"pipeline planner {name!r} is already registered")
    _PIPELINE_PLANNERS[name] = fn
    return fn


def get_pipeline_planner(name: str) -> Callable:
    try:
        return _PIPELINE_PLANNERS[name]
    except KeyError:
        raise ValueError(
            f"pipeline mode must be one of {available_pipeline_modes()}, "
            f"got {name!r}"
        ) from None


def available_pipeline_modes() -> Tuple[str, ...]:
    """Names of every registered pipeline planner."""
    return tuple(_PIPELINE_PLANNERS)


@dataclasses.dataclass(frozen=True)
class PipelinePlanResult:
    """One plan per stage of a pipeline, priced end to end through
    :meth:`repro.core.makespan.CostModel.price_pipeline`.  Each per-stage
    :class:`PlanResult` carries that stage's *own* modeled span over its
    derived ``D``; ``starts``/``finishes`` compose them along the DAG's
    critical path and ``makespan`` is the end-to-end total."""

    results: Tuple[PlanResult, ...]
    makespan: float
    starts: Tuple[float, ...]
    finishes: Tuple[float, ...]
    #: each stage's derived source vector (MB) under the chosen plans
    stage_D: Tuple[np.ndarray, ...]
    mode: str
    stage_mode: str
    barriers: Tuple[str, str, str]
    objective: float

    @property
    def plans(self) -> Tuple[ExecutionPlan, ...]:
        return tuple(r.plan for r in self.results)

    @property
    def stage_makespans(self) -> Tuple[float, ...]:
        return tuple(r.makespan for r in self.results)

    def __repr__(self):
        stages = " ".join(
            f"{s:.1f}@{t:.1f}s" for s, t in
            zip(self.stage_makespans, self.starts)
        )
        return (
            f"PipelinePlanResult(mode={self.mode}, "
            f"barriers={''.join(self.barriers)}, "
            f"stages=[{stages}], makespan={self.makespan:.1f}s)"
        )


def _pipeline_result(
    spec: PipelineSpec, plans, barriers, mode: str, stage_mode: str,
    objective: float,
) -> PipelinePlanResult:
    """Price a stage stack end to end (float64) and wrap it."""
    cm = CostModel(spec.stages[0].platform, barriers)
    priced = cm.price_pipeline(spec, plans, barriers)
    results = []
    for k, (plan, out) in enumerate(zip(plans, priced["stages"])):
        breakdown = attribute_phases(out)
        results.append(PlanResult(
            plan=plan,
            makespan=breakdown["makespan"],
            breakdown=breakdown,
            mode=f"{mode}:{stage_mode}",
            barriers=tuple(barriers),
            objective=breakdown["makespan"],
        ))
    return PipelinePlanResult(
        results=tuple(results),
        makespan=float(priced["makespan"]),
        starts=tuple(float(t) for t in priced["start"]),
        finishes=tuple(float(t) for t in priced["finish"]),
        stage_D=tuple(priced["D"]),
        mode=mode,
        stage_mode=stage_mode,
        barriers=tuple(barriers),
        objective=objective,
    )


def optimize_pipeline(
    spec: PipelineSpec,
    mode: str = "end_to_end",
    stage_mode: str = "e2e_multi",
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    n_restarts: int = 16,
    steps: int = 400,
    seed: int = 0,
) -> PipelinePlanResult:
    """Plan every stage of a pipeline with the given pipeline ``mode`` (any
    name in :func:`available_pipeline_modes` — built in:

    * ``stagewise``   — plan each stage myopically in topological order
      with the per-stage ``stage_mode`` planner, each stage's ``D``
      derived from the already-fixed upstream plans.  This is the
      baseline the paper's end-to-end argument extends across stages: it
      places stage-``k`` reducers where stage ``k`` finishes fastest,
      even when that strands stage ``k+1``'s input behind slow links.
    * ``end_to_end``  — one annealed optimization over *all* stages'
      stacked ``x``/``y`` against the composed pipeline makespan, with
      gradients flowing through the inter-stage ``D`` coupling
      (downstream ``D`` is a function of upstream ``y``).  The stagewise
      stack competes as a float64 candidate, so ``end_to_end`` is never
      modeled-worse than ``stagewise``.

    The result prices every candidate stack end to end through the one
    float64 cost model (:meth:`CostModel.price_pipeline`)."""
    planner = get_pipeline_planner(mode)
    barriers = tuple(barriers)
    plans = planner(
        spec, barriers,
        stage_mode=stage_mode, n_restarts=n_restarts, steps=steps, seed=seed,
    )
    res = _pipeline_result(spec, plans, barriers, mode, stage_mode, 0.0)
    return dataclasses.replace(res, objective=res.makespan)


def _stagewise_plans(
    spec: PipelineSpec, barriers, *, stage_mode, n_restarts, steps, seed
) -> "list[ExecutionPlan]":
    """Topological-greedy stage planning (shared by ``stagewise`` itself
    and the warm starts / competing candidate of ``end_to_end``)."""
    planner = get_planner(stage_mode)
    sub = spec.substrate
    plans: List[Optional[ExecutionPlan]] = [None] * spec.n_stages
    # topo order guarantees every ancestor is planned before its stage's D
    # is read, so filler plans in not-yet-planned slots never influence it
    # — and the coupling formula stays in its one home, derived_D
    filler = uniform_plan(sub.view(np.zeros(sub.nS), 1.0))
    for pos, k in enumerate(spec.topo_order()):
        stage = spec.stages[k]
        if stage.deps:
            D = spec.derived_D(
                [p if p is not None else filler for p in plans]
            )[k]
            view = sub.view(D, stage.alpha, name=f"{sub.name}/stage{k}")
        else:
            view = stage.platform
        plan, _ = planner(view, barriers, n_restarts=n_restarts, steps=steps,
                          seed=seed + 17 * pos, fixed_x=None)
        plans[k] = plan
    return plans  # type: ignore[return-value]


@register_pipeline_planner("stagewise")
def _stagewise_pipeline(spec, barriers, *, stage_mode, n_restarts, steps,
                        seed):
    """Each stage planned as if it were the last: the per-stage-myopic
    baseline (upstream plans fixed before a downstream stage is even
    looked at)."""
    return _stagewise_plans(
        spec, barriers, stage_mode=stage_mode, n_restarts=n_restarts,
        steps=steps, seed=seed,
    )


@_counted_solver(static_argnames=("topo", "deps", "barriers", "steps"))
def _solve_pipeline_batch(
    D_roots,  # (K, nS) — root stages' D (zero rows for dependent stages)
    alphas,  # (K,)
    out_scales,  # (K,)
    caps,  # 4-tuple: B_sm, B_mr, C_m, C_r
    logits_x0,  # (R, K, nS, nM)
    logits_y0,  # (R, K, nR)
    scale,
    topo: Tuple[int, ...],
    deps: Tuple[Tuple[int, ...], ...],
    barriers: Tuple[str, str, str],
    steps: int,
    lr: float = 0.08,
    tau0_frac: float = 0.3,
    tau1_frac: float = 1e-3,
):
    """Anneal ``R`` restarts of the *composed pipeline* makespan over all
    stages' stacked plans.  Each downstream stage's ``D`` is rebuilt from
    its upstream stages' traced ``y`` inside the loss, so gradients flow
    through the inter-stage coupling — reducer placement of stage ``k``
    feels stage ``k+1``'s push costs."""
    K = logits_y0.shape[1]

    def pipeline_span(x, y, mx, pmax):
        total: list = [None] * K
        finish: list = [None] * K
        for k in topo:
            if deps[k]:
                Dk = sum(
                    out_scales[u] * alphas[u] * total[u] * y[u]
                    for u in deps[k]
                )
            else:
                Dk = D_roots[k]
            total[k] = jnp.sum(Dk)
            vols = analytic_volumes(Dk, x[k], y[k], alphas[k], xp=jnp)
            out = volume_model(*vols, *caps, barriers, mx, pmax, xp=jnp)
            if deps[k]:
                start = mx(jnp.stack([finish[u] for u in deps[k]]))
            else:
                start = 0.0
            finish[k] = start + out["makespan"]
        return mx(jnp.stack(finish))

    def loss(params, tau):
        mx, pmax = smooth_ops(tau)
        x = jax.nn.softmax(params["x"], axis=-1)
        y = jax.nn.softmax(params["y"], axis=-1)
        return pipeline_span(x, y, mx, pmax) / scale

    def one_restart(lx0, ly0):
        params = _adam_anneal(
            loss, {"x": lx0, "y": ly0}, steps, scale, lr, tau0_frac, tau1_frac
        )
        x = jax.nn.softmax(params["x"], axis=-1)
        y = jax.nn.softmax(params["y"], axis=-1)
        mx, pmax = hard_ops()
        return x, y, pipeline_span(x, y, mx, pmax)

    return jax.vmap(one_restart)(logits_x0, logits_y0)


@register_pipeline_planner("end_to_end")
def _end_to_end_pipeline(spec, barriers, *, stage_mode, n_restarts, steps,
                         seed):
    """The paper's end-to-end argument lifted across stages: one annealed
    optimization over every stage's stacked ``x``/``y`` against the
    composed pipeline makespan.  Warm starts include the stagewise stack
    (which also competes in the float64 selection, so the result is never
    modeled-worse than ``stagewise``), a uniform stack, and a
    placement-aware stack that biases every non-sink stage's reducers
    toward nodes with fast *outgoing* push links — the sites the next
    stage can actually leave from."""
    K, sub = spec.n_stages, spec.substrate
    nS, nM, nR = sub.nS, sub.nM, sub.nR
    stagewise = _stagewise_plans(
        spec, barriers, stage_mode=stage_mode, n_restarts=n_restarts,
        steps=steps, seed=seed,
    )
    eps = 1e-9
    rng = np.random.default_rng(seed)
    sw_x = np.stack([np.log(np.asarray(p.x) + eps) for p in stagewise])
    sw_y = np.stack([np.log(np.asarray(p.y) + eps) for p in stagewise])

    greedy_x = np.broadcast_to(
        np.log(sub.B_sm / sub.B_sm.max() + eps), (K, nS, nM)
    ).copy()
    # reducers that downstream stages can leave from: bias stage k's y by
    # the mean outgoing push bandwidth of the node hosting each reducer
    # (reducer r == source r on a pipeline-capable substrate)
    has_children = [False] * K
    for stage in spec.stages:
        for u in stage.deps:
            has_children[u] = True
    exit_bias = (
        np.log(np.mean(sub.B_sm, axis=1) / sub.B_sm.max() + eps)
        if nS == nR else np.zeros(nR)
    )
    sink_bias = np.log(sub.C_r / sub.C_r.max() + eps)
    placed_y = np.stack([
        exit_bias if has_children[k] else sink_bias for k in range(K)
    ])
    lx = [sw_x, np.zeros((K, nS, nM)), greedy_x]
    ly = [sw_y, np.zeros((K, nR)), placed_y]
    while len(lx) < n_restarts:
        sigma = rng.uniform(0.3, 3.0)
        lx.append(rng.normal(0.0, sigma, size=(K, nS, nM)))
        ly.append(rng.normal(0.0, sigma, size=(K, nR)))
    logits_x = jnp.asarray(np.stack(lx[:n_restarts]), jnp.float32)
    logits_y = jnp.asarray(np.stack(ly[:n_restarts]), jnp.float32)

    D_roots = np.zeros((K, nS))
    for k, stage in enumerate(spec.stages):
        if not stage.deps:
            D_roots[k] = stage.platform.D
    cm = CostModel(spec.stages[0].platform, barriers)
    scale = max(
        float(cm.price_pipeline(spec, stagewise)["makespan"]), 1e-6
    )
    xs, ys, _ = _solve_pipeline_batch(
        jnp.asarray(D_roots, jnp.float32),
        jnp.asarray(np.array([s.alpha for s in spec.stages]), jnp.float32),
        jnp.asarray(np.array([s.out_scale for s in spec.stages]),
                    jnp.float32),
        tuple(jnp.asarray(a, jnp.float32)
              for a in (sub.B_sm, sub.B_mr, sub.C_m, sub.C_r)),
        logits_x,
        logits_y,
        jnp.float32(scale),
        topo=spec.topo_order(),
        deps=tuple(s.deps for s in spec.stages),
        barriers=tuple(barriers),
        steps=steps,
    )

    # exact float64 end-to-end pricing picks the winner; the stagewise
    # stack competes as candidate -1
    candidates = [
        _normalized_plans(np.asarray(xs[r]), np.asarray(ys[r]), "end_to_end")
        for r in range(int(xs.shape[0]))
    ]
    candidates.append([
        dataclasses.replace(p, meta="end_to_end") for p in stagewise
    ])
    scores = [
        float(cm.price_pipeline(spec, plans)["makespan"])
        for plans in candidates
    ]
    return candidates[int(np.argmin(scores))]


# ---------------------------------------------------------------------------
# brute force (validation on tiny instances)
# ---------------------------------------------------------------------------

def brute_force_plan(
    platform: Platform,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    grid: int = 20,
) -> PlanResult:
    """Exhaustive grid search over plans; only feasible for tiny platforms
    (it enumerates a simplex grid per source row and for ``y``)."""
    nS, nM, nR = platform.nS, platform.nM, platform.nR
    if nM > 3 or nR > 3 or nS > 3:
        raise ValueError("brute force only supported for <=3 nodes per tier")

    def simplex_grid(dim):
        pts = []
        for comb in itertools.product(range(grid + 1), repeat=dim - 1):
            if sum(comb) <= grid:
                last = grid - sum(comb)
                pts.append(tuple(c / grid for c in comb) + (last / grid,))
        return np.array(pts)

    rows = simplex_grid(nM)  # candidate rows for each source
    ys = simplex_grid(nR)

    arrs = platform.as_arrays()
    mx, pmax = hard_ops()
    best = (np.inf, None, None)
    # enumerate the cross product of row choices (vectorized over y)
    ys_j = jnp.asarray(ys)

    @jax.jit
    def eval_ys(x):
        def f(y):
            out = phase_model(*[jnp.asarray(a) for a in arrs[:5]],
                              arrs[5], x, y, tuple(barriers), mx, pmax)
            return out["makespan"]
        return jax.vmap(f)(ys_j)

    for rows_choice in itertools.product(range(len(rows)), repeat=nS):
        x = np.stack([rows[r] for r in rows_choice])
        vals = np.asarray(eval_ys(jnp.asarray(x)))
        k = int(vals.argmin())
        if vals[k] < best[0]:
            best = (float(vals[k]), x, ys[k])

    plan = ExecutionPlan(x=best[1], y=best[2], meta="brute_force")
    return PlanResult(
        plan=plan,
        makespan=best[0],
        breakdown=phase_breakdown(platform, plan, barriers),
        mode="brute_force",
        barriers=tuple(barriers),
        objective=best[0],
    )
