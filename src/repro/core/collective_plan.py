"""Hierarchical multi-pod gradient aggregation, planned with the paper's
model.

In multi-pod data parallelism the gradient all-reduce decomposes into

  push    : intra-pod reduce-scatter (ICI)   — every chip ends up with a
            1/N shard of the pod-local gradient sum,
  map     : local accumulation (free),
  shuffle : cross-pod reduction over DCN     — each *parameter segment* is
            reduced at exactly one owning pod (one-reducer-per-key!), then
  reduce  : the reduced segments are broadcast back (intra-pod all-gather).

The cross-pod stage is exactly the paper's shuffle: the key space is the
parameter index space, ``y_k`` is the fraction of parameters owned by pod
``k``, and heterogeneous per-pod DCN bandwidth makes non-uniform ownership
profitable.  This module plans ``y`` via :func:`repro.core.optimize`'s
machinery and converts the result into concrete **segment sizes** (quantized
to TP-shard-aligned blocks) that the training step's shard_map collective
schedule consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .makespan import BARRIERS_ALL_PIPELINED
from .optimize import optimize_plan
from .plan import ExecutionPlan
from .platform import Platform

__all__ = ["ReductionPlan", "plan_cross_pod_reduction", "reduction_platform"]


@dataclasses.dataclass(frozen=True)
class ReductionPlan:
    """Cross-pod reduction ownership.

    ``fractions[k]`` — fraction of the flat parameter space pod ``k`` owns
    for the DCN reduction; ``segment_sizes`` — the same quantized to
    ``block`` elements, summing to ``n_elements``; ``est_time_s`` — modeled
    wall time of the full hierarchical all-reduce.
    """

    fractions: np.ndarray
    segment_sizes: np.ndarray
    n_elements: int
    block: int
    est_time_s: float
    uniform_time_s: float

    @property
    def speedup_vs_uniform(self) -> float:
        return self.uniform_time_s / max(self.est_time_s, 1e-12)

    def segment_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.segment_sizes)])


def reduction_platform(
    grad_mb: float,
    pod_dcn_bw_mbps: Sequence[float],
    ici_bw_mbps: float = 50_000.0,
    chips_per_pod: int = 256,
    accum_rate_mbps: float = 800_000.0,
) -> Platform:
    """Express one hierarchical all-reduce as a tripartite platform.

    Sources and mappers are pods (the intra-pod reduce-scatter feeds the
    pod's DCN egress), reducers are pods as segment owners.  ``D_i`` is the
    pod-local reduced gradient (``grad_mb``); push links model the intra-pod
    reduce-scatter bandwidth (ICI, scaled by the (N-1)/N ring factor);
    shuffle links model pod-to-pod DCN paths (bounded by the slower end's
    per-pod DCN bandwidth); compute rates model the reduction arithmetic
    (HBM-bound, effectively free relative to DCN).
    """
    bw = np.asarray(pod_dcn_bw_mbps, dtype=np.float64)
    P = bw.shape[0]
    ring = (chips_per_pod - 1) / chips_per_pod if chips_per_pod > 1 else 1.0
    # push: each pod feeds its own aggregation stage over ICI (x = I).
    B_sm = np.full((P, P), 1e-6)
    np.fill_diagonal(B_sm, ici_bw_mbps * ring)
    # shuffle: pod j ships the segment owned by pod k.  The sender's DCN NIC
    # is shared across its P-1 remote destinations (egress serialization) —
    # the per-link independence of the paper's model needs this division to
    # describe a NIC-bound fabric.
    B_mr = np.empty((P, P))
    for j in range(P):
        for k in range(P):
            B_mr[j, k] = (
                ici_bw_mbps * ring if j == k else bw[j] / max(P - 1, 1)
            )
    pods = np.arange(P)
    return Platform(
        D=np.full(P, grad_mb),
        B_sm=B_sm,
        B_mr=B_mr,
        C_m=np.full(P, accum_rate_mbps),
        # reduce = the owner ingesting P-1 remote contributions through its
        # own DCN NIC (ingress serialization) and accumulating.
        C_r=np.minimum(bw, accum_rate_mbps),
        alpha=1.0,
        cluster_s=pods,
        cluster_m=pods,
        cluster_r=pods,
        name=f"xpod_reduction_{P}pods",
    )


def plan_cross_pod_reduction(
    grad_mb: float,
    pod_dcn_bw_mbps: Sequence[float],
    n_elements: int,
    block: int = 512,
    ici_bw_mbps: float = 50_000.0,
    chips_per_pod: int = 256,
    n_restarts: int = 8,
    steps: int = 300,
    seed: int = 0,
) -> ReductionPlan:
    """Plan non-uniform cross-pod segment ownership.

    With homogeneous DCN this reduces to uniform 1/P ownership; with
    heterogeneous per-pod DCN bandwidth (shared fabrics, degraded NICs,
    multi-tenant cells) the slower pods own proportionally less of the
    parameter space.
    """
    platform = reduction_platform(
        grad_mb, pod_dcn_bw_mbps, ici_bw_mbps, chips_per_pod
    )
    P = platform.nR
    # sources push their own gradient to their own aggregator: x = I.
    x = np.eye(P)
    res = optimize_plan(
        platform,
        mode="e2e_shuffle",
        barriers=BARRIERS_ALL_PIPELINED,
        n_restarts=n_restarts,
        steps=steps,
        seed=seed,
        fixed_x=x,
    )
    from .makespan import makespan

    plan = ExecutionPlan(x=x, y=res.plan.y, meta="xpod_reduction")
    uniform = ExecutionPlan(x=x, y=np.full(P, 1.0 / P), meta="uniform")
    est = makespan(platform, plan, BARRIERS_ALL_PIPELINED)
    uni = makespan(platform, uniform, BARRIERS_ALL_PIPELINED)
    if est > uni:  # never accept a plan worse than uniform ownership
        plan, est = uniform, uni

    # quantize fractions to block-aligned segment sizes summing exactly.
    n_blocks = max(n_elements // block, P)
    raw = plan.y * n_blocks
    sizes = np.floor(raw).astype(np.int64)
    remainder = int(n_blocks - sizes.sum())
    order = np.argsort(-(raw - sizes))
    for idx in order[:remainder]:
        sizes[idx] += 1
    seg = sizes * block
    seg[-1] += n_elements - int(seg.sum())  # absorb the tail
    return ReductionPlan(
        fractions=plan.y.copy(),
        segment_sizes=seg,
        n_elements=n_elements,
        block=block,
        est_time_s=float(est),
        uniform_time_s=float(uni),
    )
