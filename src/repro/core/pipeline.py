"""Multi-stage pipelines: DAGs of MapReduce stages over one substrate.

Real geo-analytics workloads are rarely a single MapReduce job — they are
chains (and DAGs) of stages where one stage's reduce output is the next
stage's source data.  This module is the *plan layer* of that extension:

* :class:`StageSpec` — one stage: its platform view (``D`` is authoritative
  only for root stages), the upstream stages feeding it, and the stage's
  reduce-output scale (output MB per reduce-input MB).
* :class:`PipelineSpec` — the validated stage DAG: upstream indices must
  form an acyclic graph (cycles are rejected at construction), every stage
  must live on the same :class:`~repro.core.platform.Substrate`, and a
  dependent stage requires ``nS == nR`` so that upstream reducer ``r`` is
  downstream source ``r`` (each substrate node hosts one source, one
  mapper, one reducer — the layout every generator in
  :mod:`repro.core.platform` produces).

The *cross-stage coupling* lives in :meth:`PipelineSpec.derived_D`: a
downstream stage's source vector is a function of its upstream stages'
shuffle fractions ``y`` — placing stage ``k``'s reducers decides where
stage ``k+1``'s data sits.  A stagewise-greedy planner ignores that
coupling (it places stage-``k`` reducers where stage ``k`` finishes
fastest, even when that strands stage ``k+1``'s input behind slow
backbone links); the ``end_to_end`` pipeline planner in
:mod:`repro.core.optimize` differentiates straight through it.  Pricing
lives in :meth:`repro.core.makespan.CostModel.price_pipeline`; execution
(with real per-source release gating) in :mod:`repro.core.simulate`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..analysis.validate import validate_stage_coupling
from .plan import ExecutionPlan
from .platform import Platform, Substrate

__all__ = ["PipelineSpec", "StageSpec", "chain_spec"]


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One stage of a pipeline: a MapReduce job plus its upstream edges.

    Attributes:
      platform:  the stage's substrate view.  ``platform.D`` is the stage's
                 source data only for *root* stages (no ``deps``); for a
                 dependent stage the effective ``D`` is derived from the
                 upstream stages' reduce output (see
                 :meth:`PipelineSpec.derived_D`) and the view's own ``D``
                 is ignored.
      deps:      indices of the upstream stages whose reduce output feeds
                 this stage (source ``s`` receives upstream reducer ``s``'s
                 output).
      out_scale: reduce-output MB per reduce-input MB of this stage — the
                 reduce-side analogue of ``alpha`` (1.0: the reducers emit
                 what they ingest, e.g. a sort; 0.1: a 10x aggregation).
      name:      label for reports.
    """

    platform: Platform
    deps: Tuple[int, ...] = ()
    out_scale: float = 1.0
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "deps", tuple(int(d) for d in self.deps))
        if self.out_scale < 0:
            raise ValueError(f"out_scale must be >= 0, got {self.out_scale}")
        if len(set(self.deps)) != len(self.deps):
            raise ValueError(f"duplicate deps {self.deps}")

    @property
    def alpha(self) -> float:
        """The stage's map expansion factor (read off its platform view)."""
        return float(self.platform.alpha)


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    """A validated DAG of :class:`StageSpec`\\ s over one substrate."""

    stages: Tuple[StageSpec, ...]

    def __post_init__(self):
        stages = tuple(self.stages)
        object.__setattr__(self, "stages", stages)
        if not stages:
            raise ValueError("a pipeline needs at least one stage")
        n = len(stages)
        sub = Substrate.of(stages[0].platform)
        for k, stage in enumerate(stages):
            if not sub.compatible(Substrate.of(stage.platform)):
                raise ValueError(
                    f"stage {k} ({stage.platform.name!r}) does not share the "
                    "substrate — build stage platforms with Substrate.view()"
                )
            validate_stage_coupling(
                k, stage.platform.nS, stage.platform.nR, stage.deps, n
            )
        object.__setattr__(self, "_topo", self._toposort())

    # -- structure ---------------------------------------------------------
    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def substrate(self) -> Substrate:
        return Substrate.of(self.stages[0].platform)

    def _toposort(self) -> Tuple[int, ...]:
        """Kahn topological order; raises on cycles."""
        n = len(self.stages)
        indeg = [len(s.deps) for s in self.stages]
        children: List[List[int]] = [[] for _ in range(n)]
        for k, stage in enumerate(self.stages):
            for d in stage.deps:
                children[d].append(k)
        order = [k for k in range(n) if indeg[k] == 0]
        head = 0
        while head < len(order):
            for c in children[order[head]]:
                indeg[c] -= 1
                if indeg[c] == 0:
                    order.append(c)
            head += 1
        if len(order) != n:
            cyclic = sorted(set(range(n)) - set(order))
            raise ValueError(
                f"pipeline stage graph has a cycle through stages {cyclic}"
            )
        return tuple(order)

    def topo_order(self) -> Tuple[int, ...]:
        """Stage indices in dependency order (upstream before downstream)."""
        return self._topo

    def children(self) -> Tuple[Tuple[int, ...], ...]:
        """Per-stage downstream stage indices (the transpose of ``deps``)."""
        out: List[List[int]] = [[] for _ in self.stages]
        for k, stage in enumerate(self.stages):
            for d in stage.deps:
                out[d].append(k)
        return tuple(tuple(c) for c in out)

    def sinks(self) -> Tuple[int, ...]:
        """Stages no other stage consumes (the pipeline's outputs)."""
        consumed = {d for s in self.stages for d in s.deps}
        return tuple(k for k in range(len(self.stages)) if k not in consumed)

    # -- cross-stage data coupling ----------------------------------------
    def derived_D(
        self, plans: Sequence[ExecutionPlan]
    ) -> List[np.ndarray]:
        """Each stage's effective source vector (MB) under ``plans``.

        Root stages keep their platform's ``D``.  A dependent stage's
        source ``s`` receives every upstream stage ``u``'s reduce output at
        reducer ``s``: ``out_scale_u · alpha_u · total_u · y_u[s]`` where
        ``total_u`` is stage ``u``'s total map input (== its own derived
        ``D`` summed, since push fractions conserve volume).  This is the
        inter-stage coupling — downstream ``D`` is a function of upstream
        ``y`` — that end-to-end pipeline planning differentiates through
        and stagewise planning ignores.
        """
        if len(plans) != len(self.stages):
            raise ValueError(
                f"one plan per stage, got {len(plans)} plans for "
                f"{len(self.stages)} stages"
            )
        out: List[Optional[np.ndarray]] = [None] * len(self.stages)
        for k in self._topo:
            stage = self.stages[k]
            if not stage.deps:
                out[k] = np.asarray(stage.platform.D, dtype=np.float64).copy()
                continue
            D = np.zeros(stage.platform.nS, dtype=np.float64)
            for u in stage.deps:
                up = self.stages[u]
                total_u = float(out[u].sum())
                D += (
                    up.out_scale * up.alpha * total_u
                    * np.asarray(plans[u].y, dtype=np.float64)
                )
            out[k] = D
        return list(out)  # type: ignore[arg-type]

    def stage_platforms(
        self, plans: Sequence[ExecutionPlan]
    ) -> List[Platform]:
        """Per-stage platform views carrying the derived ``D`` — what the
        cost model prices and the facade adopts after planning."""
        sub = self.substrate
        return [
            sub.view(D, stage.alpha,
                     name=stage.name or f"{sub.name}/stage{k}")
            for k, (stage, D) in enumerate(
                zip(self.stages, self.derived_D(plans))
            )
        ]


def chain_spec(
    platforms: Sequence[Platform],
    out_scales: Optional[Sequence[float]] = None,
    names: Optional[Sequence[str]] = None,
) -> PipelineSpec:
    """A linear pipeline: stage ``k+1`` consumes stage ``k``'s reduce
    output — the dominant multi-stage shape (iterated MapReduce)."""
    if out_scales is None:
        out_scales = [1.0] * len(platforms)
    if names is None:
        names = [f"stage{k}" for k in range(len(platforms))]
    if not (len(platforms) == len(out_scales) == len(names)):
        raise ValueError("platforms, out_scales and names must align")
    stages = [
        StageSpec(
            platform=p,
            deps=(k - 1,) if k else (),
            out_scale=float(out_scales[k]),
            name=str(names[k]),
        )
        for k, p in enumerate(platforms)
    ]
    return PipelineSpec(stages=tuple(stages))
