"""MoE dispatch planning — the paper's shuffle optimization applied to
token→expert all-to-all.

A Mixture-of-Experts layer routes every token to its top-k experts.  Under
expert parallelism the experts live on different devices (possibly different
*pods*), so routing is an all-to-all over heterogeneous links: intra-pod ICI
vs inter-pod DCN.  The correspondence to the paper is exact:

* data sources / mappers = the data-parallel token shards (router output),
* reducers              = expert shards,
* the one-reducer-per-key constraint = one-*expert*-per-token-assignment:
  every token assigned to expert ``e`` must reach the shard hosting ``e``,
* ``alpha``             = top_k (each token's hidden vector is replicated to
  k experts),
* ``y_k``               = fraction of router probability mass the planner
  *biases* toward expert group ``k``.

The planner cannot change which expert a token semantically wants — but MoE
routers are trained with load-balancing auxiliary losses and capacity
factors, and production systems bias routing for systems reasons.  The plan
is exported as **per-expert-group capacity fractions**: the MoE layer turns
them into per-expert capacity and an additive router bias, keeping hot
experts on well-connected shards busy and starving experts behind slow DCN
links.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .makespan import BARRIERS_ALL_PIPELINED, makespan
from .optimize import optimize_plan
from .plan import ExecutionPlan
from .platform import Platform

__all__ = ["MoEDispatchPlan", "plan_moe_dispatch", "moe_platform"]


@dataclasses.dataclass(frozen=True)
class MoEDispatchPlan:
    """``group_fractions[g]`` — planned share of routed tokens for expert
    group ``g``; ``capacity_factor[g]`` — multiplier on the uniform
    per-group capacity; ``router_bias[g]`` — additive log-bias implementing
    the plan in a trained router; ``est_time_s`` / ``uniform_time_s`` —
    modeled all-to-all times."""

    group_fractions: np.ndarray
    capacity_factor: np.ndarray
    router_bias: np.ndarray
    est_time_s: float
    uniform_time_s: float

    @property
    def speedup_vs_uniform(self) -> float:
        return self.uniform_time_s / max(self.est_time_s, 1e-12)


def moe_platform(
    tokens_mb_per_shard: float,
    n_token_shards: int,
    group_pod: Sequence[int],
    shard_pod: Sequence[int],
    top_k: int = 1,
    ici_bw_mbps: float = 50_000.0,
    dcn_bw_mbps: float = 6_400.0,
    expert_flops_rate_mbps: float = 25_000.0,
) -> Platform:
    """Build the tripartite platform for one MoE dispatch.

    ``shard_pod[i]`` — pod of token shard ``i``; ``group_pod[g]`` — pod of
    expert group ``g``.  Push is the router itself (device-local, fast);
    shuffle is the dispatch all-to-all; reduce is expert FFN compute.
    """
    shard_pod = np.asarray(shard_pod)
    group_pod = np.asarray(group_pod)
    nS = n_token_shards
    nG = group_pod.shape[0]
    # push: token shards "push" to themselves (router is local) — model as a
    # near-infinite diagonal so the push phase is negligible.
    B_sm = np.full((nS, nS), 1e9)
    # dispatch all-to-all: a shard's egress NIC is shared across the remote
    # groups it feeds (same per-link sharing note as collective_plan).
    n_remote = np.array(
        [max(int((group_pod != shard_pod[j]).sum()), 1) for j in range(nS)]
    )
    B_mr = np.empty((nS, nG))
    for j in range(nS):
        for g in range(nG):
            B_mr[j, g] = (
                ici_bw_mbps
                if shard_pod[j] == group_pod[g]
                else dcn_bw_mbps / n_remote[j]
            )
    rate = np.broadcast_to(
        np.asarray(expert_flops_rate_mbps, dtype=np.float64), (nG,)
    ).copy()
    return Platform(
        D=np.full(nS, tokens_mb_per_shard),
        B_sm=B_sm,
        B_mr=B_mr,
        C_m=np.full(nS, 1e9),  # router cost negligible
        C_r=rate,
        alpha=float(top_k),
        cluster_s=shard_pod.copy(),
        cluster_m=shard_pod.copy(),
        cluster_r=group_pod.copy(),
        name=f"moe_dispatch_{nG}groups",
    )


def plan_moe_dispatch(
    tokens_mb_per_shard: float,
    n_token_shards: int,
    group_pod: Sequence[int],
    shard_pod: Sequence[int],
    top_k: int = 1,
    ici_bw_mbps: float = 50_000.0,
    dcn_bw_mbps: float = 6_400.0,
    expert_flops_rate_mbps=25_000.0,
    max_capacity_factor: float = 2.0,
    n_restarts: int = 8,
    steps: int = 300,
    seed: int = 0,
) -> MoEDispatchPlan:
    """Plan expert-group token fractions minimizing dispatch+compute time."""
    platform = moe_platform(
        tokens_mb_per_shard,
        n_token_shards,
        group_pod,
        shard_pod,
        top_k,
        ici_bw_mbps,
        dcn_bw_mbps,
        expert_flops_rate_mbps,
    )
    nG = platform.nR
    x = np.eye(n_token_shards)
    res = optimize_plan(
        platform,
        mode="e2e_shuffle",
        barriers=BARRIERS_ALL_PIPELINED,
        n_restarts=n_restarts,
        steps=steps,
        seed=seed,
        fixed_x=x,
    )
    y = res.plan.y.copy()
    uniform = np.full(nG, 1.0 / nG)
    # cap the skew: an expert group can absorb at most max_capacity_factor ×
    # its uniform share (routers cannot be biased arbitrarily without
    # quality loss).  Water-fill: cap, redistribute the excess among the
    # uncapped groups proportionally, repeat until stable.
    cap_val = max_capacity_factor / nG
    for _ in range(nG):
        over = y > cap_val + 1e-12
        if not over.any():
            break
        excess = float((y[over] - cap_val).sum())
        y[over] = cap_val
        free = ~over
        if not free.any():
            y = np.full(nG, 1.0 / nG)
            break
        y[free] += excess * y[free] / max(y[free].sum(), 1e-12)
    y = y / y.sum()
    est = makespan(platform, ExecutionPlan(x=x, y=y), BARRIERS_ALL_PIPELINED)
    uni = makespan(
        platform, ExecutionPlan(x=x, y=uniform), BARRIERS_ALL_PIPELINED
    )
    if est > uni:
        y, est = uniform, uni
    cap = y / uniform
    bias = np.log(np.maximum(y, 1e-9)) - np.log(uniform)
    return MoEDispatchPlan(
        group_fractions=y,
        capacity_factor=cap,
        router_bias=bias,
        est_time_s=float(est),
        uniform_time_s=float(uni),
    )
