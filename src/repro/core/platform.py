"""Platform model for distributed MapReduce execution (paper §2.1).

The distributed platform is a tripartite graph ``S ∪ M ∪ R`` (data sources,
mappers, reducers).  Node ``i ∈ M ∪ R`` has a compute capacity ``C_i`` in
bytes/second of *incoming* data processed; edge ``(i, j)`` has bandwidth
``B_ij``; data ``D_i`` originates at source ``i``; the application is modeled
by a single expansion factor ``alpha`` = (map output bytes) / (map input
bytes).

All quantities in this module use **MB** and **seconds** (so rates are MB/s),
which keeps the numbers well-scaled for the gradient-based optimizer.

Generators are provided for

* the two-cluster worked example of paper §1.3,
* the PlanetLab-derived environments of §4.1 (1 / 2 / 4 / 8 data centers,
  Table 1 bandwidth ranges, 9–90 MB/s compute rates), and
* a TPU-pod environment (ICI-connected pods over a slower DCN), which is the
  geo-distributed platform the rest of this framework plans for.
"""
from __future__ import annotations

import bisect
import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

from ..analysis.validate import require_finite, require_positive

__all__ = [
    "CapacityTrace",
    "FailureEvent",
    "FailureTrace",
    "Platform",
    "Substrate",
    "two_cluster_example",
    "planetlab_platform",
    "tpu_pod_platform",
    "PLANETLAB_SITES",
    "TABLE1_BANDWIDTH_KBPS",
]


@dataclasses.dataclass(frozen=True)
class CapacityTrace:
    """A drifting resource capacity as a right-open step function.

    ``values[i]`` (MB/s) applies on ``[times[i], times[i+1])``; the last
    value holds forever.  ``times`` must start at 0 and strictly increase,
    so a trace always answers :meth:`at` for any ``t >= 0``.  Traces attach
    to a :class:`Substrate` by resource name (see
    :meth:`Substrate.with_traces`) and model WAN capacity drift the planner
    did not know at plan time: the executor serves each chunk at the
    capacity in force when its service *starts*, and :meth:`Substrate.at`
    gives an online planner the capacities in force at any instant.
    """

    times: Tuple[float, ...]
    values: Tuple[float, ...]

    def __post_init__(self):
        times = tuple(float(t) for t in self.times)
        values = tuple(float(v) for v in self.values)
        object.__setattr__(self, "times", times)
        object.__setattr__(self, "values", values)
        if len(times) != len(values) or not times:
            raise ValueError("times and values must be equal-length, non-empty")
        if times[0] != 0.0:
            raise ValueError("a CapacityTrace must start at t=0")
        if any(b <= a for a, b in zip(times, times[1:])):
            raise ValueError("times must strictly increase")
        if any(v <= 0 for v in values):
            raise ValueError("capacities must be strictly positive")

    @classmethod
    def step(cls, before: float, after: float, t: float) -> "CapacityTrace":
        """A single capacity step: ``before`` MB/s on [0, t), ``after``
        from ``t`` on — the one-event drift of a degrading backbone link."""
        return cls(times=(0.0, float(t)), values=(before, after))

    def at(self, t: float) -> float:
        """Capacity (MB/s) in force at absolute time ``t``."""
        idx = bisect.bisect_right(self.times, float(t)) - 1
        return self.values[max(idx, 0)]


#: the typed discrete failure modes the executor injects (ROADMAP §2):
#: workers die, whole clusters partition away and heal.
FAILURE_KINDS = ("mapper_kill", "reducer_kill", "cluster_partition")

#: capacity factor applied to dead/partitioned resources in planning views
#: (:meth:`Substrate.at`): not exactly zero — the softmax planner needs an
#: epsilon escape mass, matching ``optimize._degraded_platform``.
FAILURE_EPS = 1e-3


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One typed discrete failure.

    Kinds (``FAILURE_KINDS``):

    * ``mapper_kill``  — the worker on mapper ``node`` dies at ``time``;
      un-delivered partial input is lost and re-executed from a surviving
      replica (when one holds the bytes) or re-pushed from the source.
    * ``reducer_kill`` — reducer ``node`` dies at ``time``; delivered but
      un-consumed shuffle input *and* already-reduced output are lost and
      re-emitted from the mappers' durable map output.
    * ``cluster_partition`` — cluster ``cluster`` partitions away on
      ``[time, t_repair)``: every link crossing the partition boundary is
      down, in-flight transfers on those links are dropped (retransmitted
      after repair), queued ones wait or get re-routed by a replan.
      ``t_repair=None`` means the partition never heals.

    Kills attach per job (``SimConfig(failures=...)`` — that job's worker
    dies) or substrate-wide (:meth:`Substrate.with_failures` — the node
    dies for every job); partitions are fabric facts and only attach to
    the substrate.
    """

    kind: str
    time: float
    node: Optional[int] = None
    cluster: Optional[int] = None
    t_repair: Optional[float] = None

    def __post_init__(self):
        if self.kind not in FAILURE_KINDS:
            raise ValueError(
                f"kind must be one of {FAILURE_KINDS}, got {self.kind!r}"
            )
        object.__setattr__(self, "time", float(self.time))
        if not np.isfinite(self.time) or self.time < 0:
            raise ValueError(f"time must be finite and >= 0, got {self.time}")
        if self.kind == "cluster_partition":
            if self.node is not None:
                raise ValueError("cluster_partition takes cluster=, not node=")
            if self.cluster is None:
                raise ValueError("cluster_partition needs cluster=")
            object.__setattr__(self, "cluster", int(self.cluster))
            if self.t_repair is not None:
                object.__setattr__(self, "t_repair", float(self.t_repair))
                if self.t_repair <= self.time:
                    raise ValueError(
                        f"t_repair={self.t_repair} must exceed time={self.time}"
                    )
        else:
            if self.node is None:
                raise ValueError(f"{self.kind} needs node=")
            if self.cluster is not None or self.t_repair is not None:
                raise ValueError(
                    f"{self.kind} takes node= and time= only (kills are "
                    "permanent; repair applies to partitions)"
                )
            object.__setattr__(self, "node", int(self.node))
            if self.node < 0:
                raise ValueError(f"node must be >= 0, got {self.node}")

    # -- ergonomic constructors -------------------------------------------
    @classmethod
    def mapper_kill(cls, mapper: int, time: float) -> "FailureEvent":
        return cls(kind="mapper_kill", time=time, node=mapper)

    @classmethod
    def reducer_kill(cls, reducer: int, time: float) -> "FailureEvent":
        return cls(kind="reducer_kill", time=time, node=reducer)

    @classmethod
    def cluster_partition(
        cls, cluster: int, time: float, t_repair: Optional[float] = None
    ) -> "FailureEvent":
        return cls(kind="cluster_partition", time=time, cluster=cluster,
                   t_repair=t_repair)


@dataclasses.dataclass(frozen=True)
class FailureTrace:
    """A substrate-level fault script: typed :class:`FailureEvent`\\ s in
    time order, attached via :meth:`Substrate.with_failures` exactly like a
    :class:`CapacityTrace` attaches per resource.  The executor fires each
    event against *every* job sharing the substrate; :meth:`times` gives an
    online policy the decision instants to watch, and :meth:`Substrate.at`
    folds the failure state in force at ``t`` into the capacity arrays a
    re-planner sees (dead resources at ``FAILURE_EPS`` until repair)."""

    events: Tuple[FailureEvent, ...]

    def __post_init__(self):
        events = tuple(self.events)
        for ev in events:
            if not isinstance(ev, FailureEvent):
                raise TypeError(f"not a FailureEvent: {ev!r}")
        object.__setattr__(
            self, "events",
            tuple(sorted(events, key=lambda e: (e.time, e.kind,
                                                -1 if e.node is None else e.node,
                                                -1 if e.cluster is None
                                                else e.cluster))),
        )

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    def times(self) -> Tuple[float, ...]:
        """Every decision instant (ascending, t > 0): each failure's fire
        time plus each partition's repair time — what a reactive online
        policy watches, the fault analogue of
        :meth:`Substrate.drift_times`."""
        ts = {ev.time for ev in self.events if ev.time > 0}
        ts |= {ev.t_repair for ev in self.events
               if ev.t_repair is not None and ev.t_repair > 0}
        return tuple(sorted(ts))


#: resource-name grammar shared with :meth:`Substrate.resources` — traces
#: key into the same namespace the executor's per-resource stats use.
_TRACE_KEY_RE = re.compile(
    r"^(?:push\[s(\d+)->m(\d+)\]|shuffle\[m(\d+)->r(\d+)\]"
    r"|map\[m(\d+)\]|reduce\[r(\d+)\])$"
)


@dataclasses.dataclass(frozen=True)
class Substrate:
    """The shared physical resources of a distributed platform.

    A substrate is everything about the tripartite graph that is *not*
    job-specific: named link/compute resources with capacities, plus the
    cluster topology.  Concurrent jobs contend for the same substrate
    entries; a :class:`Platform` is one job's slice of it
    (:meth:`Substrate.view` attaches the job's ``D`` and ``alpha`` *without
    copying* the capacity arrays, so two jobs literally reference the same
    ``B_sm``/``B_mr``/``C_m``/``C_r`` rows).

    Attributes:
      B_sm:  (nS, nM) push-link bandwidth, MB/s.
      B_mr:  (nM, nR) shuffle-link bandwidth, MB/s.
      C_m:   (nM,) mapper compute rate, MB/s of input data.
      C_r:   (nR,) reducer compute rate, MB/s of input data.
      cluster_s/m/r: integer cluster (site) id per node.
      traces: optional {resource name -> :class:`CapacityTrace`} overriding
        the (nominal, t=0) capacity arrays over time.  The executor reads
        the trace at each chunk's service start; an online planner reads
        :meth:`at` for the capacities in force at a decision instant.
      failures: optional substrate-level :class:`FailureTrace` — discrete
        fault events (kills, partitions) affecting every job sharing the
        substrate, threaded through the executor like the traces.
    """

    B_sm: np.ndarray
    B_mr: np.ndarray
    C_m: np.ndarray
    C_r: np.ndarray
    cluster_s: np.ndarray
    cluster_m: np.ndarray
    cluster_r: np.ndarray
    name: str = "substrate"
    traces: Optional[Dict[str, CapacityTrace]] = None
    failures: Optional[FailureTrace] = None

    def __post_init__(self):
        for field in ("B_sm", "B_mr", "C_m", "C_r"):
            # require_positive also rejects NaN/inf, which `<= 0` lets pass
            object.__setattr__(
                self, field, require_positive(field, getattr(self, field))
            )
        nS, nM = self.B_sm.shape
        nM2, nR = self.B_mr.shape
        if nM != nM2:
            raise ValueError(f"B_sm/B_mr mapper dims disagree: {nM} vs {nM2}")
        if self.C_m.shape != (nM,):
            raise ValueError(f"C_m shape {self.C_m.shape} != ({nM},)")
        if self.C_r.shape != (nR,):
            raise ValueError(f"C_r shape {self.C_r.shape} != ({nR},)")
        if self.traces:
            known = self.resources()
            for key, trace in self.traces.items():
                if not isinstance(trace, CapacityTrace):
                    raise TypeError(f"trace for {key!r} is not a CapacityTrace")
                if _TRACE_KEY_RE.match(key) is None or key not in known:
                    raise ValueError(
                        f"unknown trace key {key!r} — use a resource name "
                        "from Substrate.resources()"
                    )
        if self.failures:
            if not isinstance(self.failures, FailureTrace):
                raise TypeError("failures must be a FailureTrace")
            clusters = (set(np.unique(self.cluster_s).tolist())
                        | set(np.unique(self.cluster_m).tolist())
                        | set(np.unique(self.cluster_r).tolist()))
            for ev in self.failures:
                if ev.kind == "mapper_kill" and ev.node >= self.nM:
                    raise ValueError(
                        f"mapper_kill node {ev.node} out of range (nM={self.nM})"
                    )
                if ev.kind == "reducer_kill" and ev.node >= self.nR:
                    raise ValueError(
                        f"reducer_kill node {ev.node} out of range (nR={self.nR})"
                    )
                if ev.kind == "cluster_partition" and ev.cluster not in clusters:
                    raise ValueError(
                        f"cluster_partition cluster {ev.cluster} is not a "
                        f"cluster id of this substrate ({sorted(clusters)})"
                    )

    # -- sizes ------------------------------------------------------------
    @property
    def nS(self) -> int:
        return self.B_sm.shape[0]

    @property
    def nM(self) -> int:
        return self.B_sm.shape[1]

    @property
    def nR(self) -> int:
        return self.B_mr.shape[1]

    # -- construction ------------------------------------------------------
    @classmethod
    def of(cls, platform: "Platform") -> "Substrate":
        """The substrate behind ``platform`` — its declared one when it was
        built as a view, otherwise a substrate sharing the platform's own
        capacity arrays (so views of it contend with the original job)."""
        if platform.substrate is not None:
            return platform.substrate
        return cls(
            B_sm=platform.B_sm,
            B_mr=platform.B_mr,
            C_m=platform.C_m,
            C_r=platform.C_r,
            cluster_s=platform.cluster_s,
            cluster_m=platform.cluster_m,
            cluster_r=platform.cluster_r,
            name=platform.name,
        )

    def view(
        self,
        D: np.ndarray,
        alpha: float = 1.0,
        name: Optional[str] = None,
    ) -> "Platform":
        """One job's slice of this substrate: a :class:`Platform` carrying
        the job's data layout ``D`` and expansion factor ``alpha`` while
        *sharing* (not copying) the capacity arrays."""
        return Platform(
            D=np.asarray(D, dtype=np.float64),
            B_sm=self.B_sm,
            B_mr=self.B_mr,
            C_m=self.C_m,
            C_r=self.C_r,
            alpha=float(alpha),
            cluster_s=self.cluster_s,
            cluster_m=self.cluster_m,
            cluster_r=self.cluster_r,
            name=name or f"{self.name}/job",
            substrate=self,
        )

    def compatible(self, other: "Substrate") -> bool:
        """Two substrates describe the same physical resources when they are
        the same object or hold identical capacity arrays (jobs built from
        equal generator calls may legitimately share)."""
        if self is other:
            return True
        return (
            self.B_sm.shape == other.B_sm.shape
            and self.B_mr.shape == other.B_mr.shape
            and np.array_equal(self.B_sm, other.B_sm)
            and np.array_equal(self.B_mr, other.B_mr)
            and np.array_equal(self.C_m, other.C_m)
            and np.array_equal(self.C_r, other.C_r)
        )

    # -- named resources ---------------------------------------------------
    def resources(self) -> Dict[str, float]:
        """Every named resource and its capacity (MB/s): push/shuffle links
        and map/reduce compute nodes.  These names key the per-resource
        utilization stats of the multi-job executor."""
        out: Dict[str, float] = {}
        for i in range(self.nS):
            for j in range(self.nM):
                out[f"push[s{i}->m{j}]"] = float(self.B_sm[i, j])
        for j in range(self.nM):
            for k in range(self.nR):
                out[f"shuffle[m{j}->r{k}]"] = float(self.B_mr[j, k])
        for j in range(self.nM):
            out[f"map[m{j}]"] = float(self.C_m[j])
        for k in range(self.nR):
            out[f"reduce[r{k}]"] = float(self.C_r[k])
        return out

    def residual(
        self,
        push_frac: Optional[np.ndarray] = None,
        shuffle_frac: Optional[np.ndarray] = None,
        map_frac: Optional[np.ndarray] = None,
        reduce_frac: Optional[np.ndarray] = None,
        floor: float = 0.05,
    ) -> "Substrate":
        """A *planning* view of this substrate with the given fraction of
        each resource's capacity already committed to earlier jobs (greedy
        sequential scheduling).  Residual capacities are floored at
        ``floor`` of the original so later jobs always see a usable (if
        slow) platform.  The result is a distinct substrate — it prices
        hypothetical residual capacity and must not be used as the identity
        of the physical resources."""

        def scale(cap, frac):
            if frac is None:
                return cap.copy()  # never alias the physical substrate
            frac = np.clip(np.asarray(frac, dtype=np.float64), 0.0, 1.0 - floor)
            return cap * (1.0 - frac)

        return dataclasses.replace(
            self,
            B_sm=scale(self.B_sm, push_frac),
            B_mr=scale(self.B_mr, shuffle_frac),
            C_m=scale(self.C_m, map_frac),
            C_r=scale(self.C_r, reduce_frac),
            traces=None,  # a hypothetical planning view, not the live fabric
            failures=None,
            name=f"{self.name}/residual",
        )

    # -- capacity drift ----------------------------------------------------
    def with_traces(self, traces: Dict[str, CapacityTrace]) -> "Substrate":
        """This substrate with drifting capacities: ``traces`` maps resource
        names (the :meth:`resources` namespace) to step-function
        :class:`CapacityTrace`\\ s.  The base arrays stay the *nominal*
        (t=0) view every offline planner sees; the executor and
        :meth:`at` read the traces."""
        return dataclasses.replace(self, traces=dict(traces))

    def trace_for(self, name: str) -> Optional[CapacityTrace]:
        """The capacity trace attached to resource ``name``, if any."""
        return self.traces.get(name) if self.traces else None

    def drift_times(self) -> Tuple[float, ...]:
        """Every future instant (t > 0, ascending) at which some traced
        capacity steps — the event times a reactive online policy watches."""
        if not self.traces:
            return ()
        return tuple(sorted({
            t for trace in self.traces.values() for t in trace.times if t > 0
        }))

    # -- failures ----------------------------------------------------------
    def with_failures(self, events) -> "Substrate":
        """This substrate with a fault script: ``events`` is a
        :class:`FailureTrace` or an iterable of :class:`FailureEvent`\\ s.
        The executor fires each event against every job sharing the
        substrate; :meth:`at` folds the active failure state into the
        planning view (the fault analogue of :meth:`with_traces`)."""
        trace = events if isinstance(events, FailureTrace) \
            else FailureTrace(tuple(events))
        return dataclasses.replace(self, failures=trace)

    def failure_times(self) -> Tuple[float, ...]:
        """Every substrate-level failure/repair instant (t > 0, ascending)
        — decision times for a reactive online policy, like
        :meth:`drift_times`."""
        return self.failures.times() if self.failures else ()

    def partition_cut(self, cluster: int) -> Tuple[np.ndarray, np.ndarray]:
        """Boolean masks of the links a partition of ``cluster`` severs:
        ``(push_cut (nS, nM), shuffle_cut (nM, nR))`` — exactly the links
        with one endpoint inside the cluster and one outside."""
        s_in = self.cluster_s == cluster
        m_in = self.cluster_m == cluster
        r_in = self.cluster_r == cluster
        return (s_in[:, None] != m_in[None, :],
                m_in[:, None] != r_in[None, :])

    def at(self, t: float) -> "Substrate":
        """The capacities in force at absolute time ``t``: a plain (trace
        and failure free) substrate whose arrays fold every trace *and*
        every active failure in — the *current view* an online planner
        replans against.  Dead workers and partitioned links sit at
        ``FAILURE_EPS`` of nominal (until a partition's repair), so
        :func:`repro.core.optimize.replan_schedule` steers residual work
        around them without losing the softmax's escape mass."""
        if not self.traces and not self.failures:
            return self
        B_sm, B_mr = self.B_sm.copy(), self.B_mr.copy()
        C_m, C_r = self.C_m.copy(), self.C_r.copy()
        for key, trace in (self.traces or {}).items():
            m = _TRACE_KEY_RE.match(key)
            ps, pm, sm, sr, mm, rr = m.groups()
            if ps is not None:
                B_sm[int(ps), int(pm)] = trace.at(t)
            elif sm is not None:
                B_mr[int(sm), int(sr)] = trace.at(t)
            elif mm is not None:
                C_m[int(mm)] = trace.at(t)
            else:
                C_r[int(rr)] = trace.at(t)
        for ev in (self.failures or ()):
            if ev.time > t:
                continue
            if ev.kind == "mapper_kill":
                C_m[ev.node] *= FAILURE_EPS
                B_sm[:, ev.node] *= FAILURE_EPS
            elif ev.kind == "reducer_kill":
                C_r[ev.node] *= FAILURE_EPS
                B_mr[:, ev.node] *= FAILURE_EPS
            elif ev.t_repair is None or t < ev.t_repair:
                push_cut, shuf_cut = self.partition_cut(ev.cluster)
                B_sm = np.where(push_cut, B_sm * FAILURE_EPS, B_sm)
                B_mr = np.where(shuf_cut, B_mr * FAILURE_EPS, B_mr)
        return dataclasses.replace(
            self, B_sm=B_sm, B_mr=B_mr, C_m=C_m, C_r=C_r,
            traces=None, failures=None, name=f"{self.name}@{t:g}s",
        )

    def describe(self) -> str:
        drift = f" drifting@{len(self.traces)}" if self.traces else ""
        fail = f" failures@{len(self.failures)}" if self.failures else ""
        return (
            f"Substrate({self.name}: nS={self.nS} nM={self.nM} nR={self.nR}, "
            f"{len(self.resources())} resources{drift}{fail})"
        )


@dataclasses.dataclass(frozen=True)
class Platform:
    """A tripartite MapReduce platform (paper Figure 3): one job's slice of
    a (possibly shared) :class:`Substrate`.

    Attributes:
      D:     (nS,) data originating at each source, MB.
      B_sm:  (nS, nM) push-link bandwidth, MB/s.
      B_mr:  (nM, nR) shuffle-link bandwidth, MB/s.
      C_m:   (nM,) mapper compute rate, MB/s of input data.
      C_r:   (nR,) reducer compute rate, MB/s of input data.
      alpha: map output/input expansion factor.
      cluster_s/m/r: integer cluster (site) id per node — used by "local"
        heuristic plans and by the replication model; not used by the
        optimizer itself.
      substrate: the shared substrate this platform is a view of (set by
        :meth:`Substrate.view`); ``None`` for a standalone single-job
        platform, in which case :meth:`Substrate.of` lifts one on demand.
    """

    D: np.ndarray
    B_sm: np.ndarray
    B_mr: np.ndarray
    C_m: np.ndarray
    C_r: np.ndarray
    alpha: float
    cluster_s: np.ndarray
    cluster_m: np.ndarray
    cluster_r: np.ndarray
    name: str = "platform"
    substrate: Optional[Substrate] = None

    def __post_init__(self):
        object.__setattr__(self, "D", require_finite("D", self.D))
        for field in ("B_sm", "B_mr", "C_m", "C_r"):
            object.__setattr__(
                self, field, require_positive(field, getattr(self, field))
            )
        nS, nM = self.B_sm.shape
        nM2, nR = self.B_mr.shape
        if nM != nM2:
            raise ValueError(f"B_sm/B_mr mapper dims disagree: {nM} vs {nM2}")
        if self.D.shape != (nS,):
            raise ValueError(f"D shape {self.D.shape} != ({nS},)")
        if self.C_m.shape != (nM,):
            raise ValueError(f"C_m shape {self.C_m.shape} != ({nM},)")
        if self.C_r.shape != (nR,):
            raise ValueError(f"C_r shape {self.C_r.shape} != ({nR},)")
        if np.any(self.D < 0):
            raise ValueError("negative data size")
        if self.alpha <= 0:
            raise ValueError("alpha must be > 0")

    # -- sizes ------------------------------------------------------------
    @property
    def nS(self) -> int:
        return self.D.shape[0]

    @property
    def nM(self) -> int:
        return self.B_sm.shape[1]

    @property
    def nR(self) -> int:
        return self.B_mr.shape[1]

    def with_alpha(self, alpha: float) -> "Platform":
        return dataclasses.replace(self, alpha=float(alpha))

    def as_arrays(self):
        """Arrays in the order makespan() expects."""
        return (self.D, self.B_sm, self.B_mr, self.C_m, self.C_r, self.alpha)

    def total_data(self) -> float:
        return float(self.D.sum())

    def describe(self) -> str:
        return (
            f"Platform({self.name}: nS={self.nS} nM={self.nM} nR={self.nR} "
            f"D_total={self.total_data():.0f}MB alpha={self.alpha})"
        )


# ---------------------------------------------------------------------------
# §1.3 worked example
# ---------------------------------------------------------------------------

def two_cluster_example(
    alpha: float = 1.0,
    local_bw: float = 100.0,
    nonlocal_bw: float = 100.0,
    compute: float = 100.0,
    d1: float = 150_000.0,
    d2: float = 50_000.0,
) -> Platform:
    """The two-cluster example of paper §1.3.

    Two clusters, each with one source, one mapper, one reducer.  D1=150 GB,
    D2=50 GB (expressed in MB).  Local (intra-cluster) links run at
    ``local_bw`` MB/s, non-local at ``nonlocal_bw`` MB/s; every compute node
    processes ``compute`` MB/s.
    """
    local = np.array([[local_bw, nonlocal_bw], [nonlocal_bw, local_bw]])
    return Platform(
        D=np.array([d1, d2]),
        B_sm=local.copy(),
        B_mr=local.copy(),
        C_m=np.array([compute, compute]),
        C_r=np.array([compute, compute]),
        alpha=alpha,
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name=f"two_cluster(alpha={alpha},nl={nonlocal_bw})",
    )


# ---------------------------------------------------------------------------
# PlanetLab environments (paper §3.2/§4.1, Table 1)
# ---------------------------------------------------------------------------

#: The eight PlanetLab sites used in the paper (§4.1), with their continent.
PLANETLAB_SITES: Tuple[Tuple[str, str], ...] = (
    ("ucsb.edu", "US"),
    ("tamu.edu", "US"),
    ("hpl.hp.com", "US"),
    ("uiuc.edu", "US"),
    ("tkn.tu-berlin.de", "EU"),
    ("essex.ac.uk", "EU"),
    ("pnl.nitech.ac.jp", "Asia"),
    ("wide.ad.jp", "Asia"),
)

#: Table 1 — measured slowest/fastest inter-cluster bandwidth in KB/s.
TABLE1_BANDWIDTH_KBPS = {
    ("US", "US"): (216.0, 9405.0),
    ("US", "EU"): (110.0, 2267.0),
    ("US", "Asia"): (61.0, 3305.0),
    ("EU", "US"): (794.0, 2734.0),
    ("EU", "EU"): (4475.0, 11053.0),
    ("EU", "Asia"): (1502.0, 1593.0),
    ("Asia", "US"): (401.0, 3610.0),
    ("Asia", "EU"): (290.0, 1071.0),
    ("Asia", "Asia"): (23762.0, 23875.0),
}

#: Gigabit-Ethernet LAN bandwidth for intra-site links (the paper's emulated
#: testbed interconnect), MB/s.
LAN_BW_MBPS = 117.0

#: Unscaled compute-rate range measured on PlanetLab nodes (§3.2), MB/s.
COMPUTE_RATE_RANGE = (9.0, 90.0)


def _site_list(n_datacenters: int) -> Tuple[Tuple[str, str], ...]:
    if n_datacenters == 1:
        # Local data center: eight replica nodes at tamu.edu.
        return tuple([("tamu.edu", "US")] * 8)
    if n_datacenters == 2:
        # Intra-continental: tamu.edu + ucsb.edu, 4 replicas each.
        return tuple([("tamu.edu", "US")] * 4 + [("ucsb.edu", "US")] * 4)
    if n_datacenters == 4:
        # Global 4: ucsb, tamu, tu-berlin, nitech; 2 replicas each.
        sites = [
            ("ucsb.edu", "US"),
            ("tamu.edu", "US"),
            ("tkn.tu-berlin.de", "EU"),
            ("pnl.nitech.ac.jp", "Asia"),
        ]
        return tuple(s for s in sites for _ in range(2))
    if n_datacenters == 8:
        return PLANETLAB_SITES
    raise ValueError("n_datacenters must be one of {1, 2, 4, 8}")


def planetlab_platform(
    n_datacenters: int = 8,
    alpha: float = 1.0,
    data_per_source_mb: float = 256.0,
    seed: int = 0,
    compute_heterogeneity: bool = True,
) -> Platform:
    """Generate a PlanetLab-like environment per paper §4.1.

    Eight nodes total regardless of ``n_datacenters`` (replicas fill in when
    there are fewer real sites).  Each node hosts one source, one mapper and
    one reducer.  Inter-site bandwidth is sampled log-uniformly within the
    Table 1 (slowest, fastest) range for the continent pair; intra-site links
    run at LAN speed.  Compute rates are sampled in the measured 9–90 MB/s
    range (or fixed at the midpoint when ``compute_heterogeneity=False``).
    """
    rng = np.random.default_rng(seed)
    sites = _site_list(n_datacenters)
    n = len(sites)
    site_ids = np.array(
        [sorted({s for s, _ in sites}).index(s) for s, _ in sites], dtype=np.int64
    )

    # one measurement per unique site pair / per unique site: replica nodes
    # share their original's characteristics (paper §4.1: "we added replica
    # nodes ... with the measured node/link characteristics of the
    # corresponding real nodes") — a single-DC environment is therefore
    # genuinely homogeneous.
    pair_bw: dict = {}

    def site_pair_bw(si, ci, sj, cj) -> float:
        key = (si, sj)
        if key not in pair_bw:
            lo, hi = TABLE1_BANDWIDTH_KBPS[(ci, cj)]
            pair_bw[key] = float(
                np.exp(rng.uniform(np.log(lo), np.log(hi)))
            ) / 1024.0  # KB/s -> MB/s
        return pair_bw[key]

    bw = np.zeros((n, n))
    for i, (si, ci) in enumerate(sites):
        for j, (sj, cj) in enumerate(sites):
            if si == sj:
                bw[i, j] = LAN_BW_MBPS
            else:
                bw[i, j] = site_pair_bw(si, ci, sj, cj)

    lo, hi = COMPUTE_RATE_RANGE
    site_rate: dict = {}

    def rate_for(site):
        if site not in site_rate:
            site_rate[site] = (
                float(np.exp(rng.uniform(np.log(lo), np.log(hi)))),
                float(np.exp(rng.uniform(np.log(lo), np.log(hi)))),
            )
        return site_rate[site]

    if compute_heterogeneity:
        C_m = np.array([rate_for(s)[0] for s, _ in sites])
        C_r = np.array([rate_for(s)[1] for s, _ in sites])
    else:
        mid = float(np.mean(COMPUTE_RATE_RANGE))
        C_m = np.full(n, mid)
        C_r = np.full(n, mid)

    return Platform(
        D=np.full(n, data_per_source_mb),
        B_sm=bw.copy(),
        B_mr=bw.copy(),
        C_m=C_m,
        C_r=C_r,
        alpha=alpha,
        cluster_s=site_ids,
        cluster_m=site_ids,
        cluster_r=site_ids,
        name=f"planetlab_{n_datacenters}dc",
    )


# ---------------------------------------------------------------------------
# TPU pod environments — the paper's platform model applied to a TPU fleet
# ---------------------------------------------------------------------------

def tpu_pod_platform(
    n_pods: int = 2,
    hosts_per_pod: int = 4,
    alpha: float = 1.0,
    data_per_source_mb: float = 65536.0,
    ici_bw_mbps: float = 50_000.0,
    dcn_bw_mbps: float = 6_400.0,
    ingest_bw_mbps: float = 3_200.0,
    compute_rate_mbps: float = 25_000.0,
    compute_jitter: float = 0.0,
    seed: int = 0,
) -> Platform:
    """A TPU fleet as the paper's highly-distributed platform.

    Sources are data-ingest hosts (one per host), mappers/reducers are pod
    slices.  Intra-pod links use ICI bandwidth, inter-pod links use DCN, and
    source→mapper links are bounded by host ingest NICs (min with the
    network path).  ``compute_jitter`` > 0 models heterogeneous effective
    throughput (multi-tenancy / thermal throttling), sampled log-normally.
    """
    rng = np.random.default_rng(seed)
    n = n_pods * hosts_per_pod
    pod = np.repeat(np.arange(n_pods), hosts_per_pod)

    same_pod = pod[:, None] == pod[None, :]
    net = np.where(same_pod, ici_bw_mbps, dcn_bw_mbps).astype(np.float64)
    B_sm = np.minimum(net, ingest_bw_mbps)
    B_mr = net.copy()

    def rates():
        if compute_jitter > 0:
            return compute_rate_mbps * np.exp(
                rng.normal(0.0, compute_jitter, size=n)
            )
        return np.full(n, compute_rate_mbps)

    return Platform(
        D=np.full(n, data_per_source_mb),
        B_sm=B_sm,
        B_mr=B_mr,
        C_m=rates(),
        C_r=rates(),
        alpha=alpha,
        cluster_s=pod.copy(),
        cluster_m=pod.copy(),
        cluster_r=pod.copy(),
        name=f"tpu_{n_pods}pods",
    )
