"""Makespan model (paper §2.2, Equations 4–14) and the shared cost model.

The model computes the end-to-end completion time of a MapReduce job for a
given platform, execution plan, and **barrier configuration**.  Each of the
three phase boundaries (push/map, map/shuffle, shuffle/reduce) is one of:

* ``'G'`` — global barrier: every node finishes the previous phase before any
  node starts the next (Equations 4–11).
* ``'L'`` — local barrier: a node starts the next phase as soon as *it* has
  all its inputs; the combination operator ``⊕`` is ``+`` (Equations 12–14).
* ``'P'`` — pipelined: a node starts as soon as the first byte arrives;
  ``⊕`` is ``max``.

The phase equations live in exactly one place — :func:`volume_model`, which
prices explicit per-phase data volumes (MB) through the platform's
bandwidths and compute rates.  Two front ends share it:

* the **analytic** path derives volumes from a plan (``D_i·x_ij`` etc.) —
  :func:`phase_model` for the differentiable JAX optimizer,
  :class:`CostModel` (numpy, float64) for exact evaluation;
* the **measured** path prices byte matrices recorded by the execution
  engine (:meth:`CostModel.price_volumes`) — so model and measurement can
  never diverge.

``tau`` selects the max operator: ``tau=None`` (or 0) uses the exact hard
``max`` (use this for *evaluating* a plan); ``tau > 0`` uses the smooth
upper bound ``tau·logsumexp(v/tau)`` so that gradients flow into every
branch of the max (use this for *optimizing* a plan, annealing ``tau → 0``).

Times are expressed in seconds for platforms built by
:mod:`repro.core.platform` (MB and MB/s units).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.validate import validate_volumes
from .plan import ExecutionPlan
from .platform import Platform

__all__ = [
    "BARRIERS_GGL",
    "BARRIERS_ALL_GLOBAL",
    "BARRIERS_ALL_PIPELINED",
    "CostModel",
    "JobProgress",
    "analytic_volumes",
    "attribute_phases",
    "makespan",
    "makespan_model",
    "phase_breakdown",
    "replication_matrix",
    "residual_volumes",
    "shared_effective_volumes",
    "volume_model",
]

#: Hadoop's effective configuration (paper §4.6.1): global push/map barrier
#: (separate DistCP-like push job), pipelined map/shuffle, local
#: shuffle/reduce barrier.
BARRIERS_GGL: Tuple[str, str, str] = ("G", "G", "L")
BARRIERS_ALL_GLOBAL: Tuple[str, str, str] = ("G", "G", "G")
BARRIERS_ALL_PIPELINED: Tuple[str, str, str] = ("P", "P", "P")

_VALID = frozenset("GLP")


def _check_barriers(barriers: Tuple[str, str, str]) -> Tuple[str, str, str]:
    barriers = tuple(barriers)
    if len(barriers) != 3 or any(b not in _VALID for b in barriers):
        raise ValueError(f"barriers must be a triple over G/L/P, got {barriers}")
    return barriers


def hard_ops():
    """Exact (max, pairwise-max) reduction ops."""
    return (lambda v, axis=None: jnp.max(v, axis=axis)), jnp.maximum


def smooth_ops(tau):
    """Smooth upper-bound ops, ``tau`` may be a traced scalar (annealing)."""

    def mx(v, axis=None):
        return tau * jax.nn.logsumexp(v / tau, axis=axis)

    def pmax(a, b):
        return tau * jnp.logaddexp(a / tau, b / tau)

    return mx, pmax


def volume_model(
    V_push, V_map, V_shuffle, V_reduce, B_sm, B_mr, C_m, C_r, barriers, mx, pmax, xp=jnp
):
    """Phase-timing equations over explicit per-phase data volumes (MB).

    This is the single home of Equations 4–14.  ``V_push`` is the (nS, nM)
    MB pushed over each source→mapper link, ``V_map`` the (nM,) MB of map
    input per mapper, ``V_shuffle`` the (nM, nR) MB shuffled over each
    mapper→reducer link, and ``V_reduce`` the (nR,) MB of reduce input.
    The volumes may be analytic (derived from a plan) or measured (recorded
    by the execution engine) — the pricing is identical either way.

    ``xp`` selects the array module (``jnp`` for the differentiable
    optimizer path, ``np`` for exact float64 evaluation); ``mx``/``pmax``
    select hard or smooth max reductions.
    """
    barriers = _check_barriers(barriers)
    b_pm, b_ms, b_sr = barriers

    def combine(op):
        # ⊕ (paper §2.2): after a G or L barrier phases run in sequence
        # (``+``); when pipelined they fully overlap (``max``).
        return (lambda a, b: a + b) if op in ("G", "L") else pmax

    # --- push phase (Equation 4) -------------------------------------------
    # push_end_j = max_i V_push_ij / B_ij
    push_t = V_push / B_sm  # (nS, nM)
    push_end = mx(push_t, axis=0)  # (nM,)

    # --- map phase (Equations 5/6 or 12) ------------------------------------
    map_time = V_map / C_m
    if b_pm == "G":
        map_start = xp.broadcast_to(mx(push_end), push_end.shape)
    else:
        map_start = push_end
    map_end = combine(b_pm)(map_start, map_time)  # (nM,)

    # --- shuffle phase (Equations 7/8 or 13) ---------------------------------
    shuffle_t = V_shuffle / B_mr  # (nM, nR)
    if b_ms == "G":
        shuffle_start = xp.broadcast_to(mx(map_end), map_end.shape)
    else:
        shuffle_start = map_end
    shuffle_end = mx(combine(b_ms)(shuffle_start[:, None], shuffle_t), axis=0)  # (nR,)

    # --- reduce phase (Equations 9/10 or 14) ---------------------------------
    reduce_time = V_reduce / C_r  # (nR,)
    if b_sr == "G":
        reduce_start = xp.broadcast_to(mx(shuffle_end), shuffle_end.shape)
    else:
        reduce_start = shuffle_end
    reduce_end = combine(b_sr)(reduce_start, reduce_time)  # (nR,)

    return {
        "push_end": push_end,
        "map_end": map_end,
        "shuffle_end": shuffle_end,
        "reduce_end": reduce_end,
        "makespan": mx(reduce_end),
        "push_time": mx(push_end),
        "map_time": mx(map_time),
        "shuffle_time": mx(shuffle_t),
        "reduce_time": mx(reduce_time),
    }


def analytic_volumes(D, x, y, alpha, xp=jnp, rep=None):
    """Per-phase data volumes (MB) implied by a plan: ``D_i·x_ij`` pushed,
    ``xᵀD`` mapped, ``α·map_in_j·y_k`` shuffled, ``α·Σmap_in·y`` reduced.

    ``rep`` is an optional (nM, nM) replica-routing matrix
    (:func:`replication_matrix`): push volumes are right-multiplied by it,
    so link ``(i, t)`` carries the original push plus every replica write
    the executor routes to ``t``.  Map/shuffle/reduce volumes are *not*
    inflated — replica targets store the bytes but never run map work.
    """
    V_push = D[:, None] * x  # (nS, nM)
    map_in = x.T @ D  # (nM,)
    if rep is not None:
        V_push = V_push @ rep
    V_shuffle = alpha * (map_in[:, None] * y[None, :])  # (nM, nR)
    V_reduce = alpha * xp.sum(map_in) * y  # (nR,)
    return V_push, map_in, V_shuffle, V_reduce


def replication_matrix(
    cluster_m, replication: int = 1, cross_cluster: bool = False
) -> Optional[np.ndarray]:
    """The (nM, nM) push-volume routing matrix of ``replication``-way
    writes: entry ``(j, t)`` is how many copies of a chunk destined for
    mapper ``j`` the executor writes over the source's link to ``t``
    (identity + replica fan-out).  Mirrors the executor's deterministic
    target choice (:meth:`repro.core.simulate._MultiSim._replicate`):
    replicas of mapper ``j``'s chunks go to the other mappers of ``j``'s
    cluster (or, with ``cross_cluster``, to other clusters), round-robin
    from ``j+1``.  ``V_push @ replication_matrix(...)`` is the modeled
    per-link push traffic including replica writes — the term the cost
    model was silently missing for ``SimConfig.replication > 1``.

    Returns ``None`` for ``replication == 1`` (no inflation).
    """
    if replication <= 1:
        return None
    cluster_m = np.asarray(cluster_m)
    nM = cluster_m.shape[0]
    R = np.eye(nM)
    for j in range(nM):
        if cross_cluster:
            candidates = [m for m in range(nM)
                          if cluster_m[m] != cluster_m[j]]
        else:
            candidates = [m for m in range(nM)
                          if cluster_m[m] == cluster_m[j] and m != j]
        if not candidates:
            candidates = [m for m in range(nM) if m != j]
        if not candidates:  # single-mapper substrate: nowhere to replicate
            continue
        for r in range(replication - 1):
            R[j, candidates[(j + r + 1) % len(candidates)]] += 1.0
    return R


def shared_effective_volumes(volumes, kappa: float = 0.0, xp=np):
    """Congestion-effective per-job volumes on a shared substrate.

    ``volumes`` is a sequence of per-job ``(V_push, V_map, V_shuffle,
    V_reduce)`` tuples over the *same* substrate.  When concurrent jobs
    route data through the same link or compute node, a fair-share server
    finishes each job's demand only after serving everyone's: the time job
    ``g`` experiences on a resource is ``(V_g + Σ_{h≠g} V_h) / capacity``
    whenever job ``g`` uses the resource at all, and ``0`` when it does not.
    Those contention-inflated volumes are what this returns — feed them to
    :func:`volume_model` (or :meth:`CostModel.price_volumes`) and the
    ordinary single-job phase equations price the shared schedule, keeping
    one float64 home for model *and* measurement.

    ``kappa=0`` applies the exact hard usage gate ``1[V_g > 1e-9]`` — the
    same 1e-9 MB cutoff below which the executor emits no chunk at all, so
    softmax-epsilon plan entries are "unused" on both sides (use for
    evaluation); ``kappa > 0`` smooths it to ``V_g / (V_g + kappa)`` so the
    joint optimizer's gradients can trade contention against link speed
    (use a kappa small against typical per-resource volumes).
    """
    volumes = [tuple(v) for v in volumes]
    if len(volumes) <= 1:
        return list(volumes)
    totals = [sum(job[c] for job in volumes) for c in range(4)]
    out = []
    for job in volumes:
        eff = []
        for V, total in zip(job, totals):
            if kappa > 0:
                gate = V / (V + kappa)
            else:
                gate = xp.where(V > 1e-9, 1.0, 0.0)
            eff.append(V + gate * (total - V))
        out.append(tuple(eff))
    return out


@dataclasses.dataclass(frozen=True)
class JobProgress:
    """One job's *remaining* work at an observation instant, bucketed by
    what an online re-planner can still control.

    Captured by the executor's ``snapshot()`` (see
    :class:`repro.core.simulate.ProgressSnapshot`); priced by
    :meth:`CostModel.price_residual` through the same float64
    :func:`volume_model` equations as everything else, so online decisions
    stay on the one shared cost model.

    Attributes:
      resid_push:        (nS,) push MB still at the sources / queued but not
                         started — re-routable by a new ``x``.
      committed_push:    (nS, nM) push MB in service on a link — it will
                         land where it was sent.
      at_mapper:         (nM,) map-input MB already delivered (or gated)
                         at each mapper but not yet mapped.
      shuffle_pool:      (nM,) map-*output* MB at each mapper awaiting
                         shuffle (gated or queued, not started) —
                         re-routable by a new ``y``.
      committed_shuffle: (nM, nR) shuffle MB in service on a link.
      at_reducer:        (nR,) reduce-input MB delivered/queued at each
                         reducer but not yet reduced.
      map_alive:         (nM,) bool worker liveness at the observation
                         instant (``None`` = all alive) — a re-planner must
                         route around dead mappers, not just around slow
                         links.
      red_alive:         (nR,) bool reducer liveness (``None`` = all
                         alive) — the executor bounces emissions off dead
                         reducers, so pricing masks the plan's ``y`` to
                         the survivors (:func:`_live_plan_arrays`).
    """

    job: int
    released: bool
    done: bool
    resid_push: np.ndarray
    committed_push: np.ndarray
    at_mapper: np.ndarray
    shuffle_pool: np.ndarray
    committed_shuffle: np.ndarray
    at_reducer: np.ndarray
    alpha: float
    total_push_mb: float
    map_alive: Optional[np.ndarray] = None
    red_alive: Optional[np.ndarray] = None

    @classmethod
    def fresh(cls, platform: Platform, job: int = 0) -> "JobProgress":
        """The zero-progress snapshot: every byte still at its source —
        pricing it reproduces :meth:`CostModel.price_plan` exactly."""
        nS, nM, nR = platform.nS, platform.nM, platform.nR
        return cls(
            job=job, released=False, done=False,
            resid_push=platform.D.copy(),
            committed_push=np.zeros((nS, nM)),
            at_mapper=np.zeros(nM),
            shuffle_pool=np.zeros(nM),
            committed_shuffle=np.zeros((nM, nR)),
            at_reducer=np.zeros(nR),
            alpha=float(platform.alpha),
            total_push_mb=float(platform.D.sum()),
            map_alive=np.ones(nM, dtype=bool),
            red_alive=np.ones(nR, dtype=bool),
        )

    #: the six residual buckets, in the positional order
    #: :func:`residual_volumes` (and every residual solver) consumes them
    RESIDUAL_FIELDS = ("resid_push", "committed_push", "at_mapper",
                       "shuffle_pool", "committed_shuffle", "at_reducer")

    @classmethod
    def stack(cls, progresses) -> "Tuple[np.ndarray, ...]":
        """Stack the six residual buckets of ``progresses`` along a new
        leading job axis — the ``(J, ...)`` float64 arrays the batched and
        joint residual solvers consume (one stacking discipline, so the
        solo-batched, shared, and pricing paths can never disagree on
        bucket order)."""
        return tuple(
            np.stack([
                np.asarray(getattr(pr, field), dtype=np.float64)
                for pr in progresses
            ])
            for field in cls.RESIDUAL_FIELDS
        )

    def reroutable_mb(self) -> Dict[str, float]:
        """MB an online plan swap would pull back and re-route: push bytes
        still queued at the sources (steered by a new ``x``) and map-output
        bytes pooled at the mappers awaiting shuffle (steered by a new
        ``y``).  Committed/delivered buckets are excluded — a swap cannot
        move them.  This is the volume the replan-cost hysteresis charges
        (see :func:`repro.core.optimize.swap_charge`)."""
        return {
            "push": float(self.resid_push.sum()),
            "shuffle": float(self.shuffle_pool.sum()),
        }

    def remaining_mb(self) -> Dict[str, float]:
        """Remaining MB per phase (push/map input; shuffle/reduce output)."""
        push = float(self.resid_push.sum() + self.committed_push.sum())
        map_in = push + float(self.at_mapper.sum())
        shuffle = (
            self.alpha * map_in
            + float(self.shuffle_pool.sum() + self.committed_shuffle.sum())
        )
        reduce = shuffle + float(self.at_reducer.sum())
        return {"push": push, "map": map_in, "shuffle": shuffle,
                "reduce": reduce}

    def undeliver_reducer(
        self, k: int, by_mapper: Optional[np.ndarray] = None
    ) -> "JobProgress":
        """Return a copy with reducer ``k``'s volume un-delivered — the
        model-side mirror of the executor's reducer-kill claw-back: bytes
        on the wire toward (or landed at) the dead reducer return to their
        origin mappers' shuffle pools for re-routing, and ``red_alive[k]``
        flips dead.  ``by_mapper`` ((nM,) MB) is the full provenance of the
        landed + already-reduced volume lost with the node (the executor's
        ``reduced_by`` ledger); without it the landed bucket is spread
        evenly over the mappers."""
        nM = self.at_mapper.shape[0]
        k = int(k)
        pool = np.asarray(self.shuffle_pool, dtype=np.float64).copy()
        committed = np.asarray(
            self.committed_shuffle, dtype=np.float64
        ).copy()
        at_red = np.asarray(self.at_reducer, dtype=np.float64).copy()
        pool += committed[:, k]
        committed[:, k] = 0.0
        landed = float(at_red[k])
        at_red[k] = 0.0
        if by_mapper is not None:
            add = np.asarray(by_mapper, dtype=np.float64)
            if add.shape != (nM,):
                raise ValueError(
                    f"by_mapper must have shape ({nM},), got {add.shape}"
                )
            pool += add
        elif landed > 0:
            pool += landed / nM
        red_alive = (
            np.ones(at_red.shape[0], dtype=bool)
            if self.red_alive is None
            else np.asarray(self.red_alive, dtype=bool).copy()
        )
        red_alive[k] = False
        return dataclasses.replace(
            self, shuffle_pool=pool, committed_shuffle=committed,
            at_reducer=at_red, red_alive=red_alive,
        )

    def completion(self) -> Dict[str, float]:
        """Per-phase completion fraction in [0, 1]."""
        rem = self.remaining_mb()
        tot_in = max(self.total_push_mb, 1e-12)
        tot_out = max(self.alpha * self.total_push_mb, 1e-12)
        return {
            "push": 1.0 - min(rem["push"] / tot_in, 1.0),
            "map": 1.0 - min(rem["map"] / tot_in, 1.0),
            "shuffle": 1.0 - min(rem["shuffle"] / tot_out, 1.0),
            "reduce": 1.0 - min(rem["reduce"] / tot_out, 1.0),
        }


def _live_plan_arrays(
    progress: JobProgress, plan: ExecutionPlan
) -> Tuple[np.ndarray, np.ndarray]:
    """The plan arrays the executor *effectively* routes by at this
    snapshot: ``y`` masked to surviving reducers and renormalized (the
    executor bounces emissions off dead reducers and re-splits them over
    the survivors — pricing must route the same way to stay exact), ``x``
    as-is (dead mappers are handled by recovery + capacity degradation,
    not by re-normalizing the split).  Identity when every reducer is
    alive, so failure-free pricing stays on the exact original float
    path."""
    x = np.asarray(plan.x)
    y = np.asarray(plan.y)
    ra = progress.red_alive
    if ra is not None:
        ra = np.asarray(ra, dtype=bool)
        if not ra.all():
            live = np.where(ra, y, 0.0)
            if live.sum() <= 1e-12:
                live = np.where(ra, 1.0, 0.0)
                if live.sum() == 0:
                    raise ValueError("all reducers dead")
            y = live / live.sum()
    return x, y


def residual_volumes(
    resid_push, committed_push, at_mapper, shuffle_pool, committed_shuffle,
    at_reducer, alpha, x, y, xp=jnp, rep=None,
):
    """Per-phase volumes of the *remaining* work under a candidate plan.

    The re-routable buckets flow through the candidate ``x``/``y`` exactly
    like :func:`analytic_volumes` routes a fresh job; the committed buckets
    enter as fixed per-resource volumes.  With zero committed/delivered
    buckets this degenerates to ``analytic_volumes(resid_push, x, y,
    alpha)`` — a fresh job is the special case of an untouched residual.
    ``rep`` (see :func:`replication_matrix`) inflates the re-routable push
    with its replica writes; committed transfers are already on the wire
    and enter as-is.
    """
    V_push = resid_push[:, None] * x
    if rep is not None:
        V_push = V_push @ rep
    V_push = V_push + committed_push
    map_in = x.T @ resid_push + at_mapper + xp.sum(committed_push, axis=0)
    out = alpha * map_in + shuffle_pool  # map-output MB leaving each mapper
    V_shuffle = out[:, None] * y[None, :] + committed_shuffle
    V_reduce = (
        xp.sum(out) * y + xp.sum(committed_shuffle, axis=0) + at_reducer
    )
    return V_push, map_in, V_shuffle, V_reduce


def phase_model(
    D, B_sm, B_mr, C_m, C_r, alpha, x, y, barriers, mx, pmax
) -> Dict[str, jnp.ndarray]:
    """Analytic phase-timing model parameterized by the max ops (the same
    equations serve exact evaluation and smooth optimization)."""
    V_push, V_map, V_shuffle, V_reduce = analytic_volumes(D, x, y, alpha, xp=jnp)
    return volume_model(
        V_push, V_map, V_shuffle, V_reduce, B_sm, B_mr, C_m, C_r,
        barriers, mx, pmax, xp=jnp,
    )


@functools.partial(jax.jit, static_argnames=("barriers", "tau"))
def makespan_model(
    D: jnp.ndarray,
    B_sm: jnp.ndarray,
    B_mr: jnp.ndarray,
    C_m: jnp.ndarray,
    C_r: jnp.ndarray,
    alpha: float,
    x: jnp.ndarray,
    y: jnp.ndarray,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    tau: Optional[float] = None,
) -> Dict[str, jnp.ndarray]:
    """Full phase-timing model with a *static* smoothing temperature.

    ``tau=None`` evaluates the exact model; a positive ``tau`` gives the
    smooth upper bound.  (The optimizer uses :func:`phase_model` with
    :func:`smooth_ops` directly so the temperature can be annealed as a
    traced value inside one compiled loop.)
    """
    mx, pmax = smooth_ops(tau) if tau else hard_ops()
    return phase_model(D, B_sm, B_mr, C_m, C_r, alpha, x, y, barriers, mx, pmax)


def _np_hard_ops():
    """Exact (max, pairwise-max) reduction ops for the float64 numpy path."""
    return (lambda v, axis=None: np.max(v, axis=axis)), np.maximum


def attribute_phases(out) -> Dict[str, float]:
    """Sequential attribution of the makespan to the four phases, for the
    stacked-bar figures (Figs 5/6/9).  Under global barriers this is exact;
    under relaxed barriers overlapped time is attributed to the earlier
    phase (matching how the paper plots Hadoop's overlapped phases).
    """
    push = float(np.max(np.asarray(out["push_end"])))
    map_e = float(np.max(np.asarray(out["map_end"])))
    shuf_e = float(np.max(np.asarray(out["shuffle_end"])))
    total = float(out["makespan"])
    return {
        "push": push,
        "map": max(map_e - push, 0.0),
        "shuffle": max(shuf_e - map_e, 0.0),
        "reduce": max(total - shuf_e, 0.0),
        "makespan": total,
    }


@dataclasses.dataclass(frozen=True)
class CostModel:
    """The shared pricing model: one set of phase equations for analytic
    plan volumes *and* measured byte matrices.

    ``price_plan`` derives ``D_i·x_ij``-style volumes from a plan;
    ``price_volumes`` accepts explicit per-phase MB volumes (e.g. the byte
    matrices recorded by :class:`repro.mapreduce.engine.GeoMapReduce`,
    converted to MB).  Both run the exact hard-max equations in float64, so
    pricing the analytic volumes of a plan reproduces :func:`makespan`
    bit-for-bit.

    ``replication``/``cross_cluster_replication`` mirror the executor's
    :class:`repro.core.simulate.SimConfig` fields: every *derived* push
    volume (plan, residual, shared, pipeline pricing) is inflated by the
    replica-routing matrix (:func:`replication_matrix`), so the model
    prices the replica writes the executor actually performs.  Explicit
    volumes passed to :meth:`price_volumes` are taken as-is — measured
    byte matrices already contain whatever traffic really moved.
    """

    platform: Platform
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL
    replication: int = 1
    cross_cluster_replication: bool = False

    def __post_init__(self):
        object.__setattr__(self, "barriers", _check_barriers(self.barriers))
        if self.replication < 1:
            raise ValueError(
                f"replication must be >= 1, got {self.replication}"
            )

    def _barriers(self, barriers) -> Tuple[str, str, str]:
        return self.barriers if barriers is None else _check_barriers(barriers)

    def _rep(self) -> Optional[np.ndarray]:
        """The replica-routing matrix, ``None`` for replication=1."""
        return replication_matrix(
            self.platform.cluster_m, self.replication,
            self.cross_cluster_replication,
        )

    # -- volume derivation ---------------------------------------------------
    def analytic_volumes(self, plan: ExecutionPlan):
        """(V_push, V_map, V_shuffle, V_reduce) in MB implied by ``plan``
        (push inflated by replica writes when ``replication > 1``)."""
        p = self.platform
        return analytic_volumes(p.D, np.asarray(plan.x), np.asarray(plan.y),
                                p.alpha, xp=np, rep=self._rep())

    # -- pricing -------------------------------------------------------------
    def price_volumes(
        self, V_push, V_map, V_shuffle, V_reduce, barriers=None
    ) -> Dict[str, np.ndarray]:
        """Price explicit per-phase volumes (MB); returns the phase-end
        arrays plus the scalar ``makespan`` (seconds)."""
        p = self.platform
        V_push = np.asarray(V_push, dtype=np.float64)
        V_map = np.asarray(V_map, dtype=np.float64)
        V_shuffle = np.asarray(V_shuffle, dtype=np.float64)
        V_reduce = np.asarray(V_reduce, dtype=np.float64)
        validate_volumes(V_push, V_map, V_shuffle, V_reduce,
                         dims=(p.nS, p.nM, p.nR))
        mx, pmax = _np_hard_ops()
        return volume_model(
            V_push, V_map, V_shuffle, V_reduce,
            p.B_sm, p.B_mr, p.C_m, p.C_r,
            self._barriers(barriers), mx, pmax, xp=np,
        )

    def price_plan(self, plan: ExecutionPlan, barriers=None) -> Dict[str, np.ndarray]:
        """Price the analytic volumes of ``plan`` (the model side)."""
        return self.price_volumes(*self.analytic_volumes(plan), barriers=barriers)

    def price_residual(
        self, progress: JobProgress, plan: ExecutionPlan, barriers=None
    ) -> Dict[str, np.ndarray]:
        """Price the *remaining* work of an observed job under a candidate
        plan: the snapshot's re-routable volumes flow through ``plan``'s
        ``x``/``y``, the committed ones enter as fixed per-resource load,
        and everything runs through the identical float64 phase equations
        (:func:`residual_volumes` → :func:`volume_model`).  Pricing a
        zero-progress snapshot (:meth:`JobProgress.fresh`) reproduces
        :meth:`price_plan` exactly — online and offline decisions share one
        cost model."""
        x, y = _live_plan_arrays(progress, plan)
        return self.price_volumes(
            *residual_volumes(
                progress.resid_push, progress.committed_push,
                progress.at_mapper, progress.shuffle_pool,
                progress.committed_shuffle, progress.at_reducer,
                progress.alpha, x, y,
                xp=np, rep=self._rep(),
            ),
            barriers=barriers,
        )

    def residual_makespan(
        self, progress: JobProgress, plan: ExecutionPlan, barriers=None
    ) -> float:
        """Modeled seconds to finish the observed job under ``plan``."""
        return float(self.price_residual(progress, plan, barriers)["makespan"])

    # -- scalar / report conveniences ---------------------------------------
    def makespan(self, plan: ExecutionPlan, barriers=None) -> float:
        return float(self.price_plan(plan, barriers)["makespan"])

    def breakdown(self, plan: ExecutionPlan, barriers=None) -> Dict[str, float]:
        return attribute_phases(self.price_plan(plan, barriers))

    def breakdown_volumes(
        self, V_push, V_map, V_shuffle, V_reduce, barriers=None
    ) -> Dict[str, float]:
        return attribute_phases(
            self.price_volumes(V_push, V_map, V_shuffle, V_reduce, barriers)
        )

    # -- multi-job pricing ---------------------------------------------------
    def price_shared(
        self, volumes_list, barriers=None
    ) -> "list[Dict[str, np.ndarray]]":
        """Price N concurrent jobs' volumes on the shared substrate: each
        job's per-phase volumes are inflated by the other jobs' demand on
        every resource it touches (:func:`shared_effective_volumes`, hard
        gate) and priced through the identical float64 phase equations.
        ``volumes_list`` holds one ``(V_push, V_map, V_shuffle, V_reduce)``
        tuple per job — analytic or measured, exactly as for
        :meth:`price_volumes`.  When the model replicates
        (``replication > 1``), each job's push volumes are inflated by the
        replica writes *before* contention — so concurrent jobs contend
        for the replica traffic too (pass measured volumes through a
        replication-1 model; they already contain the real traffic)."""
        rep = self._rep()
        if rep is not None:
            volumes_list = [
                (np.asarray(v[0], dtype=np.float64) @ rep, v[1], v[2], v[3])
                for v in volumes_list
            ]
        eff = shared_effective_volumes(volumes_list, kappa=0.0, xp=np)
        return [self.price_volumes(*v, barriers=barriers) for v in eff]

    def schedule_makespan(self, volumes_list, barriers=None) -> float:
        """Aggregate (max over jobs) modeled makespan of N concurrent jobs
        under shared-capacity pricing."""
        return max(
            float(out["makespan"])
            for out in self.price_shared(volumes_list, barriers)
        )

    def price_residual_shared(
        self, progress_list, plans, barriers=None
    ) -> "list[Dict[str, np.ndarray]]":
        """Price N concurrent jobs' *remaining* work jointly on the shared
        substrate: each job's residual volumes under its candidate plan
        (:func:`residual_volumes`) are inflated by the other jobs' residual
        demand on every resource it touches (:func:`shared_effective_volumes`,
        hard gate) and priced through the identical float64 phase equations.
        This is what schedule-aware online re-planning optimizes — the
        multi-job analogue of :meth:`price_residual`, and with fresh
        zero-progress snapshots it reproduces :meth:`price_shared` of the
        plans' analytic volumes exactly (a fresh schedule is the special
        case of an untouched residual)."""
        if len(progress_list) != len(plans):
            raise ValueError(
                f"one plan per progress, got {len(progress_list)} progresses "
                f"and {len(plans)} plans"
            )
        rep = self._rep()
        vols = [
            residual_volumes(
                pr.resid_push, pr.committed_push, pr.at_mapper,
                pr.shuffle_pool, pr.committed_shuffle, pr.at_reducer,
                pr.alpha, *_live_plan_arrays(pr, plan), xp=np,
                rep=rep,
            )
            for pr, plan in zip(progress_list, plans)
        ]
        eff = shared_effective_volumes(vols, kappa=0.0, xp=np)
        return [self.price_volumes(*v, barriers=barriers) for v in eff]

    def residual_schedule_makespan(
        self, progress_list, plans, barriers=None
    ) -> float:
        """Aggregate (max over jobs) modeled seconds to finish the observed
        jobs' residuals under their candidate plans, with shared-capacity
        contention."""
        return max(
            float(out["makespan"])
            for out in self.price_residual_shared(progress_list, plans,
                                                  barriers)
        )

    # -- pipeline pricing ----------------------------------------------------
    def price_pipeline(self, spec, plans, barriers=None) -> Dict[str, object]:
        """Price a stage DAG end to end: chain the identical float64 phase
        equations across stages, with each downstream stage's ``D`` derived
        from its upstream stages' shuffle placement
        (:meth:`repro.core.pipeline.PipelineSpec.derived_D` — the
        inter-stage coupling flows through the one home of the phase
        equations) and makespans composed along the DAG's critical path: a
        stage starts when every upstream stage's reduce output has landed
        (the inter-stage barrier the executor gates per source; the
        scalar-start composition here is its tight upper bound).

        Returns ``{"stages": [per-stage price_volumes dicts], "start":
        [...], "finish": [...], "D": [derived per-stage D], "makespan"}``.
        A single root stage reproduces :meth:`price_plan` exactly.
        """
        barriers = self._barriers(barriers)
        if len(plans) != spec.n_stages:
            raise ValueError(
                f"one plan per stage, got {len(plans)} plans for "
                f"{spec.n_stages} stages"
            )
        D_list = spec.derived_D(plans)
        sub = spec.substrate
        rep = self._rep()
        n = spec.n_stages
        outs: "list" = [None] * n
        start = [0.0] * n
        finish = [0.0] * n
        mx, pmax = _np_hard_ops()
        for k in spec.topo_order():
            stage = spec.stages[k]
            V = analytic_volumes(
                D_list[k], np.asarray(plans[k].x), np.asarray(plans[k].y),
                stage.alpha, xp=np, rep=rep,
            )
            outs[k] = volume_model(
                *V, sub.B_sm, sub.B_mr, sub.C_m, sub.C_r,
                barriers, mx, pmax, xp=np,
            )
            start[k] = max((finish[u] for u in stage.deps), default=0.0)
            finish[k] = start[k] + float(outs[k]["makespan"])
        return {
            "stages": outs,
            "start": start,
            "finish": finish,
            "D": D_list,
            "makespan": max(finish),
        }

    def pipeline_makespan(self, spec, plans, barriers=None) -> float:
        """Modeled end-to-end seconds of the whole stage DAG."""
        return float(self.price_pipeline(spec, plans, barriers)["makespan"])


def makespan(
    platform: Platform,
    plan: ExecutionPlan,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    tau: Optional[float] = None,
) -> float:
    """Evaluate the (hard, by default) makespan of ``plan`` on ``platform``.

    The hard evaluation runs through the shared :class:`CostModel` (exact,
    float64); a positive ``tau`` evaluates the smooth JAX upper bound used
    by the optimizer.
    """
    if tau:
        D, B_sm, B_mr, C_m, C_r, alpha = platform.as_arrays()
        out = makespan_model(
            jnp.asarray(D),
            jnp.asarray(B_sm),
            jnp.asarray(B_mr),
            jnp.asarray(C_m),
            jnp.asarray(C_r),
            float(alpha),
            jnp.asarray(plan.x),
            jnp.asarray(plan.y),
            barriers=tuple(barriers),
            tau=tau,
        )
        return float(out["makespan"])
    return CostModel(platform, tuple(barriers)).makespan(plan)


def phase_breakdown(
    platform: Platform,
    plan: ExecutionPlan,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
) -> Dict[str, float]:
    """Sequential phase attribution of ``plan``'s modeled makespan (see
    :func:`attribute_phases`)."""
    return CostModel(platform, tuple(barriers)).breakdown(plan)
