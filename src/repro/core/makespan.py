"""Differentiable makespan model (paper §2.2, Equations 4–14).

The model computes the end-to-end completion time of a MapReduce job for a
given platform, execution plan, and **barrier configuration**.  Each of the
three phase boundaries (push/map, map/shuffle, shuffle/reduce) is one of:

* ``'G'`` — global barrier: every node finishes the previous phase before any
  node starts the next (Equations 4–11).
* ``'L'`` — local barrier: a node starts the next phase as soon as *it* has
  all its inputs; the combination operator ``⊕`` is ``+`` (Equations 12–14).
* ``'P'`` — pipelined: a node starts as soon as the first byte arrives;
  ``⊕`` is ``max``.

The whole model is written in JAX and is differentiable.  ``tau`` selects the
max operator: ``tau=None`` (or 0) uses the exact hard ``max`` (use this for
*evaluating* a plan); ``tau > 0`` uses the smooth upper bound
``tau·logsumexp(v/tau)`` so that gradients flow into every branch of the max
(use this for *optimizing* a plan, annealing ``tau → 0``).

Times are expressed in seconds for platforms built by
:mod:`repro.core.platform` (MB and MB/s units).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .plan import ExecutionPlan
from .platform import Platform

__all__ = [
    "BARRIERS_GGL",
    "BARRIERS_ALL_GLOBAL",
    "BARRIERS_ALL_PIPELINED",
    "makespan",
    "makespan_model",
    "phase_breakdown",
]

#: Hadoop's effective configuration (paper §4.6.1): global push/map barrier
#: (separate DistCP-like push job), pipelined map/shuffle, local
#: shuffle/reduce barrier.
BARRIERS_GGL: Tuple[str, str, str] = ("G", "G", "L")
BARRIERS_ALL_GLOBAL: Tuple[str, str, str] = ("G", "G", "G")
BARRIERS_ALL_PIPELINED: Tuple[str, str, str] = ("P", "P", "P")

_VALID = frozenset("GLP")


def _check_barriers(barriers: Tuple[str, str, str]) -> Tuple[str, str, str]:
    barriers = tuple(barriers)
    if len(barriers) != 3 or any(b not in _VALID for b in barriers):
        raise ValueError(f"barriers must be a triple over G/L/P, got {barriers}")
    return barriers


def hard_ops():
    """Exact (max, pairwise-max) reduction ops."""
    return (lambda v, axis=None: jnp.max(v, axis=axis)), jnp.maximum


def smooth_ops(tau):
    """Smooth upper-bound ops, ``tau`` may be a traced scalar (annealing)."""

    def mx(v, axis=None):
        return tau * jax.nn.logsumexp(v / tau, axis=axis)

    def pmax(a, b):
        return tau * jnp.logaddexp(a / tau, b / tau)

    return mx, pmax


def phase_model(
    D, B_sm, B_mr, C_m, C_r, alpha, x, y, barriers, mx, pmax
) -> Dict[str, jnp.ndarray]:
    """Core phase-timing model parameterized by the max ops (so the same
    equations serve both exact evaluation and smooth optimization)."""
    barriers = _check_barriers(barriers)
    b_pm, b_ms, b_sr = barriers

    def combine(op):
        # ⊕ (paper §2.2): after a G or L barrier phases run in sequence
        # (``+``); when pipelined they fully overlap (``max``).
        return (lambda a, b: a + b) if op in ("G", "L") else pmax

    # --- push phase (Equation 4) -------------------------------------------
    # push_end_j = max_i D_i x_ij / B_ij
    push_t = (D[:, None] * x) / B_sm  # (nS, nM)
    push_end = mx(push_t, axis=0)  # (nM,)

    # --- map phase (Equations 5/6 or 12) ------------------------------------
    map_in = x.T @ D  # (nM,) MB of input at each mapper
    map_time = map_in / C_m
    if b_pm == "G":
        map_start = jnp.broadcast_to(mx(push_end), push_end.shape)
    else:
        map_start = push_end
    map_end = combine(b_pm)(map_start, map_time)  # (nM,)

    # --- shuffle phase (Equations 7/8 or 13) ---------------------------------
    # data from mapper j to reducer k: alpha * map_in_j * y_k
    shuffle_t = alpha * (map_in[:, None] * y[None, :]) / B_mr  # (nM, nR)
    if b_ms == "G":
        shuffle_start = jnp.broadcast_to(mx(map_end), map_end.shape)
    else:
        shuffle_start = map_end
    shuffle_end = mx(combine(b_ms)(shuffle_start[:, None], shuffle_t), axis=0)  # (nR,)

    # --- reduce phase (Equations 9/10 or 14) ---------------------------------
    total_intermediate = alpha * jnp.sum(map_in)
    reduce_time = total_intermediate * y / C_r  # (nR,)
    if b_sr == "G":
        reduce_start = jnp.broadcast_to(mx(shuffle_end), shuffle_end.shape)
    else:
        reduce_start = shuffle_end
    reduce_end = combine(b_sr)(reduce_start, reduce_time)  # (nR,)

    return {
        "push_end": push_end,
        "map_end": map_end,
        "shuffle_end": shuffle_end,
        "reduce_end": reduce_end,
        "makespan": mx(reduce_end),
        "push_time": mx(push_end),
        "map_time": mx(map_time),
        "shuffle_time": mx(shuffle_t),
        "reduce_time": mx(reduce_time),
    }


@functools.partial(jax.jit, static_argnames=("barriers", "tau"))
def makespan_model(
    D: jnp.ndarray,
    B_sm: jnp.ndarray,
    B_mr: jnp.ndarray,
    C_m: jnp.ndarray,
    C_r: jnp.ndarray,
    alpha: float,
    x: jnp.ndarray,
    y: jnp.ndarray,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    tau: Optional[float] = None,
) -> Dict[str, jnp.ndarray]:
    """Full phase-timing model with a *static* smoothing temperature.

    ``tau=None`` evaluates the exact model; a positive ``tau`` gives the
    smooth upper bound.  (The optimizer uses :func:`phase_model` with
    :func:`smooth_ops` directly so the temperature can be annealed as a
    traced value inside one compiled loop.)
    """
    mx, pmax = smooth_ops(tau) if tau else hard_ops()
    return phase_model(D, B_sm, B_mr, C_m, C_r, alpha, x, y, barriers, mx, pmax)


def makespan(
    platform: Platform,
    plan: ExecutionPlan,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    tau: Optional[float] = None,
) -> float:
    """Evaluate the (hard, by default) makespan of ``plan`` on ``platform``."""
    D, B_sm, B_mr, C_m, C_r, alpha = platform.as_arrays()
    out = makespan_model(
        jnp.asarray(D),
        jnp.asarray(B_sm),
        jnp.asarray(B_mr),
        jnp.asarray(C_m),
        jnp.asarray(C_r),
        float(alpha),
        jnp.asarray(plan.x),
        jnp.asarray(plan.y),
        barriers=tuple(barriers),
        tau=tau,
    )
    return float(out["makespan"])


def phase_breakdown(
    platform: Platform,
    plan: ExecutionPlan,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
) -> Dict[str, float]:
    """Sequential attribution of the makespan to the four phases, for the
    stacked-bar figures (Figs 5/6/9).  Under global barriers this is exact;
    under relaxed barriers overlapped time is attributed to the earlier
    phase (matching how the paper plots Hadoop's overlapped phases).
    """
    D, B_sm, B_mr, C_m, C_r, alpha = platform.as_arrays()
    out = makespan_model(
        jnp.asarray(D), jnp.asarray(B_sm), jnp.asarray(B_mr),
        jnp.asarray(C_m), jnp.asarray(C_r), float(alpha),
        jnp.asarray(plan.x), jnp.asarray(plan.y),
        barriers=tuple(barriers), tau=None,
    )
    push = float(jnp.max(out["push_end"]))
    map_e = float(jnp.max(out["map_end"]))
    shuf_e = float(jnp.max(out["shuffle_end"]))
    total = float(out["makespan"])
    return {
        "push": push,
        "map": max(map_e - push, 0.0),
        "shuffle": max(shuf_e - map_e, 0.0),
        "reduce": max(total - shuf_e, 0.0),
        "makespan": total,
    }
