"""The paper's MIP linearization (§2.3), implemented as a *verifier*.

The paper removes two nonlinearities to obtain a Mixed Integer Program:

1. ``max`` operators become bound constraints (``max_i z_i = Z`` →
   ``∀i: z_i ≤ Z`` with ``Z`` minimized).  This transform is exact.
2. Bilinear terms ``x_ij · y_k`` (shuffle/reduce loads) are rewritten in
   separable form ``w² − w'²`` with ``w = (x+y)/2``, ``w' = (x−y)/2``, and
   each quadratic is replaced by a piecewise-linear approximation over ~9
   segments (the paper reports a worst-case deviation of 4.15%).

A Gurobi-class MIP solver is unavailable in this environment (and
un-JAX-like), so we do not *solve* the MIP here — plan search is done by the
annealed gradient solver in :mod:`repro.core.optimize`, validated by brute
force.  What this module establishes is that the paper's *linearization is a
faithful stand-in for the exact model*: ``linearized_makespan`` evaluates the
model with every bilinear term routed through the separable piecewise-linear
approximation, and the tests check it tracks the exact model within the
paper's reported tolerance on random plans and platforms.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .makespan import BARRIERS_ALL_GLOBAL, makespan
from .plan import ExecutionPlan
from .platform import Platform

__all__ = [
    "pwl_square",
    "separable_product",
    "linearized_makespan",
    "worst_case_pwl_deviation",
]


def pwl_square(w: np.ndarray, lo: float, hi: float, segments: int = 9) -> np.ndarray:
    """Piecewise-linear (chord) approximation of ``w²`` over ``[lo, hi]``.

    The chord approximation is what an LP/MIP expresses with convex
    combination (lambda) variables; evaluating it directly is equivalent to
    the MIP's choice of the active segment.
    """
    w = np.asarray(w, dtype=np.float64)
    knots = np.linspace(lo, hi, segments + 1)
    vals = knots**2
    idx = np.clip(np.searchsorted(knots, w, side="right") - 1, 0, segments - 1)
    w0, w1 = knots[idx], knots[idx + 1]
    f0, f1 = vals[idx], vals[idx + 1]
    t = np.where(w1 > w0, (w - w0) / np.where(w1 > w0, w1 - w0, 1.0), 0.0)
    return f0 + t * (f1 - f0)


def separable_product(
    x: np.ndarray, y: np.ndarray, segments: int = 9
) -> np.ndarray:
    """The paper's separable-form product: ``x·y = w² − w'²`` with both
    quadratics piecewise-linearized.  ``x``/``y`` broadcast; both in [0, 1].
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    w = 0.5 * (x + y)  # in [0, 1]
    wp = 0.5 * (x - y)  # in [-0.5, 0.5]
    return pwl_square(w, 0.0, 1.0, segments) - pwl_square(wp, -0.5, 0.5, segments)


def worst_case_pwl_deviation(segments: int = 9, n: int = 100_001) -> float:
    """Max absolute deviation of the separable PWL product from the true
    product over a dense grid of ``(x, y) ∈ [0,1]²``."""
    g = np.linspace(0.0, 1.0, int(np.sqrt(n)))
    X, Y = np.meshgrid(g, g)
    approx = separable_product(X, Y, segments)
    return float(np.max(np.abs(approx - X * Y)))


def linearized_makespan(
    platform: Platform,
    plan: ExecutionPlan,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    segments: int = 9,
) -> float:
    """Makespan with every bilinear ``x_ij·y_k`` term evaluated through the
    paper's separable piecewise-linear approximation (global barriers follow
    Equations 4–11; relaxed barriers follow 12–14)."""
    D, B_sm, B_mr, C_m, C_r, alpha = platform.as_arrays()
    x, y = plan.x, plan.y
    b_pm, b_ms, b_sr = barriers

    push_end = np.max((D[:, None] * x) / B_sm, axis=0)
    map_in = x.T @ D
    map_time = map_in / C_m
    map_start = np.full_like(push_end, push_end.max()) if b_pm == "G" else push_end
    map_end = (
        np.maximum(map_start, map_time) if b_pm == "P" else map_start + map_time
    )

    # shuffle load uses the linearized product: D_i * lin(x_ij, y_k)
    # summed over i — this is exactly the term the paper linearizes (Eq 8).
    lin_xy = separable_product(x[:, :, None], y[None, None, :], segments)
    load_jk = alpha * np.einsum("i,ijk->jk", D, lin_xy)  # (nM, nR)
    shuffle_t = load_jk / B_mr
    shuffle_start = (
        np.full_like(map_end, map_end.max()) if b_ms == "G" else map_end
    )
    if b_ms == "P":
        shuffle_end = np.max(np.maximum(shuffle_start[:, None], shuffle_t), axis=0)
    else:
        shuffle_end = np.max(shuffle_start[:, None] + shuffle_t, axis=0)

    reduce_time = load_jk.sum(axis=0) / C_r
    reduce_start = (
        np.full_like(shuffle_end, shuffle_end.max()) if b_sr == "G" else shuffle_end
    )
    reduce_end = (
        np.maximum(reduce_start, reduce_time)
        if b_sr == "P"
        else reduce_start + reduce_time
    )
    return float(reduce_end.max())


def linearization_gap(
    platform: Platform,
    plan: ExecutionPlan,
    barriers: Tuple[str, str, str] = BARRIERS_ALL_GLOBAL,
    segments: int = 9,
) -> float:
    """Relative |linearized − exact| / exact for one plan."""
    exact = makespan(platform, plan, barriers)
    lin = linearized_makespan(platform, plan, barriers, segments)
    return abs(lin - exact) / max(exact, 1e-12)
