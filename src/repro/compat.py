"""Version gates for jax APIs that moved between releases.

The container pins one jax version; call sites written against newer (or
older) APIs import from here instead of hard-coding a location, so the
codebase runs on both sides of the moves:

* ``shard_map`` — ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (old), including the
  ``check_vma`` → ``check_rep`` keyword rename.
"""
from __future__ import annotations

try:  # jax >= 0.6: top-level export, `check_vma` keyword
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # older jax: experimental module, `check_rep` keyword
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"

__all__ = ["shard_map"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with the replication-check flag spelled per the
    installed jax version."""
    kwargs = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
