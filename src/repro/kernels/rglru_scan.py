"""RG-LRU gated linear recurrence Pallas TPU kernel (RecurrentGemma).

    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ x_t

Same chunked-parallel-scan structure as :mod:`repro.kernels.mamba_scan`:
log-depth ``associative_scan`` inside a VMEM chunk, inter-chunk carry in
scratch across the sequential chunk grid dimension, feature dimension tiled
as its own grid axis.

TARGET: TPU.  VALIDATED: ``interpret=True`` vs :func:`repro.kernels.ref.rglru_scan_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rglru_scan"]


def _rglru_kernel(x_ref, a_ref, h0_ref, y_ref, hT_ref, h_scr, *, nchunks, use_h0):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32) if use_h0 else jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # (T, bd)
    a = a_ref[0].astype(jnp.float32)  # (T, bd)
    inject = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x

    def op(l, r):
        return (l[0] * r[0], r[1] + r[0] * l[1])

    cumdecay, hs = jax.lax.associative_scan(op, (a, inject), axis=0)
    hs = hs + cumdecay * h_scr[...]
    y_ref[0] = hs.astype(y_ref.dtype)
    h_scr[...] = hs[-1:]

    @pl.when(c == nchunks - 1)
    def _final():
        hT_ref[...] = h_scr[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def rglru_scan(
    x: jnp.ndarray,  # (B, T, D)
    a: jnp.ndarray,  # (B, T, D) in (0, 1)
    h0: Optional[jnp.ndarray] = None,  # (B, D)
    chunk: int = 256,
    block_d: int = 256,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Gated linear recurrence; semantics = ref.rglru_scan_ref.

    Returns ``(h_all, h_T)``.
    """
    B, T, D = x.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ck = min(chunk, T)
    bd = min(block_d, D)
    assert D % bd == 0, (D, bd)
    Tp = -(-T // ck) * ck
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0))
        x = jnp.pad(x, pad)
        # a=1 on padding: h_t = 1·h + 0·x, so the carried state (and hence
        # h_T) is preserved through padded steps.
        a = jnp.pad(a, pad, constant_values=1.0)
    nchunks = Tp // ck
    nd = D // bd
    use_h0 = h0 is not None
    if h0 is None:
        h0 = jnp.zeros((B, D), jnp.float32)

    kernel = functools.partial(_rglru_kernel, nchunks=nchunks, use_h0=use_h0)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nd, nchunks),
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd), lambda b, d, c: (b, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, bd), lambda b, d, c: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, D), x.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        interpret=interpret,
    )(x, a, h0)
    return y[:, :T], hT
