"""Mamba-1 selective-scan Pallas TPU kernel (chunked parallel scan).

GPU Mamba implementations rely on warp-level shuffles and shared-memory
scans; the TPU-native adaptation is a **chunked scan**: the sequence is cut
into VMEM-resident chunks, each chunk is solved with a log-depth
``associative_scan`` on the VPU (fully parallel over the d_inner block and
the state dimension), and the inter-chunk state is carried through VMEM
scratch across the sequential chunk grid dimension.  d_inner is tiled as a
second grid dimension so the per-block working set
(``chunk × bd × d_state`` floats) fits VMEM.

TARGET: TPU.  VALIDATED: ``interpret=True`` vs :func:`repro.kernels.ref.mamba_scan_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mamba_scan"]


def _mamba_kernel(x_ref, d_ref, A_ref, B_ref, C_ref, Dp_ref, h0_ref,
                  y_ref, hT_ref, h_scr, *, nchunks, use_h0):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32) if use_h0 else jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # (T, bd)
    dt = d_ref[0].astype(jnp.float32)  # (T, bd)
    A = A_ref[...].astype(jnp.float32)  # (bd, Ds)
    Bc = B_ref[0].astype(jnp.float32)  # (T, Ds)
    Cc = C_ref[0].astype(jnp.float32)  # (T, Ds)
    Dp = Dp_ref[...].astype(jnp.float32)  # (1, bd)

    decay = jnp.exp(dt[:, :, None] * A[None])  # (T, bd, Ds)
    inject = (dt * x)[:, :, None] * Bc[:, None, :]  # (T, bd, Ds)

    def op(l, r):
        return (l[0] * r[0], r[1] + r[0] * l[1])

    cumdecay, hs = jax.lax.associative_scan(op, (decay, inject), axis=0)
    hs = hs + cumdecay * h_scr[...][None]
    y = jnp.sum(hs * Cc[:, None, :], axis=2) + Dp * x  # (T, bd)
    y_ref[0] = y.astype(y_ref.dtype)
    h_scr[...] = hs[-1]

    @pl.when(c == nchunks - 1)
    def _final():
        hT_ref[0] = h_scr[...].astype(hT_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "block_d", "interpret"),
)
def mamba_scan(
    x: jnp.ndarray,  # (B, T, Di)
    delta: jnp.ndarray,  # (B, T, Di)
    A: jnp.ndarray,  # (Di, Ds)
    Bc: jnp.ndarray,  # (B, T, Ds)
    Cc: jnp.ndarray,  # (B, T, Ds)
    D: jnp.ndarray,  # (Di,)
    h0: Optional[jnp.ndarray] = None,  # (B, Di, Ds)
    chunk: int = 128,
    block_d: int = 128,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Selective scan; semantics = :func:`repro.kernels.ref.mamba_scan_ref`.

    Returns ``(y, h_T)``.  ``h0`` enables stateful decode (the serving path
    carries the SSM state between steps).
    """
    B, T, Di = x.shape
    Ds = A.shape[1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ck = min(chunk, T)
    bd = min(block_d, Di)
    assert Di % bd == 0, (Di, bd)
    Tp = -(-T // ck) * ck
    if Tp != T:
        pad = ((0, 0), (0, Tp - T), (0, 0))
        # zero delta on padding => identity dynamics, zero injection
        x, delta, Bc, Cc = (jnp.pad(a, pad) for a in (x, delta, Bc, Cc))
    nchunks = Tp // ck
    nd = Di // bd
    use_h0 = h0 is not None
    if h0 is None:
        h0 = jnp.zeros((B, Di, Ds), jnp.float32)

    kernel = functools.partial(_mamba_kernel, nchunks=nchunks, use_h0=use_h0)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, nd, nchunks),
        in_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),  # x
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),  # delta
            pl.BlockSpec((bd, Ds), lambda b, d, c: (d, 0)),  # A
            pl.BlockSpec((1, ck, Ds), lambda b, d, c: (b, c, 0)),  # B
            pl.BlockSpec((1, ck, Ds), lambda b, d, c: (b, c, 0)),  # C
            pl.BlockSpec((1, bd), lambda b, d, c: (0, d)),  # D (skip)
            pl.BlockSpec((1, bd, Ds), lambda b, d, c: (b, d, 0)),  # h0
        ],
        out_specs=[
            pl.BlockSpec((1, ck, bd), lambda b, d, c: (b, c, d)),  # y
            pl.BlockSpec((1, bd, Ds), lambda b, d, c: (b, d, 0)),  # hT
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Di), x.dtype),
            jax.ShapeDtypeStruct((B, Di, Ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, Ds), jnp.float32)],
        interpret=interpret,
    )(x, delta, A, Bc, Cc, D.reshape(1, Di), h0)
    return y[:, :T], hT
