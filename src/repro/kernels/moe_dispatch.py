"""MoE token-dispatch Pallas TPU kernel.

Scatters routed tokens into per-expert capacity buffers ``(E, C, D)``.  A
GPU implementation scatters with atomics; the TPU-native adaptation is again
an MXU one-hot matmul: per (expert, token-block) grid cell we build
``P[c, n] = (expert_ids[n] == e) & (slot_ids[n] == c)`` and accumulate
``P @ tokens`` into the expert's VMEM-resident buffer.  Capacity overflow
(``slot >= C``) drops tokens exactly like the reference.

The slot assignment (cumulative position of each token within its expert)
is computed outside the kernel — it is a cheap prefix-sum over int32s; the
bandwidth- and MXU-heavy scatter is what the kernel owns.

TARGET: TPU.  VALIDATED: ``interpret=True`` vs ref.moe_dispatch_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["moe_dispatch", "compute_slots"]


def compute_slots(expert_ids: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Position of each token within its expert's buffer (0-based), i.e. a
    per-expert running count in token order."""
    onehot = jax.nn.one_hot(expert_ids, num_experts, dtype=jnp.int32)
    running = jnp.cumsum(onehot, axis=0) - 1  # (T, E)
    return jnp.take_along_axis(running, expert_ids[:, None], axis=1).squeeze(-1)


def _dispatch_kernel(t_ref, id_ref, slot_ref, o_ref, *, block_t, capacity, nt):
    e = pl.program_id(0)
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    toks = t_ref[...].astype(jnp.float32)  # (bt, D)
    ids = id_ref[...]  # (bt, 1)
    slots = slot_ref[...]  # (bt, 1)
    cap_iota = jax.lax.broadcasted_iota(jnp.int32, (capacity, block_t), 0)
    sel = jnp.logical_and(
        ids.T == e, slots.T == cap_iota
    ).astype(jnp.float32)  # (C, bt)
    o_ref[0] += jax.lax.dot_general(
        sel, toks, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("num_experts", "capacity", "block_t", "interpret")
)
def moe_dispatch(
    tokens: jnp.ndarray,  # (T, D)
    expert_ids: jnp.ndarray,  # (T,)
    slot_ids: jnp.ndarray,  # (T,)
    num_experts: int,
    capacity: int,
    block_t: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Dispatch; semantics = ref.moe_dispatch_ref.  Returns (E, C, D)."""
    T, D = tokens.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bt = min(block_t, T)
    Tp = -(-T // bt) * bt
    if Tp != T:
        tokens = jnp.pad(tokens, ((0, Tp - T), (0, 0)))
        expert_ids = jnp.pad(expert_ids, (0, Tp - T), constant_values=num_experts)
        slot_ids = jnp.pad(slot_ids, (0, Tp - T), constant_values=capacity)
    nt = Tp // bt
    kernel = functools.partial(
        _dispatch_kernel, block_t=bt, capacity=capacity, nt=nt
    )
    out = pl.pallas_call(
        kernel,
        grid=(num_experts, nt),
        in_specs=[
            pl.BlockSpec((bt, D), lambda e, t: (t, 0)),
            pl.BlockSpec((bt, 1), lambda e, t: (t, 0)),
            pl.BlockSpec((bt, 1), lambda e, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, capacity, D), lambda e, t: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_experts, capacity, D), jnp.float32),
        interpret=interpret,
    )(
        tokens,
        expert_ids.astype(jnp.int32).reshape(-1, 1),
        slot_ids.astype(jnp.int32).reshape(-1, 1),
    )
    return out.astype(tokens.dtype)
