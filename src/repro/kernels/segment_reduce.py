"""Sorted-segment-sum Pallas TPU kernel — the MapReduce combiner primitive.

The plan-driven MapReduce engine reduces sorted (key, value) runs; the hot
loop is a segment sum.  A GPU implementation would use warp ballots /
shared-memory atomics; the TPU-native adaptation turns the scatter-add into
an **MXU one-hot matmul**: for each VMEM block of rows we build the one-hot
partition matrix ``P[n, s] = (ids[n] == s)`` with ``broadcasted_iota`` and
accumulate ``Pᵀ @ values`` into a VMEM-resident output block across the
sequential grid dimension.  No atomics, no data-dependent control flow —
just dense systolic work.

TARGET: TPU.  VALIDATED: ``interpret=True`` vs ref.segment_sum_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_sum"]


def _segsum_kernel(v_ref, id_ref, o_ref, *, block_n, num_segments):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = v_ref[...].astype(jnp.float32)  # (bn, D)
    ids = id_ref[...]  # (bn, 1) int32
    seg = jax.lax.broadcasted_iota(jnp.int32, (block_n, num_segments), 1)
    onehot = (ids == seg).astype(jnp.float32)  # (bn, S)
    o_ref[...] += jax.lax.dot_general(
        onehot, vals, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "block_n", "interpret")
)
def segment_sum(
    values: jnp.ndarray,  # (N, D)
    segment_ids: jnp.ndarray,  # (N,) int32
    num_segments: int,
    block_n: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Segment sum; semantics = ref.segment_sum_ref (ids need not be sorted
    for correctness, but sorted runs are the intended/benchmarked case)."""
    N, D = values.shape
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    bn = min(block_n, N)
    Np = -(-N // bn) * bn
    if Np != N:
        values = jnp.pad(values, ((0, Np - N), (0, 0)))
        # pad ids with an out-of-range id so they hit no segment
        segment_ids = jnp.pad(
            segment_ids, (0, Np - N), constant_values=num_segments
        )
    nb = Np // bn
    kernel = functools.partial(
        _segsum_kernel, block_n=bn, num_segments=num_segments
    )
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, D), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, D), jnp.float32),
        interpret=interpret,
    )(values, segment_ids.astype(jnp.int32).reshape(-1, 1))
    return out.astype(values.dtype)
