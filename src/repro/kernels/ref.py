"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the semantics contracts: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here.  They are also the
implementations used on paths where the kernel is not warranted (tiny
shapes, CPU smoke tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "attention_ref",
    "mamba_scan_ref",
    "rglru_scan_ref",
    "segment_sum_ref",
    "moe_dispatch_ref",
    "moe_combine_ref",
]


def attention_ref(
    q: jnp.ndarray,  # (B, Hq, T, Dh)
    k: jnp.ndarray,  # (B, Hkv, S, Dh)
    v: jnp.ndarray,  # (B, Hkv, S, Dh)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Multi-head attention with GQA, causal and sliding-window masking.

    ``q_offset`` positions the queries inside the kv sequence (decode /
    chunked prefill): query ``t`` attends to keys ``<= t + q_offset``.
    ``window``: keys further than ``window-1`` behind the query are masked.
    """
    B, Hq, T, Dh = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    if scale is None:
        scale = Dh**-0.5
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(T)[:, None] + q_offset
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows produce NaN from softmax(-inf); zero them
    probs = jnp.where(jnp.any(mask, axis=-1)[None, None, :, None], probs, 0.0)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def mamba_scan_ref(
    x: jnp.ndarray,  # (B, T, Di)
    delta: jnp.ndarray,  # (B, T, Di)
    A: jnp.ndarray,  # (Di, Ds)    (negative-definite diagonal dynamics)
    Bc: jnp.ndarray,  # (B, T, Ds)
    Cc: jnp.ndarray,  # (B, T, Ds)
    D: jnp.ndarray,  # (Di,)
    h0: Optional[jnp.ndarray] = None,  # (B, Di, Ds)
):
    """Mamba-1 selective scan.

      h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t ⊙ x_t) ⊗ B_t
      y_t = (h_t · C_t) + D ⊙ x_t

    Returns ``(y, h_T)`` with y: (B, T, Di), h_T: (B, Di, Ds).
    """
    Bn, T, Di = x.shape
    Ds = A.shape[1]
    xf = x.astype(jnp.float32)
    df = delta.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bn, Di, Ds), jnp.float32)

    def step(h, t):
        decay = jnp.exp(df[:, t][:, :, None] * Af[None])  # (B, Di, Ds)
        inject = (df[:, t] * xf[:, t])[:, :, None] * Bf[:, t][:, None, :]
        h = decay * h + inject
        y = jnp.einsum("bds,bs->bd", h, Cf[:, t])
        return h, y

    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(T))
    y = jnp.moveaxis(ys, 0, 1) + D.astype(jnp.float32)[None, None] * xf
    return y.astype(x.dtype), hT


def rglru_scan_ref(
    x: jnp.ndarray,  # (B, T, D) gated input
    a: jnp.ndarray,  # (B, T, D) recurrence gate in (0, 1)
    h0: Optional[jnp.ndarray] = None,  # (B, D)
):
    """RG-LRU diagonal linear recurrence (RecurrentGemma):

      h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ x_t

    Returns ``(h_all, h_T)``: the full hidden sequence and the final state.
    """
    Bn, T, Dd = x.shape
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bn, Dd), jnp.float32)

    def step(h, t):
        h = af[:, t] * h + jnp.sqrt(jnp.maximum(1.0 - af[:, t] ** 2, 0.0)) * xf[:, t]
        return h, h

    hT, hs = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(T))
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), hT


def segment_sum_ref(
    values: jnp.ndarray,  # (N, D)
    segment_ids: jnp.ndarray,  # (N,) int32, sorted ascending
    num_segments: int,
) -> jnp.ndarray:
    """Sorted-segment sum — the MapReduce combiner/reducer primitive."""
    return jax.ops.segment_sum(
        values.astype(jnp.float32), segment_ids, num_segments
    ).astype(values.dtype)


def moe_dispatch_ref(
    tokens: jnp.ndarray,  # (T, D)
    expert_ids: jnp.ndarray,  # (T,) int32
    slot_ids: jnp.ndarray,  # (T,) int32 position within the expert buffer
    num_experts: int,
    capacity: int,
) -> jnp.ndarray:
    """Scatter tokens into per-expert capacity buffers: out (E, C, D).

    Tokens with ``slot_ids >= capacity`` are dropped (capacity overflow),
    matching production MoE semantics.
    """
    T, D = tokens.shape
    out = jnp.zeros((num_experts, capacity, D), jnp.float32)
    keep = slot_ids < capacity
    out = out.at[
        jnp.where(keep, expert_ids, 0), jnp.where(keep, slot_ids, 0)
    ].add(jnp.where(keep[:, None], tokens.astype(jnp.float32), 0.0))
    return out.astype(tokens.dtype)


def moe_combine_ref(
    expert_out: jnp.ndarray,  # (E, C, D)
    expert_ids: jnp.ndarray,  # (T,)
    slot_ids: jnp.ndarray,  # (T,)
    gates: jnp.ndarray,  # (T,)
    capacity: int,
) -> jnp.ndarray:
    """Gather per-expert outputs back to token order, weighted by gate."""
    keep = slot_ids < capacity
    gathered = expert_out[
        jnp.where(keep, expert_ids, 0), jnp.where(keep, slot_ids, 0)
    ]
    out = gathered.astype(jnp.float32) * jnp.where(keep, gates, 0.0)[:, None]
    return out.astype(expert_out.dtype)
