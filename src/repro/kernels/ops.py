"""Public ops layer: jit'd wrappers that select kernel vs reference.

Models and the MapReduce engine call these; each op dispatches to the Pallas
kernel when shapes warrant it (and pads/tiles appropriately), or to the pure
jnp reference for tiny shapes where kernel launch structure is overhead.
``use_kernel=False`` forces the reference path everywhere (useful to isolate
kernels in A/B tests and on the dry-run path, where XLA's fused attention is
lowered instead so `cost_analysis` sees the dense FLOPs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .mamba_scan import mamba_scan
from .moe_dispatch import compute_slots, moe_dispatch
from .rglru_scan import rglru_scan
from .segment_reduce import segment_sum

__all__ = [
    "attention",
    "ssm_scan",
    "gated_linear_recurrence",
    "sorted_segment_sum",
    "dispatch_tokens",
    "combine_tokens",
    "compute_slots",
]

#: Below these sizes the kernel's block/grid machinery is pure overhead.
_MIN_KERNEL_SEQ = 64


def attention(
    q, k, v,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    use_kernel: bool = True,
    block_q: int = 128,
    block_k: int = 128,
):
    """GQA attention (B, Hq, T, Dh) × (B, Hkv, S, Dh) → (B, Hq, T, Dh)."""
    T, S = q.shape[2], k.shape[2]
    if use_kernel and T >= _MIN_KERNEL_SEQ and S >= _MIN_KERNEL_SEQ:
        return flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            block_q=block_q, block_k=block_k,
        )
    return ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)


def ssm_scan(x, delta, A, Bc, Cc, D, h0=None, use_kernel: bool = True,
             chunk: int = 128, block_d: int = 128):
    """Mamba-1 selective scan → (y, h_T)."""
    if use_kernel and x.shape[1] >= _MIN_KERNEL_SEQ and x.shape[2] % block_d == 0:
        return mamba_scan(x, delta, A, Bc, Cc, D, h0, chunk=chunk, block_d=block_d)
    return ref.mamba_scan_ref(x, delta, A, Bc, Cc, D, h0)


def gated_linear_recurrence(x, a, h0=None, use_kernel: bool = True,
                            chunk: int = 256, block_d: int = 256):
    """RG-LRU → (h_all, h_T)."""
    if use_kernel and x.shape[1] >= _MIN_KERNEL_SEQ and x.shape[2] % block_d == 0:
        return rglru_scan(x, a, h0, chunk=chunk, block_d=block_d)
    return ref.rglru_scan_ref(x, a, h0)


def sorted_segment_sum(values, segment_ids, num_segments: int,
                       use_kernel: bool = True, block_n: int = 512):
    if use_kernel and values.shape[0] >= _MIN_KERNEL_SEQ:
        return segment_sum(values, segment_ids, num_segments, block_n=block_n)
    return ref.segment_sum_ref(values, segment_ids, num_segments)


def dispatch_tokens(tokens, expert_ids, num_experts: int, capacity: int,
                    use_kernel: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Route tokens into (E, C, D) buffers; returns (buffers, slot_ids)."""
    slots = compute_slots(expert_ids, num_experts)
    if use_kernel and tokens.shape[0] >= _MIN_KERNEL_SEQ:
        out = moe_dispatch(tokens, expert_ids, slots, num_experts, capacity)
    else:
        out = ref.moe_dispatch_ref(tokens, expert_ids, slots, num_experts, capacity)
    return out, slots


def combine_tokens(expert_out, expert_ids, slot_ids, gates, capacity: int):
    """Inverse of dispatch: gather expert outputs back to token order."""
    return ref.moe_combine_ref(expert_out, expert_ids, slot_ids, gates, capacity)
