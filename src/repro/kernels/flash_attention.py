"""Blockwise-softmax (flash) attention Pallas TPU kernel.

Prefill-path attention with GQA, causal and sliding-window masking.  The
kernel tiles queries and keys into VMEM blocks (``BlockSpec``), keeps the
running max / normalizer / accumulator in VMEM scratch across the
(sequential) kv-block grid dimension, and uses the MXU for both the
``q·kᵀ`` and ``p·v`` contractions.  Fully-masked kv blocks (beyond the
causal frontier or behind the sliding window) are skipped with ``pl.when``,
which makes causal attention ~2× and windowed attention ~T/W cheaper than
the dense loop — this is the arithmetic the roofline analysis credits.

TARGET: TPU (MXU 128×128; block shapes default to multiples of 128).
VALIDATED: ``interpret=True`` on CPU against :func:`repro.kernels.ref.attention_ref`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention"]

_NEG_INF = float("-inf")


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, q_offset, kv_len, bq, bk, nk,
):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # Block-level skip: the whole kv block is masked out for this q block.
    q_lo = qi * bq + q_offset
    q_hi = q_lo + bq - 1
    k_lo, k_hi = ki * bk, ki * bk + bk - 1
    live = k_lo <= jnp.minimum(q_hi, kv_len - 1) if causal else k_lo < kv_len
    if window is not None:
        live = jnp.logical_and(live, k_hi > q_lo - window)

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        m_safe = jnp.where(m_new == _NEG_INF, 0.0, m_new)
        alpha = jnp.where(m_prev == _NEG_INF, 0.0, jnp.exp(m_prev - m_safe))
        p = jnp.exp(s - m_safe)
        p = jnp.where(mask, p, 0.0)
        l_new = alpha * l_prev + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0, :, :] = (
            acc_scr[...] / jnp.where(l == 0.0, 1.0, l)
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "scale", "block_q", "block_k", "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,  # (B, Hq, T, Dh)
    k: jnp.ndarray,  # (B, Hkv, S, Dh)
    v: jnp.ndarray,  # (B, Hkv, S, Dh)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Flash attention.  Semantics = :func:`repro.kernels.ref.attention_ref`."""
    B, Hq, T, Dh = q.shape
    _, Hkv, S, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if scale is None:
        scale = Dh**-0.5
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    bq = min(block_q, T)
    bk = min(block_k, S)
    Tp = -(-T // bq) * bq
    Sp = -(-S // bk) * bk
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    nq, nk = Tp // bq, Sp // bk

    kernel = functools.partial(
        _attn_kernel,
        scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        kv_len=S,
        bq=bq,
        bk=bk,
        nk=nk,
    )
    from jax.experimental.pallas import tpu as pltpu

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, Dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, Dh), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Tp, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),  # running max m
            pltpu.VMEM((bq, 1), jnp.float32),  # running normalizer l
            pltpu.VMEM((bq, Dh), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :T]
