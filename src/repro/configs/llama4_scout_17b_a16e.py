"""Llama-4-Scout-17B-16E (MoE, early fusion) — backbone config.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

iRoPE layout: groups of four layers — three local-RoPE attention layers
(8192-token chunked window) followed by one global NoPE layer.  Every layer
carries a top-1 16-expert MoE FFN (the released model interleaves a shared
expert; we model the routed experts, noted in DESIGN.md).  The MoE dispatch
is the paper-technique integration point (``geo_plannable``).
"""
from repro.models.config import ArchConfig, Block

_LOCAL = Block(mixer="attn", ffn="moe", rope=True, window=8192)
_GLOBAL = Block(mixer="attn", ffn="moe", rope=False, window=None)

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    n_experts=16,
    top_k=1,
    expert_d_ff=8192,
    norm="rmsnorm",
    act="silu",
    rope_theta=500_000.0,
    geo_plannable=True,
)
