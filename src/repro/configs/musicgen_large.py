"""MusicGen-Large — decoder-only transformer over EnCodec tokens.

48L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=2048.
[arXiv:2306.05284; hf]

The EnCodec audio frontend is a STUB per the brief: ``input_specs()`` feeds
precomputed frame embeddings (the sum of the four codebook embeddings);
this config covers the transformer backbone, with a 2048-way codec-token
output head.
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    pattern=(Block(mixer="attn", ffn="dense"),),
    norm="layernorm",
    act="gelu",
    rope_theta=10_000.0,
    frontend="embed",
)
