"""RecurrentGemma-9B — RG-LRU + local attention, 1:2 ratio (Griffin).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000, 2048-token local
attention window.  [arXiv:2402.19427; unverified]

Layout: (rglru, rglru, local-attn) × 12 groups + (rglru, rglru) tail = 38
layers.  Sub-quadratic (bounded attention window + linear recurrence) —
runs the ``long_500k`` shape.
"""
from repro.models.config import ArchConfig, Block

_RG = Block(mixer="rglru", ffn="dense")
_LA = Block(mixer="attn", ffn="dense", rope=True, window=2048)

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256000,
    pattern=(_RG, _RG, _LA),
    tail=(_RG, _RG),
    rglru_expand=1,
    norm="rmsnorm",
    act="gelu",
    rope_theta=10_000.0,
    subquadratic=True,
)
