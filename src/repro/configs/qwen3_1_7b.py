"""Qwen3-1.7B — qk-norm + GQA dense transformer.

28L d_model=2048 16H (GQA kv=8, head_dim=128) d_ff=6144 vocab=151936.
[hf:Qwen/Qwen3-8B family; hf]
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151936,
    pattern=(Block(mixer="attn", ffn="dense"),),
    norm="rmsnorm",
    qk_norm=True,
    act="silu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
