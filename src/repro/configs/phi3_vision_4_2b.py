"""Phi-3-Vision 4.2B — phi3-mini text backbone + CLIP frontend (stubbed).

32L d_model=3072 32H (MHA, kv=32) d_ff=8192 vocab=32064.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision tower is a STUB per the brief: ``input_specs()`` feeds
precomputed patch embeddings (B, T, d_model); this config covers the
transformer backbone only.
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    pattern=(Block(mixer="attn", ffn="dense"),),
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    frontend="embed",
)
