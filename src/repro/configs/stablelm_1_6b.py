"""StableLM-2-1.6B — parametric-LayerNorm dense transformer.

24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b; unverified]

(The released model applies rotary to 25% of head dims; we apply full
rotary — noted in DESIGN.md §Arch-applicability.)
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    pattern=(Block(mixer="attn", ffn="dense"),),
    norm="layernorm",
    act="silu",
    rope_theta=10_000.0,
)
