"""Architecture registry + assigned input shapes.

``get_config(name)`` — the exact published config; ``--arch <id>`` in the
launchers resolves here.  ``input_specs(cfg, shape)`` builds the
ShapeDtypeStruct stand-ins for the dry-run (weak-type-correct, shardable,
no device allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig

from .llama4_scout_17b_a16e import CONFIG as _llama4
from .granite_moe_3b_a800m import CONFIG as _granite
from .phi3_vision_4_2b import CONFIG as _phi3v
from .olmo_1b import CONFIG as _olmo
from .mistral_nemo_12b import CONFIG as _nemo
from .qwen3_1_7b import CONFIG as _qwen3
from .stablelm_1_6b import CONFIG as _stablelm
from .recurrentgemma_9b import CONFIG as _rgemma
from .falcon_mamba_7b import CONFIG as _fmamba
from .musicgen_large import CONFIG as _musicgen

__all__ = ["ARCHS", "SHAPES", "get_config", "input_specs", "cells"]

ARCHS: Dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _llama4, _granite, _phi3v, _olmo, _nemo,
        _qwen3, _stablelm, _rgemma, _fmamba, _musicgen,
    ]
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def padded_for_tp(cfg: ArchConfig, tp: int) -> ArchConfig:
    """TP-divisibility padding (DESIGN.md §TP-padding).

    * KV heads are *repeated* up to a multiple of ``tp`` — exact for GQA
      (each repeated head serves fewer query heads).
    * Query heads are padded to the next count divisible by both ``tp`` and
      the padded KV count — the extra heads are dead weight whose FLOPs
      surface in the roofline's MODEL_FLOPS/HLO_FLOPS ratio.
    * Vocab is padded to a multiple of ``tp``; padded logits are masked to
      -inf in forward (``vocab_real``), so semantics are exact.

    ``n_params()`` of the returned config counts padded shapes; roofline
    code uses the *original* config for MODEL_FLOPS.
    """
    changes = {}
    has_attn = any(b.mixer == "attn" for b in cfg.pattern + cfg.tail)
    if has_attn:
        kv = cfg.n_kv_heads
        if kv % tp and tp % kv == 0:
            kv = tp
        elif kv % tp:
            kv = -(-kv // tp) * tp
        hq = cfg.n_heads
        lcm = np.lcm(tp, kv)
        if hq % lcm:
            hq = int(-(-hq // lcm) * lcm)
        if (hq, kv) != (cfg.n_heads, cfg.n_kv_heads):
            changes.update(
                n_heads=int(hq), n_kv_heads=int(kv), head_dim=cfg.head_dim_
            )
    if cfg.vocab % tp:
        changes.update(
            vocab=int(-(-cfg.vocab // tp) * tp), vocab_real=cfg.vocab
        )
    return dataclasses.replace(cfg, **changes) if changes else cfg


def shape_supported(cfg: ArchConfig, shape: str) -> bool:
    """long_500k needs sub-quadratic sequence mixing (skip rationale in
    DESIGN.md §Shape-skips); everything else runs everywhere."""
    if shape == "long_500k":
        return cfg.subquadratic
    return True


def cells():
    """All supported (arch, shape) dry-run cells."""
    out = []
    for a, cfg in ARCHS.items():
        for s in SHAPES:
            if shape_supported(cfg, s):
                out.append((a, s))
    return out


def input_specs(
    cfg: ArchConfig, shape: str, dtype=jnp.bfloat16
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of the given shape.

    train:   {tokens|embeds, labels}
    prefill: {tokens|embeds}
    decode:  {tokens|embeds, positions} (+ cache, built separately via
             ``jax.eval_shape`` on ``model.init_cache``)
    """
    spec = SHAPES[shape]
    B, T = spec.global_batch, spec.seq_len
    i32 = jnp.int32

    def text_or_embed(bt):
        if cfg.frontend == "embed":
            return {"embeds": jax.ShapeDtypeStruct(bt + (cfg.d_model,), dtype)}
        return {"tokens": jax.ShapeDtypeStruct(bt, i32)}

    if spec.kind == "train":
        out = text_or_embed((B, T))
        out["labels"] = jax.ShapeDtypeStruct((B, T), i32)
        return out
    if spec.kind == "prefill":
        return text_or_embed((B, T))
    # decode: one new token against a cache of length seq_len
    out = text_or_embed((B, 1))
    out["positions"] = jax.ShapeDtypeStruct((B, 1), i32)
    return out


def cache_specs(cfg: ArchConfig, shape: str, dtype=jnp.bfloat16,
                kv_int8: bool = False):
    """ShapeDtypeStructs of the decode cache for a decode shape."""
    from repro.models import model as M

    spec = SHAPES[shape]
    assert spec.kind == "decode"
    return jax.eval_shape(
        lambda: M.init_cache(cfg, spec.global_batch, spec.seq_len, dtype,
                             kv_int8=kv_int8)
    )
