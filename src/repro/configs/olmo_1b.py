"""OLMo-1B — non-parametric LayerNorm dense transformer.

16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304.
[arXiv:2402.00838; hf]
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50304,
    pattern=(Block(mixer="attn", ffn="dense"),),
    norm="nonparam_ln",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)
