"""Falcon-Mamba-7B — pure Mamba-1 SSM (attention-free).

64L d_model=4096 (attn-free, d_ff=0), ssm_state=16, vocab=65024.
[arXiv:2410.05355; unverified]

Sub-quadratic by construction — runs the ``long_500k`` shape with O(1)
per-token state.
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    pattern=(Block(mixer="ssm", ffn="none"),),
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    norm="rmsnorm",
    act="silu",
    subquadratic=True,
)
