"""IBM Granite MoE 3B-A800M — 32 experts top-8 family.

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155, MoE 40
experts top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

40 experts are padded to 48 (= 3 per TP-16 shard) with -inf router mass —
padding is exact; the wasted FLOPs surface in the roofline's
MODEL_FLOPS/HLO_FLOPS ratio.  MoE dispatch is geo-plannable.
"""
from repro.models.config import ArchConfig, Block

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    pattern=(Block(mixer="attn", ffn="moe"),),
    n_experts=40,
    top_k=8,
    expert_d_ff=512,
    capacity_factor=1.25,
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    geo_plannable=True,
)
