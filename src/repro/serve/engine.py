"""Batched serving engine: continuous batching over a fixed decode grid.

A production-shaped, dependency-free serving loop:

* requests queue up with prompt tokens and a max_new_tokens budget;
* the engine keeps ``slots`` concurrent sequences in a shared KV cache
  (slot = batch row), admitting new requests into freed slots each step
  (**continuous batching** — no head-of-line blocking on long generations);
* prefill runs per-admission (right-padded into the slot's cache);
* one fused decode step advances *all* active slots;
* per-request metrics: TTFT (steps to first token) and decode steps.

Greedy sampling by default; temperature optional.  The engine is exercised
on reduced configs in tests and ``examples/serve_lm.py``; the full-config
decode path is what the ``decode_32k``/``long_500k`` dry-run cells lower.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M
from ..models.config import ArchConfig

__all__ = ["ServeConfig", "ServeEngine", "Request"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (T,) int32
    max_new_tokens: int
    temperature: float = 0.0
    # filled by the engine:
    output: Optional[List[int]] = None
    ttft_steps: Optional[int] = None
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4
    max_len: int = 512
    compute_dtype: object = jnp.float32
    use_kernels: bool = False
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig, mesh=None):
        assert cfg.frontend is None, "serving loop drives token-in archs"
        self.cfg, self.params, self.scfg, self.mesh = cfg, params, scfg, mesh
        self.cache = M.init_cache(
            cfg, scfg.slots, scfg.max_len, dtype=jnp.float32
        )
        self.slot_req: List[Optional[Request]] = [None] * scfg.slots
        self.slot_pos = np.zeros(scfg.slots, np.int32)
        self.pending: List[Request] = []
        self.step_count = 0
        self.rng = jax.random.PRNGKey(scfg.seed)

        cfg_, mesh_ = cfg, mesh

        @jax.jit
        def decode_fn(params, cache, tokens, positions):
            logits, new_cache, _ = M.decode_step(
                cfg_, params,
                {"tokens": tokens, "positions": positions}, cache,
                mesh=mesh_, compute_dtype=scfg.compute_dtype,
            )
            return logits[:, -1], new_cache

        self._decode = decode_fn

    # -- public API -----------------------------------------------------------
    def submit(self, req: Request):
        req.output = []
        self.pending.append(req)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive the loop until all submitted requests finish."""
        finished: List[Request] = []
        for _ in range(max_steps):
            self._admit()
            if all(r is None for r in self.slot_req) and not self.pending:
                break
            finished.extend(self._step())
        return finished

    # -- internals ----------------------------------------------------------
    def _admit(self):
        for s in range(self.scfg.slots):
            if self.slot_req[s] is None and self.pending:
                req = self.pending.pop(0)
                self._prefill_into_slot(s, req)

    def _prefill_into_slot(self, s: int, req: Request):
        """Per-slot B=1 prefill merged into the shared cache at slot ``s``
        — other slots' KV rows and recurrent state are untouched, which is
        what makes continuous batching correct for SSM/hybrid archs too.
        (Production batches prefills into length buckets; the bulk path is
        what the prefill_32k dry-run cells lower.)"""
        T = len(req.prompt)
        assert T + req.max_new_tokens <= self.scfg.max_len, "prompt too long"
        logits, cache1, _ = M.prefill(
            self.cfg, self.params,
            {"tokens": jnp.asarray(req.prompt[None])},
            max_cache_len=self.scfg.max_len,
            mesh=self.mesh, compute_dtype=self.scfg.compute_dtype,
        )

        def merge(full, one):
            # group-stacked leaves: (G, B, ...) vs (G, 1, ...)
            if full.ndim >= 2 and full.shape[1] == self.scfg.slots:
                return full.at[:, s].set(one[:, 0].astype(full.dtype))
            return full

        self.cache = jax.tree.map(merge, self.cache, cache1)
        first = int(np.argmax(np.asarray(logits[0, T - 1])))
        req.output.append(first)
        req.ttft_steps = self.step_count + 1
        self.slot_req[s] = req
        self.slot_pos[s] = T

    def _bulk_decode(self, tokens, positions):
        logits, cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(positions)
        )
        return logits, cache

    def _step(self) -> List[Request]:
        active = [s for s in range(self.scfg.slots) if self.slot_req[s] is not None]
        if not active:
            return []
        tokens = np.zeros((self.scfg.slots, 1), np.int32)
        positions = np.zeros((self.scfg.slots, 1), np.int32)
        for s in active:
            req = self.slot_req[s]
            tokens[s, 0] = req.output[-1]
            positions[s, 0] = self.slot_pos[s]
        logits, self.cache = self._bulk_decode(tokens, positions)
        self.step_count += 1
        done: List[Request] = []
        logits_np = np.asarray(logits)
        for s in active:
            req = self.slot_req[s]
            if req.temperature > 0:
                self.rng, sub = jax.random.split(self.rng)
                nxt = int(
                    jax.random.categorical(
                        sub, jnp.asarray(logits_np[s]) / req.temperature
                    )
                )
            else:
                nxt = int(np.argmax(logits_np[s]))
            req.output.append(nxt)
            self.slot_pos[s] += 1
            if len(req.output) >= req.max_new_tokens:
                req.done = True
                done.append(req)
                self.slot_req[s] = None
        return done
