from .engine import ServeConfig, ServeEngine, Request

__all__ = ["ServeConfig", "ServeEngine", "Request"]
