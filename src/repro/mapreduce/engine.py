"""Plan-driven MapReduce engine.

Executes a MapReduce application under an explicit execution plan
(:class:`repro.core.plan.ExecutionPlan`), enforcing the paper's three Hadoop
modifications (§3.1):

* **coupled placement/execution** (LocalOnly): a mapper processes exactly
  the records pushed to it, a reducer exactly its key buckets;
* **plan-controlled push**: source ``i`` sends fraction ``x_ij`` of its
  records to mapper ``j`` (contiguous deterministic split);
* **plan-controlled shuffle**: intermediate keys are hashed into many small
  buckets and buckets are assigned to reducers proportionally to ``y_k``
  (:func:`repro.mapreduce.partition.bucket_owners`).

The engine runs the *actual computation* (real maps/reduces over real
records, with the Pallas ``segment_sum`` kernel in the reduce hot loop) and
records the *actual bytes* moved per link per phase.  Wall-clock makespan on
a modeled platform is obtained by pricing those measured byte/compute
volumes through the **shared cost model**
(:class:`repro.core.makespan.CostModel` — the exact same equations the
planner optimizes, with measured quantities replacing the analytic
``D_i·x_ij`` terms, so model and measurement cannot diverge).  This is how
the Fig-9 benchmark drives real applications over the emulated PlanetLab
network, exactly in the spirit of the paper's ``tc``-emulated testbed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.makespan import CostModel
from ..core.plan import ExecutionPlan
from ..core.platform import Platform
from .partition import bucket_owners, hash_keys

__all__ = ["MRApp", "GeoMapReduce", "PhaseStats"]

Records = Tuple[np.ndarray, np.ndarray]  # (keys int64 (N,), values (N,) or (N,D))


def _empty_records_like(records: Sequence[Records]) -> Records:
    """Zero-length ``(keys, values)`` whose dtype and trailing value shape
    match the app's actual records (preferring a non-empty pair), so empty
    partitions concatenate cleanly with float / vector-valued loads."""
    proto: Optional[Records] = None
    for k, v in records:
        proto = (k, v)
        if k.shape[0]:
            break
    if proto is None:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    k, v = proto
    return np.asarray(k)[:0], np.asarray(v)[:0]


@dataclasses.dataclass(frozen=True)
class MRApp:
    """A MapReduce application.

    map_fn: (keys, values) -> (out_keys, out_values) — vectorized.
    reduce_fn: (sorted_keys, values_in_key_order) -> (keys, values) —
      applied per reducer on its full, key-sorted partition.
    record_bytes / intermediate_record_bytes: accounting sizes.
    """

    name: str
    map_fn: Callable[[np.ndarray, np.ndarray], Records]
    reduce_fn: Callable[[np.ndarray, np.ndarray], Records]
    record_bytes: int = 8
    intermediate_record_bytes: int = 8


@dataclasses.dataclass
class PhaseStats:
    push_bytes: np.ndarray  # (nS, nM)
    map_in_bytes: np.ndarray  # (nM,)
    shuffle_bytes: np.ndarray  # (nM, nR)
    reduce_in_bytes: np.ndarray  # (nR,)
    alpha_measured: float

    def volumes_mb(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Measured per-phase volumes in the MB units the cost model prices."""
        MB = 1e6
        return (
            self.push_bytes / MB,
            self.map_in_bytes / MB,
            self.shuffle_bytes / MB,
            self.reduce_in_bytes / MB,
        )

    def makespan(
        self, platform: Platform, barriers: Tuple[str, str, str] = ("G", "G", "L")
    ) -> Dict[str, float]:
        """Price the measured byte volumes through the shared
        :class:`repro.core.makespan.CostModel` (which also validates the
        barrier triple)."""
        return CostModel(platform, barriers).breakdown_volumes(*self.volumes_mb())


class GeoMapReduce:
    def __init__(
        self,
        platform: Platform,
        plan: ExecutionPlan,
        app: MRApp,
        n_buckets: int = 512,
        use_kernel_reduce: bool = True,
    ):
        assert plan.nS == platform.nS and plan.nM == platform.nM
        self.platform, self.plan, self.app = platform, plan, app
        self.n_buckets = n_buckets
        self.owners = bucket_owners(plan.y, n_buckets)
        self.use_kernel_reduce = use_kernel_reduce

    # -- phases ------------------------------------------------------------
    def _push(self, per_source: Sequence[Records]):
        """Split each source's records into contiguous chunks per x_ij."""
        nS, nM = self.plan.nS, self.plan.nM
        incoming: List[List[Records]] = [[] for _ in range(nM)]
        push_bytes = np.zeros((nS, nM))
        for i, (keys, values) in enumerate(per_source):
            n = keys.shape[0]
            # largest-remainder split of n records by x row
            raw = self.plan.x[i] * n
            counts = np.floor(raw).astype(np.int64)
            for idx in np.argsort(-(raw - counts))[: n - counts.sum()]:
                counts[idx] += 1
            off = 0
            for j in range(nM):
                c = int(counts[j])
                if c:
                    incoming[j].append((keys[off : off + c], values[off : off + c]))
                    push_bytes[i, j] = c * self.app.record_bytes
                off += c
        merged = []
        for j in range(nM):
            if incoming[j]:
                ks = np.concatenate([k for k, _ in incoming[j]])
                vs = np.concatenate([v for _, v in incoming[j]])
            else:
                ks, vs = _empty_records_like(per_source)
            merged.append((ks, vs))
        return merged, push_bytes

    def _map(self, per_mapper: Sequence[Records]):
        out = []
        in_bytes = np.zeros(len(per_mapper))
        for j, (keys, values) in enumerate(per_mapper):
            in_bytes[j] = keys.shape[0] * self.app.record_bytes
            mk, mv = self.app.map_fn(keys, values)
            out.append((np.asarray(mk, np.int64), np.asarray(mv)))
        return out, in_bytes

    def _shuffle(self, mapped: Sequence[Records]):
        nM, nR = self.plan.nM, self.plan.nR
        shuffle_bytes = np.zeros((nM, nR))
        to_reducer: List[List[Records]] = [[] for _ in range(nR)]
        for j, (mk, mv) in enumerate(mapped):
            if mk.shape[0] == 0:
                continue
            buckets = hash_keys(mk, self.n_buckets)
            dest = self.owners[buckets]
            order = np.argsort(dest, kind="stable")
            dk, dv, dd = mk[order], mv[order], dest[order]
            bounds = np.searchsorted(dd, np.arange(nR + 1))
            for k in range(nR):
                lo, hi = bounds[k], bounds[k + 1]
                if hi > lo:
                    to_reducer[k].append((dk[lo:hi], dv[lo:hi]))
                    shuffle_bytes[j, k] = (
                        (hi - lo) * self.app.intermediate_record_bytes
                    )
        merged = []
        for k in range(nR):
            if to_reducer[k]:
                ks = np.concatenate([a for a, _ in to_reducer[k]])
                vs = np.concatenate([b for _, b in to_reducer[k]])
            else:
                ks, vs = _empty_records_like(mapped)
            merged.append((ks, vs))
        return merged, shuffle_bytes

    def _reduce(self, per_reducer: Sequence[Records]):
        outs = []
        in_bytes = np.zeros(len(per_reducer))
        for k, (keys, values) in enumerate(per_reducer):
            in_bytes[k] = keys.shape[0] * self.app.intermediate_record_bytes
            if keys.shape[0] == 0:
                outs.append((keys, values))
                continue
            order = np.argsort(keys, kind="stable")
            outs.append(self.app.reduce_fn(keys[order], values[order]))
        return outs, in_bytes

    # -- run ----------------------------------------------------------------
    def run(self, per_source: Sequence[Records]):
        """Execute; returns (per-reducer outputs, PhaseStats)."""
        per_mapper, push_bytes = self._push(per_source)
        mapped, map_in = self._map(per_mapper)
        per_reducer, shuffle_bytes = self._shuffle(mapped)
        outs, reduce_in = self._reduce(per_reducer)
        total_in = max(map_in.sum(), 1e-9)
        stats = PhaseStats(
            push_bytes=push_bytes,
            map_in_bytes=map_in,
            shuffle_bytes=shuffle_bytes,
            reduce_in_bytes=reduce_in,
            alpha_measured=float(reduce_in.sum() / total_in),
        )
        return outs, stats
