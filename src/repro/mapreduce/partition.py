"""Plan-driven key partitioning (paper §3.1.3).

The paper's custom Partitioner hashes intermediate keys into many small
buckets and assigns buckets to reducers in proportion to the plan's ``y_k``
fractions (valid because Equation 3 forces every mapper to use the same
partition function — one-reducer-per-key).  ``bucket_owners`` reproduces
that: ``owners[b]`` is the reducer owning bucket ``b``, with bucket counts
per reducer proportional to ``y``.
"""
from __future__ import annotations

import numpy as np

__all__ = ["hash_keys", "bucket_owners"]


def hash_keys(keys: np.ndarray, n_buckets: int) -> np.ndarray:
    """Deterministic int32 mix (splitmix-style) → bucket ids."""
    x = keys.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x % np.uint64(n_buckets)).astype(np.int32)


def bucket_owners(y: np.ndarray, n_buckets: int) -> np.ndarray:
    """Assign ``n_buckets`` hash buckets to reducers proportionally to the
    plan fractions ``y`` (largest-remainder rounding, exact partition)."""
    y = np.asarray(y, dtype=np.float64)
    raw = y * n_buckets
    counts = np.floor(raw).astype(np.int64)
    rem = n_buckets - counts.sum()
    order = np.argsort(-(raw - counts))
    for idx in order[: int(rem)]:
        counts[idx] += 1
    owners = np.repeat(np.arange(len(y)), counts)
    assert owners.shape[0] == n_buckets
    return owners.astype(np.int32)
