"""The paper's three evaluation applications (§4.6.2), on the plan-driven
engine — plus the synthetic α-controlled job used for model validation
(§3.2).

* **Word Count** — heavy aggregation, in-mapper combining (α ≈ 0.09 in the
  paper; here α is whatever the generated corpus yields, measured).
* **Sessionization** — a distributed sort: identity map keyed by user, the
  reducer orders each user's log entries by timestamp and cuts sessions at
  gaps > threshold (α = 1.0).
* **Full Inverted Index** — positional index over (doc, word) pairs; the
  intermediate records append position info, so α > 1.

Values are packed into int64s (value packing stands in for serialized
records; byte accounting uses the app's record sizes).  The reduce hot loop
uses the Pallas ``segment_sum`` kernel via :mod:`repro.kernels.ops`.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from ..kernels import ops as kops
from .engine import MRApp

__all__ = [
    "word_count",
    "sessionization",
    "inverted_index",
    "synthetic_alpha_job",
    "generate_documents",
    "generate_logs",
]


# ---------------------------------------------------------------------------
# corpora
# ---------------------------------------------------------------------------

def generate_documents(
    n_docs: int, words_per_doc: int, vocab: int = 10_000, seed: int = 0
):
    """(doc_id keys, word values) — Zipf-distributed words."""
    rng = np.random.default_rng(seed)
    words = np.minimum(rng.zipf(1.4, size=n_docs * words_per_doc), vocab) - 1
    doc_ids = np.repeat(np.arange(n_docs), words_per_doc)
    pos = np.tile(np.arange(words_per_doc), n_docs)
    # value packs (doc_id, position, word)
    packed = (doc_ids.astype(np.int64) << 40) | (pos.astype(np.int64) << 20) | words
    return doc_ids.astype(np.int64), packed


def generate_logs(n_entries: int, n_users: int = 500, seed: int = 0):
    """WorldCup-trace-like web log: (user, timestamp) pairs."""
    rng = np.random.default_rng(seed)
    users = rng.integers(0, n_users, size=n_entries).astype(np.int64)
    ts = np.sort(rng.integers(0, 10_000_000, size=n_entries)).astype(np.int64)
    packed = (users << 32) | ts
    return users, packed


# ---------------------------------------------------------------------------
# applications
# ---------------------------------------------------------------------------

def word_count(use_kernel: bool = True) -> MRApp:
    def map_fn(keys, values) -> Tuple[np.ndarray, np.ndarray]:
        words = (values & ((1 << 20) - 1)).astype(np.int64)
        # in-mapper combining (Lin & Dyer): emit (word, count) once per word
        uniq, counts = np.unique(words, return_counts=True)
        return uniq, counts.astype(np.int64)

    def reduce_fn(keys, values):
        uniq, start = np.unique(keys, return_index=True)
        seg = np.searchsorted(uniq, keys).astype(np.int32)
        import jax.numpy as jnp

        sums = kops.sorted_segment_sum(
            np.asarray(values, np.float32)[:, None],
            jnp.asarray(seg),
            int(uniq.shape[0]),
            use_kernel=use_kernel,
        )
        return uniq, np.asarray(sums)[:, 0].astype(np.int64)

    return MRApp(
        name="word_count", map_fn=map_fn, reduce_fn=reduce_fn,
        record_bytes=16, intermediate_record_bytes=16,
    )


def sessionization(gap: int = 30_000) -> MRApp:
    def map_fn(keys, values):
        return keys, values  # identity: route by user id

    def reduce_fn(keys, values):
        # values already grouped by key (engine sorts by key); order each
        # user's entries by timestamp and cut sessions at large gaps.
        ts = (values & ((1 << 32) - 1)).astype(np.int64)
        order = np.lexsort((ts, keys))
        k, t = keys[order], ts[order]
        new_user = np.concatenate([[True], k[1:] != k[:-1]])
        big_gap = np.concatenate([[False], (t[1:] - t[:-1]) > gap])
        session_start = new_user | big_gap
        session_id = np.cumsum(session_start) - 1
        return k, ((session_id.astype(np.int64) << 32) | t)

    return MRApp(
        name="sessionization", map_fn=map_fn, reduce_fn=reduce_fn,
        record_bytes=16, intermediate_record_bytes=16,
    )


def inverted_index() -> MRApp:
    def map_fn(keys, values):
        words = (values & ((1 << 20) - 1)).astype(np.int64)
        doc = (values >> 40).astype(np.int64)
        pos = ((values >> 20) & ((1 << 20) - 1)).astype(np.int64)
        # posting carries (doc, position) — the "full" index: α > 1 in byte
        # terms (intermediate records are bigger than inputs).
        return words, (doc << 20) | pos

    def reduce_fn(keys, values):
        order = np.lexsort((values, keys))
        return keys[order], values[order]

    return MRApp(
        name="inverted_index", map_fn=map_fn, reduce_fn=reduce_fn,
        record_bytes=8, intermediate_record_bytes=16,
    )


def synthetic_alpha_job(alpha: float) -> MRApp:
    """The §3.2 synthetic job: mappers re-emit each record ``alpha×`` (in
    expectation) with an identity reduce — direct control over the data
    expansion factor."""

    def map_fn(keys, values):
        n = keys.shape[0]
        whole = int(np.floor(alpha))
        frac = alpha - whole
        reps = np.full(n, whole, np.int64)
        if frac > 0:
            # deterministic fractional expansion: first round(frac*n)
            reps[: int(round(frac * n))] += 1
        return np.repeat(keys, reps), np.repeat(values, reps)

    def reduce_fn(keys, values):
        return keys, values

    return MRApp(
        name=f"synthetic_alpha_{alpha}", map_fn=map_fn, reduce_fn=reduce_fn,
        record_bytes=8, intermediate_record_bytes=8,
    )
