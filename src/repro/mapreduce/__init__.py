from .engine import GeoMapReduce, PhaseStats
from .partition import bucket_owners, hash_keys
from . import apps

__all__ = ["GeoMapReduce", "PhaseStats", "bucket_owners", "hash_keys", "apps"]
