"""Error-feedback gradient compression for the cross-pod (DCN) hop.

At 1000+-node scale the pod-axis gradient reduction rides the slowest
fabric.  Standard mitigation: compress only the *cross-pod* summand and keep
full precision inside the pod, with **error feedback** (the compression
residual is added back into the next step's gradient) so convergence is
preserved.

``compress``/``decompress`` implement stochastic-rounding int8 with a
per-block scale (block = last axis), and bf16 truncation.  They are pure
functions usable inside the jitted train step; the residual buffer is part
of the train state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ef_compress_tree", "ef_ratio"]


def compress_int8(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blockwise (per-row) int8 quantization with stochastic rounding.
    Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    flat = xf.reshape(-1, x.shape[-1]) if x.ndim > 1 else xf.reshape(1, -1)
    scale = jnp.max(jnp.abs(flat), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    y = flat / scale
    noise = jax.random.uniform(key, y.shape) - 0.5
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q.reshape(x.shape), scale.reshape(
        x.shape[:-1] + (1,) if x.ndim > 1 else (1, 1)
    )


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads, residual, key, kind: str = "int8"):
    """Error-feedback compression over a gradient pytree.

    Returns (compressed_then_decompressed_grads, new_residual).  The
    returned grads are what the *cross-pod* reduction transports (already
    reconstructed, so the caller's collective code stays dtype-agnostic in
    this reference implementation; a deployment would move the int8 payload
    itself).
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = jax.tree_util.tree_leaves(residual)
    keys = jax.random.split(key, len(leaves))
    out, new_res = [], []
    for g, r, k in zip(leaves, res_leaves, keys):
        gf = g.astype(jnp.float32) + r
        if kind == "int8":
            q, s = compress_int8(gf, k)
            rec = decompress_int8(q, s)
        elif kind == "bf16":
            rec = gf.astype(jnp.bfloat16).astype(jnp.float32)
        else:
            raise ValueError(kind)
        out.append(rec.astype(g.dtype))
        new_res.append(gf - rec)
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        jax.tree_util.tree_unflatten(treedef, new_res),
    )


def ef_ratio(kind: str) -> float:
    """Bytes-on-the-wire ratio vs f32 (for the roofline's collective term)."""
    return {"int8": 0.25, "bf16": 0.5, "none": 1.0}[kind]
