"""Checkpointing: atomic commit, async save, retention, elastic re-shard.

Layout (one directory per step)::

    <dir>/step_000123/
        MANIFEST.json        # {path: {shape, dtype, file}}, step, extras
        arrays/<idx>.npy     # one .npy per leaf (host numpy)
        COMMITTED            # written last — a checkpoint without it is
                             # garbage from a crashed save and is ignored

Properties needed at fleet scale:

* **atomic**: the COMMITTED marker is written after every array fsync; a
  node failure mid-save can never produce a checkpoint that restores.
* **async**: ``save_async`` snapshots to host memory synchronously (cheap)
  and writes in a background thread so the train loop keeps stepping.
* **retention**: keep the newest ``keep`` checkpoints, always preserving
  any checkpoint marked ``milestone``.
* **elastic re-shard**: arrays are stored unsharded (host-gathered), so a
  restore can land on *any* mesh shape — restore takes the target sharding
  pytree and device_puts each leaf accordingly.  A 2-pod checkpoint
  restores onto 1 pod (or 4) unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_MARKER = "COMMITTED"


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)  # handles dict/attr/index keys
        if path in out:
            raise ValueError(f"duplicate checkpoint leaf path {path!r}")
        out[path] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- enumeration -------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, _MARKER)
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, extras: Optional[dict] = None,
             milestone: bool = False):
        """Synchronous atomic save."""
        snapshot = jax.tree.map(np.asarray, jax.device_get(tree))
        self._write(step, snapshot, extras or {}, milestone)
        self._gc()

    def save_async(self, step: int, tree, extras: Optional[dict] = None,
                   milestone: bool = False):
        """Snapshot now, write in the background.  Raises any error from the
        previous async save (so failures are not silent)."""
        self.wait()
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        snapshot = jax.tree.map(np.asarray, jax.device_get(tree))

        def work():
            try:
                self._write(step, snapshot, extras or {}, milestone)
                self._gc()
            except BaseException as e:  # surfaced on next save/wait
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, snapshot, extras: dict, milestone: bool):
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(prefix=".tmp_save_", dir=self.dir)
        try:
            arrays_dir = os.path.join(tmp, "arrays")
            os.makedirs(arrays_dir)
            leaves = _leaf_paths(snapshot)
            manifest = {"step": step, "milestone": milestone, "extras": extras,
                        "leaves": {}}
            for i, (path, leaf) in enumerate(sorted(leaves.items())):
                arr = np.asarray(leaf)
                fname = f"{i}.npy"
                with open(os.path.join(arrays_dir, fname), "wb") as f:
                    np.save(f, arr)
                    f.flush()
                    os.fsync(f.fileno())
                manifest["leaves"][path] = {
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "file": fname,
                }
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            with open(os.path.join(tmp, _MARKER), "w") as f:
                f.write("ok")
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    def _gc(self):
        steps = self.steps()
        if len(steps) <= self.keep:
            return
        for s in steps[: -self.keep]:
            d = self._step_dir(s)
            try:
                with open(os.path.join(d, "MANIFEST.json")) as f:
                    if json.load(f).get("milestone"):
                        continue
            except OSError:
                pass
            shutil.rmtree(d, ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def restore(self, step: Optional[int], like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching pytree of
        ``jax.sharding.Sharding`` — this is the elastic re-shard path: the
        stored full arrays are device_put with the *target* sharding,
        whatever mesh it belongs to."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.dir}")
        d = self._step_dir(step)
        if not os.path.exists(os.path.join(d, _MARKER)):
            raise FileNotFoundError(f"checkpoint step {step} is not committed")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        stored = manifest["leaves"]
        want = _leaf_paths(like)
        missing = set(want) - set(stored)
        if missing:
            raise KeyError(f"checkpoint lacks leaves: {sorted(missing)[:5]} ...")
        shard_map_ = _leaf_paths(shardings) if shardings is not None else {}
        out = {}
        for path, leaf in want.items():
            meta = stored[path]
            arr = np.load(os.path.join(d, "arrays", meta["file"]))
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{path}: stored {arr.shape} != wanted {want_shape}"
                )
            if path in shard_map_:
                out[path] = jax.device_put(arr, shard_map_[path])
            else:
                out[path] = arr
        # rebuild the tree in `like`'s structure
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = [out[jax.tree_util.keystr(kp)] for kp, _ in flat]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), leaves
        ), manifest["extras"], step
