"""AdamW from scratch (pytree-native), with global-norm clipping and a
linear-warmup cosine schedule.  Optimizer state shards exactly like the
parameters (the ``m``/``v`` trees inherit the param PartitionSpecs), which
is what makes the FSDP-over-'data' layout hold end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    #: parameters whose path contains any of these substrings are excluded
    #: from weight decay (norms, biases, router plan tensors).
    no_decay: tuple = ("norm", "bias", "scale", "plan_", "A_log", "dt_bias")


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def cosine_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_frac: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1),
            0.0, 1.0,
        )
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def _decay_mask(params, no_decay) -> Any:
    def mask(kp, _):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", ""))) for k in kp)
        return not any(s in path for s in no_decay)

    return jax.tree_util.tree_map_with_path(mask, params)


def global_norm(tree) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(a.astype(jnp.float32)))
        for a in jax.tree_util.tree_leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(
    cfg: AdamWConfig,
    params,
    grads,
    state: AdamWState,
    lr_fn: Optional[Callable] = None,
) -> tuple:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: (g * scale).astype(jnp.float32), grads)

    step = state.step + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda a, g: cfg.b1 * a + (1 - cfg.b1) * g, state.m, grads)
    v = jax.tree.map(lambda a, g: cfg.b2 * a + (1 - cfg.b2) * g * g, state.v, grads)
    bc1 = 1 - cfg.b1**t
    bc2 = 1 - cfg.b2**t
    lr = (lr_fn(state.step) if lr_fn is not None else cfg.lr)
    decay = _decay_mask(params, cfg.no_decay)

    def upd(p, mi, vi, dec):
        u = (mi / bc1) / (jnp.sqrt(vi / bc2) + cfg.eps)
        if dec:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v, decay)
    return new_params, AdamWState(step=step, m=m, v=v), {
        "grad_norm": gnorm, "lr": lr,
    }
