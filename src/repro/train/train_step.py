"""The jitted training step: microbatched grad accumulation, AdamW,
optional cross-pod error-feedback gradient compression.

``make_train_step`` returns (step_fn, state_shardings); the launcher jits
it with the parameter/optimizer shardings from ``model.param_shardings``
(FSDP over 'data', TP/EP over 'model', DP over 'pod'×'data').  Gradient
reductions across the data/pod axes are inserted by XLA SPMD; the
*planned* hierarchical cross-pod schedule is available separately in
:mod:`repro.train.collective_schedule` (shard_map implementation driven by
:mod:`repro.core.collective_plan`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..models import model as M
from ..models.config import ArchConfig
from .compression import ef_compress_tree
from .optim import AdamWConfig, AdamWState, adamw_init, adamw_update

__all__ = ["TrainState", "TrainConfig", "make_train_step", "init_state"]


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    residual: Any  # error-feedback residual (zeros when compression off)
    rng: jnp.ndarray
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    remat: bool = True
    compute_dtype: Any = jnp.bfloat16
    compression: str = "none"  # none | bf16 | int8
    use_kernels: bool = False
    z_loss: float = 1e-4
    unroll_groups: bool = False  # analysis builds (see launch.dryrun)


def init_state(cfg: ArchConfig, params, seed: int = 0,
               compression: str = "none") -> TrainState:
    residual = (
        jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32), params)
        if compression != "none"
        else jax.tree.map(lambda a: jnp.zeros((), jnp.float32), params)
    )
    return TrainState(
        params=params,
        opt=adamw_init(params),
        residual=residual,
        rng=jax.random.PRNGKey(seed),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(
    cfg: ArchConfig,
    tcfg: TrainConfig,
    mesh=None,
    lr_fn: Optional[Callable] = None,
) -> Callable:
    """Build the pure train-step function (jit/lower it at the call site)."""

    def loss_for(params, batch):
        return M.loss_fn(
            cfg, params, batch, mesh=mesh,
            use_kernels=tcfg.use_kernels,
            compute_dtype=tcfg.compute_dtype,
            remat=tcfg.remat, z_loss=tcfg.z_loss,
            unroll_groups=tcfg.unroll_groups,
        )

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(state: TrainState, batch):
        if tcfg.microbatches > 1:
            k = tcfg.microbatches

            def mb(batch_part):
                return jax.tree.map(
                    lambda a: a.reshape((k, a.shape[0] // k) + a.shape[1:]),
                    batch_part,
                )

            batches = mb(batch)

            def acc(carry, mb_batch):
                g_acc, l_acc = carry
                (l, metrics), g = grad_fn(state.params, mb_batch)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + l), metrics

            g0 = jax.tree.map(
                lambda a: jnp.zeros(a.shape, jnp.float32), state.params
            )
            (g_sum, l_sum), metrics_stack = jax.lax.scan(
                acc, (g0, jnp.float32(0.0)), batches
            )
            grads = jax.tree.map(lambda a: a / k, g_sum)
            loss = l_sum / k
            metrics = jax.tree.map(lambda a: a[-1], metrics_stack)
        else:
            (loss, metrics), grads = grad_fn(state.params, batch)

        rng, sub = jax.random.split(state.rng)
        residual = state.residual
        if tcfg.compression != "none":
            grads, residual = ef_compress_tree(
                grads, residual, sub, kind=tcfg.compression
            )

        params, opt, opt_metrics = adamw_update(
            tcfg.adamw, state.params, grads, state.opt, lr_fn
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return (
            TrainState(params=params, opt=opt, residual=residual,
                       rng=rng, step=state.step + 1),
            metrics,
        )

    return train_step


def state_shardings(cfg: ArchConfig, state_shape: TrainState, mesh):
    """NamedSharding pytree for the train state: optimizer moments and
    residuals shard exactly like their parameters; scalars replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    pspecs = M.param_shardings(cfg, state_shape.params)
    as_named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    rep = NamedSharding(mesh, P())

    def like_params(tree):
        # moments/residual trees mirror params; scalar placeholders replicate
        return jax.tree.map(
            lambda leaf, sh: rep if leaf.ndim == 0 else sh, tree, as_named
        )

    return TrainState(
        params=as_named,
        opt=AdamWState(step=rep, m=like_params(state_shape.opt.m),
                       v=like_params(state_shape.opt.v)),
        residual=like_params(state_shape.residual),
        rng=rep,
        step=rep,
    )
