"""Plan-driven hierarchical cross-pod all-reduce (shard_map).

This is the runnable counterpart of :mod:`repro.core.collective_plan`: the
planner chooses non-uniform per-pod segment ownership for the DCN hop; this
module executes that schedule on a ``(pod, data, ...)`` mesh:

  1. intra-pod reduce-scatter over the 'data' axis (ICI),
  2. cross-pod all-reduce over the 'pod' axis, applied per *planned
     segment* (slow-DCN pods own less of the parameter space — in a real
     fleet each segment's reduction is rooted at its owner; in XLA we
     express the ownership as a segmented all-reduce, which the compiler
     schedules per segment),
  3. intra-pod all-gather over 'data'.

On homogeneous fabrics the planned segments are uniform and this is exactly
the classic hierarchical all-reduce (bandwidth-optimal: each gradient byte
crosses the DCN once instead of data_parallel_degree times).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map

__all__ = ["hierarchical_allreduce", "flat_size"]


def flat_size(tree) -> int:
    return int(sum(np.prod(a.shape) for a in jax.tree_util.tree_leaves(tree)))


def hierarchical_allreduce(
    tree,
    mesh,
    segment_sizes: Sequence[int] | None = None,
    mean: bool = True,
):
    """All-reduce a pytree over ('pod', 'data') with the hierarchical
    schedule.  ``segment_sizes`` — per-pod planned ownership (from
    ``plan_cross_pod_reduction``); None = uniform.

    The tree is flattened to one vector, padded to pod×data divisibility,
    reduced, and unflattened — matching how fused gradient buckets work in
    production trainers.
    """
    assert "pod" in mesh.axis_names and "data" in mesh.axis_names
    n_pod = mesh.shape["pod"]
    n_data = mesh.shape["data"]
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(np.prod(s)) for s in shapes]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    n = flat.shape[0]
    block = n_pod * n_data
    npad = (-n) % block
    flat = jnp.pad(flat, (0, npad))

    denom = float(n_pod * n_data) if mean else 1.0

    def local(v):
        # v arrives replicated (P() in_spec)
        # 1. intra-pod reduce-scatter over 'data'
        v = jax.lax.psum_scatter(
            v.reshape(n_data, -1), "data", scatter_dimension=0, tiled=False
        )  # (chunk,)
        # 2. cross-pod reduction of the scattered chunk. The planned
        # ownership segments live inside this chunk; XLA schedules the
        # all-reduce over the pod axis once per fused buffer.
        v = jax.lax.psum(v, "pod")
        # 3. intra-pod all-gather over 'data'
        v = jax.lax.all_gather(v, "data", axis=0, tiled=False).reshape(-1)
        return v / denom

    reduced = shard_map(
        local, mesh=mesh, in_specs=P(), out_specs=P(), check_vma=False
    )(flat)
    reduced = reduced[:n]
    out, off = [], 0
    for shape, size in zip(shapes, sizes):
        out.append(reduced[off : off + size].reshape(shape))
        off += size
    return jax.tree_util.tree_unflatten(treedef, out)
