"""Tests for the plan optimizer (paper §2.3/§4) and its validation against
brute force and the paper's linearization."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.makespan import makespan, phase_breakdown
from repro.core.milp import (
    linearization_gap,
    separable_product,
    worst_case_pwl_deviation,
)
from repro.core.optimize import brute_force_plan, optimize_plan
from repro.core.plan import uniform_plan, validate_plan
from repro.core.platform import planetlab_platform, two_cluster_example


class TestOptimizer:
    def test_beats_brute_force_grid(self):
        """e2e_multi must match or beat a grid-20 brute force on the tiny
        two-cluster instance (the brute force is itself only grid-exact)."""
        for alpha in [0.1, 1.0, 10.0]:
            p = two_cluster_example(alpha=alpha, nonlocal_bw=10.0)
            opt = optimize_plan(p, "e2e_multi", n_restarts=12, steps=400)
            bf = brute_force_plan(p, grid=20)
            assert opt.makespan <= bf.makespan * 1.02

    def test_e2e_multi_dominates_other_modes(self):
        p = planetlab_platform(8, alpha=1.0, seed=0)
        multi = optimize_plan(p, "e2e_multi", n_restarts=16, steps=400)
        for mode in ["uniform", "local_push", "myopic_multi", "e2e_push", "e2e_shuffle"]:
            other = optimize_plan(p, mode, n_restarts=8, steps=300)
            assert multi.makespan <= other.makespan * 1.01, mode

    def test_myopic_push_minimizes_push_duration(self):
        p = planetlab_platform(8, alpha=1.0, seed=1)
        myopic = optimize_plan(p, "myopic_push", n_restarts=12, steps=400)
        e2e = optimize_plan(p, "e2e_multi", n_restarts=12, steps=400)
        # myopically optimal push is at least as fast *in the push phase* ...
        assert (
            phase_breakdown(p, myopic.plan)["push"]
            <= phase_breakdown(p, e2e.plan)["push"] * 1.05
        )
        # ... but loses (or at best ties) end-to-end
        assert e2e.makespan <= myopic.makespan * 1.01

    def test_homogeneous_platform_optimizer_matches_uniform(self):
        """§4.5: in a single homogeneous data center the uniform schedule is
        near-optimal; the optimizer should not do better by more than a hair
        and must not do worse."""
        p = planetlab_platform(1, alpha=1.0, seed=0, compute_heterogeneity=False)
        opt = optimize_plan(p, "e2e_multi", n_restarts=8, steps=300)
        uni = makespan(p, uniform_plan(p))
        assert opt.makespan <= uni * 1.001
        assert opt.makespan >= uni * 0.95

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000), alpha=st.sampled_from([0.1, 1.0, 10.0]))
    def test_plans_always_valid_and_never_worse_than_uniform(self, seed, alpha):
        p = planetlab_platform(4, alpha=alpha, seed=seed % 13)
        r = optimize_plan(p, "e2e_multi", n_restarts=6, steps=250, seed=seed)
        validate_plan(r.plan.x, r.plan.y)
        assert np.isfinite(r.makespan)
        assert r.makespan <= makespan(p, uniform_plan(p)) + 1e-6


class TestPaperHeadlines:
    """§4.2/§4.3 headline numbers, on the globally-distributed 8-DC setup."""

    @pytest.fixture(scope="class")
    def results(self):
        out = {}
        for alpha in [0.1, 1.0, 10.0]:
            p = planetlab_platform(8, alpha=alpha, seed=0)
            out[alpha] = {
                mode: optimize_plan(p, mode, n_restarts=16, steps=400)
                for mode in [
                    "uniform",
                    "myopic_multi",
                    "e2e_push",
                    "e2e_shuffle",
                    "e2e_multi",
                ]
            }
        return out

    def test_e2e_multi_strongly_beats_uniform(self, results):
        # paper: 82-87% reduction over uniform across alpha
        for alpha, r in results.items():
            red = 1 - r["e2e_multi"].makespan / r["uniform"].makespan
            assert red > 0.60, (alpha, red)

    def test_e2e_multi_beats_myopic(self, results):
        # paper: 65-82% reduction over myopic multi-phase.  On our sampled
        # Table-1 platform the myopic gap grows with alpha (when push
        # dominates at alpha=0.1, a myopically optimal push is close to
        # end-to-end optimal); at alpha=10 we reproduce the paper's ~66%.
        floors = {0.1: 0.05, 1.0: 0.18, 10.0: 0.50}
        for alpha, r in results.items():
            red = 1 - r["e2e_multi"].makespan / r["myopic_multi"].makespan
            assert red > floors[alpha], (alpha, red)

    def test_multi_phase_beats_best_single_phase(self, results):
        # paper: 37-64% over the best single-phase optimization
        for alpha, r in results.items():
            best_single = min(r["e2e_push"].makespan, r["e2e_shuffle"].makespan)
            red = 1 - r["e2e_multi"].makespan / best_single
            assert red > 0.15, (alpha, red)


class TestLinearization:
    def test_pwl_square_deviation_small(self):
        # chord error of w^2 over 9 segments: (1/9)^2 / 4 ≈ 0.0031
        assert worst_case_pwl_deviation(9) < 0.0062

    def test_separable_identity(self):
        rng = np.random.default_rng(0)
        x = rng.uniform(0, 1, size=100)
        y = rng.uniform(0, 1, size=100)
        # with many segments the separable form converges to the product
        assert np.allclose(separable_product(x, y, segments=400), x * y, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100), alpha=st.sampled_from([0.1, 1.0, 2.0]))
    def test_linearized_model_tracks_exact(self, seed, alpha):
        """The paper's MIP objective (9-segment PWL) stays within ~10% of the
        exact model — i.e. the linearization it solves is faithful."""
        p = planetlab_platform(8, alpha=alpha, seed=seed % 7)
        rng = np.random.default_rng(seed)
        x = rng.dirichlet(np.ones(p.nM), size=p.nS)
        y = rng.dirichlet(np.ones(p.nR))
        from repro.core.plan import ExecutionPlan

        plan = ExecutionPlan(x=x, y=y)
        assert linearization_gap(p, plan) < 0.10
