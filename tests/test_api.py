"""Tests for the unified job API: CostModel parity (measured-volume pricing
must reproduce the analytic model exactly), the planner registry, and the
GeoJob plan→execute round trip."""
import itertools

import numpy as np
import pytest

from repro.api import GeoJob, JobReport, split_sources
from repro.core.makespan import (
    BARRIERS_ALL_GLOBAL,
    BARRIERS_ALL_PIPELINED,
    BARRIERS_GGL,
    CostModel,
    makespan,
    phase_breakdown,
)
from repro.core.optimize import (
    MODES,
    available_modes,
    get_planner,
    optimize_plan,
    register_planner,
)
from repro.core.plan import ExecutionPlan, local_push_plan, uniform_plan
from repro.core.platform import planetlab_platform, two_cluster_example
from repro.core.simulate import SimConfig
from repro.mapreduce.apps import generate_documents, word_count
from repro.mapreduce.engine import PhaseStats

ALL_BARRIER_TRIPLES = list(itertools.product("GLP", repeat=3))


def _plans(platform, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "uniform": uniform_plan(platform),
        "local": local_push_plan(platform),
        "random": ExecutionPlan(
            x=rng.dirichlet(np.ones(platform.nM), size=platform.nS),
            y=rng.dirichlet(np.ones(platform.nR)),
        ),
    }


class TestCostModelParity:
    """The acceptance bar: pricing *measured* volumes through the shared
    CostModel must agree with the analytic model to 1e-9 when the volumes
    are the analytic ones — for every barrier triple in {G,L,P}³."""

    @pytest.mark.parametrize("barriers", ALL_BARRIER_TRIPLES,
                             ids=["".join(b) for b in ALL_BARRIER_TRIPLES])
    def test_measured_pricing_matches_analytic(self, barriers):
        p = planetlab_platform(4, alpha=1.7, seed=2)
        cm = CostModel(p, barriers)
        for name, plan in _plans(p).items():
            vols = cm.analytic_volumes(plan)
            got = cm.breakdown_volumes(*vols)["makespan"]
            want = makespan(p, plan, barriers)
            assert abs(got - want) <= 1e-9, (name, barriers)

    @pytest.mark.parametrize(
        "barriers", [BARRIERS_GGL, BARRIERS_ALL_GLOBAL, BARRIERS_ALL_PIPELINED],
        ids=["GGL", "GGG", "PPP"],
    )
    def test_phasestats_delegates_to_cost_model(self, barriers):
        """PhaseStats byte matrices holding exactly the analytic volumes must
        reproduce core.makespan's breakdown through the same equations."""
        p = planetlab_platform(4, alpha=0.4, seed=7)
        for name, plan in _plans(p, seed=1).items():
            V_push, V_map, V_shuf, V_red = CostModel(p).analytic_volumes(plan)
            stats = PhaseStats(
                push_bytes=V_push * 1e6,
                map_in_bytes=V_map * 1e6,
                shuffle_bytes=V_shuf * 1e6,
                reduce_in_bytes=V_red * 1e6,
                alpha_measured=p.alpha,
            )
            got = stats.makespan(p, barriers)
            want = phase_breakdown(p, plan, barriers)
            for phase in ("push", "map", "shuffle", "reduce", "makespan"):
                assert got[phase] == pytest.approx(want[phase], abs=1e-9), (
                    name, barriers, phase,
                )

    def test_price_plan_equals_makespan_everywhere(self):
        p = two_cluster_example(alpha=3.0, nonlocal_bw=10.0)
        plan = uniform_plan(p)
        for barriers in ALL_BARRIER_TRIPLES:
            cm = CostModel(p, barriers)
            assert cm.makespan(plan) == makespan(p, plan, barriers)

    def test_barrier_validation_is_shared(self):
        p = planetlab_platform(2, seed=0)
        stats = PhaseStats(
            push_bytes=np.ones((p.nS, p.nM)),
            map_in_bytes=np.ones(p.nM),
            shuffle_bytes=np.ones((p.nM, p.nR)),
            reduce_in_bytes=np.ones(p.nR),
            alpha_measured=1.0,
        )
        with pytest.raises(ValueError):
            stats.makespan(p, ("G", "G", "X"))
        with pytest.raises(ValueError):
            CostModel(p, ("G", "G"))
        with pytest.raises(ValueError):
            SimConfig(barriers=("Q", "G", "L"))


class TestPlannerRegistry:
    def test_builtin_modes_registered(self):
        assert set(MODES) <= set(available_modes())

    def test_unknown_mode_raises(self):
        p = two_cluster_example()
        with pytest.raises(ValueError, match="mode must be one of"):
            optimize_plan(p, "no_such_mode")
        with pytest.raises(ValueError):
            get_planner("no_such_mode")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_planner("e2e_multi", lambda *a, **k: None)

    def test_custom_planner_plugs_in(self):
        from repro.core import optimize as O

        @register_planner("test_best_link")
        def _best_link(platform, barriers, *, n_restarts, steps, seed, fixed_x):
            x = np.zeros((platform.nS, platform.nM))
            x[np.arange(platform.nS), np.argmax(platform.B_sm, axis=1)] = 1.0
            plan = ExecutionPlan(x=x, y=uniform_plan(platform).y, meta="best_link")
            return plan, makespan(platform, plan, barriers)

        try:
            assert "test_best_link" in available_modes()
            p = two_cluster_example(nonlocal_bw=10.0)
            res = optimize_plan(p, "test_best_link")
            assert res.mode == "test_best_link"
            assert res.makespan == pytest.approx(res.objective)
            # ... and the facade dispatches to it without modification
            job = GeoJob(p).plan("test_best_link", barriers=BARRIERS_GGL)
            assert job.planned.mode == "test_best_link"
            assert job.simulate().makespan > 0
        finally:
            del O._PLANNERS["test_best_link"]


class TestGeoJob:
    @pytest.fixture(scope="class")
    def tiny(self):
        return two_cluster_example(alpha=1.0, nonlocal_bw=10.0)

    def test_every_registered_mode_roundtrips(self, tiny):
        """plan→simulate round trip for every planner in the registry."""
        for mode in available_modes():
            job = GeoJob(tiny).plan(mode, barriers=BARRIERS_GGL,
                                    n_restarts=4, steps=60)
            res = job.planned
            assert res.mode == mode
            assert np.isfinite(res.makespan) and res.makespan > 0
            assert res.breakdown["makespan"] == pytest.approx(res.makespan)
            sim = job.simulate(chunk_mb=4096.0)
            assert np.isfinite(sim.makespan) and sim.makespan > 0

    def test_execute_reports_modeled_vs_measured(self):
        p = planetlab_platform(8, alpha=1.0, seed=0)
        srcs = split_sources(*generate_documents(200, 40, seed=1), p.nS)
        job = GeoJob(p, word_count()).calibrate(srcs)
        report = job.plan("e2e_multi", barriers=BARRIERS_GGL,
                          n_restarts=6, steps=150).execute(srcs)
        assert isinstance(report, JobReport)
        assert set(report.modeled) == set(report.measured)
        assert report.makespan_measured > 0
        assert report.makespan_modeled == pytest.approx(report.result.makespan)
        assert set(report.deltas()) == set(report.modeled)
        # calibration makes model and measurement comparable: within 2x
        assert abs(report.model_error()) < 1.0
        # the job really ran: word counts come back
        assert sum(len(k) for k, _ in report.outputs) > 0
        assert "e2e_multi" in report.summary()

    def test_calibrate_measures_alpha_and_volumes(self):
        p = planetlab_platform(8, alpha=1.0, seed=0)
        keys, vals = generate_documents(200, 40, seed=1)
        srcs = split_sources(keys, vals, p.nS)
        job = GeoJob(p, word_count()).calibrate(srcs)
        assert job.platform.alpha < 0.7  # heavy aggregation
        assert job.platform.D.sum() == pytest.approx(
            keys.shape[0] * word_count().record_bytes / 1e6
        )

    def test_unplanned_job_raises(self, tiny):
        with pytest.raises(RuntimeError, match="no plan yet"):
            GeoJob(tiny, word_count()).execute([])
        with pytest.raises(RuntimeError, match="no plan yet"):
            GeoJob(tiny).simulate()

    def test_execute_without_app_raises(self, tiny):
        job = GeoJob(tiny).with_plan(uniform_plan(tiny))
        with pytest.raises(RuntimeError, match="needs an application"):
            job.execute([])

    def test_with_plan_prices_through_cost_model(self, tiny):
        job = GeoJob(tiny).with_plan(local_push_plan(tiny), BARRIERS_GGL)
        assert job.planned.makespan == pytest.approx(
            makespan(tiny, local_push_plan(tiny), BARRIERS_GGL)
        )
        assert job.planned.mode == "local_push"