"""Tests for :mod:`repro.analysis` — the lint rules (against bad/good
fixtures), the executor conservation/determinism audits, and the shared
structural validators now wired into the model front doors."""
import itertools
import re
from pathlib import Path

import numpy as np
import pytest

from repro.analysis import audit, lint, validate
from repro.analysis.audit import QUICK_SCENARIOS
from repro.api import GeoJob
from repro.core.makespan import CostModel
from repro.core.platform import planetlab_platform
from repro.core.simulate import SimConfig, open_schedule

FIXTURES = Path(__file__).parent / "analysis_fixtures"
REPO_ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# lint: every file rule has a failing and a passing fixture
# ---------------------------------------------------------------------------

FILE_RULE_CASES = [
    ("f64-pricing-purity", "bad_pricing.py", "good_pricing.py"),
    ("no-bare-heappush", "bad_heappush.py", "good_heappush.py"),
    ("as-dict-json", "bad_as_dict.py", "good_as_dict.py"),
    ("solver-compile-counters",
     "bad_solver_counter.py", "good_solver_counter.py"),
]


@pytest.mark.parametrize(
    "rule,bad,good", FILE_RULE_CASES, ids=[c[0] for c in FILE_RULE_CASES]
)
def test_file_rule_fixtures(rule, bad, good):
    bad_findings = lint.lint_file(FIXTURES / bad)
    assert any(f.rule == rule for f in bad_findings), (
        f"{bad} should trip {rule}, got {bad_findings}"
    )
    # findings print as "file:line: RULE message"
    for f in bad_findings:
        assert re.fullmatch(r".+:\d+: [\w-]+ .+", str(f), re.DOTALL)
    assert lint.lint_file(FIXTURES / good) == []


def test_pricing_purity_flags_unpinned_xp_call():
    findings = lint.lint_file(FIXTURES / "bad_pricing.py")
    msgs = [f.message for f in findings]
    assert any("without pinning xp=np" in m for m in msgs)
    assert any("`jnp` used" in m for m in msgs)


def test_as_dict_rule_names_each_offender():
    findings = lint.lint_file(FIXTURES / "bad_as_dict.py")
    msgs = " ".join(f.message for f in findings)
    assert "set is not JSON-serializable" in msgs
    assert "bytes literal" in msgs
    assert "raw ndarray" in msgs


def test_waiver_comment_suppresses_finding():
    assert lint.lint_file(FIXTURES / "waived_heappush.py") == []


def test_registry_coverage_fixture_projects():
    findings = lint.lint_project(FIXTURES / "bad_registry")
    assert any(
        f.rule == "registry-coverage" and "ghost_mode" in f.message
        for f in findings
    ), findings
    assert lint.lint_project(FIXTURES / "good_registry") == []


def test_repo_lint_clean():
    """The repo itself must lint clean — the CI `analyze` job enforces the
    same invariant via `python -m repro.analysis`."""
    assert lint.lint_project(REPO_ROOT) == []


# ---------------------------------------------------------------------------
# audit: conservation across every barrier triple + the quick scenarios
# ---------------------------------------------------------------------------

BARRIER_TRIPLES = list(itertools.product("GLP", repeat=3))


@pytest.mark.parametrize(
    "barriers", BARRIER_TRIPLES, ids=["".join(b) for b in BARRIER_TRIPLES]
)
def test_conservation_all_barrier_triples(barriers):
    p = planetlab_platform(4, alpha=1.7, seed=2)
    eng = open_schedule(
        [(p, audit.uniform_plan(p), SimConfig(barriers=barriers, audit=True))]
    )
    assert eng.run().violations == []


@pytest.mark.parametrize(
    "name,build", QUICK_SCENARIOS, ids=[n for n, _ in QUICK_SCENARIOS]
)
def test_quick_scenario_conservation_and_snapshots(name, build):
    assert audit.conservation_audit(build) == []
    assert audit.snapshot_audit(build) == []


def test_swap_path_conservation():
    """The steered path — pull-back + re-split of gated shuffle work — must
    keep the byte ledger balanced too."""
    assert audit.swap_conservation_audit() == []


# ---------------------------------------------------------------------------
# audit: determinism under permuted same-timestamp tie-breaks
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "name,build", QUICK_SCENARIOS, ids=[n for n, _ in QUICK_SCENARIOS]
)
def test_determinism_under_permuted_tiebreaks(name, build):
    assert audit.determinism_audit(name, build, k=5, seed=0) == []


def test_raced_fixture_is_detected():
    """Both chunks of the planted fixture land on the one mapper at exactly
    t=4.0 with different sizes, so the service order — and everything
    downstream — depends on the tie-break.  The audit must flag it, at the
    racing timestamp."""
    divs = audit.determinism_audit("raced", audit.raced_engine, k=5, seed=0)
    assert divs, "planted race went undetected"
    assert any(abs(d.time - 4.0) < 1e-9 for d in divs), divs
    assert "diverges" in str(divs[0])


def test_run_all_is_clean():
    report = audit.run_all(k=2, seed=0)
    assert report.ok, "\n".join(report.lines())


# ---------------------------------------------------------------------------
# validators shared into the model front doors
# ---------------------------------------------------------------------------


def test_validator_helpers():
    with pytest.raises(ValueError, match="strictly positive"):
        validate.require_positive("B", np.array([1.0, 0.0]))
    with pytest.raises(ValueError, match="non-finite"):
        validate.require_finite("D", np.array([1.0, np.nan]))
    with pytest.raises(ValueError, match="do not sum to 1"):
        validate.require_row_stochastic("x", np.array([[0.5, 0.2]]))
    with pytest.raises(ValueError, match=r"nS=3 != nR=2"):
        validate.validate_stage_coupling(1, 3, 2, (0,), 2)
    with pytest.raises(ValueError, match="V_shuffle shape"):
        validate.validate_volumes(
            np.ones((2, 2)), np.ones(2), np.ones((3, 1)), np.ones(1),
            dims=(2, 2, 1),
        )


def test_with_plan_rejects_foreign_platform_plan():
    from repro.core.plan import ExecutionPlan

    p = planetlab_platform(4, alpha=1.0, seed=0)  # 8 nodes
    foreign = ExecutionPlan(  # a valid plan for a 4-source platform
        x=np.full((4, p.nM), 1.0 / p.nM), y=np.full(p.nR, 1.0 / p.nR)
    )
    with pytest.raises(ValueError, match="does not match"):
        GeoJob(p).with_plan(foreign)


def test_price_volumes_rejects_nan_volume():
    p = planetlab_platform(4, alpha=1.0, seed=0)
    cm = CostModel(p, ("G", "G", "L"))
    V_push, V_map, V_shuffle, V_reduce = cm.analytic_volumes(
        audit.uniform_plan(p)
    )
    V_map = np.asarray(V_map, dtype=np.float64).copy()
    V_map[0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        cm.price_volumes(V_push, V_map, V_shuffle, V_reduce)
