"""Guarded import of the optional ``hypothesis`` dependency.

The seed environment does not ship ``hypothesis`` (it is the ``test`` extra
in pyproject.toml), and a bare ``from hypothesis import ...`` at module
scope used to kill the whole suite at collection time.  Importing from this
module instead keeps every non-property test runnable: when ``hypothesis``
is missing, ``given`` becomes a skip marker and ``settings``/``st`` become
inert stand-ins, so only the property-based tests are skipped.
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:  # optional dependency — degrade to skips
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis is not installed")

    def settings(*_args, **_kwargs):
        def decorate(fn):
            return fn

        return decorate

    class _InertStrategies:
        """Accepts any ``st.<strategy>(...)`` call at decoration time."""

        def __getattr__(self, _name):
            return lambda *args, **kwargs: None

    st = _InertStrategies()

__all__ = ["HAS_HYPOTHESIS", "given", "settings", "st"]
