"""Tests for schedule-aware, cost-aware online re-planning (PR 4):
shared-capacity residual pricing, joint residual co-replanning
(`replan_schedule`), the replan-cost hysteresis (`OnlineConfig` /
`swap_charge`), the `*_shared` online policies, and the
`schedule_online_shared` acceptance scenario where solo-residual
re-planning thrashes and co-replanning wins."""
import dataclasses
import itertools

import numpy as np
import pytest

from repro.api import Arrival, GeoJob, GeoSchedule, OnlineConfig
from repro.core.makespan import (
    BARRIERS_GGL,
    CostModel,
    JobProgress,
    analytic_volumes,
)
from repro.core.optimize import (
    available_online_policies,
    get_online_config,
    replan_schedule,
    swap_charge,
)
from repro.core.plan import ExecutionPlan, uniform_plan
from repro.core.platform import CapacityTrace, Substrate
from repro.core.simulate import SimConfig, open_schedule, simulate_schedule

ALL_BARRIER_TRIPLES = list(itertools.product("GLP", repeat=3))

OPT = dict(n_restarts=6, steps=150)


def pair_substrate(**traces) -> Substrate:
    sub = Substrate(
        B_sm=np.array([[200.0, 150.0], [150.0, 200.0]]),
        B_mr=np.array([[500.0, 100.0], [500.0, 100.0]]),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([2000.0, 2000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="online_pair",
    )
    return sub.with_traces(traces) if traces else sub


def two_fresh_jobs(sub):
    v1 = sub.view(np.array([3000.0, 3000.0]), 1.0, name="a")
    v2 = sub.view(np.array([1500.0, 1500.0]), 1.5, name="b")
    return (v1, v2), (uniform_plan(v1), uniform_plan(v2)), (
        JobProgress.fresh(v1), JobProgress.fresh(v2))


# ---------------------------------------------------------------------------
# shared residual pricing on the one cost model
# ---------------------------------------------------------------------------


class TestPriceResidualShared:
    @pytest.mark.parametrize("barriers", ALL_BARRIER_TRIPLES,
                             ids=["".join(b) for b in ALL_BARRIER_TRIPLES])
    def test_fresh_snapshot_reproduces_price_shared(self, barriers):
        """The satellite acceptance: with zero-progress snapshots,
        price_residual_shared agrees with price_shared of the plans'
        analytic volumes to 1e-9 on every barrier triple — online and
        offline schedule decisions share one cost model."""
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        cm = CostModel(views[0], barriers)
        got = cm.price_residual_shared(list(fresh), list(plans))
        vols = [
            analytic_volumes(v.D, np.asarray(p.x), np.asarray(p.y),
                             v.alpha, xp=np)
            for v, p in zip(views, plans)
        ]
        want = cm.price_shared(vols)
        assert len(got) == len(want) == 2
        for a, b in zip(got, want):
            assert abs(float(a["makespan"]) - float(b["makespan"])) <= 1e-9
            np.testing.assert_allclose(a["reduce_end"], b["reduce_end"],
                                       atol=1e-9)

    def test_single_job_matches_solo_residual(self):
        """With one job there is nobody to contend with: shared residual
        pricing degenerates to price_residual exactly."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        plan = uniform_plan(v)
        fresh = JobProgress.fresh(v)
        cm = CostModel(v, BARRIERS_GGL)
        solo = cm.price_residual(fresh, plan)
        shared = cm.price_residual_shared([fresh], [plan])
        assert float(shared[0]["makespan"]) == pytest.approx(
            float(solo["makespan"]), abs=1e-12
        )

    def test_contention_inflates_both_jobs(self):
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        cm = CostModel(views[0], BARRIERS_GGL)
        shared = cm.price_residual_shared(list(fresh), list(plans))
        for v, p, out in zip(views, plans, shared):
            solo = CostModel(v, BARRIERS_GGL).price_residual(
                JobProgress.fresh(v), p
            )
            assert float(out["makespan"]) > float(solo["makespan"])
        agg = cm.residual_schedule_makespan(list(fresh), list(plans))
        assert agg == pytest.approx(
            max(float(out["makespan"]) for out in shared)
        )

    def test_length_mismatch_raises(self):
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        cm = CostModel(views[0], BARRIERS_GGL)
        with pytest.raises(ValueError, match="one plan per progress"):
            cm.price_residual_shared(list(fresh), [plans[0]])


# ---------------------------------------------------------------------------
# joint residual co-replanning
# ---------------------------------------------------------------------------


class TestReplanSchedule:
    def test_never_modeled_worse_than_incumbents(self):
        """The incumbent stack competes in float64, so the co-replanned
        aggregate is never worse than keeping every plan."""
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        res = replan_schedule(sub, list(plans), list(fresh),
                              barriers=BARRIERS_GGL, **OPT)
        assert res.makespan <= max(res.before) + 1e-9
        assert res.improvement >= 0.0
        assert len(res.plans) == len(res.before) == len(res.after) == 2

    def test_improves_contended_uniform_stack(self):
        """Two uniform plans fighting over the same fast links leave obvious
        shared-pricing headroom — the joint solver must find some."""
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        res = replan_schedule(sub, list(plans), list(fresh),
                              barriers=BARRIERS_GGL, **OPT)
        assert res.makespan < max(res.before)

    def test_done_jobs_pass_through(self):
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        done = dataclasses.replace(fresh[1], done=True)
        res = replan_schedule(sub, list(plans), [fresh[0], done],
                              barriers=BARRIERS_GGL, **OPT)
        assert res.plans[1] is plans[1]
        assert res.before[1] == res.after[1] == 0.0

    def test_all_done_returns_incumbents(self):
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        done = [dataclasses.replace(pr, done=True) for pr in fresh]
        res = replan_schedule(sub, list(plans), done,
                              barriers=BARRIERS_GGL, **OPT)
        assert res.plans == tuple(plans)
        assert res.makespan == 0.0

    def test_accepts_progress_snapshot(self):
        """The executor's ProgressSnapshot is usable directly as the
        multi-job residual view."""
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        plan = uniform_plan(v)
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=100.0)
        eng = open_schedule([(v, plan, cfg)], substrate=sub)
        eng.run_until(5.0)
        snap = eng.snapshot()
        assert len(snap.residual_view()) == 1
        assert snap.residual_view()[0][0] == 0
        res = replan_schedule(sub, [plan], snap, barriers=BARRIERS_GGL,
                              **OPT)
        assert res.makespan <= max(res.before) + 1e-9

    def test_length_mismatch_raises(self):
        sub = pair_substrate()
        views, plans, fresh = two_fresh_jobs(sub)
        with pytest.raises(ValueError, match="one incumbent per progress"):
            replan_schedule(sub, [plans[0]], list(fresh))


# ---------------------------------------------------------------------------
# OnlineConfig, swap_charge and the policy registry
# ---------------------------------------------------------------------------


class TestOnlineConfig:
    def test_shared_variants_registered(self):
        assert {"reactive_shared", "horizon_shared"} <= set(
            available_online_policies()
        )
        for name in ("reactive_shared", "horizon_shared"):
            cfg = get_online_config(name)
            assert cfg.shared and cfg.hysteresis == 1.0

    def test_solo_policies_default_config(self):
        for name in ("static", "reactive", "horizon"):
            cfg = get_online_config(name)
            assert not cfg.shared and cfg.hysteresis == 0.0

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="online policy must be one of"):
            get_online_config("no_such_policy")

    def test_horizon_shared_requires_replan_dt(self):
        """horizon_shared replans only on ticks, like horizon — without
        replan_dt it would silently reproduce static, so it must raise."""
        sub = pair_substrate()
        v = sub.view(np.array([1000.0, 1000.0]), 1.0)
        sched = GeoSchedule(
            [GeoJob(v).with_plan(uniform_plan(v), BARRIERS_GGL)]
        ).with_plans()
        with pytest.raises(ValueError, match="replan_dt"):
            sched.run_online(policy="horizon_shared",
                             cfg=SimConfig(barriers=BARRIERS_GGL))

    def test_validation(self):
        assert OnlineConfig(hysteresis=float("inf")).hysteresis == float("inf")
        with pytest.raises(ValueError, match="hysteresis"):
            OnlineConfig(hysteresis=-0.5)
        with pytest.raises(ValueError, match="hysteresis"):
            OnlineConfig(hysteresis=float("nan"))
        with pytest.raises(ValueError, match="solver_cost_s"):
            OnlineConfig(solver_cost_s=-1.0)

    def test_candidate_pricing_validation(self):
        assert OnlineConfig().candidate_pricing == "model"
        ok = OnlineConfig(shared=True, candidate_pricing="fluid")
        assert ok.candidate_pricing == "fluid"
        with pytest.raises(ValueError, match="candidate_pricing"):
            OnlineConfig(shared=True, candidate_pricing="des")
        # fluid pricing scores the whole co-replanned stack: solo mode
        # has no stack to score
        with pytest.raises(ValueError, match="shared=True"):
            OnlineConfig(candidate_pricing="fluid")

    def test_reactive_fluid_registered(self):
        assert "reactive_fluid" in available_online_policies()
        cfg = get_online_config("reactive_fluid")
        assert cfg.shared and cfg.incremental
        assert cfg.candidate_pricing == "fluid"


class TestSwapCharge:
    def test_identity_swap_costs_solver_only(self):
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        plan = uniform_plan(v)
        fresh = JobProgress.fresh(v)
        assert swap_charge(sub, fresh, plan, plan, solver_cost_s=2.5) \
            == pytest.approx(2.5)

    def test_rerouting_queued_bytes_costs_more(self):
        sub = pair_substrate()
        v = sub.view(np.array([2000.0, 1000.0]), 1.0)
        fresh = JobProgress.fresh(v)
        a = uniform_plan(v)
        b = ExecutionPlan(x=np.array([[1.0, 0.0], [0.0, 1.0]]),
                          y=np.array([1.0, 0.0]))
        charge = swap_charge(sub, fresh, a, b, solver_cost_s=1.0)
        assert charge > 1.0
        # monotone in the re-routed volume: nothing queued -> solver only
        drained = dataclasses.replace(
            fresh, resid_push=np.zeros(2), shuffle_pool=np.zeros(2)
        )
        assert swap_charge(sub, drained, a, b, solver_cost_s=1.0) \
            == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# hysteresis: inf reproduces static byte-for-byte
# ---------------------------------------------------------------------------


class TestInfiniteHysteresisIsStatic:
    @pytest.mark.parametrize("barriers", [("G", "G", "L"), ("P", "P", "P"),
                                          ("L", "G", "P")],
                             ids=lambda b: "".join(b))
    def test_reproduces_static_policy(self, barriers):
        """The satellite acceptance: hysteresis=inf rejects every swap, so
        the steered run is phase-for-phase the frozen offline pipeline —
        with an arrival and capacity drift in play."""
        sub = pair_substrate(**{
            "shuffle[m0->r0]": CapacityTrace.step(500.0, 2.0, 40.0),
            "shuffle[m1->r0]": CapacityTrace.step(500.0, 2.0, 40.0),
        })
        v1 = sub.view(np.array([3000.0, 3000.0]), 1.0, name="steady")
        v2 = sub.view(np.array([1500.0, 1500.0]), 1.0, name="late")
        plan1, plan2 = uniform_plan(v1), uniform_plan(v2)
        cfg = SimConfig(barriers=barriers, chunk_mb=256.0)
        t_arrival = 13.7
        sched = GeoSchedule(
            [GeoJob(v1).with_plan(plan1, barriers)]
        ).with_plans()
        report = sched.run_online(
            policy="reactive_shared",
            arrivals=[Arrival(GeoJob(v2).with_plan(plan2, barriers),
                              t_arrival)],
            cfg=cfg, n_restarts=2, steps=40,
            online=OnlineConfig(shared=True, hysteresis=float("inf")),
        )
        ref = simulate_schedule(
            [(v1, plan1, cfg),
             (v2, plan2, dataclasses.replace(cfg, start_time=t_arrival))],
            substrate=sub,
        )
        for got, want in zip(report.sim.jobs, ref.jobs):
            for phase, t in want.phases().items():
                assert abs(got.phases()[phase] - t) <= 1e-9, phase
        assert abs(report.makespan_online - ref.makespan) <= 1e-9
        assert report.swaps == ()
        # the declined candidates are on the record, with their charges
        assert all(d.charge > 0 for d in report.rejected)
        assert report.plans[0] is plan1 and report.plans[1] is plan2


# ---------------------------------------------------------------------------
# the fluid-priced replan gate
# ---------------------------------------------------------------------------


class TestFluidPricedGate:
    """`candidate_pricing="fluid"`: the replan gate scores incumbent and
    candidate stacks with the same float64 fluid rollout and adopts only
    on a strict fluid improvement — never priced worse than keeping the
    incumbents, under the pricing in force."""

    @pytest.fixture(scope="class")
    def report(self):
        sub = pair_substrate(**{
            "shuffle[m0->r0]": CapacityTrace.step(500.0, 2.0, 40.0),
            "shuffle[m1->r0]": CapacityTrace.step(500.0, 2.0, 40.0),
        })
        v1 = sub.view(np.array([3000.0, 3000.0]), 1.0, name="steady")
        v2 = sub.view(np.array([1500.0, 1500.0]), 1.0, name="late")
        cfg = SimConfig(barriers=BARRIERS_GGL, chunk_mb=128.0)
        sched = GeoSchedule(
            [GeoJob(v1).with_plan(uniform_plan(v1), BARRIERS_GGL)]
        ).with_plans()
        return sched.run_online(
            policy="reactive_fluid",
            arrivals=[Arrival(
                GeoJob(v2).with_plan(uniform_plan(v2), BARRIERS_GGL),
                13.7,
            )],
            cfg=cfg, n_restarts=2, steps=40,
        )

    def test_never_fluid_priced_worse(self, report):
        """THE regression: every adopted stack is strictly better under
        the fluid rollout than keeping the incumbents; rejected
        candidates leave the modeled spans untouched."""
        by_time = {}
        for d in report.decisions:
            if d.action in ("swap", "reject", "keep"):
                by_time.setdefault(d.time, []).append(d)
        swaps = 0
        for t, group in by_time.items():
            adopted = [d for d in group if d.action == "swap"]
            if adopted:
                # all-or-nothing stack adoption, priced as a stack
                swaps += 1
                assert max(d.modeled_after for d in group) \
                    < max(d.modeled_before for d in group), t
            for d in group:
                if d.action in ("reject", "keep"):
                    assert d.modeled_after == d.modeled_before, t
        assert swaps >= 1, "scenario exercised no fluid-priced swap"

    def test_fluid_gate_steers_better_than_frozen(self, report):
        assert report.makespan_online < report.makespan_static

    def test_decisions_priced_by_the_rollout(self, report):
        """The drift-aware property shows up in the record: the pricing
        at the pre-drift arrival already anticipates the t=40 capacity
        collapse, so the modeled spans dwarf the closed-form residual
        (which would price ~tens of seconds on the healthy fabric)."""
        arrival = [d for d in report.decisions
                   if d.event == "arrival" and d.action != "inject"]
        assert arrival and all(d.modeled_before > 100.0 for d in arrival)


# ---------------------------------------------------------------------------
# the acceptance scenario: co-replanning + hysteresis wins
# ---------------------------------------------------------------------------


def shared_scenario():
    """The `schedule_online_shared` fabric (see
    benchmarks.paper_figures.shared_online_substrate): the late job is
    stuck on reducer r1, the fast reducer r0 degrades mid-shuffle of the
    steady job, and two nuisance trace steps on dead links bait
    hysteresis-free re-planning into thrashing."""
    from benchmarks.paper_figures import shared_online_substrate

    sub = shared_online_substrate()
    steady = GeoJob(sub.view(np.array([8000.0, 8000.0, 0.0, 0.0]), 1.0,
                             name="steady"))
    late_view = sub.view(np.array([0.0, 0.0, 6000.0, 6000.0]), 1.0,
                         name="late")
    return sub, steady, late_view


@pytest.fixture(scope="module")
def shared_scenario_reports():
    """Run the acceptance scenario once for all assertions: frozen joint,
    solo reactive, reactive_shared, and hysteresis-free co-replanning."""
    sub, steady, late_view = shared_scenario()
    cfg = SimConfig(barriers=BARRIERS_GGL)
    t_arrival = 50.0
    frozen = GeoSchedule([steady, GeoJob(late_view)]).plan(
        "joint", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
    )
    frozen_sim = simulate_schedule(
        [(steady.platform, frozen.planned.plans[0], cfg),
         (late_view, frozen.planned.plans[1],
          dataclasses.replace(cfg, start_time=t_arrival))],
        substrate=sub,
    )
    sched = GeoSchedule([steady]).plan(
        "independent", mode="e2e_multi", barriers=BARRIERS_GGL, **OPT
    )
    reports = {"frozen_sim": frozen_sim}
    # solver_cost_s pinned: the nuisance-swap assertions compare gate
    # decisions across runs, so the charge must be deterministic and
    # host-independent, not this machine's measured solve time
    for name, policy, online in (
        ("solo", "reactive", None),
        ("shared", "reactive_shared",
         OnlineConfig(shared=True, hysteresis=1.0, solver_cost_s=1.0)),
        ("no_hysteresis", "reactive_shared",
         OnlineConfig(shared=True, hysteresis=0.0)),
    ):
        arrival = Arrival(
            GeoJob(late_view).with_plan(frozen.planned.plans[1],
                                        BARRIERS_GGL),
            t_arrival,
        )
        reports[name] = sched.run_online(
            policy=policy, arrivals=[arrival], cfg=cfg, online=online,
            **OPT,
        )
    return reports


class TestSharedScenario:
    def test_shared_beats_frozen_joint(self, shared_scenario_reports):
        r = shared_scenario_reports
        gain = 1.0 - r["shared"].makespan_online / r["frozen_sim"].makespan
        assert gain >= 0.10, (
            f"reactive_shared {r['shared'].makespan_online:.0f}s vs frozen "
            f"joint {r['frozen_sim'].makespan:.0f}s — only {gain:.0%}"
        )

    def test_shared_beats_solo_residual_replanning(
        self, shared_scenario_reports
    ):
        """THE tentpole acceptance: co-replanning sees the late job stuck
        on r1 and keeps the steady job off it; solo residual re-planning
        spills onto r1 because each job is priced as a sole tenant."""
        r = shared_scenario_reports
        assert r["shared"].makespan_online < r["solo"].makespan_online, (
            f"shared {r['shared'].makespan_online:.0f}s vs solo "
            f"{r['solo'].makespan_online:.0f}s"
        )

    def test_hysteresis_accepts_fewer_swaps(self, shared_scenario_reports):
        """The nuisance drift events bait epsilon swaps out of
        hysteresis-free co-replanning; the replan-cost charge rejects
        them."""
        r = shared_scenario_reports
        assert len(r["shared"].swaps) < len(r["no_hysteresis"].swaps)
        assert len(r["shared"].rejected) >= 1
        # without losing the big wins: same ballpark makespan
        assert r["shared"].makespan_online <= \
            r["no_hysteresis"].makespan_online * 1.10

    def test_decision_accounting(self, shared_scenario_reports):
        r = shared_scenario_reports
        report = r["shared"]
        for d in report.decisions:
            assert d.action in ("inject", "swap", "keep", "reject")
            if d.action in ("swap", "reject"):
                assert d.charge > 0.0
            assert d.modeled_after >= 0.0
        assert report.charged_s > 0.0
        assert "charged" in report.timeline()
        assert "rejected" in report.summary()
