"""Planner-as-a-service throughput (ISSUE 7 / ROADMAP §1): the shape-keyed
solver-executable cache and its counters, batched-vs-sequential solve
equivalence, warm-started incremental replans (never modeled worse), the
measured solver-cost EMA behind ``swap_charge``, and the
``reactive_incremental`` online policy.

Compile-count assertions use *unique* static step budgets per test (jit
executables are process-wide, so a budget another test also uses would
make the first solve here a warm hit)."""
import itertools

import numpy as np
import pytest

from repro.api import GeoJob, GeoSchedule, OnlineConfig
from repro.core import (
    SolverService,
    SolveTimeEMA,
    get_online_config,
    get_online_policy,
    optimize_plan,
    optimize_plan_batch,
    replan,
    replan_batch,
    reset_solver_cache_stats,
    solver_cache_stats,
    uniform_plan,
)
from repro.core.makespan import BARRIERS_GGL, CostModel, JobProgress
from repro.core.platform import CapacityTrace, Substrate, planetlab_platform
from repro.core.simulate import SimConfig


def _snap():
    return solver_cache_stats()


def _delta(before, after=None):
    after = after if after is not None else _snap()
    return {k: after[k] - before[k] for k in after}


def _platform(n=2, alpha=1.0, seed=0):
    return planetlab_platform(n, alpha=alpha, seed=seed)


def _small_platform(name="svc_small"):
    """A 2x2x2 platform — planetlab platforms always have 8 nodes, so this
    is the differently-*shaped* problem for cache-key tests."""
    return Substrate(
        B_sm=np.array([[200.0, 150.0], [150.0, 200.0]]),
        B_mr=np.array([[500.0, 100.0], [500.0, 100.0]]),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([2000.0, 2000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name=name,
    ).view(np.array([8000.0, 8000.0]), 1.0, name=name)


# ---------------------------------------------------------------------------
# cache semantics via the compile/hit counters
# ---------------------------------------------------------------------------


class TestSolverCache:
    def test_same_shape_second_solve_zero_new_compiles(self):
        opts = dict(n_restarts=3, steps=41)
        optimize_plan(_platform(2, 0.7, seed=0), "e2e_multi", seed=1, **opts)
        before = _snap()
        optimize_plan(_platform(2, 1.3, seed=1), "e2e_multi", seed=2, **opts)
        d = _delta(before)
        assert d["compiles"] == 0, d
        assert d["hits"] == d["calls"] and d["misses"] == 0, d

    def test_different_shape_compiles_exactly_once(self):
        opts = dict(n_restarts=3, steps=43)
        optimize_plan(_platform(2, seed=0), "e2e_multi", seed=1, **opts)
        before = _snap()
        optimize_plan(_small_platform(), "e2e_multi", seed=1, **opts)
        d = _delta(before)
        assert d["compiles"] == 1 and d["misses"] == 1, d
        before = _snap()
        optimize_plan(_small_platform("svc_small_2"), "e2e_multi", seed=3,
                      **opts)
        d = _delta(before)
        assert d["compiles"] == 0 and d["hits"] == d["calls"], d

    def test_cache_survives_across_geoschedule_instances(self):
        opts = dict(n_restarts=3, steps=47)

        def schedule(tag):
            view = _small_platform(f"svc_sched_{tag}")
            sib = view.substrate.view(
                np.array([4000.0, 4000.0]), 1.0, name=f"svc_sib_{tag}"
            )
            return GeoSchedule([GeoJob(view), GeoJob(sib)])

        schedule("a").plan("independent", mode="e2e_multi",
                           barriers=BARRIERS_GGL, **opts)
        before = _snap()
        schedule("b").plan("independent", mode="e2e_multi",
                           barriers=BARRIERS_GGL, **opts)
        d = _delta(before)
        assert d["compiles"] == 0, (
            f"a fresh GeoSchedule re-compiled a known shape: {d}"
        )

    def test_reset_zeroes_counters_not_executables(self):
        opts = dict(n_restarts=3, steps=53)
        optimize_plan(_platform(2, seed=0), "e2e_multi", seed=1, **opts)
        reset_solver_cache_stats()
        assert _snap() == {"calls": 0, "hits": 0, "misses": 0,
                           "compiles": 0, "entries": 0, "shapes": 0}
        # the key set was cleared too (a repeat is a "miss" again), but the
        # jit executable survives: no new compile
        optimize_plan(_platform(2, seed=0), "e2e_multi", seed=1, **opts)
        d = _snap()
        assert d["misses"] == 1 and d["compiles"] == 0, d

    def test_solver_service_shares_the_process_cache(self):
        svc1 = SolverService(mode="e2e_multi", barriers=BARRIERS_GGL,
                             n_restarts=3, steps=59)
        svc1.plan(_platform(2, seed=0), seed=1)
        before = svc1.stats()
        svc2 = SolverService(mode="e2e_multi", barriers=BARRIERS_GGL,
                             n_restarts=3, steps=59)
        res = svc2.plan_many([_platform(2, seed=1), _platform(2, seed=2)],
                             seeds=[3, 4])
        assert len(res) == 2
        d = _delta(before, svc2.stats())
        # a batch of 2 is a NEW executable (B is a shape axis) but a second
        # service instance pays nothing extra for it afterwards
        before = svc2.stats()
        svc1.plan_many([_platform(2, seed=3), _platform(2, seed=4)],
                       seeds=[5, 6])
        d = _delta(before, svc1.stats())
        assert d["compiles"] == 0 and d["hits"] == d["calls"], d


# ---------------------------------------------------------------------------
# batched solves == sequential per-request solves
# ---------------------------------------------------------------------------


class TestBatchedEquivalence:
    # short anneals: the check targets the request-batching plumbing
    # (seeds, scales, per-request assembly), not f32 chaos — longer
    # anneals amplify vmap-vs-single XLA fusion round-off chaotically
    OPTS = dict(n_restarts=4, steps=10)

    @pytest.mark.parametrize("mode", ["e2e_multi", "myopic_multi",
                                      "e2e_push"])
    def test_plan_batch_matches_sequential(self, mode):
        plats = [_platform(2, alpha=a, seed=s)
                 for s, a in enumerate((0.5, 1.0, 2.0))]
        seeds = [11, 12, 13]
        batch = optimize_plan_batch(plats, mode, barriers=BARRIERS_GGL,
                                    seeds=seeds, **self.OPTS)
        for p, s, b in zip(plats, seeds, batch):
            solo = optimize_plan(p, mode, barriers=BARRIERS_GGL, seed=s,
                                 **self.OPTS)
            np.testing.assert_allclose(b.plan.x, solo.plan.x, atol=1e-6)
            np.testing.assert_allclose(b.plan.y, solo.plan.y, atol=1e-6)
            assert b.makespan == pytest.approx(solo.makespan, rel=1e-4)

    def test_replan_batch_matches_sequential(self):
        plats = [_platform(2, alpha=1.0, seed=s) for s in (0, 1, 2)]
        incs = [uniform_plan(p) for p in plats]
        seeds = [5, 6, 7]
        batch = replan_batch(plats, incs, barriers=BARRIERS_GGL,
                             seeds=seeds, **self.OPTS)
        for p, inc, s, b in zip(plats, incs, seeds, batch):
            solo = replan(p, inc, barriers=BARRIERS_GGL, seed=s,
                          **self.OPTS)
            np.testing.assert_allclose(b.plan.x, solo.plan.x, atol=1e-6)
            np.testing.assert_allclose(b.plan.y, solo.plan.y, atol=1e-6)

    def test_mixed_shapes_grouped_not_rejected(self):
        plats = [_platform(2, seed=0), _platform(4, seed=0),
                 _platform(2, seed=1)]
        res = optimize_plan_batch(plats, "e2e_multi", barriers=BARRIERS_GGL,
                                  seeds=[1, 2, 3], **self.OPTS)
        assert [r.plan.x.shape[1] for r in res] == [p.nM for p in plats]

    def test_seed_length_mismatch_raises(self):
        with pytest.raises(ValueError, match="one seed per platform"):
            optimize_plan_batch([_platform(2)], "e2e_multi", seeds=[1, 2],
                                **self.OPTS)
        with pytest.raises(ValueError, match="one incumbent"):
            replan_batch([_platform(2)], [], **self.OPTS)


# ---------------------------------------------------------------------------
# incremental warm-start replans
# ---------------------------------------------------------------------------


class TestIncrementalReplan:
    def test_never_modeled_worse_all_27_barrier_triples(self):
        p = _platform(2, alpha=1.0, seed=0)
        inc = uniform_plan(p)
        fresh = JobProgress.fresh(p)
        for barriers in itertools.product("GPL", repeat=3):
            inc_span = float(CostModel(p, barriers).price_residual(
                fresh, inc)["makespan"])
            res = replan(p, inc, barriers=barriers, n_restarts=2,
                         steps=200, seed=3, incremental=True)
            assert res.makespan <= inc_span + 1e-9, (
                f"incremental replan modeled worse than the incumbent "
                f"under {barriers}: {res.makespan} > {inc_span}"
            )

    def test_incremental_reuses_full_anneal_executable(self):
        """lr/tau are weak-typed traced scalars and the incremental budget
        reuses known steps values here, so flipping incremental must not
        trigger a new compile once both step counts are warm."""
        p = _platform(2, alpha=1.0, seed=0)
        inc = uniform_plan(p)
        # warm both executables: full (steps=200) and incremental (25)
        replan(p, inc, n_restarts=4, steps=200, seed=1, incremental=False)
        replan(p, inc, n_restarts=4, steps=200, seed=1, incremental=True)
        before = _snap()
        replan(p, inc, n_restarts=4, steps=200, seed=2, incremental=True)
        replan(p, inc, n_restarts=4, steps=200, seed=2, incremental=False)
        d = _delta(before)
        assert d["compiles"] == 0, d

    def test_shared_incremental_budget_divides_by_stack_size(self):
        """replan_schedule's incremental polish pays ONE anneal budget for
        the whole stack: the per-job step budget divided by the
        power-of-two-quantized live-job count, floored at 8."""
        from repro.core.optimize import (
            _incremental_budget,
            _shared_incremental_budget,
        )

        n, s = _incremental_budget(8, 200)
        assert (n, s) == (4, 25)
        assert _shared_incremental_budget(8, 200, 1) == (4, 25)
        assert _shared_incremental_budget(8, 200, 2) == (4, 12)
        # quantized divisor: 3 and 4 jobs land on the same static budget
        assert _shared_incremental_budget(8, 200, 3) \
            == _shared_incremental_budget(8, 200, 4) == (4, 8)
        # the floor: the stack can grow without the budget vanishing
        assert _shared_incremental_budget(8, 200, 100) == (4, 8)
        assert _shared_incremental_budget(8, 1600, 2) == (4, 100)

    def test_shared_incremental_schedule_warm_cache_and_not_worse(self):
        """Counter-verified (the satellite acceptance): a repeat
        incremental co-replan at the same stack size is a pure warm hit —
        zero new compiles — and the shared budget keeps the float64
        never-modeled-worse selection."""
        from repro.core.makespan import CostModel as _CM
        from repro.core.optimize import replan_schedule

        view = _small_platform("svc_sched_budget")
        sub = view.substrate
        sib = sub.view(np.array([4000.0, 4000.0]), 1.0, name="svc_bud_b")
        plans = [uniform_plan(view), uniform_plan(sib)]
        fresh = [JobProgress.fresh(view, 0), JobProgress.fresh(sib, 1)]
        opts = dict(barriers=BARRIERS_GGL, n_restarts=4, steps=1600)
        res = replan_schedule(sub, plans, fresh, seed=1, incremental=True,
                              **opts)
        assert res.makespan <= max(res.before) + 1e-9
        before = _snap()
        res2 = replan_schedule(sub, plans, fresh, seed=2,
                               incremental=True, **opts)
        d = _delta(before)
        assert d["compiles"] == 0, d
        assert res2.makespan <= max(res2.before) + 1e-9

    def test_incremental_starts_from_incumbent_basin(self):
        """A near-optimal incumbent survives the low-temperature polish:
        the result is the incumbent or something modeled at least as
        good, never a basin-hopped regression."""
        p = _platform(2, alpha=1.0, seed=1)
        good = optimize_plan(p, "e2e_multi", barriers=BARRIERS_GGL,
                             n_restarts=6, steps=150, seed=0)
        res = replan(p, good.plan, barriers=BARRIERS_GGL, n_restarts=4,
                     steps=200, seed=5, incremental=True)
        assert res.makespan <= good.makespan + 1e-6


# ---------------------------------------------------------------------------
# measured solver cost: SolveTimeEMA + OnlineConfig wiring
# ---------------------------------------------------------------------------


class TestSolveTimeEMA:
    def test_fixed_mode_pins_the_charge(self):
        ema = SolveTimeEMA(fixed=2.5)
        ema.observe(0.001)
        assert ema.charge_s() == 2.5

    def test_fallback_before_first_warm_sample(self):
        assert SolveTimeEMA().charge_s() == 1.0

    def test_measured_charge_quantizes_to_half_decades(self):
        ema = SolveTimeEMA()
        ema.observe(0.02)
        assert ema.charge_s() == pytest.approx(10.0 ** -1.5)
        for _ in range(50):
            ema.observe(0.8)
        assert ema.charge_s() == pytest.approx(1.0)

    def test_cold_compile_samples_are_excluded(self):
        ema = SolveTimeEMA()
        ema.observe(30.0, compiled=True)
        assert ema.charge_s() == 1.0 and ema.excluded == 1
        ema.observe(0.02)
        assert ema.charge_s() == pytest.approx(10.0 ** -1.5)
        ema.observe(30.0, compiled=True)  # still excluded when warm
        assert ema.charge_s() == pytest.approx(10.0 ** -1.5)

    def test_nonpositive_and_nonfinite_samples_excluded(self):
        ema = SolveTimeEMA()
        ema.observe(0.0)
        ema.observe(-1.0)
        ema.observe(float("nan"))
        assert ema.samples == 0 and ema.excluded == 3

    def test_validation(self):
        with pytest.raises(ValueError, match="fixed"):
            SolveTimeEMA(fixed=-1.0)
        with pytest.raises(ValueError, match="beta"):
            SolveTimeEMA(beta=0.0)


class TestOnlineConfigMeasuredCost:
    def test_defaults_are_measured_and_full_anneal(self):
        cfg = OnlineConfig()
        assert cfg.solver_cost_s is None
        assert cfg.incremental is False

    def test_negative_pinned_cost_rejected(self):
        with pytest.raises(ValueError, match="solver_cost_s"):
            OnlineConfig(solver_cost_s=-0.5)

    def test_reactive_incremental_policy_config(self):
        cfg = get_online_config("reactive_incremental")
        assert cfg.shared and cfg.incremental
        assert cfg.hysteresis == 1.0 and cfg.solver_cost_s is None
        fn = get_online_policy("reactive_incremental")
        assert fn("drift", None) and fn("arrival", None)
        assert not fn("tick", None)


# ---------------------------------------------------------------------------
# hysteresis invariants under measured cost (PR 3/4 behavior preserved)
# ---------------------------------------------------------------------------


def _drift_frozen():
    sub = Substrate(
        B_sm=np.array([[200.0, 150.0], [150.0, 200.0]]),
        B_mr=np.array([[500.0, 100.0], [500.0, 100.0]]),
        C_m=np.array([100.0, 100.0]),
        C_r=np.array([2000.0, 2000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="svc_pair",
    ).with_traces({
        "shuffle[m0->r0]": CapacityTrace.step(500.0, 2.0, 105.0),
        "shuffle[m1->r0]": CapacityTrace.step(500.0, 2.0, 105.0),
    })
    job = GeoJob(sub.view(np.array([8000.0, 8000.0]), 1.0, name="steady"))
    return GeoSchedule([job]).plan(
        "independent", mode="e2e_multi", barriers=BARRIERS_GGL,
        n_restarts=4, steps=80,
    )


def _drift_run(online, frozen=None):
    frozen = frozen if frozen is not None else _drift_frozen()
    return frozen.run_online(
        policy="reactive", cfg=SimConfig(barriers=BARRIERS_GGL),
        n_restarts=4, steps=80, online=online,
    )


class TestHysteresisInvariantsUnderMeasuredCost:
    def test_zero_hysteresis_decisions_identical_measured_vs_pinned(self):
        """hysteresis=0 swaps on any improvement — the charge (measured or
        pinned) multiplies a zero gate, so PR 3 behavior is bit-identical
        whichever cost model is in force."""
        measured = _drift_run(OnlineConfig(hysteresis=0.0))
        pinned = _drift_run(OnlineConfig(hysteresis=0.0, solver_cost_s=1.0))
        assert [
            (d.time, d.event, d.job, d.action) for d in measured.decisions
        ] == [
            (d.time, d.event, d.job, d.action) for d in pinned.decisions
        ]
        assert measured.makespan_online == pinned.makespan_online

    def test_infinite_hysteresis_never_solves(self):
        """hysteresis=inf reproduces `static` without even attempting a
        solve, so the measured EMA never gets a sample either."""
        frozen = _drift_frozen()
        before = _snap()
        report = _drift_run(OnlineConfig(hysteresis=float("inf")), frozen)
        assert _delta(before)["calls"] == 0
        assert report.swaps == ()
        static = _drift_run(OnlineConfig(hysteresis=float("inf"),
                                         solver_cost_s=123.0))
        assert report.makespan_online == static.makespan_online
