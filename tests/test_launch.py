"""Launch-layer tests: collective-traffic parser, analytic attention flops,
mesh construction, and the fault-tolerant train launcher (kill/resume)."""
import os
import subprocess
import sys

import pytest
from _hypothesis_compat import given, settings, st

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class TestCollectiveParser:
    def _parse(self, hlo):
        # import without triggering the 512-device flag side effect
        import repro.launch.dryrun as dr

        return dr.collective_bytes_from_hlo(hlo)

    def test_all_reduce_ring_accounting(self):
        hlo = (
            "%all-reduce.1 = f32[1024]{0} all-reduce(%x), "
            "replica_groups={{0,1,2,3}}, to_apply=%add\n"
        )
        out = self._parse(hlo)
        # 2 * S * (G-1)/G = 2 * 4096 * 3/4
        assert out["all-reduce"] == pytest.approx(2 * 4096 * 3 / 4)

    def test_iota_replica_groups_v2(self):
        hlo = (
            "%all-gather.3 = bf16[2048,512]{1,0} all-gather(%p), "
            "channel_id=7, replica_groups=[16,16]<=[16,16]T(1,0), "
            "dimensions={0}, use_global_device_ids=true\n"
        )
        out = self._parse(hlo)
        S = 2048 * 512 * 2
        assert out["all-gather"] == pytest.approx(S * 15 / 16)

    def test_tuple_shapes_and_start_ops(self):
        hlo = (
            "%ar = (f32[128]{0}, f32[256]{0}) all-reduce-start(%a, %b), "
            "replica_groups={{0,1}}\n"
            "%d = (f32[128]{0}, f32[256]{0}) all-reduce-done(%ar)\n"
        )
        out = self._parse(hlo)
        S = (128 + 256) * 4
        assert out["all-reduce"] == pytest.approx(2 * S * 0.5)

    def test_non_collectives_ignored(self):
        hlo = (
            "%dot.1 = f32[128,128]{1,0} dot(%a, %b)\n"
            "%fusion.all-reduce-like = f32[4]{0} add(%x, %y)\n"
        )
        out = self._parse(hlo)
        assert out["total"] == 0.0


class TestAnalyticAttention:
    def _brute(self, T, q_offset, window):
        total = 0
        for t in range(q_offset, q_offset + T):
            vis = t + 1
            if window is not None:
                vis = min(vis, window)
            total += vis
        return total

    @settings(max_examples=40, deadline=None)
    @given(
        T=st.integers(1, 300),
        off=st.integers(0, 200),
        w=st.one_of(st.none(), st.integers(1, 128)),
    )
    def test_visible_context_closed_form(self, T, off, w):
        from repro.launch.analysis import visible_context_sum

        assert visible_context_sum(T, off, w) == self._brute(T, off, w)

    def test_attention_flops_families(self):
        from repro.configs import ARCHS
        from repro.launch.analysis import attention_flops

        # attention-free arch: zero attention flops
        assert attention_flops(ARCHS["falcon-mamba-7b"], "train", 8, 1024) == 0
        # windowed < full for the same geometry
        full = attention_flops(ARCHS["mistral-nemo-12b"], "train", 1, 65536)
        # recurrentgemma has 1/3 attn layers AND a 2048 window
        hyb = attention_flops(ARCHS["recurrentgemma-9b"], "train", 1, 65536)
        assert hyb < full


class TestMesh:
    def test_make_production_mesh_is_a_function_not_constant(self):
        import inspect

        from repro.launch import mesh as mesh_mod

        assert callable(mesh_mod.make_production_mesh)
        src = inspect.getsource(mesh_mod)
        assert "make_mesh" in src
        # no module-level mesh: importing never touched jax device state
        assert not any(
            isinstance(v, object) and type(v).__name__ == "Mesh"
            for v in vars(mesh_mod).values()
        )


class TestTrainLauncherResume:
    def test_kill_and_resume_continues_from_committed_step(self, tmp_path):
        """Run 40 steps with checkpoints every 20; then 'restart' with a
        60-step budget — the second run must resume from step 40 and the
        loss trajectory must continue (fault-tolerance deliverable)."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(_ROOT, "src")
        base = [
            sys.executable, "-m", "repro.launch.train",
            "--arch", "qwen3-1.7b", "--reduced",
            "--batch", "2", "--seq", "32", "--lr", "1e-3",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "20",
            "--resume", "auto", "--log-every", "20",
        ]
        r1 = subprocess.run(
            base + ["--steps", "40"], capture_output=True, text=True,
            env=env, timeout=560,
        )
        assert r1.returncode == 0, r1.stderr
        assert "step    40" in r1.stdout
        r2 = subprocess.run(
            base + ["--steps", "60"], capture_output=True, text=True,
            env=env, timeout=560,
        )
        assert r2.returncode == 0, r2.stderr
        assert "restored committed step 40" in r2.stdout
        # it did NOT redo steps 1..40
        assert "step    20 " not in r2.stdout
        assert "step    60" in r2.stdout
