"""Tests for the TPU-side integrations of the paper's planner:
cross-pod gradient aggregation and MoE dispatch planning."""
import numpy as np
import pytest

from repro.core.collective_plan import plan_cross_pod_reduction
from repro.core.moe_plan import plan_moe_dispatch


class TestCrossPodReduction:
    def test_homogeneous_dcn_is_uniform(self):
        rp = plan_cross_pod_reduction(
            grad_mb=4000.0, pod_dcn_bw_mbps=[6400] * 4, n_elements=1 << 20
        )
        assert np.allclose(rp.fractions, 0.25, atol=0.02)
        assert rp.speedup_vs_uniform == pytest.approx(1.0, abs=1e-3)

    def test_slow_pod_owns_less(self):
        rp = plan_cross_pod_reduction(
            grad_mb=4000.0,
            pod_dcn_bw_mbps=[6400, 6400, 1600, 6400],
            n_elements=1 << 20,
        )
        assert rp.fractions[2] < 0.15  # the 4x-slower pod owns much less
        assert rp.speedup_vs_uniform > 1.05
        # never worse than uniform, by construction
        assert rp.est_time_s <= rp.uniform_time_s + 1e-9

    def test_segments_partition_exactly(self):
        n = 1_000_003  # deliberately non-aligned
        rp = plan_cross_pod_reduction(
            grad_mb=1000.0, pod_dcn_bw_mbps=[6400, 3200], n_elements=n
        )
        assert int(rp.segment_sizes.sum()) == n
        assert (rp.segment_sizes >= 0).all()
        offs = rp.segment_offsets()
        assert offs[0] == 0 and offs[-1] == n


class TestMoEDispatch:
    def test_homogeneous_is_uniform(self):
        mp = plan_moe_dispatch(
            tokens_mb_per_shard=64.0,
            n_token_shards=4,
            group_pod=[0, 0, 1, 1],
            shard_pod=[0, 0, 1, 1],
            top_k=1,
        )
        assert np.allclose(mp.group_fractions, 0.25, atol=0.02)

    def test_slow_experts_get_fewer_tokens(self):
        mp = plan_moe_dispatch(
            tokens_mb_per_shard=64.0,
            n_token_shards=4,
            group_pod=[0, 0, 1, 1],
            shard_pod=[0, 0, 1, 1],
            top_k=1,
            expert_flops_rate_mbps=[25000, 25000, 8000, 8000],
        )
        assert mp.group_fractions[:2].sum() > mp.group_fractions[2:].sum()
        assert mp.speedup_vs_uniform > 1.1
        # the bias implements the fractions in log space
        assert np.all(mp.router_bias[:2] > mp.router_bias[2:].max())

    def test_capacity_cap_respected(self):
        mp = plan_moe_dispatch(
            tokens_mb_per_shard=64.0,
            n_token_shards=2,
            group_pod=[0, 1, 1, 1],
            shard_pod=[0, 1],
            top_k=2,
            expert_flops_rate_mbps=[50000, 1000, 1000, 1000],
            max_capacity_factor=2.0,
        )
        assert mp.group_fractions.max() <= 2.0 / 4 + 1e-9
        assert mp.capacity_factor.max() <= 2.0 + 1e-9
