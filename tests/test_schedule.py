"""Tests for the shared-substrate refactor (PR 2): Substrate views,
shared-capacity pricing, the resource-centric multi-job executor
(N=1 equivalence + contention), schedule policies, and the GeoSchedule
facade."""
import itertools

import numpy as np
import pytest

from repro.api import GeoJob, GeoSchedule, JobReport, ScheduleReport, split_sources
from repro.core.makespan import (
    BARRIERS_GGL,
    CostModel,
    makespan,
    shared_effective_volumes,
)
from repro.core.optimize import (
    available_policies,
    get_schedule_planner,
    optimize_schedule,
    register_schedule_planner,
)
from repro.core.plan import uniform_plan
from repro.core.platform import FailureEvent, Substrate, \
    planetlab_platform
from repro.core.simulate import (
    SimConfig,
    simulate,
    simulate_schedule,
)
from repro.mapreduce.apps import generate_documents, word_count

ALL_BARRIER_TRIPLES = list(itertools.product("GLP", repeat=3))


def contended_substrate() -> Substrate:
    """Two mappers; source 0 can only reach mapper 0 quickly, source 1 can
    reach both — the scenario where per-job-myopic plans collide."""
    return Substrate(
        B_sm=np.array([[10_000.0, 1.0], [10_000.0, 10_000.0]]),
        B_mr=np.full((2, 2), 10_000.0),
        C_m=np.array([50.0, 50.0]),
        C_r=np.array([10_000.0, 10_000.0]),
        cluster_s=np.array([0, 1]),
        cluster_m=np.array([0, 1]),
        cluster_r=np.array([0, 1]),
        name="contended_pair",
    )


class TestSubstrate:
    def test_view_shares_capacity_arrays(self):
        sub = Substrate.of(planetlab_platform(4, seed=0))
        a = sub.view(np.full(sub.nS, 100.0), 1.0, name="a")
        b = sub.view(np.full(sub.nS, 50.0), 2.0, name="b")
        for field in ("B_sm", "B_mr", "C_m", "C_r"):
            assert getattr(a, field) is getattr(sub, field)
            assert getattr(b, field) is getattr(sub, field)
        assert a.substrate is sub and b.substrate is sub
        assert a.alpha == 1.0 and b.alpha == 2.0

    def test_of_lifts_standalone_platform(self):
        p = planetlab_platform(4, seed=3)
        sub = Substrate.of(p)
        assert sub.B_sm is p.B_sm
        # a view of the lifted substrate is compatible with the original
        assert sub.compatible(Substrate.of(sub.view(p.D, p.alpha)))

    def test_compatible_by_value(self):
        s1 = Substrate.of(planetlab_platform(4, seed=5))
        s2 = Substrate.of(planetlab_platform(4, seed=5))
        s3 = Substrate.of(planetlab_platform(4, seed=6))
        assert s1.compatible(s2)  # equal generator calls may share
        assert not s1.compatible(s3)

    def test_resources_named_and_complete(self):
        sub = contended_substrate()
        res = sub.resources()
        assert len(res) == sub.nS * sub.nM + sub.nM * sub.nR + sub.nM + sub.nR
        assert res["push[s0->m1]"] == 1.0
        assert res["map[m0]"] == 50.0
        assert res["reduce[r1]"] == 10_000.0

    def test_residual_scales_and_floors(self):
        sub = contended_substrate()
        red = sub.residual(map_frac=np.array([1.5, 0.2]))
        assert red.C_m[0] == pytest.approx(sub.C_m[0] * 0.05)  # floored
        assert red.C_m[1] == pytest.approx(sub.C_m[1] * 0.8)
        assert red.B_sm is not sub.B_sm  # a planning copy, not the identity

    def test_validation(self):
        with pytest.raises(ValueError, match="strictly positive"):
            Substrate(
                B_sm=np.zeros((1, 1)), B_mr=np.ones((1, 1)),
                C_m=np.ones(1), C_r=np.ones(1),
                cluster_s=np.zeros(1), cluster_m=np.zeros(1),
                cluster_r=np.zeros(1),
            )


class TestSharedPricing:
    def test_single_job_unchanged(self):
        p = planetlab_platform(4, alpha=1.3, seed=2)
        cm = CostModel(p, BARRIERS_GGL)
        vols = cm.analytic_volumes(uniform_plan(p))
        [shared] = cm.price_shared([vols])
        plain = cm.price_volumes(*vols)
        assert shared["makespan"] == plain["makespan"]

    def test_disjoint_jobs_price_independently(self):
        """Jobs touching disjoint resources see zero contention."""
        sub = contended_substrate()
        a = sub.view(np.array([10_000.0, 0.0]), 1.0)
        b = sub.view(np.array([0.0, 10_000.0]), 1.0)
        plan_a = np.zeros((2, 2)); plan_a[:, 0] = 1.0  # all to m0
        plan_b = np.zeros((2, 2)); plan_b[:, 1] = 1.0  # all to m1
        from repro.core.plan import ExecutionPlan
        pa = ExecutionPlan(x=plan_a, y=np.array([1.0, 0.0]))
        pb = ExecutionPlan(x=plan_b, y=np.array([0.0, 1.0]))
        cm = CostModel(a, BARRIERS_GGL)
        va = CostModel(a).analytic_volumes(pa)
        vb = CostModel(b).analytic_volumes(pb)
        got = cm.price_shared([va, vb], BARRIERS_GGL)
        assert got[0]["makespan"] == pytest.approx(makespan(a, pa, BARRIERS_GGL))
        assert got[1]["makespan"] == pytest.approx(makespan(b, pb, BARRIERS_GGL))

    def test_overlap_inflates_both(self):
        p = planetlab_platform(4, alpha=1.0, seed=0)
        cm = CostModel(p, BARRIERS_GGL)
        vols = cm.analytic_volumes(uniform_plan(p))
        alone = float(cm.price_volumes(*vols)["makespan"])
        both = cm.price_shared([vols, vols])
        for out in both:
            assert float(out["makespan"]) == pytest.approx(2 * alone)

    def test_smooth_gate_approaches_hard(self):
        p = planetlab_platform(2, alpha=1.0, seed=1)
        vols = CostModel(p).analytic_volumes(uniform_plan(p))
        hard = shared_effective_volumes([vols, vols], kappa=0.0, xp=np)
        soft = shared_effective_volumes([vols, vols], kappa=1e-9, xp=np)
        for h, s in zip(hard[0], soft[0]):
            np.testing.assert_allclose(h, s, rtol=1e-6)


class TestExecutorEquivalence:
    """The refactor bar: N=1 scheduling reproduces the single-job executor
    phase-for-phase, for every barrier triple."""

    @pytest.fixture(scope="class")
    def platform(self):
        return planetlab_platform(4, alpha=1.2, seed=1)

    @pytest.mark.parametrize("barriers", ALL_BARRIER_TRIPLES,
                             ids=["".join(b) for b in ALL_BARRIER_TRIPLES])
    def test_n1_schedule_matches_simulate(self, platform, barriers):
        plan = uniform_plan(platform)
        cfg = SimConfig(chunk_mb=32.0, barriers=barriers)
        legacy = simulate(platform, plan, cfg)
        sched = simulate_schedule([(platform, plan, cfg)])
        assert len(sched.jobs) == 1
        got, want = sched.jobs[0].phases(), legacy.phases()
        for phase in want:
            assert abs(got[phase] - want[phase]) <= 1e-9, phase
        assert sched.makespan == pytest.approx(legacy.makespan, abs=1e-9)

    def test_n1_geoschedule_matches_geojob(self, platform):
        job = GeoJob(platform).plan("uniform", barriers=BARRIERS_GGL)
        solo = job.simulate()
        report = GeoSchedule([GeoJob(platform)]).plan(
            "independent", mode="uniform", barriers=BARRIERS_GGL
        ).simulate()
        for phase, want in solo.phases().items():
            assert abs(report.sims[0].phases()[phase] - want) <= 1e-9, phase

    def test_n1_dynamics_preserved(self, platform):
        """Speculation/stealing/failure/replication semantics survive the
        refactor: the N=1 schedule path reproduces them event-for-event."""
        plan = uniform_plan(platform)
        for cfg in [
            SimConfig(barriers=BARRIERS_GGL, stragglers={("m", 1): 8.0},
                      speculation=True, stealing=True),
            SimConfig(barriers=BARRIERS_GGL,
                      failures=[FailureEvent.mapper_kill(2, 2.0)],
                      speculation=True),
            SimConfig(barriers=BARRIERS_GGL, replication=3,
                      cross_cluster_replication=True),
            SimConfig(barriers=BARRIERS_GGL, compute_noise=0.2, seed=42),
        ]:
            a = simulate(platform, plan, cfg)
            b = simulate_schedule([(platform, plan, cfg)]).jobs[0]
            assert a.phases() == b.phases()
            assert a.wasted_mb == b.wasted_mb
            assert a.recovered_chunks == b.recovered_chunks


class TestContention:
    def test_shared_link_no_earlier_than_alone(self):
        """Two jobs squeezing through the same links finish no earlier than
        either would alone, and the schedule horizon covers both."""
        p = planetlab_platform(4, alpha=1.0, seed=0)
        sub = Substrate.of(p)
        a = sub.view(p.D, 1.0, name="a")
        b = sub.view(p.D * 0.5, 1.0, name="b")
        plan_a, plan_b = uniform_plan(a), uniform_plan(b)
        alone_a = simulate(a, plan_a).makespan
        alone_b = simulate(b, plan_b).makespan
        sched = simulate_schedule([(a, plan_a), (b, plan_b)])
        assert sched.jobs[0].makespan >= alone_a - 1e-9
        assert sched.jobs[1].makespan >= alone_b - 1e-9
        assert sched.makespan >= max(alone_a, alone_b) - 1e-9
        assert len(sched.contended()) > 0

    def test_resource_stats_accounting(self):
        sub = contended_substrate()
        a = sub.view(np.array([4_000.0, 0.0]), 1.0)
        b = sub.view(np.array([0.0, 4_000.0]), 1.0)
        sched = simulate_schedule([(a, uniform_plan(a)), (b, uniform_plan(b))])
        util = sched.utilization()
        assert set(util) == set(sub.resources())
        assert all(0.0 <= u <= 1.0 + 1e-9 for u in util.values())
        # both jobs uniformly split -> both mappers served both jobs
        assert sched.resources["map[m0]"].jobs == {0, 1}
        vol = sum(s.volume_mb for n, s in sched.resources.items()
                  if n.startswith("push["))
        assert vol == pytest.approx(8_000.0)

    def test_multijob_stealing_completes_all_work(self):
        """Stealing with a local map/shuffle barrier while ANOTHER job keeps
        the victim node busy: the thief job's gates must still open (the
        victim being busy with someone else's chunk cannot hold them shut)
        and every byte must reach the reducers."""
        sub = contended_substrate()
        a = sub.view(np.array([4_000.0, 0.0]), 1.0, name="steals")
        b = sub.view(np.array([0.0, 4_000.0]), 1.0, name="bystander")
        barriers = ("G", "L", "L")
        sched = simulate_schedule([
            (a, uniform_plan(a),
             SimConfig(barriers=barriers, stealing=True, chunk_mb=16.0,
                       stragglers={("m", 0): 8.0})),
            (b, uniform_plan(b), SimConfig(barriers=barriers, chunk_mb=16.0)),
        ])
        for sim in sched.jobs:
            assert np.isfinite(sim.makespan) and sim.makespan > 0
            assert sim.reduce_end >= sim.shuffle_end > 0
        # completion invariant: all alpha-expanded bytes were reduced
        reduced = sum(s.volume_mb for n, s in sched.resources.items()
                      if n.startswith("reduce["))
        assert reduced == pytest.approx(8_000.0)

    def test_start_time_releases_job_late(self):
        p = planetlab_platform(2, alpha=1.0, seed=0)
        sub = Substrate.of(p)
        v = sub.view(p.D, 1.0)
        plan = uniform_plan(v)
        t0 = simulate(v, plan).makespan
        late = simulate_schedule(
            [(v, plan, SimConfig(start_time=100.0))]
        ).jobs[0]
        assert late.makespan == pytest.approx(t0 + 100.0, rel=1e-9)

    def test_substrate_mismatch_raises(self):
        p1 = planetlab_platform(4, seed=0)
        p2 = planetlab_platform(4, seed=1)
        with pytest.raises(ValueError, match="not a view"):
            simulate_schedule([(p1, uniform_plan(p1)),
                               (p2, uniform_plan(p2))])


class TestSchedulePolicies:
    def test_builtin_policies_registered(self):
        assert {"independent", "sequential", "joint"} <= set(available_policies())

    def test_unknown_policy_raises(self):
        p = planetlab_platform(2, seed=0)
        with pytest.raises(ValueError, match="policy must be one of"):
            optimize_schedule([p], policy="no_such_policy")
        with pytest.raises(ValueError):
            get_schedule_planner("no_such_policy")

    def test_duplicate_registration_raises(self):
        with pytest.raises(ValueError, match="already registered"):
            register_schedule_planner("joint", lambda *a, **k: None)

    def test_custom_policy_plugs_in(self):
        from repro.core import optimize as O

        @register_schedule_planner("test_all_uniform")
        def _all_uniform(substrate, platforms, barriers, *, mode, n_restarts,
                         steps, seed):
            return [uniform_plan(p) for p in platforms]

        try:
            sub = contended_substrate()
            views = [sub.view(np.array([1_000.0, 0.0])),
                     sub.view(np.array([0.0, 1_000.0]))]
            res = optimize_schedule(views, policy="test_all_uniform")
            assert res.policy == "test_all_uniform"
            assert len(res.results) == 2
            # ... and the facade dispatches to it without modification
            rep = GeoSchedule([GeoJob(v) for v in views]).plan(
                "test_all_uniform").simulate()
            assert rep.makespan_sim > 0
        finally:
            del O._SCHEDULE_PLANNERS["test_all_uniform"]

    @pytest.fixture(scope="class")
    def contended_views(self):
        sub = contended_substrate()
        return [
            sub.view(np.array([40_000.0, 0.0]), 1.0, name="pinned"),
            sub.view(np.array([0.0, 40_000.0]), 1.0, name="flexible"),
        ]

    def test_joint_beats_independent(self, contended_views):
        """The acceptance bar: on a shared substrate where myopic plans
        collide, joint planning is strictly better — modeled *and* as
        actually executed (same shared substrate, real contention)."""
        opts = dict(mode="e2e_multi", barriers=BARRIERS_GGL,
                    n_restarts=8, steps=250)
        indep = optimize_schedule(contended_views, policy="independent", **opts)
        joint = optimize_schedule(contended_views, policy="joint", **opts)
        # modeled: never worse by construction, strictly better here
        assert joint.makespan < indep.makespan
        # simulated on the same shared substrate: strictly lower aggregate
        cfg = SimConfig(barriers=BARRIERS_GGL)
        sim_of = lambda res: simulate_schedule(
            [(v, plan, cfg) for v, plan in zip(contended_views, res.plans)]
        ).makespan
        sim_indep, sim_joint = sim_of(indep), sim_of(joint)
        assert sim_joint < sim_indep * 0.95
        # and the model agrees with the execution on both
        assert joint.makespan == pytest.approx(sim_joint, rel=0.1)

    def test_sequential_between(self, contended_views):
        opts = dict(mode="e2e_multi", barriers=BARRIERS_GGL,
                    n_restarts=6, steps=200)
        seq = optimize_schedule(contended_views, policy="sequential", **opts)
        indep = optimize_schedule(contended_views, policy="independent", **opts)
        assert seq.makespan < indep.makespan

    def test_schedule_result_shape(self, contended_views):
        res = optimize_schedule(contended_views, policy="independent",
                                mode="uniform")
        assert len(res.results) == len(res.plans) == 2
        assert res.makespan == pytest.approx(
            max(r.makespan for r in res.results))
        assert res.results[0].mode == "independent:uniform"
        assert "SchedulePlanResult" in repr(res)


class TestGeoScheduleFacade:
    def test_unplanned_raises(self):
        p = planetlab_platform(2, seed=0)
        with pytest.raises(RuntimeError, match="no plan yet"):
            GeoSchedule([GeoJob(p)]).simulate()

    def test_empty_raises(self):
        with pytest.raises(ValueError, match="at least one job"):
            GeoSchedule([])

    def test_mismatched_substrates_raise(self):
        a = GeoJob(planetlab_platform(4, seed=0))
        b = GeoJob(planetlab_platform(4, seed=1))
        with pytest.raises(ValueError, match="does not share the substrate"):
            GeoSchedule([a, b])

    def test_plan_adopts_per_job_results(self):
        sub = contended_substrate()
        jobs = [GeoJob(sub.view(np.array([1_000.0, 0.0]))),
                GeoJob(sub.view(np.array([0.0, 1_000.0])))]
        sched = GeoSchedule(jobs).plan("independent", mode="uniform")
        for job, res in zip(jobs, sched.planned.results):
            assert job.planned is res
            assert job.simulate().makespan > 0  # jobs stay usable facades

    def test_simulate_report(self):
        sub = contended_substrate()
        jobs = [GeoJob(sub.view(np.array([2_000.0, 0.0]))),
                GeoJob(sub.view(np.array([0.0, 2_000.0])))]
        rep = GeoSchedule(jobs).plan("independent", mode="uniform").simulate()
        assert isinstance(rep, ScheduleReport)
        assert rep.jobs is None and rep.makespan_measured is None
        assert len(rep.sims) == 2
        assert rep.makespan_sim == max(s.makespan for s in rep.sims)
        assert set(rep.utilization()) == set(sub.resources())
        assert "independent[" in rep.summary()

    def test_execute_reports_shared_measured(self):
        p = planetlab_platform(4, alpha=1.0, seed=0)
        sub = Substrate.of(p)
        keys, vals = generate_documents(240, 40, seed=1)
        jobs, srcs = [], []
        for g, frac in enumerate([1.0, 0.5]):
            n = int(keys.shape[0] * frac)
            job = GeoJob(sub.view(p.D, p.alpha, name=f"wc{g}"), word_count())
            job = job.calibrate(split_sources(keys[:n], vals[:n], sub.nS))
            jobs.append(job)
            srcs.append(split_sources(keys[:n], vals[:n], sub.nS))
        rep = GeoSchedule(jobs).plan(
            "sequential", barriers=BARRIERS_GGL, n_restarts=4, steps=80
        ).execute(srcs)
        assert rep.jobs is not None and len(rep.jobs) == 2
        for jr in rep.jobs:
            assert isinstance(jr, JobReport)
            assert set(jr.modeled) == set(jr.measured)
            assert jr.makespan_measured > 0
            assert sum(len(k) for k, _ in jr.outputs) > 0
        assert rep.makespan_measured == pytest.approx(
            max(jr.makespan_measured for jr in rep.jobs))
        # contended measured pricing is never cheaper than each job alone
        for jr, job in zip(rep.jobs, jobs):
            alone = CostModel(job.platform, BARRIERS_GGL).breakdown_volumes(
                *jr.stats.volumes_mb())
            assert jr.makespan_measured >= alone["makespan"] - 1e-9

    def test_as_dict_stable(self):
        p = planetlab_platform(2, seed=0)
        d = simulate(p, uniform_plan(p)).as_dict()
        assert set(d) == {
            "makespan", "push_end", "map_end", "shuffle_end", "reduce_end",
            "wasted_mb", "recovered_chunks", "total_map_chunks",
            "lost_mb", "reexec_mb",
        }
        assert all(isinstance(v, float) for v in d.values())
