"""Tests for the scale tier: the 3-tier substrate/job-mix generators, the
vectorized DES fast path (byte-identity under permuted tie-breaks), the
fluid executor's accuracy contract vs the DES, its refusal surface, and
the load-hotspot reporting that rides along."""
import dataclasses
import itertools
import json

import numpy as np
import pytest

from repro.analysis.audit import patch_tiebreak
from repro.core.fluid import FluidSim, fluid_score_residual
from repro.core.plan import uniform_plan
from repro.core.platform import (
    CapacityTrace,
    FailureEvent,
    Substrate,
    planetlab_platform,
)
from repro.core.simulate import SimConfig, open_schedule, simulate_schedule
from repro.core.topology import scale_job_mix, scale_tier_substrate

#: fluid-mode accuracy contract (documented in README / fluid.py): schedule
#: makespan relative error vs the chunk-granular DES.
FLUID_REL_TOL = 0.02


def _small_tier(seed=7):
    return scale_tier_substrate(
        n_regions=2, edges_per_region=6, mappers_per_region=4,
        n_backbone=1, reducers_per_backbone=4, seed=seed,
    )


def _result_key(res):
    """Canonical byte-comparison form of a schedule result."""
    return json.dumps(res.as_dict(), sort_keys=True)


class TestGenerators:
    def test_substrate_deterministic_by_seed(self):
        a, b = _small_tier(seed=7), _small_tier(seed=7)
        for field in ("B_sm", "B_mr", "C_m", "C_r"):
            np.testing.assert_array_equal(getattr(a, field),
                                          getattr(b, field))
        c = _small_tier(seed=8)
        assert not np.array_equal(a.B_sm, c.B_sm)

    def test_job_mix_deterministic_by_seed(self):
        sub = _small_tier()
        mix = lambda s: scale_job_mix(sub, n_jobs=5, seed=s,
                                      arrival_spread_s=50.0)
        for (pa, xa, ca), (pb, xb, cb) in zip(mix(3), mix(3)):
            np.testing.assert_array_equal(pa.D, pb.D)
            np.testing.assert_array_equal(xa.x, xb.x)
            np.testing.assert_array_equal(xa.y, xb.y)
            assert ca == cb
        other = mix(4)
        assert any(
            not np.array_equal(a[0].D, b[0].D)
            for a, b in zip(mix(3), other)
        )

    def test_job_mix_respects_base_cfg(self):
        sub = _small_tier()
        entries = scale_job_mix(
            sub, n_jobs=3, seed=0, base_cfg=SimConfig(mode="fluid")
        )
        assert all(cfg.mode == "fluid" for _, _, cfg in entries)


class TestVectorizedIdentity:
    """The vectorized DES must be byte-identical to the scalar event loop —
    including under permuted same-timestamp tie-breaks, which certifies
    the scenario (and hence the identity) as race-free."""

    @pytest.fixture(scope="class")
    def entries(self):
        sub = _small_tier()
        return sub, scale_job_mix(
            sub, n_jobs=6, seed=11, arrival_spread_s=40.0,
            base_cfg=SimConfig(chunk_mb=32.0, audit=True),
        )

    def _run(self, sub, entries, mode, rng=None):
        jobs = [(p, pl, dataclasses.replace(c, mode=mode))
                for p, pl, c in entries]
        eng = open_schedule(jobs, substrate=sub)
        if rng is not None:
            patch_tiebreak(eng, rng)
        return eng.run()

    def test_byte_identical_under_permuted_tiebreaks(self, entries):
        sub, jobs = entries
        vec = self._run(sub, jobs, mode="event_vec")
        assert vec.violations == []
        ref = _result_key(self._run(sub, jobs, mode="event"))
        assert _result_key(vec) == ref
        for seed in range(5):
            permuted = self._run(
                sub, jobs, mode="event",
                rng=np.random.default_rng(seed),
            )
            assert _result_key(permuted) == ref, f"tie-break seed {seed}"


class TestSteeredVectorizedIdentity:
    """Steered engines (``run_until`` / ``snapshot`` / ``swap_plan`` /
    ``inject``) drain each segment through the batched scans and must stay
    byte-identical to the scalar steered loop, including under permuted
    same-timestamp tie-breaks."""

    @pytest.fixture(scope="class")
    def entries(self):
        sub = _small_tier()
        return sub, scale_job_mix(
            sub, n_jobs=6, seed=11, arrival_spread_s=40.0,
            base_cfg=SimConfig(chunk_mb=32.0, audit=True),
        )

    def _steer(self, sub, entries, mode, rng=None):
        jobs = [(p, pl, dataclasses.replace(c, mode=mode))
                for p, pl, c in entries]
        held = jobs.pop()
        eng = open_schedule(jobs, substrate=sub)
        if rng is not None:
            patch_tiebreak(eng, rng)
        eng.run_until(20.0)
        eng.snapshot()
        eng.swap_plan(0, uniform_plan(jobs[0][0]))
        eng.run_until(60.0, inclusive=True)
        eng.inject([held])
        eng.run_until(90.0)
        return eng.run()

    def test_steered_byte_identical(self, entries):
        sub, jobs = entries
        vec = self._steer(sub, jobs, mode="event_vec")
        assert vec.violations == []
        ref = _result_key(self._steer(sub, jobs, mode="event"))
        assert _result_key(vec) == ref
        for seed in range(5):
            permuted = self._steer(
                sub, jobs, mode="event",
                rng=np.random.default_rng(seed),
            )
            assert _result_key(permuted) == ref, f"tie-break seed {seed}"

    def test_mixed_segments_byte_identical(self, entries):
        """A vec-eligible engine steered across many tiny horizons (each
        segment re-deciding scalar-vs-vec) still lands on the scalar
        result byte-for-byte."""
        sub, jobs = entries
        jobs = [(p, pl, dataclasses.replace(c, mode="event_vec"))
                for p, pl, c in jobs]
        eng = open_schedule(jobs, substrate=sub)
        for t in np.linspace(5.0, 120.0, 24):
            eng.run_until(float(t))
        fine = eng.run()
        ref = open_schedule(
            [(p, pl, dataclasses.replace(c, mode="event"))
             for p, pl, c in jobs],
            substrate=sub).run()
        assert _result_key(fine) == _result_key(ref)


class TestFluidAccuracy:
    """SimConfig(mode="fluid") reproduces the DES schedule makespan to
    within the documented tolerance, with the conservation auditor green
    on both sides."""

    @pytest.fixture(scope="class")
    def platform(self):
        return planetlab_platform(4, alpha=1.3, seed=5)

    @pytest.mark.parametrize(
        "barriers",
        ["".join(t) for t in itertools.product("GLP", repeat=3)],
    )
    def test_single_job_all_27_triples(self, platform, barriers):
        plan = uniform_plan(platform)
        des = simulate_schedule([(platform, plan, SimConfig(
            barriers=barriers, chunk_mb=4.0, mode="event_vec", audit=True))])
        fluid = simulate_schedule([(platform, plan, SimConfig(
            barriers=barriers, mode="fluid", audit=True))])
        assert des.violations == [] and fluid.violations == []
        rel = abs(fluid.makespan - des.makespan) / des.makespan
        assert rel <= FLUID_REL_TOL, f"{barriers}: rel error {rel:.4f}"

    @pytest.mark.parametrize("barriers", ["GGL", "PPP", "LLP"])
    def test_contended_two_job_schedule(self, platform, barriers):
        """Two jobs contending for the same links with staggered releases:
        the *schedule* makespan contract holds (per-job times of the
        shadowed job are not part of the fluid contract)."""
        plan = uniform_plan(platform)
        cfg_e = SimConfig(barriers=barriers, chunk_mb=4.0,
                          mode="event_vec", audit=True)
        des = simulate_schedule([
            (platform, plan, cfg_e),
            (platform, plan, dataclasses.replace(cfg_e, start_time=30.0,
                                                 chunk_mb=3.0)),
        ])
        cfg_f = SimConfig(barriers=barriers, mode="fluid", audit=True)
        fluid = simulate_schedule([
            (platform, plan, cfg_f),
            (platform, plan, dataclasses.replace(cfg_f, start_time=30.0)),
        ])
        assert des.violations == [] and fluid.violations == []
        rel = abs(fluid.makespan - des.makespan) / des.makespan
        assert rel <= FLUID_REL_TOL

    def test_scale_mix_fluid_runs(self):
        """The generated mix drains in fluid mode, deterministically."""
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=8, seed=2,
                                arrival_spread_s=60.0,
                                base_cfg=SimConfig(mode="fluid", audit=True))
        a = simulate_schedule(entries, substrate=sub)
        b = simulate_schedule(entries, substrate=sub)
        assert a.violations == []
        assert a.makespan == b.makespan
        assert _result_key(a) == _result_key(b)


def traced_substrate(platform):
    """The platform's substrate with drift traces on every tier: push and
    shuffle links, a mapper and a reducer all step mid-run, so a parity
    run crosses several rate-change events in every phase."""
    return Substrate.of(platform).with_traces({
        "push[s0->m1]": CapacityTrace.step(
            float(platform.B_sm[0, 1]), float(platform.B_sm[0, 1]) * 0.25,
            40.0),
        "push[s3->m2]": CapacityTrace(
            times=(0.0, 25.0, 120.0),
            values=(float(platform.B_sm[3, 2]),
                    float(platform.B_sm[3, 2]) * 0.3,
                    float(platform.B_sm[3, 2]) * 2.0)),
        "map[m0]": CapacityTrace.step(
            float(platform.C_m[0]), float(platform.C_m[0]) * 0.5, 80.0),
        "shuffle[m1->r0]": CapacityTrace.step(
            float(platform.B_mr[1, 0]), float(platform.B_mr[1, 0]) * 0.3,
            150.0),
        "reduce[r2]": CapacityTrace.step(
            float(platform.C_r[2]), float(platform.C_r[2]) * 0.4, 200.0),
    })


class TestFluidTraces:
    """Fluid mode folds CapacityTrace drift into its event horizon: the
    ≤2% makespan contract vs the DES holds with rate steps in play, the
    conservation audit stays green across them, and the steered drain is
    bit-identical to the unsteered one even when run_until boundaries
    straddle drift times."""

    @pytest.fixture(scope="class")
    def traced(self):
        p = planetlab_platform(4, alpha=1.3, seed=5)
        sub = traced_substrate(p)
        return sub, sub.view(p.D, p.alpha), uniform_plan(p)

    @pytest.mark.parametrize(
        "barriers",
        ["".join(t) for t in itertools.product("GLP", repeat=3)],
    )
    def test_traced_parity_all_27_triples(self, traced, barriers):
        sub, view, plan = traced
        des = simulate_schedule([(view, plan, SimConfig(
            barriers=barriers, chunk_mb=4.0, mode="event_vec",
            audit=True))], substrate=sub)
        fluid = simulate_schedule([(view, plan, SimConfig(
            barriers=barriers, mode="fluid", audit=True))], substrate=sub)
        assert des.violations == [] and fluid.violations == []
        rel = abs(fluid.makespan - des.makespan) / des.makespan
        assert rel <= FLUID_REL_TOL, f"{barriers}: rel error {rel:.4f}"

    def test_traces_change_the_fluid_answer(self, traced):
        """The drift actually bites: the traced fluid makespan differs
        from the untraced one (guards against a silently ignored trace)."""
        sub, view, plan = traced
        cfg = SimConfig(mode="fluid", audit=True)
        traced_res = simulate_schedule([(view, plan, cfg)], substrate=sub)
        p = planetlab_platform(4, alpha=1.3, seed=5)
        plain = simulate_schedule([(p, plan, cfg)])
        assert traced_res.makespan != pytest.approx(plain.makespan,
                                                    rel=1e-6)

    def test_steered_traced_drain_matches_unsteered(self, traced):
        """run_until boundaries straddling drift steps (before, between
        and after the trace times) leave the fluid answer unchanged to
        1e-9 (the fluid steering contract — integration-interval splits
        only perturb resource stats at the float-addition ulp level)."""
        sub, view, plan = traced
        cfg = SimConfig(mode="fluid", audit=True)
        plain = simulate_schedule([(view, plan, cfg)], substrate=sub)
        eng = open_schedule([(view, plan, cfg)], substrate=sub)
        for t in (10.0, 40.0, 60.0, 130.0, 210.0):
            eng.run_until(t)
            assert eng.snapshot().time == pytest.approx(t)
        steered = eng.run()
        assert steered.violations == []
        assert steered.makespan == pytest.approx(plain.makespan, rel=1e-9)
        for sj, pj in zip(steered.jobs, plain.jobs):
            for f in ("push_end", "map_end", "shuffle_end", "reduce_end"):
                assert getattr(sj, f) == pytest.approx(getattr(pj, f),
                                                       rel=1e-9, abs=1e-9)

    def test_traced_contended_mix(self):
        """A multi-job mix over a drifting scale-tier substrate keeps the
        schedule-makespan contract."""
        sub = _small_tier()
        name_m = "map[m0]"
        name_l = None
        # degrade the busiest push link the mix actually uses
        for name in sub.resources():
            if name.startswith("push["):
                name_l = name
                break
        traces = {
            name_m: CapacityTrace.step(float(sub.C_m[0]),
                                       float(sub.C_m[0]) * 0.4, 30.0),
            name_l: CapacityTrace.step(float(sub.B_sm.max()),
                                       float(sub.B_sm.max()) * 0.5, 20.0),
        }
        traced = sub.with_traces({k: v for k, v in traces.items() if k})
        entries = scale_job_mix(traced, n_jobs=6, seed=11,
                                arrival_spread_s=40.0,
                                base_cfg=SimConfig(chunk_mb=32.0,
                                                   audit=True))
        des = simulate_schedule(
            [(p, pl, dataclasses.replace(c, mode="event_vec"))
             for p, pl, c in entries], substrate=traced)
        fluid = simulate_schedule(
            [(p, pl, dataclasses.replace(c, mode="fluid"))
             for p, pl, c in entries], substrate=traced)
        assert des.violations == [] and fluid.violations == []
        rel = abs(fluid.makespan - des.makespan) / des.makespan
        assert rel <= FLUID_REL_TOL


class TestFluidRefusals:
    """Fluid mode refuses chunk-granular semantics loudly instead of
    silently approximating them."""

    @pytest.fixture(scope="class")
    def job(self):
        p = planetlab_platform(2, alpha=1.0, seed=0)
        return p, uniform_plan(p)

    def test_mixed_modes_rejected(self, job):
        p, plan = job
        with pytest.raises(ValueError, match="agree on SimConfig.mode"):
            open_schedule([
                (p, plan, SimConfig(mode="fluid")),
                (p, plan, SimConfig(mode="event")),
            ])

    def test_stage_links_rejected(self, job):
        p, plan = job
        with pytest.raises(ValueError, match="stage links"):
            open_schedule(
                [(p, plan, SimConfig(mode="fluid")),
                 (p, plan, SimConfig(mode="fluid"))],
                stage_links={1: [(0, 1.0)]},
            )

    @pytest.mark.parametrize("kwargs,match", [
        (dict(speculation=True), "speculation"),
        (dict(stealing=True), "stealing"),
        (dict(failures=[FailureEvent.mapper_kill(0, 10.0)]), "failures"),
        (dict(compute_noise=0.3), "compute_noise"),
        (dict(replication=2), "replication"),
    ])
    def test_dynamics_rejected(self, job, kwargs, match):
        p, plan = job
        with pytest.raises(ValueError, match=match):
            open_schedule([(p, plan, SimConfig(mode="fluid", **kwargs))])

    def test_event_cfg_rejected_on_inject(self, job):
        p, plan = job
        eng = open_schedule([(p, plan, SimConfig(mode="fluid"))])
        assert isinstance(eng, FluidSim)
        with pytest.raises(ValueError, match='mode="fluid"'):
            eng.inject([(p, plan, SimConfig(mode="event"))])


class TestFluidSteering:
    """The fluid engine exposes the same steering surface as the DES."""

    def test_run_until_snapshot_inject(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=4, seed=5,
                                base_cfg=SimConfig(mode="fluid"))
        eng = open_schedule(entries, substrate=sub)
        eng.run_until(20.0)
        snap = eng.snapshot()
        assert snap.time == pytest.approx(20.0)
        assert any(jp.remaining_mb()["reduce"] > 0 for jp in snap.jobs)
        late = scale_job_mix(sub, n_jobs=1, seed=9,
                             base_cfg=SimConfig(mode="fluid",
                                                start_time=25.0))
        eng.inject(late)
        res = eng.run()
        assert eng.finished
        assert len(res.jobs) == 5
        # steered drain agrees with the unsteered one on the original jobs
        plain = simulate_schedule(entries + late, substrate=sub)
        assert res.makespan == pytest.approx(plain.makespan, rel=1e-9)

    def test_swap_plan_conserves(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=2, seed=1,
                                base_cfg=SimConfig(mode="fluid", audit=True))
        eng = open_schedule(entries, substrate=sub)
        eng.run_until(15.0)
        p0, plan0, _ = entries[0]
        eng.swap_plan(0, uniform_plan(p0))
        res = eng.run()
        assert res.violations == []
        assert res.makespan > 0


class TestFluidScoreResidual:
    """`fluid_score_residual` — the `candidate_pricing="fluid"` gate's
    scorer — prices a residual stack with the same dynamics the fluid
    engine executes, so it must agree with the engine exactly."""

    @pytest.fixture(scope="class")
    def pair(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=3, seed=3,
                                base_cfg=SimConfig(mode="fluid"))
        return sub, entries

    @pytest.mark.parametrize("barriers", ["GGG", "PPP", "LGP", "GLL"])
    def test_fresh_progress_reproduces_full_run(self, pair, barriers):
        """Zero-progress pricing == the full fluid run, per job, exactly
        (a fresh job is the special case of an untouched residual)."""
        from repro.core.makespan import JobProgress

        sub, entries = pair
        jobs = [(p, pl, dataclasses.replace(c, barriers=barriers,
                                            start_time=0.0))
                for p, pl, c in entries]
        full = FluidSim(sub, jobs).run()
        spans = fluid_score_residual(
            sub,
            [(p, pl, c, JobProgress.fresh(p, job=n))
             for n, (p, pl, c) in enumerate(jobs)],
        )
        np.testing.assert_allclose(
            spans, [j.reduce_end for j in full.jobs], rtol=1e-9)

    def test_midflight_pricing_matches_engine_remainder(self, pair):
        """Pricing the incumbent stack at a snapshot reproduces the
        engine's actual remaining time — the fluid analogue of the
        model path's fresh-snapshot identity."""
        sub, entries = pair
        jobs = [(p, pl, dataclasses.replace(c, start_time=0.0))
                for p, pl, c in entries]
        eng = open_schedule(jobs, substrate=sub)
        eng.run_until(15.0)
        snap = eng.snapshot()
        spans = fluid_score_residual(
            sub,
            [(p, pl, c, jp) for (p, pl, c), jp in zip(jobs, snap.jobs)],
            now=15.0,
        )
        res = eng.run()
        np.testing.assert_allclose(
            spans, [max(j.reduce_end - 15.0, 0.0) for j in res.jobs],
            rtol=1e-9, atol=1e-9)

    def test_pricing_is_drift_aware(self):
        """Unlike the closed-form model (which prices against the
        capacities in force at the decision), the fluid rollout folds the
        *future* trace steps into its horizon."""
        p = planetlab_platform(4, alpha=1.3, seed=5)
        from repro.core.makespan import JobProgress

        plan = uniform_plan(p)
        cfg = SimConfig(mode="fluid")
        entry = [(p, plan, cfg, JobProgress.fresh(p))]
        plain = fluid_score_residual(Substrate.of(p), entry)
        traced = fluid_score_residual(traced_substrate(p), entry)
        assert traced[0] != pytest.approx(plain[0], rel=1e-6)

    def test_event_cfg_jobs_are_sanitized(self):
        """Pricing strips chunk-granular dynamics instead of refusing:
        an event-mode job with failures/speculation still prices."""
        from repro.core.makespan import JobProgress

        p = planetlab_platform(2, alpha=1.0, seed=0)
        cfg = SimConfig(mode="event", speculation=True, chunk_mb=64.0,
                        failures=(FailureEvent.mapper_kill(0, 10.0),))
        spans = fluid_score_residual(
            Substrate.of(p),
            [(p, uniform_plan(p), cfg, JobProgress.fresh(p))])
        assert spans[0] > 0.0

    def test_done_job_prices_zero(self, pair):
        from repro.core.makespan import JobProgress

        sub, entries = pair
        p, pl, c = entries[0]
        done = dataclasses.replace(
            JobProgress.fresh(p),
            resid_push=np.zeros(sub.nS), done=True)
        spans = fluid_score_residual(sub, [(p, pl, c, done)])
        assert spans[0] == 0.0


class TestHotspots:
    """ResourceStats load warnings surface through ScheduleSimResult
    .hotspots() in both executor modes."""

    def test_thresholds_and_accessor(self):
        sub = _small_tier()
        entries = scale_job_mix(sub, n_jobs=4, seed=5,
                                base_cfg=SimConfig(mode="fluid"))
        res = simulate_schedule(entries, substrate=sub)
        # impossible thresholds -> clean; trivial thresholds -> every
        # served resource flagged with a readable reason
        assert res.hotspots(utilization_above=2.0,
                            backlog_age_above_s=1e12) == {}
        hot = res.hotspots(utilization_above=0.0, backlog_age_above_s=0.0)
        assert set(hot) <= set(res.resources)
        assert all(
            any("utilization" in w or "queue delay" in w for w in warns)
            for warns in hot.values()
        )
        name, stats = next(iter(res.resources.items()))
        assert stats.mean_wait_s >= 0.0
        assert stats.as_dict()["mean_wait_s"] == stats.mean_wait_s
